//! Metrics: training logs, CSV/markdown emitters, and the byte-exact
//! training-memory accounting behind the paper's Table 1.

pub mod memory;

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// One logged training step (evaluation fields present when measured).
#[derive(Clone, Debug, Default)]
pub struct Record {
    pub step: usize,
    pub train_loss: f32,
    pub train_acc: f32,
    pub val_loss: Option<f32>,
    pub val_acc: Option<f32>,
    pub grad_norm: f32,
    pub ms_per_step: f64,
}

/// Append-only training log with CSV/markdown export.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub run_name: String,
    pub records: Vec<Record>,
}

impl TrainLog {
    pub fn new(run_name: impl Into<String>) -> Self {
        TrainLog { run_name: run_name.into(), records: Vec::new() }
    }

    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn last(&self) -> Option<&Record> {
        self.records.last()
    }

    /// Latest evaluation result (val_loss, val_acc).
    pub fn last_eval(&self) -> Option<(f32, f32)> {
        self.records
            .iter()
            .rev()
            .find_map(|r| Some((r.val_loss?, r.val_acc?)))
    }

    /// Best validation accuracy seen.
    pub fn best_val_acc(&self) -> Option<f32> {
        self.records
            .iter()
            .filter_map(|r| r.val_acc)
            .fold(None, |m, v| Some(m.map_or(v, |m: f32| m.max(v))))
    }

    /// Final-k mean validation loss (curve endpoint for figures).
    pub fn final_val_loss(&self) -> Option<f32> {
        self.records.iter().rev().find_map(|r| r.val_loss)
    }

    pub fn mean_ms_per_step(&self) -> f64 {
        let xs: Vec<f64> = self
            .records
            .iter()
            .map(|r| r.ms_per_step)
            .filter(|&m| m > 0.0)
            .collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(f, "step,train_loss,train_acc,val_loss,val_acc,grad_norm,ms_per_step")?;
        for r in &self.records {
            writeln!(
                f,
                "{},{},{},{},{},{},{}",
                r.step,
                r.train_loss,
                r.train_acc,
                r.val_loss.map_or(String::new(), |v| v.to_string()),
                r.val_acc.map_or(String::new(), |v| v.to_string()),
                r.grad_norm,
                r.ms_per_step
            )?;
        }
        Ok(())
    }
}

/// mean ± std over repetition results (Table-1 style "86.22±0.42").
pub fn mean_std(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = xs
        .iter()
        .map(|&x| (x as f64 - mean).powi(2))
        .sum::<f64>()
        / n.max(1.0);
    (mean as f32, var.sqrt() as f32)
}

/// Render a markdown table: header row + aligned data rows.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&headers.join(" | "));
    out.push_str(" |\n|");
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

/// Human-readable byte count.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{:.1}MB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1}KB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_eval_tracking() {
        let mut log = TrainLog::new("t");
        log.push(Record { step: 0, train_loss: 2.0, ..Default::default() });
        log.push(Record {
            step: 10,
            train_loss: 1.5,
            val_loss: Some(1.8),
            val_acc: Some(0.4),
            ..Default::default()
        });
        log.push(Record {
            step: 20,
            train_loss: 1.2,
            val_loss: Some(1.6),
            val_acc: Some(0.55),
            ..Default::default()
        });
        assert_eq!(log.last_eval(), Some((1.6, 0.55)));
        assert_eq!(log.best_val_acc(), Some(0.55));
        assert_eq!(log.final_val_loss(), Some(1.6));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut log = TrainLog::new("t");
        log.push(Record { step: 1, train_loss: 1.0, ..Default::default() });
        let dir = std::env::temp_dir().join("bdia_test_metrics");
        let path = dir.join("log.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,train_loss"));
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-6);
        assert!((s - (2.0f32 / 3.0).sqrt()).abs() < 1e-5);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn markdown_and_bytes() {
        let md = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_bytes(3 << 20), "3.0MB");
    }
}
