//! Byte-exact training-memory accounting (the paper's Table-1 comparison).
//!
//! Host RSS on a CPU testbed measures the allocator, not the algorithm, so
//! peak training memory is *accounted analytically* from what each strategy
//! must keep live — exactly the quantities the paper's Table 1 compares:
//!
//! * model parameters + gradients + optimizer moments (identical across
//!   strategies),
//! * **stored activations**: the strategy-defining term —
//!   - vanilla / BDIA-float: all K+1 inter-block activations, plus the
//!     per-block autograd internals a standard framework keeps (attention
//!     probabilities, FFN hiddens, ...),
//!   - BDIA-reversible: two boundary activations + packed 1-bit side
//!     information per block (eq. 20) + one block's transient working set,
//!   - RevViT: the two top-of-stack streams + one block's transient.
//!
//! The live stores (`SideInfoStore`, activation vectors) also report their
//! actual bytes; tests assert the analytic model matches the live numbers.

use crate::config::TrainMode;
use crate::model::{Dims, Family};

const F32: usize = 4;

/// Activation-memory model for one training configuration.
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    pub mode: TrainMode,
    pub family: Family,
    pub dims_btd: usize,
    pub n_blocks: usize,
    pub n_enc_blocks: usize,
    pub enc_btd: usize,
    /// autograd internals per decoder/self block (bytes)
    pub block_internals: usize,
    /// autograd internals per encoder block (bytes)
    pub enc_block_internals: usize,
    pub params_bytes: usize,
}

impl MemoryModel {
    pub fn new(mode: TrainMode, family: Family, dims: &Dims, params_bytes: usize) -> Self {
        let t = dims.tokens(family);
        let btd = dims.batch * t * dims.d_model * F32;
        let enc_btd = dims.batch * dims.seq_src * dims.d_model * F32;
        MemoryModel {
            mode,
            family,
            dims_btd: btd,
            n_blocks: dims.n_blocks,
            n_enc_blocks: if family == Family::EncDec { dims.n_enc_blocks } else { 0 },
            enc_btd,
            block_internals: Self::internals(dims, t, family == Family::EncDec),
            enc_block_internals: if family == Family::EncDec {
                Self::internals(dims, dims.seq_src, false)
            } else {
                0
            },
            params_bytes,
        }
    }

    /// Bytes a standard autograd framework keeps live per block:
    /// ln1 out + q,k,v + attn probs + attn out + residual + ln2 out +
    /// ffn hidden + ffn out (the paper's ViT column measures torch autograd).
    fn internals(dims: &Dims, t: usize, cross: bool) -> usize {
        let b = dims.batch;
        let d = dims.d_model;
        let btd = b * t * d;
        let probs = b * dims.n_heads * t * t;
        let ffn_hidden = b * t * d * dims.mlp_ratio;
        let mut elems = btd /*ln1*/ + 3 * btd /*qkv*/ + probs + btd /*attn out*/
            + btd /*residual*/ + btd /*ln2*/ + ffn_hidden + btd /*ffn out*/;
        if cross {
            // cross-attention: lnx out + q + k,v over src + probs + out
            let src = dims.seq_src;
            elems += 2 * btd + 2 * b * src * d + b * dims.n_heads * t * src;
        }
        elems * F32
    }

    /// Persistent activation bytes the strategy must hold at the fwd/bwd
    /// peak (decoder/self stack).
    pub fn stored_activations(&self) -> usize {
        match self.mode {
            TrainMode::Vanilla | TrainMode::BdiaFloat => {
                // x_0..x_K plus framework internals for every block
                (self.n_blocks + 1) * self.dims_btd + self.n_blocks * self.block_internals
            }
            TrainMode::BdiaReversible => 2 * self.dims_btd, // x_{K-1}, x_K
            TrainMode::RevVit => 2 * self.dims_btd,         // two streams
        }
    }

    /// Encoder-stack counterpart (zero for single-stack families).
    pub fn stored_activations_enc(&self) -> usize {
        if self.n_enc_blocks == 0 {
            return 0;
        }
        match self.mode {
            TrainMode::Vanilla | TrainMode::BdiaFloat => {
                (self.n_enc_blocks + 1) * self.enc_btd
                    + self.n_enc_blocks * self.enc_block_internals
            }
            // reversible strategies also keep the encoder output (the
            // cross-attention memory) live for the whole decoder backward
            TrainMode::BdiaReversible | TrainMode::RevVit => 3 * self.enc_btd,
        }
    }

    /// Packed side-information bytes (BDIA-reversible only; eq. 20).
    pub fn side_info(&self) -> usize {
        if self.mode != TrainMode::BdiaReversible {
            return 0;
        }
        let dec = self.n_blocks.saturating_sub(1) * (self.dims_btd / F32).div_ceil(8);
        let enc = self.n_enc_blocks.saturating_sub(1) * (self.enc_btd / F32).div_ceil(8);
        dec + enc
    }

    /// Transient working set while back-propagating one block (reversible
    /// strategies recompute here; store-all strategies stream from storage).
    pub fn transient(&self) -> usize {
        match self.mode {
            // x_k, x_{k+1}, h, gx_{k+1}, gx_k, gx_{k-1} + HLO internals
            TrainMode::BdiaReversible => 6 * self.dims_btd + self.block_internals,
            TrainMode::RevVit => 6 * self.dims_btd + self.block_internals,
            // streaming backward still materialises one block's vjp
            TrainMode::Vanilla | TrainMode::BdiaFloat => {
                2 * self.dims_btd + self.block_internals
            }
        }
    }

    /// grads + optimizer moments (grads same size as params; Adam keeps 2x).
    pub fn optimizer_state(&self) -> usize {
        3 * self.params_bytes
    }

    /// The Table-1 number: params + training state at the backward peak.
    pub fn peak_total(&self) -> usize {
        self.params_bytes
            + self.optimizer_state()
            + self.stored_activations()
            + self.stored_activations_enc()
            + self.side_info()
            + self.transient()
    }

    /// Table-1 peak totals for every training mode of one bundle — the
    /// shared source for `bdia info`, `Session::describe` and the
    /// `memory` block of the bench report.
    pub fn peak_by_mode(
        family: Family,
        dims: &Dims,
        params_bytes: usize,
    ) -> Vec<(&'static str, usize)> {
        [
            TrainMode::Vanilla,
            TrainMode::BdiaReversible,
            TrainMode::BdiaFloat,
            TrainMode::RevVit,
        ]
        .iter()
        .map(|&mode| {
            let mm = MemoryModel::new(mode, family, dims, params_bytes);
            (mode.name(), mm.peak_total())
        })
        .collect()
    }

    pub fn breakdown_rows(&self) -> Vec<(String, usize)> {
        vec![
            ("params".into(), self.params_bytes),
            ("grads+opt".into(), self.optimizer_state()),
            ("activations".into(), self.stored_activations() + self.stored_activations_enc()),
            ("side_info".into(), self.side_info()),
            ("transient".into(), self.transient()),
            ("TOTAL".into(), self.peak_total()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims {
            d_model: 64,
            n_heads: 4,
            n_blocks: 6,
            n_enc_blocks: 0,
            mlp_ratio: 2,
            batch: 64,
            lbits: 9,
            image_size: 32,
            patch: 4,
            channels: 3,
            n_classes: 10,
            seq: 0,
            seq_src: 0,
            vocab: 0,
        }
    }

    #[test]
    fn reversible_stores_far_less_than_vanilla() {
        let d = dims();
        let p = 400_000 * F32;
        let van = MemoryModel::new(TrainMode::Vanilla, Family::Vit, &d, p);
        let rev = MemoryModel::new(TrainMode::BdiaReversible, Family::Vit, &d, p);
        let revvit = MemoryModel::new(TrainMode::RevVit, Family::Vit, &d, p);
        assert!(rev.stored_activations() < van.stored_activations() / 3);
        // ordering the paper reports: RevViT <= BDIA < vanilla
        assert!(revvit.peak_total() <= rev.peak_total());
        assert!(rev.peak_total() < van.peak_total());
    }

    #[test]
    fn side_info_is_one_bit_per_element() {
        let d = dims();
        let rev = MemoryModel::new(TrainMode::BdiaReversible, Family::Vit, &d, 0);
        let t = d.tokens(Family::Vit);
        let elems = d.batch * t * d.d_model;
        assert_eq!(rev.side_info(), (d.n_blocks - 1) * elems.div_ceil(8));
        let van = MemoryModel::new(TrainMode::Vanilla, Family::Vit, &d, 0);
        assert_eq!(van.side_info(), 0);
    }

    #[test]
    fn side_info_much_smaller_than_activations() {
        // the paper: BDIA needs only "slightly more memory than RevViT"
        let d = dims();
        let rev = MemoryModel::new(TrainMode::BdiaReversible, Family::Vit, &d, 0);
        assert!(rev.side_info() * 8 < rev.stored_activations() * (d.n_blocks - 1));
        assert!(rev.side_info() < rev.stored_activations());
    }

    #[test]
    fn encdec_accounts_both_stacks() {
        let d = Dims { n_enc_blocks: 6, seq: 24, seq_src: 24, ..dims() };
        let van = MemoryModel::new(TrainMode::Vanilla, Family::EncDec, &d, 0);
        assert!(van.stored_activations_enc() > 0);
        let rev = MemoryModel::new(TrainMode::BdiaReversible, Family::EncDec, &d, 0);
        assert!(rev.stored_activations_enc() < van.stored_activations_enc());
    }

    #[test]
    fn peak_by_mode_covers_all_modes_and_matches_direct() {
        let d = dims();
        let rows = MemoryModel::peak_by_mode(Family::Vit, &d, 400_000 * F32);
        assert_eq!(rows.len(), 4);
        for (mode, bytes) in &rows {
            let m = TrainMode::parse(mode).unwrap();
            let direct =
                MemoryModel::new(m, Family::Vit, &d, 400_000 * F32).peak_total();
            assert_eq!(*bytes, direct, "{mode}");
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let d = dims();
        let m = MemoryModel::new(TrainMode::BdiaReversible, Family::Vit, &d, 123 * F32);
        let rows = m.breakdown_rows();
        let total = rows.last().unwrap().1;
        let sum: usize = rows[..rows.len() - 1].iter().map(|(_, b)| b).sum();
        assert_eq!(total, sum);
    }
}
