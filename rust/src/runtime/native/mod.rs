//! Pure-Rust native execution backend.
//!
//! Implements every executable of the manifest ABI (embed / block / head /
//! RevViT sub-branches / fused quantized inference, forward and VJP) on top
//! of the [`crate::kernels`] deterministic parallel compute core — no XLA,
//! no PJRT, no artifacts.  Bundle manifests come from [`registry`]
//! (mirroring `python/compile/aot.py::CONFIGS`) or from an on-disk
//! `manifest.json`.
//!
//! Layout: [`blocks`] holds the shared transformer-block, head and BDIA
//! stack machinery; [`vit`], [`gpt`] and [`encdec`] hold the per-family
//! embeddings and fused-inference drivers.
//!
//! Determinism: every kernel partitions work across output rows only and
//! keeps each element's reduction order fixed, so repeated calls are
//! bit-identical **at any thread count** — the property the BDIA
//! reversibility contract (eq. 24 reconstruction) depends on
//! (`tests/determinism.rs`).

pub mod blocks;
pub mod encdec;
pub mod gpt;
pub mod registry;
pub mod vit;

use self::blocks::{BlockDims, BlockW};
use super::{ArgValue, Backend, BackendKind, CompiledExec};
use crate::model::{Dims, ExecSpec, Family, Manifest};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub struct NativeBackend;

impl Backend for NativeBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn compile(
        &self,
        manifest: &Manifest,
        exec_name: &str,
        spec: &ExecSpec,
        _dir: &Path,
    ) -> Result<Box<dyn CompiledExec>> {
        let group_leaves: BTreeMap<String, usize> = manifest
            .param_groups
            .iter()
            .map(|(g, leaves)| (g.clone(), leaves.len()))
            .collect();
        // fail at compile time, not call time, for unknown executables
        known_exec(exec_name)?;
        Ok(Box::new(NativeExec {
            name: exec_name.to_string(),
            family: manifest.family,
            dims: manifest.dims.clone(),
            spec: spec.clone(),
            group_leaves,
        }))
    }
}

fn known_exec(name: &str) -> Result<()> {
    const KNOWN: &[&str] = &[
        "embed_fwd",
        "embed_vjp",
        "block_fwd",
        "block_vjp",
        "attn_fwd",
        "attn_vjp",
        "ffn_fwd",
        "ffn_vjp",
        "head_loss_fwd",
        "head_loss_vjp",
        "enc_embed_fwd",
        "enc_embed_vjp",
        "enc_block_fwd",
        "enc_block_vjp",
        "model_infer",
        "model_infer_ex",
        "model_logits",
        "model_decode_step",
    ];
    ensure!(
        KNOWN.contains(&name),
        "native backend has no implementation for executable '{name}'"
    );
    Ok(())
}

pub(super) struct NativeExec {
    name: String,
    family: Family,
    pub(crate) dims: Dims,
    spec: ExecSpec,
    pub(crate) group_leaves: BTreeMap<String, usize>,
}

pub(crate) fn want_f32<'a>(
    data: &'a [ArgValue],
    i: usize,
    what: &str,
) -> Result<&'a Tensor> {
    match data.get(i) {
        Some(ArgValue::F32(t)) => Ok(*t),
        _ => bail!("expected f32 tensor for data input {i} ({what})"),
    }
}

pub(crate) fn want_i32<'a>(
    data: &'a [ArgValue],
    i: usize,
    what: &str,
) -> Result<&'a IntTensor> {
    match data.get(i) {
        Some(ArgValue::I32(t)) => Ok(*t),
        _ => bail!("expected i32 tensor for data input {i} ({what})"),
    }
}

pub(crate) fn want_scalar(data: &[ArgValue], i: usize, what: &str) -> Result<f32> {
    match data.get(i) {
        Some(ArgValue::Scalar(v)) => Ok(*v),
        Some(ArgValue::F32(t)) if t.len() == 1 => t.scalar_value(),
        _ => bail!("expected f32 scalar for data input {i} ({what})"),
    }
}

/// Carve `k` consecutive per-block leaf slices of width `per` out of the
/// flat parameter list, advancing `cur`.
pub(crate) fn split_blocks<'b, 'a>(
    params: &'b [&'a Tensor],
    cur: &mut usize,
    per: usize,
    k: usize,
) -> Vec<&'b [&'a Tensor]> {
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        out.push(&params[*cur..*cur + per]);
        *cur += per;
    }
    out
}

impl NativeExec {
    fn is_cross(&self) -> bool {
        self.family == Family::EncDec
    }

    fn causal(&self) -> bool {
        matches!(self.family, Family::Gpt | Family::EncDec)
    }

    /// Shape bundle for the decoder/self ("block") tower.
    pub(crate) fn main_block_dims(&self) -> BlockDims {
        BlockDims {
            b: self.dims.batch,
            t: self.dims.tokens(self.family),
            t_src: self.dims.seq_src,
            d: self.dims.d_model,
            heads: self.dims.n_heads,
            ratio: self.dims.mlp_ratio,
            causal: self.causal(),
        }
    }

    /// Shape bundle for the encoder ("enc_block") tower.
    pub(crate) fn enc_block_dims(&self) -> BlockDims {
        BlockDims {
            b: self.dims.batch,
            t: self.dims.seq_src,
            t_src: 0,
            d: self.dims.d_model,
            heads: self.dims.n_heads,
            ratio: self.dims.mlp_ratio,
            causal: false,
        }
    }

    fn n_out(&self) -> usize {
        if self.family == Family::Vit {
            self.dims.n_classes
        } else {
            self.dims.vocab
        }
    }

    /// Split the flat `model_infer` parameter list of a single-tower
    /// family (vit/gpt) into (embed, blocks, head).
    #[allow(clippy::type_complexity)]
    pub(crate) fn split_single_tower<'b, 'a>(
        &self,
        params: &'b [&'a Tensor],
    ) -> (&'b [&'a Tensor], Vec<&'b [&'a Tensor]>, &'b [&'a Tensor]) {
        let ne = self.group_leaves["embed"];
        let nb = self.group_leaves["block"];
        let nh = self.group_leaves["head"];
        let mut cur = 0usize;
        let em = &params[cur..cur + ne];
        cur += ne;
        let tower = split_blocks(params, &mut cur, nb, self.dims.n_blocks);
        let hd = &params[cur..cur + nh];
        (em, tower, hd)
    }

    /// Shared head tail of the fused inference executables.
    pub(crate) fn head_reduce(
        &self,
        head: &[&Tensor],
        xk: &Tensor,
        labels: &IntTensor,
        per_example: bool,
    ) -> Result<Vec<Tensor>> {
        let (b, d) = (self.dims.batch, self.dims.d_model);
        let t = self.dims.tokens(self.family);
        if per_example {
            blocks::head_loss_fwd_ex(
                head, xk, labels, self.family, b, t, d, self.n_out(),
            )
        } else {
            blocks::head_loss_fwd(
                head, xk, labels, self.family, b, t, d, self.n_out(),
            )
        }
    }

    fn run_model_infer(
        &self,
        params: &[&Tensor],
        data: &[ArgValue],
        per_example: bool,
    ) -> Result<Vec<Tensor>> {
        match self.family {
            Family::Vit => vit::model_infer(self, params, data, per_example),
            Family::Gpt => gpt::model_infer(self, params, data, per_example),
            Family::EncDec => encdec::model_infer(self, params, data, per_example),
        }
    }
}

impl CompiledExec for NativeExec {
    fn execute(&self, params: &[&Tensor], data: &[ArgValue]) -> Result<Vec<Tensor>> {
        let expected: usize = self
            .spec
            .param_layout
            .iter()
            .map(|(g, c)| c * self.group_leaves.get(g).copied().unwrap_or(0))
            .sum();
        ensure!(
            params.len() == expected,
            "{}: expected {expected} param leaves, got {}",
            self.name,
            params.len()
        );
        let d = self.dims.d_model;
        let b = self.dims.batch;
        match self.name.as_str() {
            // ---- embeddings ----
            "embed_fwd" => match self.family {
                Family::Vit => {
                    let images = want_f32(data, 0, "images")?;
                    let x = vit::embed_fwd(
                        params, images, b, self.dims.channels, self.dims.image_size,
                        self.dims.patch, d,
                    )?;
                    Ok(vec![x])
                }
                _ => {
                    let toks = want_i32(data, 0, "tokens")?;
                    let x = gpt::embed_fwd(
                        params, toks, b, self.dims.seq, d, self.dims.vocab,
                    )?;
                    Ok(vec![x])
                }
            },
            "embed_vjp" => match self.family {
                Family::Vit => {
                    let images = want_f32(data, 0, "images")?;
                    let g = want_f32(data, 1, "g")?;
                    vit::embed_vjp(
                        params, images, g, b, self.dims.channels,
                        self.dims.image_size, self.dims.patch, d,
                    )
                }
                _ => {
                    let toks = want_i32(data, 0, "tokens")?;
                    let g = want_f32(data, 1, "g")?;
                    gpt::embed_vjp(
                        params, toks, g, b, self.dims.seq, d, self.dims.vocab,
                    )
                }
            },
            "enc_embed_fwd" => {
                let toks = want_i32(data, 0, "src tokens")?;
                let x = gpt::embed_fwd(
                    params, toks, b, self.dims.seq_src, d, self.dims.vocab,
                )?;
                Ok(vec![x])
            }
            "enc_embed_vjp" => {
                let toks = want_i32(data, 0, "src tokens")?;
                let g = want_f32(data, 1, "g")?;
                gpt::embed_vjp(
                    params, toks, g, b, self.dims.seq_src, d, self.dims.vocab,
                )
            }

            // ---- blocks ----
            "block_fwd" => {
                let bd = self.main_block_dims();
                let w = BlockW::from_leaves(params, self.is_cross())?;
                let x = want_f32(data, 0, "x")?;
                let mem = if self.is_cross() {
                    Some(want_f32(data, 1, "mem")?)
                } else {
                    None
                };
                let h = blocks::block_h(&w, x.data(), mem.map(|m| m.data()), bd);
                Ok(vec![Tensor::from_vec(x.shape(), h)?])
            }
            "block_vjp" => {
                let bd = self.main_block_dims();
                let w = BlockW::from_leaves(params, self.is_cross())?;
                let x = want_f32(data, 0, "x")?;
                let (mem, g) = if self.is_cross() {
                    (Some(want_f32(data, 1, "mem")?), want_f32(data, 2, "g")?)
                } else {
                    (None, want_f32(data, 1, "g")?)
                };
                let (h, dx, dmem, grads) = blocks::block_vjp(
                    &w, x.data(), mem.map(|m| m.data()), g.data(), bd,
                )?;
                let mut outs = vec![
                    Tensor::from_vec(x.shape(), h)?,
                    Tensor::from_vec(x.shape(), dx)?,
                ];
                if let Some(m) = mem {
                    let dm = dmem.context("cross block produced no dmem")?;
                    outs.push(Tensor::from_vec(m.shape(), dm)?);
                }
                outs.extend(grads.into_leaf_tensors(d, self.dims.mlp_ratio)?);
                Ok(outs)
            }
            "enc_block_fwd" => {
                let bd = self.enc_block_dims();
                let w = BlockW::from_leaves(params, false)?;
                let x = want_f32(data, 0, "x")?;
                let h = blocks::block_h(&w, x.data(), None, bd);
                Ok(vec![Tensor::from_vec(x.shape(), h)?])
            }
            "enc_block_vjp" => {
                let bd = self.enc_block_dims();
                let w = BlockW::from_leaves(params, false)?;
                let x = want_f32(data, 0, "x")?;
                let g = want_f32(data, 1, "g")?;
                let (h, dx, _, grads) =
                    blocks::block_vjp(&w, x.data(), None, g.data(), bd)?;
                let mut outs = vec![
                    Tensor::from_vec(x.shape(), h)?,
                    Tensor::from_vec(x.shape(), dx)?,
                ];
                outs.extend(grads.into_leaf_tensors(d, self.dims.mlp_ratio)?);
                Ok(outs)
            }

            // ---- RevViT sub-branches ----
            "attn_fwd" => {
                let bd = self.main_block_dims();
                let w = BlockW::from_leaves(params, false)?;
                let x = want_f32(data, 0, "x")?;
                let out = blocks::attn_branch_fwd(&w, x.data(), bd);
                Ok(vec![Tensor::from_vec(x.shape(), out)?])
            }
            "attn_vjp" => {
                let bd = self.main_block_dims();
                let w = BlockW::from_leaves(params, false)?;
                let x = want_f32(data, 0, "x")?;
                let g = want_f32(data, 1, "g")?;
                let (out, dx, grads) =
                    blocks::attn_branch_vjp(&w, x.data(), g.data(), bd)?;
                let mut outs = vec![
                    Tensor::from_vec(x.shape(), out)?,
                    Tensor::from_vec(x.shape(), dx)?,
                ];
                outs.extend(grads.into_leaf_tensors(d, self.dims.mlp_ratio)?);
                Ok(outs)
            }
            "ffn_fwd" => {
                let bd = self.main_block_dims();
                let w = BlockW::from_leaves(params, false)?;
                let x = want_f32(data, 0, "x")?;
                let out = blocks::ffn_branch_fwd(&w, x.data(), bd);
                Ok(vec![Tensor::from_vec(x.shape(), out)?])
            }
            "ffn_vjp" => {
                let bd = self.main_block_dims();
                let w = BlockW::from_leaves(params, false)?;
                let x = want_f32(data, 0, "x")?;
                let g = want_f32(data, 1, "g")?;
                let (out, dx, grads) =
                    blocks::ffn_branch_vjp(&w, x.data(), g.data(), bd)?;
                let mut outs = vec![
                    Tensor::from_vec(x.shape(), out)?,
                    Tensor::from_vec(x.shape(), dx)?,
                ];
                outs.extend(grads.into_leaf_tensors(d, self.dims.mlp_ratio)?);
                Ok(outs)
            }

            // ---- head ----
            "head_loss_fwd" => {
                let x = want_f32(data, 0, "x")?;
                let labels = want_i32(data, 1, "labels")?;
                blocks::head_loss_fwd(
                    params, x, labels, self.family, b,
                    self.dims.tokens(self.family), d, self.n_out(),
                )
            }
            "head_loss_vjp" => {
                let x = want_f32(data, 0, "x")?;
                let labels = want_i32(data, 1, "labels")?;
                blocks::head_loss_vjp(
                    params, x, labels, self.family, b,
                    self.dims.tokens(self.family), d, self.n_out(),
                )
            }

            // ---- fused quantized inference ----
            "model_infer" => self.run_model_infer(params, data, false),
            "model_infer_ex" => self.run_model_infer(params, data, true),

            // ---- autoregressive decode (gpt only) ----
            "model_logits" => match self.family {
                Family::Gpt => gpt::model_logits(self, params, data),
                _ => bail!("model_logits is only available for the GPT family"),
            },
            "model_decode_step" => match self.family {
                Family::Gpt => gpt::decode_step(self, params, data),
                _ => bail!("model_decode_step is only available for the GPT family"),
            },

            other => bail!("native backend: unknown executable '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamStore;
    use crate::runtime::Runtime;
    use crate::tensor::Rng;

    fn native(bundle: &str) -> Runtime {
        Runtime::from_native_manifest(registry::manifest_for(bundle).unwrap()).unwrap()
    }

    #[test]
    fn block_fwd_shapes_and_determinism() {
        let rt = native("smoke_gpt");
        let dims = rt.manifest.dims.clone();
        let ps = ParamStore::init(&rt.manifest, 3);
        let mut rng = Rng::new(0);
        let x = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
        let fwd = rt.exec("block_fwd").unwrap();
        let refs = ps.refs_for(&fwd.spec, 0).unwrap();
        let h1 = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0);
        let h2 = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0);
        assert_eq!(h1.shape(), x.shape());
        assert_eq!(h1.data(), h2.data(), "native block_fwd must be deterministic");
        assert!(h1.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn block_vjp_primal_matches_fwd_and_emits_all_grads() {
        let rt = native("smoke_gpt");
        let dims = rt.manifest.dims.clone();
        let ps = ParamStore::init(&rt.manifest, 4);
        let mut rng = Rng::new(1);
        let x = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
        let g = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
        let fwd = rt.exec("block_fwd").unwrap();
        let vjp = rt.exec("block_vjp").unwrap();
        let refs = ps.refs_for(&fwd.spec, 1).unwrap();
        let h = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0);
        let refs = ps.refs_for(&vjp.spec, 1).unwrap();
        let outs = vjp
            .call(&refs, &[ArgValue::F32(&x), ArgValue::F32(&g)])
            .unwrap();
        assert_eq!(outs.len(), 2 + blocks::BLOCK_LEAVES);
        assert_eq!(outs[0].data(), h.data(), "vjp primal == fwd");
        // grads come back with the leaf shapes of the manifest
        for (leaf, gt) in rt.manifest.param_groups["block"].iter().zip(&outs[2..]) {
            assert_eq!(gt.shape(), &leaf.shape[..], "leaf {}", leaf.name);
        }
    }

    #[test]
    fn causal_mask_blocks_future_information() {
        // changing a future token must not change past block outputs (gpt)
        let rt = native("smoke_gpt");
        let dims = rt.manifest.dims.clone();
        let ps = ParamStore::init(&rt.manifest, 5);
        let mut rng = Rng::new(2);
        let mut xv: Vec<f32> = (0..dims.batch * dims.seq * dims.d_model)
            .map(|_| rng.normal())
            .collect();
        let x = Tensor::from_vec(&[dims.batch, dims.seq, dims.d_model], xv.clone())
            .unwrap();
        let fwd = rt.exec("block_fwd").unwrap();
        let refs = ps.refs_for(&fwd.spec, 0).unwrap();
        let h = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0);
        // perturb the LAST token of batch row 0
        let off = (dims.seq - 1) * dims.d_model;
        for j in 0..dims.d_model {
            xv[off + j] += 1.0;
        }
        let x2 = Tensor::from_vec(&[dims.batch, dims.seq, dims.d_model], xv).unwrap();
        let h2 = fwd.call(&refs, &[ArgValue::F32(&x2)]).unwrap().remove(0);
        for t in 0..dims.seq - 1 {
            let a = &h.data()[t * dims.d_model..(t + 1) * dims.d_model];
            let b = &h2.data()[t * dims.d_model..(t + 1) * dims.d_model];
            assert_eq!(a, b, "token {t} saw the future");
        }
    }

    #[test]
    fn model_infer_ex_slot_invariant_and_consistent_with_scalar() {
        // the serving batcher's contract: an example's per-slot (loss,
        // correct) must not depend on its batch slot or on its neighbours
        let rt = native("smoke_gpt");
        let dims = rt.manifest.dims.clone();
        assert_eq!(dims.batch, 2);
        let ps = ParamStore::init(&rt.manifest, 8);
        let mut rng = Rng::new(9);
        let draw = |rng: &mut Rng| -> Vec<i32> {
            (0..dims.seq).map(|_| rng.below(dims.vocab) as i32).collect()
        };
        let (ea, eb, ec) = (draw(&mut rng), draw(&mut rng), draw(&mut rng));
        let pack = |s0: &[i32], s1: &[i32]| {
            let mut v = s0.to_vec();
            v.extend_from_slice(s1);
            IntTensor::from_vec(&[dims.batch, dims.seq], v).unwrap()
        };
        let ex = rt.exec("model_infer_ex").unwrap();
        let refs = ps.refs_for(&ex.spec, 0).unwrap();
        for gamma in [0.0f32, 0.5] {
            // ea in slot 0 next to eb, vs ea in slot 1 next to ec
            let t_ab = pack(&ea, &eb);
            let t_ca = pack(&ec, &ea);
            let o1 = ex
                .call(
                    &refs,
                    &[ArgValue::I32(&t_ab), ArgValue::I32(&t_ab), ArgValue::Scalar(gamma)],
                )
                .unwrap();
            let o2 = ex
                .call(
                    &refs,
                    &[ArgValue::I32(&t_ca), ArgValue::I32(&t_ca), ArgValue::Scalar(gamma)],
                )
                .unwrap();
            assert_eq!(o1[0].shape(), &[dims.batch]);
            assert_eq!(
                o1[0].data()[0].to_bits(),
                o2[0].data()[1].to_bits(),
                "per-example loss must be slot/neighbour invariant (gamma {gamma})"
            );
            assert_eq!(o1[1].data()[0].to_bits(), o2[1].data()[1].to_bits());

            // consistency with the scalar executable on the same batch
            let sc = rt.exec("model_infer").unwrap();
            let srefs = ps.refs_for(&sc.spec, 0).unwrap();
            let so = sc
                .call(
                    &srefs,
                    &[ArgValue::I32(&t_ab), ArgValue::I32(&t_ab), ArgValue::Scalar(gamma)],
                )
                .unwrap();
            let mean_ex = (o1[0].data()[0] + o1[0].data()[1]) / 2.0;
            assert!(
                (so[0].scalar_value().unwrap() - mean_ex).abs() < 1e-5,
                "scalar loss vs per-example mean (gamma {gamma})"
            );
            let correct_sum = o1[1].data()[0] + o1[1].data()[1];
            assert_eq!(so[1].scalar_value().unwrap(), correct_sum);
        }
    }

    #[test]
    fn decode_step_matches_full_prefix_logits_bitwise() {
        let rt = native("smoke_gpt");
        let dims = rt.manifest.dims.clone();
        let (b, d, t_max, nb, vocab) =
            (dims.batch, dims.d_model, dims.seq, dims.n_blocks, dims.vocab);
        let ps = ParamStore::init(&rt.manifest, 12);
        let mut rng = Rng::new(4);
        let toks: Vec<i32> =
            (0..b * t_max).map(|_| rng.below(vocab) as i32).collect();
        let dec = rt.exec("model_decode_step").unwrap();
        let full = rt.exec("model_logits").unwrap();
        let drefs = ps.refs_for(&dec.spec, 0).unwrap();
        let frefs = ps.refs_for(&full.spec, 0).unwrap();
        let all_toks = IntTensor::from_vec(&[b, t_max], toks.clone()).unwrap();
        let mut kc = Tensor::zeros(&[nb, b, t_max, d]);
        let mut vc = Tensor::zeros(&[nb, b, t_max, d]);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for pos in 0..t_max {
            let step: Vec<i32> =
                (0..b).map(|bi| toks[bi * t_max + pos]).collect();
            let st = IntTensor::from_vec(&[b], step).unwrap();
            // lane-packing invariance: a lanes=1 call on the same caches
            // must produce bit-identical lane-0 outputs
            let solo = dec
                .call(
                    &drefs,
                    &[
                        ArgValue::I32(&st),
                        ArgValue::F32(&kc),
                        ArgValue::F32(&vc),
                        ArgValue::Scalar(pos as f32),
                        ArgValue::Scalar(1.0),
                        ArgValue::Scalar(0.0),
                    ],
                )
                .unwrap();
            let outs = dec
                .call(
                    &drefs,
                    &[
                        ArgValue::I32(&st),
                        ArgValue::F32(&kc),
                        ArgValue::F32(&vc),
                        ArgValue::Scalar(pos as f32),
                        ArgValue::Scalar(b as f32),
                        ArgValue::Scalar(0.0),
                    ],
                )
                .unwrap();
            assert_eq!(
                bits(&solo[0].data()[..vocab]),
                bits(&outs[0].data()[..vocab]),
                "lane-0 logits depend on lane packing at pos {pos}"
            );
            for k in 0..nb {
                for bi in 0..b {
                    let src = (k * b + bi) * d;
                    let dst = ((k * b + bi) * t_max + pos) * d;
                    kc.data_mut()[dst..dst + d]
                        .copy_from_slice(&outs[1].data()[src..src + d]);
                    vc.data_mut()[dst..dst + d]
                        .copy_from_slice(&outs[2].data()[src..src + d]);
                }
            }
            let t = pos + 1;
            let fl = full
                .call(
                    &frefs,
                    &[
                        ArgValue::I32(&all_toks),
                        ArgValue::Scalar(t as f32),
                        ArgValue::Scalar(0.0),
                    ],
                )
                .unwrap()
                .remove(0);
            for bi in 0..b {
                let inc = &outs[0].data()[bi * vocab..(bi + 1) * vocab];
                let base = (bi * t_max + pos) * vocab;
                let refrow = &fl.data()[base..base + vocab];
                assert_eq!(
                    bits(inc),
                    bits(refrow),
                    "decode logits diverge at pos {pos} lane {bi}"
                );
            }
        }
    }

    #[test]
    fn model_infer_gamma_zero_finite_loss() {
        let rt = native("smoke_gpt");
        let dims = rt.manifest.dims.clone();
        let ps = ParamStore::init(&rt.manifest, 6);
        let mut rng = Rng::new(3);
        let toks: Vec<i32> = (0..dims.batch * dims.seq)
            .map(|_| rng.below(dims.vocab) as i32)
            .collect();
        let tokens = IntTensor::from_vec(&[dims.batch, dims.seq], toks).unwrap();
        let infer = rt.exec("model_infer").unwrap();
        let refs = ps.refs_for(&infer.spec, 0).unwrap();
        let outs = infer
            .call(
                &refs,
                &[
                    ArgValue::I32(&tokens),
                    ArgValue::I32(&tokens),
                    ArgValue::Scalar(0.0),
                ],
            )
            .unwrap();
        let loss = outs[0].scalar_value().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((loss - (dims.vocab as f32).ln()).abs() < 1.5);
    }
}
