//! Native bundle registry: synthesize manifests without AOT artifacts.
//!
//! Mirrors `python/compile/aot.py::CONFIGS` (names, dims) and the manifest
//! leaf order produced by JAX's `tree_flatten_with_path` (dict keys sorted
//! lexicographically at every level).  Keeping the two in lockstep means a
//! `ParamStore` initialised against a native manifest binds correctly to a
//! PJRT bundle of the same config and vice versa — the manifest *is* the
//! cross-backend ABI.

use crate::model::{ArgSpec, DType, Dims, ExecSpec, Family, Init, LeafSpec, Manifest};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

const INIT_STD: f32 = 0.02;

/// Bundle names the native backend can materialise from thin air.
pub fn config_names() -> &'static [&'static str] {
    &[
        "vit_s10",
        "vit_s100",
        "gpt_tiny",
        "encdec_mt",
        "gpt_e2e",
        "smoke_vit",
        "smoke_gpt",
        "smoke_encdec",
    ]
}

/// Dims shared defaults (mirrors `ModelConfig`'s field defaults).
fn base_dims() -> Dims {
    Dims {
        d_model: 64,
        n_heads: 4,
        n_blocks: 6,
        n_enc_blocks: 0,
        mlp_ratio: 4,
        batch: 32,
        lbits: 9,
        image_size: 32,
        patch: 4,
        channels: 3,
        n_classes: 10,
        seq: 64,
        seq_src: 0,
        vocab: 96,
    }
}

/// Synthesize the manifest for a registered bundle name.
pub fn manifest_for(name: &str) -> Result<Manifest> {
    let b = base_dims();
    let (family, dims) = match name {
        // Paper §5.1: ViT with K=6 blocks on CIFAR10/100 stand-ins.
        "vit_s10" => (
            Family::Vit,
            Dims { mlp_ratio: 2, batch: 64, ..b },
        ),
        "vit_s100" => (
            Family::Vit,
            Dims { mlp_ratio: 2, batch: 64, n_classes: 100, ..b },
        ),
        // Paper §5.3: (nano)GPT2 with 12 blocks, tiny-corpus overfitting.
        "gpt_tiny" => (
            Family::Gpt,
            Dims { n_blocks: 12, mlp_ratio: 2, batch: 16, ..b },
        ),
        // Paper §5.2: en->fr translation, 6+6 encoder/decoder blocks.
        "encdec_mt" => (
            Family::EncDec,
            Dims {
                n_blocks: 6,
                n_enc_blocks: 6,
                mlp_ratio: 2,
                seq: 24,
                seq_src: 24,
                vocab: 64,
                ..b
            },
        ),
        // End-to-end driver: largest feasible LM on this testbed.
        "gpt_e2e" => (
            Family::Gpt,
            Dims {
                d_model: 256,
                n_heads: 8,
                n_blocks: 8,
                batch: 8,
                seq: 128,
                ..b
            },
        ),
        // Tiny smoke configs for cargo integration tests.
        "smoke_vit" => (
            Family::Vit,
            Dims {
                d_model: 16,
                n_heads: 2,
                n_blocks: 3,
                mlp_ratio: 2,
                batch: 2,
                image_size: 8,
                n_classes: 4,
                ..b
            },
        ),
        "smoke_gpt" => (
            Family::Gpt,
            Dims {
                d_model: 16,
                n_heads: 2,
                n_blocks: 4,
                mlp_ratio: 2,
                batch: 2,
                seq: 8,
                vocab: 11,
                ..b
            },
        ),
        "smoke_encdec" => (
            Family::EncDec,
            Dims {
                d_model: 16,
                n_heads: 2,
                n_blocks: 2,
                n_enc_blocks: 2,
                mlp_ratio: 2,
                batch: 2,
                seq: 6,
                seq_src: 6,
                vocab: 11,
                ..b
            },
        ),
        _ => bail!(
            "unknown native bundle '{name}' (known: {})",
            config_names().join(", ")
        ),
    };
    Ok(manifest_from_dims(name, family, dims))
}

// ---------------------------------------------------------------------------
// Leaf specs (flatten order = JAX sorted-dict-key traversal)
// ---------------------------------------------------------------------------

fn leaf(name: String, shape: Vec<usize>, init: Init) -> LeafSpec {
    LeafSpec { name, shape, init }
}

fn ln_leaves(prefix: &str, d: usize) -> Vec<LeafSpec> {
    vec![
        leaf(format!("{prefix}.bias"), vec![d], Init::Zeros),
        leaf(format!("{prefix}.scale"), vec![d], Init::Ones),
    ]
}

fn attn_leaves(prefix: &str, d: usize) -> Vec<LeafSpec> {
    vec![
        leaf(format!("{prefix}.bk"), vec![d], Init::Zeros),
        leaf(format!("{prefix}.bo"), vec![d], Init::Zeros),
        leaf(format!("{prefix}.bq"), vec![d], Init::Zeros),
        leaf(format!("{prefix}.bv"), vec![d], Init::Zeros),
        leaf(format!("{prefix}.wk"), vec![d, d], Init::Normal(INIT_STD)),
        leaf(format!("{prefix}.wo"), vec![d, d], Init::Normal(INIT_STD)),
        leaf(format!("{prefix}.wq"), vec![d, d], Init::Normal(INIT_STD)),
        leaf(format!("{prefix}.wv"), vec![d, d], Init::Normal(INIT_STD)),
    ]
}

fn ffn_leaves(d: usize, ratio: usize) -> Vec<LeafSpec> {
    let dr = d * ratio;
    vec![
        leaf("ffn.b1".into(), vec![dr], Init::Zeros),
        leaf("ffn.b2".into(), vec![d], Init::Zeros),
        leaf("ffn.w1".into(), vec![d, dr], Init::Normal(INIT_STD)),
        leaf("ffn.w2".into(), vec![dr, d], Init::Normal(INIT_STD)),
    ]
}

/// Block leaves: attn(8), ffn(4), ln1(2), ln2(2) [+ lnx(2), xattn(8)].
pub fn block_leaves(d: usize, ratio: usize, cross: bool) -> Vec<LeafSpec> {
    let mut v = attn_leaves("attn", d);
    v.extend(ffn_leaves(d, ratio));
    v.extend(ln_leaves("ln1", d));
    v.extend(ln_leaves("ln2", d));
    if cross {
        v.extend(ln_leaves("lnx", d));
        v.extend(attn_leaves("xattn", d));
    }
    v
}

fn embed_leaves(family: Family, dims: &Dims) -> Vec<LeafSpec> {
    let d = dims.d_model;
    match family {
        Family::Vit => {
            let pdim = dims.patch * dims.patch * dims.channels;
            let tokens = dims.tokens(Family::Vit);
            vec![
                leaf("cls".into(), vec![1, 1, d], Init::Normal(INIT_STD)),
                leaf("pos".into(), vec![tokens, d], Init::Normal(INIT_STD)),
                leaf("proj_b".into(), vec![d], Init::Zeros),
                leaf("proj_w".into(), vec![pdim, d], Init::Normal(INIT_STD)),
            ]
        }
        Family::Gpt | Family::EncDec => vec![
            leaf("wpe".into(), vec![dims.seq, d], Init::Normal(INIT_STD)),
            leaf("wte".into(), vec![dims.vocab, d], Init::Normal(INIT_STD)),
        ],
    }
}

fn enc_embed_leaves(dims: &Dims) -> Vec<LeafSpec> {
    vec![
        leaf("wpe".into(), vec![dims.seq_src, dims.d_model], Init::Normal(INIT_STD)),
        leaf("wte".into(), vec![dims.vocab, dims.d_model], Init::Normal(INIT_STD)),
    ]
}

fn head_leaves(family: Family, dims: &Dims) -> Vec<LeafSpec> {
    let d = dims.d_model;
    let out = if family == Family::Vit { dims.n_classes } else { dims.vocab };
    vec![
        leaf("b".into(), vec![out], Init::Zeros),
        leaf("ln_f.bias".into(), vec![d], Init::Zeros),
        leaf("ln_f.scale".into(), vec![d], Init::Ones),
        leaf("w".into(), vec![d, out], Init::Normal(INIT_STD)),
    ]
}

// ---------------------------------------------------------------------------
// Executable specs
// ---------------------------------------------------------------------------

fn f32_arg(name: &str, shape: Vec<usize>) -> ArgSpec {
    ArgSpec { name: name.into(), dtype: DType::F32, shape }
}

fn i32_arg(name: &str, shape: Vec<usize>) -> ArgSpec {
    ArgSpec { name: name.into(), dtype: DType::I32, shape }
}

fn leaf_outputs(leaves: &[LeafSpec]) -> Vec<ArgSpec> {
    leaves
        .iter()
        .map(|l| f32_arg(&l.name, l.shape.clone()))
        .collect()
}

fn exec(
    param_layout: Vec<(String, usize)>,
    data_inputs: Vec<ArgSpec>,
    outputs: Vec<ArgSpec>,
) -> ExecSpec {
    ExecSpec { file: "native".into(), param_layout, data_inputs, outputs }
}

fn layout(entries: &[(&str, usize)]) -> Vec<(String, usize)> {
    entries.iter().map(|(g, c)| (g.to_string(), *c)).collect()
}

/// Build the full manifest (param groups + executable ABI) for one config.
pub fn manifest_from_dims(name: &str, family: Family, dims: Dims) -> Manifest {
    let d = dims.d_model;
    let cross = family == Family::EncDec;
    let tokens = dims.tokens(family);

    let e_leaves = embed_leaves(family, &dims);
    let b_leaves = block_leaves(d, dims.mlp_ratio, cross);
    let h_leaves = head_leaves(family, &dims);

    let mut param_groups = BTreeMap::new();
    param_groups.insert("embed".to_string(), e_leaves.clone());
    param_groups.insert("block".to_string(), b_leaves.clone());
    param_groups.insert("head".to_string(), h_leaves.clone());
    if cross {
        param_groups.insert("enc_embed".to_string(), enc_embed_leaves(&dims));
        param_groups
            .insert("enc_block".to_string(), block_leaves(d, dims.mlp_ratio, false));
    }

    let x_shape = vec![dims.batch, tokens, d];
    let mem_shape = vec![dims.batch, dims.seq_src, d];
    let inputs_arg = match family {
        Family::Vit => f32_arg(
            "inputs",
            vec![dims.batch, dims.channels, dims.image_size, dims.image_size],
        ),
        _ => i32_arg("inputs", vec![dims.batch, dims.seq]),
    };
    let labels_arg = match family {
        Family::Vit => i32_arg("labels", vec![dims.batch]),
        _ => i32_arg("labels", vec![dims.batch, dims.seq]),
    };
    let scalar_out = f32_arg("out", vec![]);

    let mut executables = BTreeMap::new();

    // ---- embed ----
    executables.insert(
        "embed_fwd".to_string(),
        exec(
            layout(&[("embed", 1)]),
            vec![inputs_arg.clone()],
            vec![f32_arg("x", x_shape.clone())],
        ),
    );
    executables.insert(
        "embed_vjp".to_string(),
        exec(
            layout(&[("embed", 1)]),
            vec![inputs_arg.clone(), f32_arg("g", x_shape.clone())],
            leaf_outputs(&e_leaves),
        ),
    );

    // ---- block (decoder/self block) ----
    let mut bf_data = vec![f32_arg("x", x_shape.clone())];
    if cross {
        bf_data.push(f32_arg("mem", mem_shape.clone()));
    }
    executables.insert(
        "block_fwd".to_string(),
        exec(
            layout(&[("block", 1)]),
            bf_data.clone(),
            vec![f32_arg("h", x_shape.clone())],
        ),
    );
    let mut bv_data = bf_data.clone();
    bv_data.push(f32_arg("g", x_shape.clone()));
    let mut bv_outs = vec![
        f32_arg("h", x_shape.clone()),
        f32_arg("dx", x_shape.clone()),
    ];
    if cross {
        bv_outs.push(f32_arg("dmem", mem_shape.clone()));
    }
    bv_outs.extend(leaf_outputs(&b_leaves));
    executables.insert(
        "block_vjp".to_string(),
        exec(layout(&[("block", 1)]), bv_data, bv_outs),
    );

    // ---- RevViT sub-branch executables (vit/gpt families) ----
    if !cross {
        for (fwd, vjp) in [("attn_fwd", "attn_vjp"), ("ffn_fwd", "ffn_vjp")] {
            executables.insert(
                fwd.to_string(),
                exec(
                    layout(&[("block", 1)]),
                    vec![f32_arg("x", x_shape.clone())],
                    vec![f32_arg("out", x_shape.clone())],
                ),
            );
            let mut outs = vec![
                f32_arg("out", x_shape.clone()),
                f32_arg("dx", x_shape.clone()),
            ];
            outs.extend(leaf_outputs(&b_leaves));
            executables.insert(
                vjp.to_string(),
                exec(
                    layout(&[("block", 1)]),
                    vec![f32_arg("x", x_shape.clone()), f32_arg("g", x_shape.clone())],
                    outs,
                ),
            );
        }
    }

    // ---- head + loss ----
    executables.insert(
        "head_loss_fwd".to_string(),
        exec(
            layout(&[("head", 1)]),
            vec![f32_arg("x", x_shape.clone()), labels_arg.clone()],
            vec![scalar_out.clone(), scalar_out.clone()],
        ),
    );
    let mut hv_outs = vec![f32_arg("dx", x_shape.clone())];
    hv_outs.extend(leaf_outputs(&h_leaves));
    executables.insert(
        "head_loss_vjp".to_string(),
        exec(
            layout(&[("head", 1)]),
            vec![f32_arg("x", x_shape.clone()), labels_arg.clone()],
            hv_outs,
        ),
    );

    // ---- encoder side (encdec only) ----
    if cross {
        let src_arg = i32_arg("src", vec![dims.batch, dims.seq_src]);
        let ee_leaves = enc_embed_leaves(&dims);
        let eb_leaves = block_leaves(d, dims.mlp_ratio, false);
        executables.insert(
            "enc_embed_fwd".to_string(),
            exec(
                layout(&[("enc_embed", 1)]),
                vec![src_arg.clone()],
                vec![f32_arg("x", mem_shape.clone())],
            ),
        );
        executables.insert(
            "enc_embed_vjp".to_string(),
            exec(
                layout(&[("enc_embed", 1)]),
                vec![src_arg.clone(), f32_arg("g", mem_shape.clone())],
                leaf_outputs(&ee_leaves),
            ),
        );
        executables.insert(
            "enc_block_fwd".to_string(),
            exec(
                layout(&[("enc_block", 1)]),
                vec![f32_arg("x", mem_shape.clone())],
                vec![f32_arg("h", mem_shape.clone())],
            ),
        );
        let mut ebv_outs = vec![
            f32_arg("h", mem_shape.clone()),
            f32_arg("dx", mem_shape.clone()),
        ];
        ebv_outs.extend(leaf_outputs(&eb_leaves));
        executables.insert(
            "enc_block_vjp".to_string(),
            exec(
                layout(&[("enc_block", 1)]),
                vec![f32_arg("x", mem_shape.clone()), f32_arg("g", mem_shape.clone())],
                ebv_outs,
            ),
        );
    }

    // ---- fused quantized inference (gamma is a runtime input) ----
    let infer_layout = if cross {
        layout(&[
            ("enc_embed", 1),
            ("enc_block", dims.n_enc_blocks),
            ("embed", 1),
            ("block", dims.n_blocks),
            ("head", 1),
        ])
    } else {
        layout(&[("embed", 1), ("block", dims.n_blocks), ("head", 1)])
    };
    let infer_data = if cross {
        vec![
            i32_arg("src", vec![dims.batch, dims.seq_src]),
            i32_arg("tgt", vec![dims.batch, dims.seq]),
            labels_arg.clone(),
            f32_arg("gamma", vec![]),
        ]
    } else {
        vec![inputs_arg, labels_arg, f32_arg("gamma", vec![])]
    };
    executables.insert(
        "model_infer".to_string(),
        exec(
            infer_layout.clone(),
            infer_data.clone(),
            vec![scalar_out.clone(), scalar_out],
        ),
    );
    // Per-example variant for the serving path: identical inputs, but the
    // loss/correct outputs keep the batch dimension so a coalesced batch can
    // be split back into per-request responses.  Every per-example value
    // depends only on that example's own slot (attention, LayerNorm and the
    // quantized BDIA update never mix batch rows), which is what makes
    // micro-batched serving bit-identical to direct calls.
    executables.insert(
        "model_infer_ex".to_string(),
        exec(
            infer_layout,
            infer_data,
            vec![
                f32_arg("loss", vec![dims.batch]),
                f32_arg("correct", vec![dims.batch]),
            ],
        ),
    );

    // ---- autoregressive decode (gpt only) ----
    // model_logits: full-prefix quantized forward returning raw logits —
    // the reference side of the decode bit-identity invariant.  `len` is a
    // runtime scalar (prefix length ≤ seq); logits rows at t ≥ len are
    // zero.  model_decode_step: one token position per lane against
    // caller-owned K/V caches (rows 0..pos filled); `lanes` ≤ batch lanes
    // are active (outputs for the rest stay zero), which is what lets the
    // /generate scheduler batch sessions by shape without padding cost.
    // Every per-lane output depends only on that lane's tokens and cache
    // rows, so batched and solo calls are bit-identical per lane.
    if family == Family::Gpt {
        let tower = layout(&[("embed", 1), ("block", dims.n_blocks), ("head", 1)]);
        executables.insert(
            "model_logits".to_string(),
            exec(
                tower.clone(),
                vec![
                    i32_arg("tokens", vec![dims.batch, dims.seq]),
                    f32_arg("len", vec![]),
                    f32_arg("gamma", vec![]),
                ],
                vec![f32_arg("logits", vec![dims.batch, dims.seq, dims.vocab])],
            ),
        );
        let cache = vec![dims.n_blocks, dims.batch, dims.seq, d];
        executables.insert(
            "model_decode_step".to_string(),
            exec(
                tower,
                vec![
                    i32_arg("tokens", vec![dims.batch]),
                    f32_arg("kcache", cache.clone()),
                    f32_arg("vcache", cache),
                    f32_arg("pos", vec![]),
                    f32_arg("lanes", vec![]),
                    f32_arg("gamma", vec![]),
                ],
                vec![
                    f32_arg("logits", vec![dims.batch, dims.vocab]),
                    f32_arg("knew", vec![dims.n_blocks, dims.batch, d]),
                    f32_arg("vnew", vec![dims.n_blocks, dims.batch, d]),
                ],
            ),
        );
    }

    Manifest {
        name: name.to_string(),
        family,
        dims,
        param_groups,
        executables,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_registered_configs_build() {
        for name in config_names() {
            let m = manifest_for(name).unwrap();
            assert_eq!(&m.name, name);
            assert!(m.n_params() > 0, "{name}");
            for e in ["embed_fwd", "block_fwd", "block_vjp", "head_loss_fwd",
                      "head_loss_vjp", "model_infer"] {
                assert!(m.executables.contains_key(e), "{name} missing {e}");
            }
        }
    }

    #[test]
    fn leaf_order_matches_jax_sorted_flatten() {
        // the ABI contract with python/compile/aot.py: dict keys sorted at
        // every nesting level
        let m = manifest_for("smoke_gpt").unwrap();
        let names: Vec<&str> = m.param_groups["block"]
            .iter()
            .map(|l| l.name.as_str())
            .collect();
        assert_eq!(
            names,
            vec![
                "attn.bk", "attn.bo", "attn.bq", "attn.bv", "attn.wk",
                "attn.wo", "attn.wq", "attn.wv", "ffn.b1", "ffn.b2",
                "ffn.w1", "ffn.w2", "ln1.bias", "ln1.scale", "ln2.bias",
                "ln2.scale",
            ]
        );
        let head: Vec<&str> =
            m.param_groups["head"].iter().map(|l| l.name.as_str()).collect();
        assert_eq!(head, vec!["b", "ln_f.bias", "ln_f.scale", "w"]);
        let embed: Vec<&str> =
            m.param_groups["embed"].iter().map(|l| l.name.as_str()).collect();
        assert_eq!(embed, vec!["wpe", "wte"]);
    }

    #[test]
    fn gpt_manifests_expose_decode_executables() {
        for name in ["smoke_gpt", "gpt_tiny", "gpt_e2e"] {
            let m = manifest_for(name).unwrap();
            let spec = &m.executables["model_decode_step"];
            assert_eq!(spec.data_inputs.len(), 6, "{name}");
            assert_eq!(spec.outputs.len(), 3, "{name}");
            assert_eq!(
                spec.data_inputs[1].shape,
                vec![m.dims.n_blocks, m.dims.batch, m.dims.seq, m.dims.d_model],
                "{name} kcache shape"
            );
            assert!(m.executables.contains_key("model_logits"), "{name}");
        }
        for name in ["smoke_vit", "smoke_encdec"] {
            let m = manifest_for(name).unwrap();
            assert!(!m.executables.contains_key("model_decode_step"), "{name}");
            assert!(!m.executables.contains_key("model_logits"), "{name}");
        }
    }

    #[test]
    fn encdec_manifest_has_cross_leaves_and_enc_side() {
        let m = manifest_for("smoke_encdec").unwrap();
        assert_eq!(m.param_groups["block"].len(), 26);
        assert_eq!(m.param_groups["enc_block"].len(), 16);
        assert!(m.executables.contains_key("enc_block_vjp"));
        assert!(!m.executables.contains_key("attn_fwd"));
        // decoder block_vjp emits h, dx, dmem, then 26 leaf grads
        assert_eq!(m.executables["block_vjp"].outputs.len(), 3 + 26);
    }

    #[test]
    fn vit_embed_shapes() {
        let m = manifest_for("smoke_vit").unwrap();
        let tokens = m.dims.tokens(Family::Vit);
        assert_eq!(tokens, 5); // (8/4)^2 + 1
        let embed = &m.param_groups["embed"];
        assert_eq!(embed[0].name, "cls");
        assert_eq!(embed[1].shape, vec![tokens, 16]); // pos
        assert_eq!(embed[3].shape, vec![4 * 4 * 3, 16]); // proj_w
        // RevViT sub-branches exist for non-cross families
        assert!(m.executables.contains_key("attn_vjp"));
        assert_eq!(m.executables["attn_vjp"].outputs.len(), 2 + 16);
    }
}
