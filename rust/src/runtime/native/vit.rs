//! ViT family: patch embedding (forward + VJP) and the fused quantized
//! image-classification inference, on top of [`super::blocks`].

use super::blocks;
use crate::kernels::{col_sum, linear, matmul_tn, workspace};
use crate::quant::Fixed;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// ViT patchify: (B, C, H, W) -> (B*np, p*p*C) rows, np = (H/p)*(W/p).
/// Patch-vector element order matches the JAX transpose (b,gh,gw,py,px,c).
fn patchify(images: &[f32], b: usize, c: usize, hw: usize, p: usize) -> Vec<f32> {
    let gside = hw / p;
    let np = gside * gside;
    let pdim = p * p * c;
    let mut out = workspace::take(b * np * pdim);
    for bi in 0..b {
        for ghi in 0..gside {
            for gwi in 0..gside {
                let patch_row = (bi * np + ghi * gside + gwi) * pdim;
                for py in 0..p {
                    for px in 0..p {
                        for ch in 0..c {
                            let src = ((bi * c + ch) * hw + ghi * p + py) * hw
                                + gwi * p
                                + px;
                            out[patch_row + (py * p + px) * c + ch] = images[src];
                        }
                    }
                }
            }
        }
    }
    out
}

/// ViT embed forward.  Leaves: [cls (1,1,d), pos (tokens,d), proj_b (d),
/// proj_w (pdim,d)].
#[allow(clippy::too_many_arguments)]
pub fn embed_fwd(
    leaves: &[&Tensor],
    images: &Tensor,
    b: usize,
    c: usize,
    hw: usize,
    p: usize,
    d: usize,
) -> Result<Tensor> {
    ensure!(leaves.len() == 4, "vit embed expects 4 leaves");
    let (cls, pos, proj_b, proj_w) =
        (leaves[0].data(), leaves[1].data(), leaves[2].data(), leaves[3].data());
    let gside = hw / p;
    let np = gside * gside;
    let tokens = np + 1;
    let pdim = p * p * c;
    let patches = patchify(images.data(), b, c, hw, p);
    let z = linear(&patches, proj_w, proj_b, b * np, pdim, d);
    workspace::give(patches);
    let mut out = vec![0.0f32; b * tokens * d];
    for bi in 0..b {
        let row0 = bi * tokens * d;
        for j in 0..d {
            out[row0 + j] = cls[j] + pos[j];
        }
        for t in 0..np {
            let dst = row0 + (t + 1) * d;
            let src = (bi * np + t) * d;
            let posr = &pos[(t + 1) * d..(t + 2) * d];
            for j in 0..d {
                out[dst + j] = z[src + j] + posr[j];
            }
        }
    }
    workspace::give(z);
    Tensor::from_vec(&[b, tokens, d], out)
}

/// ViT embed VJP (parameter grads only, matching the AOT executable).
#[allow(clippy::too_many_arguments)]
pub fn embed_vjp(
    leaves: &[&Tensor],
    images: &Tensor,
    g: &Tensor,
    b: usize,
    c: usize,
    hw: usize,
    p: usize,
    d: usize,
) -> Result<Vec<Tensor>> {
    ensure!(leaves.len() == 4, "vit embed expects 4 leaves");
    let gside = hw / p;
    let np = gside * gside;
    let tokens = np + 1;
    let pdim = p * p * c;
    let gd = g.data();

    let mut dcls = vec![0.0f32; d];
    let mut dpos = vec![0.0f32; tokens * d];
    // dz rows (b*np, d) = g[:, 1:, :]
    let mut dz = workspace::take(b * np * d);
    for bi in 0..b {
        let row0 = bi * tokens * d;
        for j in 0..d {
            dcls[j] += gd[row0 + j];
            dpos[j] += gd[row0 + j];
        }
        for t in 0..np {
            let src = row0 + (t + 1) * d;
            let dst = (bi * np + t) * d;
            for j in 0..d {
                let v = gd[src + j];
                dpos[(t + 1) * d + j] += v;
                dz[dst + j] = v;
            }
        }
    }
    let patches = patchify(images.data(), b, c, hw, p);
    let dproj_w = matmul_tn(&patches, &dz, b * np, pdim, d);
    let dproj_b = col_sum(&dz, b * np, d);
    workspace::give(patches);
    workspace::give(dz);
    Ok(vec![
        Tensor::from_vec(&[1, 1, d], dcls)?,
        Tensor::from_vec(&[tokens, d], dpos)?,
        Tensor::from_vec(&[d], dproj_b)?,
        Tensor::from_vec(&[pdim, d], dproj_w)?,
    ])
}

/// Fused quantized inference for the ViT family: embed → BDIA stack →
/// head reduction (scalar or per-example).
pub(super) fn model_infer(
    ex: &super::NativeExec,
    params: &[&Tensor],
    data: &[crate::runtime::ArgValue],
    per_example: bool,
) -> Result<Vec<Tensor>> {
    let d = ex.dims.d_model;
    let b = ex.dims.batch;
    let f = Fixed::new(ex.dims.lbits);
    let images = super::want_f32(data, 0, "images")?;
    let labels = super::want_i32(data, 1, "labels")?;
    let gamma = super::want_scalar(data, 2, "gamma")?;
    let (em, tower, hd) = ex.split_single_tower(params);
    let x0 = embed_fwd(
        em, images, b, ex.dims.channels, ex.dims.image_size, ex.dims.patch, d,
    )?;
    let xk = blocks::stack_infer(
        &tower, x0, gamma, ex.main_block_dims(), false, None, f,
    )?;
    ex.head_reduce(hd, &xk, labels, per_example)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patchify_layout_matches_jax_transpose() {
        // 1 image, 1 channel, 4x4, patch 2 -> 4 patches of 4 pixels
        let images: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let p = patchify(&images, 1, 1, 4, 2);
        // patch (0,0) = rows 0-1, cols 0-1 in row-major (py,px,c) order
        assert_eq!(&p[0..4], &[0.0, 1.0, 4.0, 5.0]);
        // patch (0,1) = rows 0-1, cols 2-3
        assert_eq!(&p[4..8], &[2.0, 3.0, 6.0, 7.0]);
        // patch (1,0) = rows 2-3, cols 0-1
        assert_eq!(&p[8..12], &[8.0, 9.0, 12.0, 13.0]);
    }

    #[test]
    fn vit_labels_are_per_image() {
        use crate::runtime::native::registry;
        use crate::runtime::Runtime;
        let rt = Runtime::from_native_manifest(
            registry::manifest_for("smoke_vit").unwrap(),
        )
        .unwrap();
        let spec = &rt.exec("model_infer").unwrap().spec;
        // ViT: one label per image, not per token
        assert_eq!(
            spec.data_inputs[1].shape,
            vec![rt.manifest.dims.batch]
        );
    }
}
