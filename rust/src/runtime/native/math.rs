//! Slice-level math primitives for the native backend: matmul, layernorm,
//! GELU and multi-head attention, each with a hand-written VJP.
//!
//! Semantics mirror the JAX model exactly (`python/compile/model.py` and
//! `python/compile/kernels/attention.py`): layernorm uses population
//! variance with eps 1e-5, GELU is the tanh approximation (jax.nn.gelu's
//! default), attention is `softmax(Q K^T / sqrt(d_head)) V` with a -1e30
//! causal mask and max-subtracted softmax.  All buffers are row-major f32
//! slices; shapes are passed explicitly so callers can flatten (B, T, D)
//! activations to (B*T, D) rows.

#![allow(clippy::too_many_arguments)]

pub const NEG_INF: f32 = -1e30;
const LN_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// elementwise helpers
// ---------------------------------------------------------------------------

/// a += b
pub fn add_into(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += *y;
    }
}

/// out = a + b
pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// Column sums of a (rows, cols) matrix — bias gradients.
pub fn col_sum(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; cols];
    for r in 0..rows {
        let row = &a[r * cols..(r + 1) * cols];
        for (o, v) in out.iter_mut().zip(row) {
            *o += *v;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// matmul family
// ---------------------------------------------------------------------------

/// c(m,n) = a(m,k) @ b(k,n)
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av != 0.0 {
                let brow = &b[p * n..(p + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += av * *bv;
                }
            }
        }
    }
    c
}

/// c(k,n) = a(m,k)^T @ b(m,n)
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut c = vec![0.0f32; k * n];
    for i in 0..m {
        let brow = &b[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av != 0.0 {
                let crow = &mut c[p * n..(p + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += av * *bv;
                }
            }
        }
    }
    c
}

/// c(m,k) = a(m,n) @ b(k,n)^T
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (p, cv) in crow.iter_mut().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            let mut s = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                s += *av * *bv;
            }
            *cv = s;
        }
    }
    c
}

/// y(rows, d_out) = x(rows, d_in) @ w(d_in, d_out) + bias
pub fn linear(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
) -> Vec<f32> {
    let mut y = matmul(x, w, rows, d_in, d_out);
    for r in 0..rows {
        let row = &mut y[r * d_out..(r + 1) * d_out];
        for (v, b) in row.iter_mut().zip(bias) {
            *v += *b;
        }
    }
    y
}

// ---------------------------------------------------------------------------
// layer norm
// ---------------------------------------------------------------------------

pub struct LnCache {
    /// normalised activations (rows, d)
    pub xhat: Vec<f32>,
    /// per-row 1/sqrt(var + eps)
    pub inv: Vec<f32>,
}

/// y = (x - mean) / sqrt(var + eps) * scale + bias, per row of length d.
pub fn ln_fwd(
    scale: &[f32],
    bias: &[f32],
    x: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, LnCache) {
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        inv[r] = iv;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            let h = (xr[j] - mu) * iv;
            xh[j] = h;
            yr[j] = h * scale[j] + bias[j];
        }
    }
    (y, LnCache { xhat, inv })
}

/// Backward of [`ln_fwd`]: returns (dx, dscale, dbias).
pub fn ln_bwd(
    scale: &[f32],
    cache: &LnCache,
    dy: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d];
    let mut dscale = vec![0.0f32; d];
    let mut dbias = vec![0.0f32; d];
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &cache.xhat[r * d..(r + 1) * d];
        let iv = cache.inv[r];
        // dxhat = dy * scale; two row means close the LN jacobian
        let mut mean_dxh = 0.0f32;
        let mut mean_dxh_xh = 0.0f32;
        for j in 0..d {
            let dxh = dyr[j] * scale[j];
            mean_dxh += dxh;
            mean_dxh_xh += dxh * xh[j];
            dscale[j] += dyr[j] * xh[j];
            dbias[j] += dyr[j];
        }
        mean_dxh /= d as f32;
        mean_dxh_xh /= d as f32;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            let dxh = dyr[j] * scale[j];
            dxr[j] = iv * (dxh - mean_dxh - xh[j] * mean_dxh_xh);
        }
    }
    (dx, dscale, dbias)
}

// ---------------------------------------------------------------------------
// GELU (tanh approximation — jax.nn.gelu default)
// ---------------------------------------------------------------------------

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044_715;

#[inline]
pub fn gelu(u: f32) -> f32 {
    let t = (GELU_C * (u + GELU_A * u * u * u)).tanh();
    0.5 * u * (1.0 + t)
}

#[inline]
pub fn gelu_grad(u: f32) -> f32 {
    let w = GELU_C * (u + GELU_A * u * u * u);
    let t = w.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * u * sech2 * GELU_C * (1.0 + 3.0 * GELU_A * u * u)
}

// ---------------------------------------------------------------------------
// multi-head attention
// ---------------------------------------------------------------------------

/// Attention projection weights, views into parameter leaves.
pub struct AttnW<'a> {
    pub wq: &'a [f32],
    pub bq: &'a [f32],
    pub wk: &'a [f32],
    pub bk: &'a [f32],
    pub wv: &'a [f32],
    pub bv: &'a [f32],
    pub wo: &'a [f32],
    pub bo: &'a [f32],
}

/// Parameter gradients, same shapes as [`AttnW`].
pub struct AttnGrads {
    pub wq: Vec<f32>,
    pub bq: Vec<f32>,
    pub wk: Vec<f32>,
    pub bk: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    pub wo: Vec<f32>,
    pub bo: Vec<f32>,
}

/// Forward residuals needed by [`attn_bwd`].
pub struct AttnCache {
    /// projected q/k/v, (b*tq, d) / (b*tk, d)
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// pre-output-projection context, (b*tq, d)
    pub o: Vec<f32>,
    /// softmax weights, (b*heads, tq, tk)
    pub att: Vec<f32>,
}

/// Copy one head's rows into a contiguous (t, dh) buffer.
fn gather_head(
    src: &[f32],
    bi: usize,
    hi: usize,
    t: usize,
    d: usize,
    dh: usize,
    out: &mut [f32],
) {
    for i in 0..t {
        let base = (bi * t + i) * d + hi * dh;
        out[i * dh..(i + 1) * dh].copy_from_slice(&src[base..base + dh]);
    }
}

/// Accumulate a contiguous (t, dh) head buffer back into (b*t, d) rows.
fn scatter_head_add(
    dst: &mut [f32],
    src: &[f32],
    bi: usize,
    hi: usize,
    t: usize,
    d: usize,
    dh: usize,
) {
    for i in 0..t {
        let base = (bi * t + i) * d + hi * dh;
        for j in 0..dh {
            dst[base + j] += src[i * dh + j];
        }
    }
}

/// Multi-head attention forward.
///
/// `x`: (b*tq, d) queries input; `kv`: (b*tk, d) key/value input (== `x` for
/// self-attention).  Returns the (b*tq, d) output and the backward cache.
pub fn attn_fwd(
    w: &AttnW,
    x: &[f32],
    kv: &[f32],
    b: usize,
    tq: usize,
    tk: usize,
    d: usize,
    heads: usize,
    causal: bool,
) -> (Vec<f32>, AttnCache) {
    debug_assert_eq!(d % heads, 0);
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let nq = b * tq;
    let nk = b * tk;

    let q = linear(x, w.wq, w.bq, nq, d, d);
    let k = linear(kv, w.wk, w.bk, nk, d, d);
    let v = linear(kv, w.wv, w.bv, nk, d, d);

    let mut o = vec![0.0f32; nq * d];
    let mut att = vec![0.0f32; b * heads * tq * tk];

    let mut qh = vec![0.0f32; tq * dh];
    let mut kh = vec![0.0f32; tk * dh];
    let mut vh = vec![0.0f32; tk * dh];
    for bi in 0..b {
        for hi in 0..heads {
            gather_head(&q, bi, hi, tq, d, dh, &mut qh);
            gather_head(&k, bi, hi, tk, d, dh, &mut kh);
            gather_head(&v, bi, hi, tk, d, dh, &mut vh);
            let abase = (bi * heads + hi) * tq * tk;
            // scores + masked softmax, one query row at a time
            let mut oh = vec![0.0f32; tq * dh];
            for i in 0..tq {
                let qr = &qh[i * dh..(i + 1) * dh];
                let arow = &mut att[abase + i * tk..abase + (i + 1) * tk];
                let mut m = NEG_INF;
                for jj in 0..tk {
                    let mut s = 0.0f32;
                    let kr = &kh[jj * dh..(jj + 1) * dh];
                    for (qv, kvv) in qr.iter().zip(kr) {
                        s += *qv * *kvv;
                    }
                    s *= scale;
                    if causal && jj > i {
                        s = NEG_INF;
                    }
                    arow[jj] = s;
                    if s > m {
                        m = s;
                    }
                }
                let mut denom = 0.0f32;
                for a in arow.iter_mut() {
                    *a = (*a - m).exp();
                    denom += *a;
                }
                let or = &mut oh[i * dh..(i + 1) * dh];
                for jj in 0..tk {
                    let p = arow[jj] / denom;
                    arow[jj] = p;
                    if p != 0.0 {
                        let vr = &vh[jj * dh..(jj + 1) * dh];
                        for (ov, vv) in or.iter_mut().zip(vr) {
                            *ov += p * *vv;
                        }
                    }
                }
            }
            scatter_head_add(&mut o, &oh, bi, hi, tq, d, dh);
        }
    }

    let out = linear(&o, w.wo, w.bo, nq, d, d);
    (out, AttnCache { q, k, v, o, att })
}

/// Backward of [`attn_fwd`].  Returns (dx, dkv, param grads); for
/// self-attention the caller adds dx + dkv.
pub fn attn_bwd(
    w: &AttnW,
    x: &[f32],
    kv: &[f32],
    cache: &AttnCache,
    dout: &[f32],
    b: usize,
    tq: usize,
    tk: usize,
    d: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>, AttnGrads) {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let nq = b * tq;
    let nk = b * tk;

    // output projection
    let dbo = col_sum(dout, nq, d);
    let dwo = matmul_tn(&cache.o, dout, nq, d, d);
    let do_ = matmul_nt(dout, w.wo, nq, d, d);

    let mut dq = vec![0.0f32; nq * d];
    let mut dk = vec![0.0f32; nk * d];
    let mut dv = vec![0.0f32; nk * d];

    let mut qh = vec![0.0f32; tq * dh];
    let mut kh = vec![0.0f32; tk * dh];
    let mut vh = vec![0.0f32; tk * dh];
    let mut doh = vec![0.0f32; tq * dh];
    for bi in 0..b {
        for hi in 0..heads {
            gather_head(&cache.q, bi, hi, tq, d, dh, &mut qh);
            gather_head(&cache.k, bi, hi, tk, d, dh, &mut kh);
            gather_head(&cache.v, bi, hi, tk, d, dh, &mut vh);
            gather_head(&do_, bi, hi, tq, d, dh, &mut doh);
            let abase = (bi * heads + hi) * tq * tk;
            let att = &cache.att[abase..abase + tq * tk];

            // dv_h = att^T @ do_h ; datt = do_h @ v_h^T
            let mut dvh = vec![0.0f32; tk * dh];
            let mut dqh = vec![0.0f32; tq * dh];
            let mut dkh = vec![0.0f32; tk * dh];
            for i in 0..tq {
                let arow = &att[i * tk..(i + 1) * tk];
                let dor = &doh[i * dh..(i + 1) * dh];
                // datt row + softmax jacobian row
                let mut datt = vec![0.0f32; tk];
                let mut rowdot = 0.0f32;
                for jj in 0..tk {
                    let p = arow[jj];
                    if p != 0.0 {
                        let vr = &vh[jj * dh..(jj + 1) * dh];
                        let mut s = 0.0f32;
                        for (dov, vv) in dor.iter().zip(vr) {
                            s += *dov * *vv;
                        }
                        datt[jj] = s;
                        rowdot += s * p;
                        // dv accumulation: dv[jj] += p * do[i]
                        let dvr = &mut dvh[jj * dh..(jj + 1) * dh];
                        for (dvv, dov) in dvr.iter_mut().zip(dor) {
                            *dvv += p * *dov;
                        }
                    }
                }
                let dqr = &mut dqh[i * dh..(i + 1) * dh];
                for jj in 0..tk {
                    let p = arow[jj];
                    if p != 0.0 {
                        let ds = p * (datt[jj] - rowdot) * scale;
                        if ds != 0.0 {
                            let kr = &kh[jj * dh..(jj + 1) * dh];
                            for (dqv, kvv) in dqr.iter_mut().zip(kr) {
                                *dqv += ds * *kvv;
                            }
                            let qr = &qh[i * dh..(i + 1) * dh];
                            let dkr = &mut dkh[jj * dh..(jj + 1) * dh];
                            for (dkv_, qv) in dkr.iter_mut().zip(qr) {
                                *dkv_ += ds * *qv;
                            }
                        }
                    }
                }
            }
            scatter_head_add(&mut dq, &dqh, bi, hi, tq, d, dh);
            scatter_head_add(&mut dk, &dkh, bi, hi, tk, d, dh);
            scatter_head_add(&mut dv, &dvh, bi, hi, tk, d, dh);
        }
    }

    // input projections
    let dwq = matmul_tn(x, &dq, nq, d, d);
    let dbq = col_sum(&dq, nq, d);
    let dx = matmul_nt(&dq, w.wq, nq, d, d);

    let dwk = matmul_tn(kv, &dk, nk, d, d);
    let dbk = col_sum(&dk, nk, d);
    let mut dkv = matmul_nt(&dk, w.wk, nk, d, d);

    let dwv = matmul_tn(kv, &dv, nk, d, d);
    let dbv = col_sum(&dv, nk, d);
    let dkv_v = matmul_nt(&dv, w.wv, nk, d, d);
    add_into(&mut dkv, &dkv_v);

    (
        dx,
        dkv,
        AttnGrads {
            wq: dwq,
            bq: dbq,
            wk: dwk,
            bk: dbk,
            wv: dwv,
            bv: dbv,
            wo: dwo,
            bo: dbo,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * s).collect()
    }

    #[test]
    fn matmul_identity_and_transpose_agree() {
        // a (2,3) @ b (3,2)
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![4.0, 5.0, 10.0, 11.0]);
        // a^T @ a via matmul_tn equals explicit transpose product
        let ata = matmul_tn(&a, &a, 2, 3, 3);
        let at = vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0];
        let ata2 = matmul(&at, &a, 3, 2, 3);
        assert_eq!(ata, ata2);
        // a @ b^T with b (2,3)
        let abt = matmul_nt(&a, &a, 2, 3, 2);
        assert_eq!(abt, vec![14.0, 32.0, 32.0, 77.0]);
    }

    #[test]
    fn ln_normalises_rows() {
        let mut rng = Rng::new(0);
        let d = 8;
        let x = randv(&mut rng, 2 * d, 3.0);
        let scale = vec![1.0; d];
        let bias = vec![0.0; d];
        let (y, _) = ln_fwd(&scale, &bias, &x, 2, d);
        for r in 0..2 {
            let row = &y[r * d..(r + 1) * d];
            let mu: f32 = row.iter().sum::<f32>() / d as f32;
            let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>()
                / d as f32;
            assert!(mu.abs() < 1e-5, "mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn ln_bwd_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let d = 6;
        let rows = 2;
        let x = randv(&mut rng, rows * d, 1.0);
        let scale = randv(&mut rng, d, 0.5);
        let bias = randv(&mut rng, d, 0.5);
        let dy = randv(&mut rng, rows * d, 1.0);
        let (_, cache) = ln_fwd(&scale, &bias, &x, rows, d);
        let (dx, dscale, dbias) = ln_bwd(&scale, &cache, &dy, rows, d);

        // probe L = sum(dy * y): dL/dx == dx
        let eps = 1e-2f32;
        let probe = |xs: &[f32]| -> f64 {
            let (y, _) = ln_fwd(&scale, &bias, xs, rows, d);
            y.iter().zip(&dy).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        for idx in [0usize, 3, 7, rows * d - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = ((probe(&xp) - probe(&xm)) / (2.0 * eps as f64)) as f32;
            let an = dx[idx];
            assert!(
                (fd - an).abs() < 2e-2 * an.abs().max(1.0),
                "dx[{idx}]: fd {fd} vs {an}"
            );
        }
        // dbias is just col-sum of dy
        let cs = col_sum(&dy, rows, d);
        for j in 0..d {
            assert!((dbias[j] - cs[j]).abs() < 1e-6);
        }
        assert_eq!(dscale.len(), d);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for u in [-3.0f32, -1.0, -0.1, 0.0, 0.1, 1.0, 3.0] {
            let eps = 1e-3f32;
            let fd = (gelu(u + eps) - gelu(u - eps)) / (2.0 * eps);
            assert!(
                (fd - gelu_grad(u)).abs() < 1e-3,
                "u={u}: fd {fd} vs {}",
                gelu_grad(u)
            );
        }
        assert!((gelu(0.0)).abs() < 1e-7);
        // large positive ~ identity, large negative ~ 0
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn attention_rows_sum_to_one_and_causal_masks() {
        let mut rng = Rng::new(2);
        let (b, t, d, heads) = (2usize, 4usize, 8usize, 2usize);
        let w_ = randv(&mut rng, d * d, 0.2);
        let bias0 = vec![0.0f32; d];
        let w = AttnW {
            wq: &w_, bq: &bias0, wk: &w_, bk: &bias0, wv: &w_, bv: &bias0,
            wo: &w_, bo: &bias0,
        };
        let x = randv(&mut rng, b * t * d, 1.0);
        let (_, cache) = attn_fwd(&w, &x, &x, b, t, t, d, heads, true);
        for bh in 0..b * heads {
            for i in 0..t {
                let row = &cache.att[bh * t * t + i * t..bh * t * t + (i + 1) * t];
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "softmax row sum {s}");
                for (jj, &p) in row.iter().enumerate() {
                    if jj > i {
                        assert_eq!(p, 0.0, "causal leak at ({i},{jj})");
                    }
                }
            }
        }
    }

    #[test]
    fn attn_bwd_matches_finite_difference_on_x() {
        let mut rng = Rng::new(3);
        let (b, t, d, heads) = (1usize, 3usize, 4usize, 2usize);
        let mk = |rng: &mut Rng| randv(rng, d * d, 0.3);
        let (wq, wk, wv, wo) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let (bq, bk, bv, bo) = (
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
        );
        let w = AttnW { wq: &wq, bq: &bq, wk: &wk, bk: &bk, wv: &wv, bv: &bv,
                        wo: &wo, bo: &bo };
        let x = randv(&mut rng, b * t * d, 1.0);
        let g = randv(&mut rng, b * t * d, 1.0);
        let (_, cache) = attn_fwd(&w, &x, &x, b, t, t, d, heads, false);
        let (dx, dkv, _) = attn_bwd(&w, &x, &x, &cache, &g, b, t, t, d, heads);

        let probe = |xs: &[f32]| -> f64 {
            let (y, _) = attn_fwd(&w, xs, xs, b, t, t, d, heads, false);
            y.iter().zip(&g).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let eps = 1e-2f32;
        for idx in 0..b * t * d {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = ((probe(&xp) - probe(&xm)) / (2.0 * eps as f64)) as f32;
            let an = dx[idx] + dkv[idx]; // self-attention: both paths
            assert!(
                (fd - an).abs() < 3e-2 * an.abs().max(1.0),
                "d/dx[{idx}]: fd {fd} vs analytic {an}"
            );
        }
    }
}
