//! Encoder-decoder family: fused quantized translation inference —
//! source embed → encoder BDIA stack → target embed → cross-attending
//! decoder BDIA stack → head — on top of [`super::blocks`], reusing the
//! token embeddings from [`super::gpt`].

use super::{blocks, gpt};
use crate::quant::Fixed;
use crate::tensor::Tensor;
use anyhow::Result;

/// Fused quantized inference for the encoder-decoder family.
pub(super) fn model_infer(
    ex: &super::NativeExec,
    params: &[&Tensor],
    data: &[crate::runtime::ArgValue],
    per_example: bool,
) -> Result<Vec<Tensor>> {
    let d = ex.dims.d_model;
    let b = ex.dims.batch;
    let f = Fixed::new(ex.dims.lbits);
    let src = super::want_i32(data, 0, "src")?;
    let tgt = super::want_i32(data, 1, "tgt")?;
    let labels = super::want_i32(data, 2, "labels")?;
    let gamma = super::want_scalar(data, 3, "gamma")?;

    let nee = ex.group_leaves["enc_embed"];
    let neb = ex.group_leaves["enc_block"];
    let ne = ex.group_leaves["embed"];
    let nb = ex.group_leaves["block"];
    let nh = ex.group_leaves["head"];
    let k_enc = ex.dims.n_enc_blocks;
    let k_dec = ex.dims.n_blocks;

    let mut cur = 0usize;
    let ee = &params[cur..cur + nee];
    cur += nee;
    let enc_blocks = super::split_blocks(params, &mut cur, neb, k_enc);
    let em = &params[cur..cur + ne];
    cur += ne;
    let dec_blocks = super::split_blocks(params, &mut cur, nb, k_dec);
    let hd = &params[cur..cur + nh];

    let xe = gpt::embed_fwd(ee, src, b, ex.dims.seq_src, d, ex.dims.vocab)?;
    let mem = blocks::stack_infer(
        &enc_blocks, xe, gamma, ex.enc_block_dims(), false, None, f,
    )?;
    let xd = gpt::embed_fwd(em, tgt, b, ex.dims.seq, d, ex.dims.vocab)?;
    let xk = blocks::stack_infer(
        &dec_blocks, xd, gamma, ex.main_block_dims(), true, Some(&mem), f,
    )?;
    ex.head_reduce(hd, &xk, labels, per_example)
}
