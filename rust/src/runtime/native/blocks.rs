//! Shared block-level machinery of the native backend: the transformer
//! block (forward + fused VJP), the RevViT sub-branches, the head + loss,
//! and the fused quantized BDIA stack inference — all assembled from the
//! [`crate::kernels`] compute core.
//!
//! Parameter leaves arrive as flat `&[&Tensor]` slices in manifest flatten
//! order (see `registry::block_leaves` — attn, ffn, ln1, ln2 [, lnx,
//! xattn], each sub-dict's keys sorted); gradients are emitted in the
//! identical order, which is the executable ABI the coordinator relies on.

// shape parameters are passed individually on purpose: these signatures
// mirror the executable ABI, not an internal convenience struct
#![allow(clippy::too_many_arguments)]

use crate::kernels::{
    add, add_into, attn_bwd, attn_fwd, col_sum, linear, ln_bwd, ln_fwd,
    map_gelu, matmul_nt_w, matmul_tn, scale_by_gelu_grad, workspace, AttnCache,
    AttnGrads, AttnW, LnCache,
};
use crate::model::Family;
use crate::quant::{self, Fixed};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{ensure, Result};

// ---------------------------------------------------------------------------
// parameter views
// ---------------------------------------------------------------------------

/// Leaf counts for one block parameter group.
pub const BLOCK_LEAVES: usize = 16;
pub const BLOCK_LEAVES_CROSS: usize = 26;

// leaf indices within a block group (flatten order)
const I_ATTN: usize = 0; // bk,bo,bq,bv,wk,wo,wq,wv
const I_FFN_B1: usize = 8;
const I_FFN_B2: usize = 9;
const I_FFN_W1: usize = 10;
const I_FFN_W2: usize = 11;
const I_LN1_BIAS: usize = 12;
const I_LN1_SCALE: usize = 13;
const I_LN2_BIAS: usize = 14;
const I_LN2_SCALE: usize = 15;
const I_LNX_BIAS: usize = 16;
const I_LNX_SCALE: usize = 17;
const I_XATTN: usize = 18;

fn attn_view<'a>(leaves: &[&'a Tensor], base: usize) -> AttnW<'a> {
    AttnW {
        bk: leaves[base].data(),
        bo: leaves[base + 1].data(),
        bq: leaves[base + 2].data(),
        bv: leaves[base + 3].data(),
        wk: leaves[base + 4].data(),
        wo: leaves[base + 5].data(),
        wq: leaves[base + 6].data(),
        wv: leaves[base + 7].data(),
    }
}

/// Borrowed view of one block's parameters.
pub struct BlockW<'a> {
    pub attn: AttnW<'a>,
    pub ffn_b1: &'a [f32],
    pub ffn_b2: &'a [f32],
    pub ffn_w1: &'a [f32],
    pub ffn_w2: &'a [f32],
    pub ln1_bias: &'a [f32],
    pub ln1_scale: &'a [f32],
    pub ln2_bias: &'a [f32],
    pub ln2_scale: &'a [f32],
    pub lnx_bias: Option<&'a [f32]>,
    pub lnx_scale: Option<&'a [f32]>,
    pub xattn: Option<AttnW<'a>>,
}

impl<'a> BlockW<'a> {
    pub fn from_leaves(leaves: &[&'a Tensor], cross: bool) -> Result<Self> {
        let want = if cross { BLOCK_LEAVES_CROSS } else { BLOCK_LEAVES };
        ensure!(
            leaves.len() == want,
            "block param group: expected {want} leaves, got {}",
            leaves.len()
        );
        Ok(BlockW {
            attn: attn_view(leaves, I_ATTN),
            ffn_b1: leaves[I_FFN_B1].data(),
            ffn_b2: leaves[I_FFN_B2].data(),
            ffn_w1: leaves[I_FFN_W1].data(),
            ffn_w2: leaves[I_FFN_W2].data(),
            ln1_bias: leaves[I_LN1_BIAS].data(),
            ln1_scale: leaves[I_LN1_SCALE].data(),
            ln2_bias: leaves[I_LN2_BIAS].data(),
            ln2_scale: leaves[I_LN2_SCALE].data(),
            lnx_bias: cross.then(|| leaves[I_LNX_BIAS].data()),
            lnx_scale: cross.then(|| leaves[I_LNX_SCALE].data()),
            xattn: if cross { Some(attn_view(leaves, I_XATTN)) } else { None },
        })
    }
}

/// Static shape info for one block invocation.
#[derive(Clone, Copy)]
pub struct BlockDims {
    pub b: usize,
    /// decoder/self sequence length (tokens)
    pub t: usize,
    /// memory sequence length (cross-attention; 0 when unused)
    pub t_src: usize,
    pub d: usize,
    pub heads: usize,
    pub ratio: usize,
    pub causal: bool,
}

// ---------------------------------------------------------------------------
// FFN
// ---------------------------------------------------------------------------

struct FfnCache {
    /// pre-GELU hidden, (rows, d*ratio)
    u1: Vec<f32>,
    /// post-GELU hidden
    a: Vec<f32>,
}

impl FfnCache {
    fn recycle(self) {
        workspace::give(self.u1);
        workspace::give(self.a);
    }
}

fn ffn_fwd(
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    x: &[f32],
    rows: usize,
    d: usize,
    dr: usize,
) -> (Vec<f32>, FfnCache) {
    let u1 = linear(x, w1, b1, rows, d, dr);
    let a = map_gelu(&u1);
    let y = linear(&a, w2, b2, rows, dr, d);
    (y, FfnCache { u1, a })
}

/// Returns (dx, dw1, db1, dw2, db2).
fn ffn_bwd(
    w1: &[f32],
    w2: &[f32],
    x: &[f32],
    cache: &FfnCache,
    dy: &[f32],
    rows: usize,
    d: usize,
    dr: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let dw2 = matmul_tn(&cache.a, dy, rows, dr, d);
    let db2 = col_sum(dy, rows, d);
    let mut du1 = matmul_nt_w(dy, w2, rows, d, dr);
    scale_by_gelu_grad(&mut du1, &cache.u1);
    let dw1 = matmul_tn(x, &du1, rows, d, dr);
    let db1 = col_sum(&du1, rows, dr);
    let dx = matmul_nt_w(&du1, w1, rows, dr, d);
    workspace::give(du1);
    (dx, dw1, db1, dw2, db2)
}

// ---------------------------------------------------------------------------
// transformer block: h(x) = f(x) + g(x + f(x))  (paper eq. 4)
// ---------------------------------------------------------------------------

struct BlockCache {
    xn: Vec<f32>,
    ln1: LnCache,
    attn: AttnCache,
    /// cross-attention residuals (encdec decoder blocks)
    cross: Option<CrossCache>,
    zn: Vec<f32>,
    ln2: LnCache,
    ffn: FfnCache,
}

impl BlockCache {
    fn recycle(self) {
        workspace::give(self.xn);
        self.ln1.recycle();
        self.attn.recycle();
        if let Some(c) = self.cross {
            workspace::give(c.un);
            c.lnx.recycle();
            c.xattn.recycle();
        }
        workspace::give(self.zn);
        self.ln2.recycle();
        self.ffn.recycle();
    }
}

struct CrossCache {
    un: Vec<f32>,
    lnx: LnCache,
    xattn: AttnCache,
}

fn block_fwd_cached(
    w: &BlockW,
    x: &[f32],
    mem: Option<&[f32]>,
    dims: BlockDims,
) -> (Vec<f32>, BlockCache) {
    let rows = dims.b * dims.t;
    let d = dims.d;
    let dr = d * dims.ratio;

    let (xn, ln1) = ln_fwd(w.ln1_scale, w.ln1_bias, x, rows, d);
    let (a, attn) = attn_fwd(
        &w.attn, &xn, &xn, dims.b, dims.t, dims.t, d, dims.heads, dims.causal,
    );
    let u = add(x, &a);
    workspace::give(a);

    let (u2, cross) = if let Some(m) = mem {
        let lnx_scale = w.lnx_scale.expect("cross block without lnx");
        let lnx_bias = w.lnx_bias.expect("cross block without lnx");
        let xw = w.xattn.as_ref().expect("cross block without xattn");
        let (un, lnx) = ln_fwd(lnx_scale, lnx_bias, &u, rows, d);
        let (c, xattn) = attn_fwd(
            xw, &un, m, dims.b, dims.t, dims.t_src, d, dims.heads, false,
        );
        let u2 = add(&u, &c);
        workspace::give(c);
        workspace::give(u);
        (u2, Some(CrossCache { un, lnx, xattn }))
    } else {
        (u, None)
    };

    let (zn, ln2) = ln_fwd(w.ln2_scale, w.ln2_bias, &u2, rows, d);
    let (f, ffn) = ffn_fwd(w.ffn_w1, w.ffn_b1, w.ffn_w2, w.ffn_b2, &zn, rows, d, dr);

    // h = u2 + f - x
    let mut h = u2;
    add_into(&mut h, &f);
    workspace::give(f);
    for (hv, xv) in h.iter_mut().zip(x) {
        *hv -= *xv;
    }
    (h, BlockCache { xn, ln1, attn, cross, zn, ln2, ffn })
}

/// Forward only (model_infer / reconstruction probes).
pub fn block_h(w: &BlockW, x: &[f32], mem: Option<&[f32]>, dims: BlockDims) -> Vec<f32> {
    let (h, cache) = block_fwd_cached(w, x, mem, dims);
    cache.recycle();
    h
}

/// Single-position decode forward of one (non-cross) block against this
/// block's K/V caches: `x` is the `(b, d)` activation row at position
/// `pos`, `kcache`/`vcache` are `(b, t_max, d)` with rows `0..pos` filled.
/// Returns `(h (b,d), knew (b,d), vnew (b,d))`.
///
/// Every sub-step (LayerNorm, the attention row, FFN, the residual
/// combine) is row-local, so `h` is bit-identical to row `pos` of
/// [`block_h`] with `causal = true` over the full prefix — the decode
/// invariant `tests/generate.rs` enforces.
#[allow(clippy::too_many_arguments)]
pub fn block_decode_row(
    w: &BlockW,
    x: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    b: usize,
    pos: usize,
    t_max: usize,
    d: usize,
    heads: usize,
    ratio: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let rows = b;
    let dr = d * ratio;
    let (xn, ln1) = ln_fwd(w.ln1_scale, w.ln1_bias, x, rows, d);
    let (a, knew, vnew) = crate::kernels::attn_decode(
        &w.attn, &xn, kcache, vcache, b, pos, t_max, d, heads,
    );
    workspace::give(xn);
    ln1.recycle();
    let u = add(x, &a);
    workspace::give(a);
    let (zn, ln2) = ln_fwd(w.ln2_scale, w.ln2_bias, &u, rows, d);
    let (f, ffn) = ffn_fwd(w.ffn_w1, w.ffn_b1, w.ffn_w2, w.ffn_b2, &zn, rows, d, dr);
    workspace::give(zn);
    ln2.recycle();
    ffn.recycle();
    // h = u + f - x (same element-order as block_fwd_cached)
    let mut h = u;
    add_into(&mut h, &f);
    workspace::give(f);
    for (hv, xv) in h.iter_mut().zip(x) {
        *hv -= *xv;
    }
    (h, knew, vnew)
}

/// Per-leaf parameter gradients of one block, emitted in flatten order.
pub struct BlockGrads {
    attn: AttnGrads,
    ffn_b1: Vec<f32>,
    ffn_b2: Vec<f32>,
    ffn_w1: Vec<f32>,
    ffn_w2: Vec<f32>,
    ln1_bias: Vec<f32>,
    ln1_scale: Vec<f32>,
    ln2_bias: Vec<f32>,
    ln2_scale: Vec<f32>,
    cross: Option<(Vec<f32>, Vec<f32>, AttnGrads)>, // (lnx_bias, lnx_scale, xattn)
}

fn attn_grad_tensors(g: AttnGrads, d: usize) -> Result<Vec<Tensor>> {
    Ok(vec![
        Tensor::from_vec(&[d], g.bk)?,
        Tensor::from_vec(&[d], g.bo)?,
        Tensor::from_vec(&[d], g.bq)?,
        Tensor::from_vec(&[d], g.bv)?,
        Tensor::from_vec(&[d, d], g.wk)?,
        Tensor::from_vec(&[d, d], g.wo)?,
        Tensor::from_vec(&[d, d], g.wq)?,
        Tensor::from_vec(&[d, d], g.wv)?,
    ])
}

impl BlockGrads {
    /// Tensors in block leaf order (the block_vjp output tail).
    pub fn into_leaf_tensors(self, d: usize, ratio: usize) -> Result<Vec<Tensor>> {
        let dr = d * ratio;
        let mut out = attn_grad_tensors(self.attn, d)?;
        out.push(Tensor::from_vec(&[dr], self.ffn_b1)?);
        out.push(Tensor::from_vec(&[d], self.ffn_b2)?);
        out.push(Tensor::from_vec(&[d, dr], self.ffn_w1)?);
        out.push(Tensor::from_vec(&[dr, d], self.ffn_w2)?);
        out.push(Tensor::from_vec(&[d], self.ln1_bias)?);
        out.push(Tensor::from_vec(&[d], self.ln1_scale)?);
        out.push(Tensor::from_vec(&[d], self.ln2_bias)?);
        out.push(Tensor::from_vec(&[d], self.ln2_scale)?);
        if let Some((lnx_bias, lnx_scale, xattn)) = self.cross {
            out.push(Tensor::from_vec(&[d], lnx_bias)?);
            out.push(Tensor::from_vec(&[d], lnx_scale)?);
            out.extend(attn_grad_tensors(xattn, d)?);
        }
        Ok(out)
    }
}

/// Fused block VJP: recompute the forward, then back-propagate `g`.
/// Returns `(h, dx, dmem, grads)` — the `block_vjp` executable contract.
pub fn block_vjp(
    w: &BlockW,
    x: &[f32],
    mem: Option<&[f32]>,
    g: &[f32],
    dims: BlockDims,
) -> Result<(Vec<f32>, Vec<f32>, Option<Vec<f32>>, BlockGrads)> {
    let rows = dims.b * dims.t;
    let d = dims.d;
    let dr = d * dims.ratio;
    let (h, cache) = block_fwd_cached(w, x, mem, dims);

    // h = u2 + f - x ;   df = g
    let (dzn, ffn_w1_g, ffn_b1_g, ffn_w2_g, ffn_b2_g) = ffn_bwd(
        w.ffn_w1, w.ffn_w2, &cache.zn, &cache.ffn, g, rows, d, dr,
    );
    let (du2_ln, ln2_bias_dscale) = {
        let (dx2, dscale, dbias) = ln_bwd(w.ln2_scale, &cache.ln2, &dzn, rows, d);
        (dx2, (dbias, dscale))
    };
    workspace::give(dzn);
    // du2 = g (residual term) + LN2 chain
    let mut du2 = g.to_vec();
    add_into(&mut du2, &du2_ln);
    workspace::give(du2_ln);

    let (mut du, dmem, cross_grads) = if let Some(cc) = &cache.cross {
        let xw = w.xattn.as_ref().expect("xattn");
        let m = mem.expect("mem");
        let (dun, dm, xattn_g) = attn_bwd(
            xw, &cc.un, m, &cc.xattn, &du2, dims.b, dims.t, dims.t_src, d,
            dims.heads,
        );
        let (du_ln, lnx_dscale, lnx_dbias) = {
            let (dxl, dscale, dbias) =
                ln_bwd(w.lnx_scale.expect("lnx"), &cc.lnx, &dun, rows, d);
            (dxl, dscale, dbias)
        };
        workspace::give(dun);
        // u2 = u + c: c-path through lnx, plus the direct residual du2
        let mut du = du2.clone();
        add_into(&mut du, &du_ln);
        workspace::give(du_ln);
        (du, Some(dm), Some((lnx_dbias, lnx_dscale, xattn_g)))
    } else {
        // no cross branch: du == du2, move it (hot path — one full
        // activation buffer per block per backward step)
        (du2, None, None)
    };

    // u = x + a ;  da = du
    let (dxn_q, dxn_kv, attn_g) = attn_bwd(
        &w.attn, &cache.xn, &cache.xn, &cache.attn, &du, dims.b, dims.t, dims.t,
        d, dims.heads,
    );
    let mut dxn = dxn_q;
    add_into(&mut dxn, &dxn_kv);
    workspace::give(dxn_kv);
    let (dx_ln1, ln1_dscale, ln1_dbias) = {
        let (dxl, dscale, dbias) = ln_bwd(w.ln1_scale, &cache.ln1, &dxn, rows, d);
        (dxl, dscale, dbias)
    };
    workspace::give(dxn);

    // dx = du (u = x + a)  +  ln1 chain  -  g (the explicit -x in h)
    let mut dx = std::mem::take(&mut du);
    add_into(&mut dx, &dx_ln1);
    workspace::give(dx_ln1);
    for (dv, gv) in dx.iter_mut().zip(g) {
        *dv -= *gv;
    }
    cache.recycle();

    let (ln2_dbias, ln2_dscale) = ln2_bias_dscale;
    let grads = BlockGrads {
        attn: attn_g,
        ffn_b1: ffn_b1_g,
        ffn_b2: ffn_b2_g,
        ffn_w1: ffn_w1_g,
        ffn_w2: ffn_w2_g,
        ln1_bias: ln1_dbias,
        ln1_scale: ln1_dscale,
        ln2_bias: ln2_dbias,
        ln2_scale: ln2_dscale,
        cross: cross_grads,
    };
    Ok((h, dx, dmem, grads))
}

// ---------------------------------------------------------------------------
// RevViT sub-branches: F = attn(ln1(.)), G = ffn(ln2(.))
// ---------------------------------------------------------------------------

/// attn_fwd executable: attention over ln1-normalised input.
pub fn attn_branch_fwd(w: &BlockW, x: &[f32], dims: BlockDims) -> Vec<f32> {
    let rows = dims.b * dims.t;
    let (xn, ln1) = ln_fwd(w.ln1_scale, w.ln1_bias, x, rows, dims.d);
    let (out, cache) = attn_fwd(
        &w.attn, &xn, &xn, dims.b, dims.t, dims.t, dims.d, dims.heads,
        dims.causal,
    );
    workspace::give(xn);
    ln1.recycle();
    cache.recycle();
    out
}

/// attn_vjp executable: (out, dx, grads over ALL block leaves — zeros for
/// the untouched ffn/ln2 leaves, mirroring jax `keep_unused`).
pub fn attn_branch_vjp(
    w: &BlockW,
    x: &[f32],
    g: &[f32],
    dims: BlockDims,
) -> Result<(Vec<f32>, Vec<f32>, BlockGrads)> {
    let rows = dims.b * dims.t;
    let d = dims.d;
    let dr = d * dims.ratio;
    let (xn, ln1) = ln_fwd(w.ln1_scale, w.ln1_bias, x, rows, d);
    let (out, cache) = attn_fwd(
        &w.attn, &xn, &xn, dims.b, dims.t, dims.t, d, dims.heads, dims.causal,
    );
    let (dxn_q, dxn_kv, attn_g) =
        attn_bwd(&w.attn, &xn, &xn, &cache, g, dims.b, dims.t, dims.t, d, dims.heads);
    cache.recycle();
    workspace::give(xn);
    let mut dxn = dxn_q;
    add_into(&mut dxn, &dxn_kv);
    workspace::give(dxn_kv);
    let (dx, ln1_dscale, ln1_dbias) = ln_bwd(w.ln1_scale, &ln1, &dxn, rows, d);
    workspace::give(dxn);
    ln1.recycle();
    let grads = BlockGrads {
        attn: attn_g,
        ffn_b1: vec![0.0; dr],
        ffn_b2: vec![0.0; d],
        ffn_w1: vec![0.0; d * dr],
        ffn_w2: vec![0.0; dr * d],
        ln1_bias: ln1_dbias,
        ln1_scale: ln1_dscale,
        ln2_bias: vec![0.0; d],
        ln2_scale: vec![0.0; d],
        cross: None,
    };
    Ok((out, dx, grads))
}

/// ffn_fwd executable: FFN over ln2-normalised input.
pub fn ffn_branch_fwd(w: &BlockW, x: &[f32], dims: BlockDims) -> Vec<f32> {
    let rows = dims.b * dims.t;
    let dr = dims.d * dims.ratio;
    let (zn, ln2) = ln_fwd(w.ln2_scale, w.ln2_bias, x, rows, dims.d);
    let (out, ffn) =
        ffn_fwd(w.ffn_w1, w.ffn_b1, w.ffn_w2, w.ffn_b2, &zn, rows, dims.d, dr);
    workspace::give(zn);
    ln2.recycle();
    ffn.recycle();
    out
}

/// ffn_vjp executable (zeros for attn/ln1 leaves).
pub fn ffn_branch_vjp(
    w: &BlockW,
    x: &[f32],
    g: &[f32],
    dims: BlockDims,
) -> Result<(Vec<f32>, Vec<f32>, BlockGrads)> {
    let rows = dims.b * dims.t;
    let d = dims.d;
    let dr = d * dims.ratio;
    let (zn, ln2) = ln_fwd(w.ln2_scale, w.ln2_bias, x, rows, d);
    let (out, cache) =
        ffn_fwd(w.ffn_w1, w.ffn_b1, w.ffn_w2, w.ffn_b2, &zn, rows, d, dr);
    let (dzn, dw1, db1, dw2, db2) =
        ffn_bwd(w.ffn_w1, w.ffn_w2, &zn, &cache, g, rows, d, dr);
    cache.recycle();
    workspace::give(zn);
    let (dx, ln2_dscale, ln2_dbias) = ln_bwd(w.ln2_scale, &ln2, &dzn, rows, d);
    workspace::give(dzn);
    ln2.recycle();
    let grads = BlockGrads {
        attn: AttnGrads {
            wq: vec![0.0; d * d],
            bq: vec![0.0; d],
            wk: vec![0.0; d * d],
            bk: vec![0.0; d],
            wv: vec![0.0; d * d],
            bv: vec![0.0; d],
            wo: vec![0.0; d * d],
            bo: vec![0.0; d],
        },
        ffn_b1: db1,
        ffn_b2: db2,
        ffn_w1: dw1,
        ffn_w2: dw2,
        ln1_bias: vec![0.0; d],
        ln1_scale: vec![0.0; d],
        ln2_bias: ln2_dbias,
        ln2_scale: ln2_dscale,
        cross: None,
    };
    Ok((out, dx, grads))
}

// ---------------------------------------------------------------------------
// fused quantized BDIA stack inference (eqs. 18, 19, 21/22)
// ---------------------------------------------------------------------------

/// Quantized stack inference with constant gamma, shared by all families.
pub fn stack_infer(
    blocks: &[&[&Tensor]],
    x0: Tensor,
    gamma: f32,
    bd: BlockDims,
    cross: bool,
    mem: Option<&Tensor>,
    f: Fixed,
) -> Result<Tensor> {
    let shape = x0.shape().to_vec();
    let mut x = x0;
    quant::quantize_activation(&mut x, f); // eq. 18
    let w0 = BlockW::from_leaves(blocks[0], cross)?;
    let h0 = block_h(&w0, x.data(), mem.map(|m| m.data()), bd);
    let h0t = Tensor::from_vec(&shape, h0)?;
    let x1 = quant::first_step_quant(&x, &h0t, f)?; // eq. 19
    let (mut x_prev, mut x_cur) = (x, x1);
    for leaves in blocks.iter().skip(1) {
        let wk = BlockW::from_leaves(leaves, cross)?;
        let h = block_h(&wk, x_cur.data(), mem.map(|m| m.data()), bd);
        // eq. 21 with constant gamma (gamma = 0 collapses to eq. 22)
        let xp = x_prev.data();
        let xc = x_cur.data();
        let mut nxt = workspace::take(h.len());
        // elementwise: each output element depends on one index only, so
        // the row-partitioned pool applies (grain keeps tiny dims serial)
        crate::kernels::pool::for_rows(&mut nxt, 1, 1 << 12, |i0, chunk| {
            for (off, nv) in chunk.iter_mut().enumerate() {
                let i = i0 + off;
                // NOTE: t1 uses plain round-half-away quantization, matching
                // the inference kernel (`kernels/bdia_update.py::_bdia_kernel`)
                // — NOT the training combine's eq.-23 parity division, which
                // needs the side bit that only exists during training.  At
                // gamma = +/-0.5 the two can differ by one grid step on odd
                // negative unit counts; this is the paper's intended
                // inference semantics (eq. 22 at gamma = 0 is unaffected).
                let t1 = f.quantize(gamma * xp[i]);
                let t2 = f.quantize((1.0 - gamma) * xc[i] + (1.0 + gamma) * h[i]);
                *nv = t1 + t2;
            }
        });
        workspace::give(h);
        x_prev = x_cur;
        x_cur = Tensor::from_vec(&shape, nxt)?;
    }
    Ok(x_cur)
}

// ---------------------------------------------------------------------------
// head + loss
// ---------------------------------------------------------------------------

/// Leaves: [b (out), ln_f.bias (d), ln_f.scale (d), w (d,out)].
struct HeadW<'a> {
    b: &'a [f32],
    ln_bias: &'a [f32],
    ln_scale: &'a [f32],
    w: &'a [f32],
}

fn head_view<'a>(leaves: &[&'a Tensor]) -> Result<HeadW<'a>> {
    ensure!(leaves.len() == 4, "head expects 4 leaves");
    Ok(HeadW {
        b: leaves[0].data(),
        ln_bias: leaves[1].data(),
        ln_scale: leaves[2].data(),
        w: leaves[3].data(),
    })
}

/// CE of one logits row, filling `probs` with the row's softmax: returns
/// `(-log p[y], argmax == y)`.  This is THE per-row scoring kernel — the
/// scalar head ([`ce_rows`]) and the per-example serving head
/// ([`head_loss_fwd_ex`]) both call it, which is what makes their per-row
/// values bit-identical by construction (the serving batcher's
/// bit-exactness contract).
fn ce_row(lr: &[f32], y: usize, probs: &mut [f32]) -> (f64, bool) {
    let mut m = lr[0];
    let mut argmax = 0usize;
    for (c, &v) in lr.iter().enumerate() {
        if v > m {
            m = v;
            argmax = c;
        }
    }
    let mut denom = 0.0f32;
    for (p, &v) in probs.iter_mut().zip(lr) {
        *p = (v - m).exp();
        denom += *p;
    }
    for p in probs.iter_mut() {
        *p /= denom;
    }
    let logp = (lr[y] - m) - denom.ln();
    (-(logp as f64), argmax == y)
}

/// Softmax cross-entropy over logits rows; returns (loss, ncorrect,
/// per-row softmax) — softmax retained for the VJP.
///
/// Rows score in parallel (each row's softmax is row-local); the loss and
/// correct-count reductions then run serially in row order, so the
/// scalars are bit-identical at any thread count.
fn ce_rows(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    n_out: usize,
) -> (f32, f32, Vec<f32>) {
    use crate::kernels::pool;
    let mut probs = workspace::take(rows * n_out);
    let mut row_loss = vec![0.0f64; rows];
    let mut row_hit = vec![false; rows];
    let parts = pool::n_tasks(rows, crate::kernels::matmul::row_grain(4 * n_out));
    if parts <= 1 {
        for r in 0..rows {
            let lr = &logits[r * n_out..(r + 1) * n_out];
            let (l, hit) =
                ce_row(lr, labels[r] as usize, &mut probs[r * n_out..(r + 1) * n_out]);
            row_loss[r] = l;
            row_hit[r] = hit;
        }
    } else {
        let ps = pool::split_rows_mut(&mut probs, n_out, parts);
        let ls = pool::split_rows_mut(&mut row_loss, 1, parts);
        let hs = pool::split_rows_mut(&mut row_hit, 1, parts);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ps
            .into_iter()
            .zip(ls)
            .zip(hs)
            .map(|((mut cp, mut cl), mut ch)| {
                Box::new(move || {
                    for li in 0..cl.rows.len() {
                        let r = cp.row0 + li;
                        let lr = &logits[r * n_out..(r + 1) * n_out];
                        let (l, hit) = ce_row(
                            lr,
                            labels[r] as usize,
                            &mut cp.rows[li * n_out..(li + 1) * n_out],
                        );
                        cl.rows[li] = l;
                        ch.rows[li] = hit;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_tasks(tasks);
    }
    // serial reductions, r ascending (bit contract)
    let mut loss = 0.0f64;
    let mut ncorrect = 0.0f32;
    for r in 0..rows {
        loss += row_loss[r];
        if row_hit[r] {
            ncorrect += 1.0;
        }
    }
    ((loss / rows as f64) as f32, ncorrect, probs)
}

/// Shared head prefix: LN → (ViT: cls-token select) → projection.  Both
/// the scalar and per-example heads score these identical logits, so their
/// per-row results can only differ in the final reduction.
fn head_logits(
    w: &HeadW,
    x: &Tensor,
    family: Family,
    b: usize,
    t: usize,
    d: usize,
    n_out: usize,
) -> (Vec<f32>, usize) {
    let rows_all = b * t;
    let (z, ln) = ln_fwd(w.ln_scale, w.ln_bias, x.data(), rows_all, d);
    ln.recycle();
    let (zc, rows): (Vec<f32>, usize) = if family == Family::Vit {
        // cls token only
        let mut out = workspace::take(b * d);
        for bi in 0..b {
            out[bi * d..(bi + 1) * d]
                .copy_from_slice(&z[bi * t * d..bi * t * d + d]);
        }
        workspace::give(z);
        (out, b)
    } else {
        (z, rows_all)
    };
    let logits = linear(&zc, w.w, w.b, rows, d, n_out);
    workspace::give(zc);
    (logits, rows)
}

/// Raw head logits over all rows, no loss reduction: LN → (ViT: cls
/// select) → projection, shape `(rows, n_out)`.  The decode step and the
/// full-prefix reference forward both score through this function, so
/// their logits agree bit-for-bit by construction.
pub fn head_logits_rows(
    leaves: &[&Tensor],
    x: &Tensor,
    family: Family,
    b: usize,
    t: usize,
    d: usize,
    n_out: usize,
) -> Result<Tensor> {
    let w = head_view(leaves)?;
    let (logits, rows) = head_logits(&w, x, family, b, t, d, n_out);
    Tensor::from_vec(&[rows, n_out], logits)
}

/// head_loss_fwd: (mean CE loss, #correct), both scalars.
pub fn head_loss_fwd(
    leaves: &[&Tensor],
    x: &Tensor,
    labels: &IntTensor,
    family: Family,
    b: usize,
    t: usize,
    d: usize,
    n_out: usize,
) -> Result<Vec<Tensor>> {
    let w = head_view(leaves)?;
    let (logits, rows) = head_logits(&w, x, family, b, t, d, n_out);
    let (loss, ncorrect, probs) = ce_rows(&logits, labels.data(), rows, n_out);
    workspace::give(logits);
    workspace::give(probs);
    Ok(vec![Tensor::scalar(loss), Tensor::scalar(ncorrect)])
}

/// head_loss_fwd_ex: per-example (mean CE loss, #correct), each of shape
/// `[b]`.  Every output element is a function of that example's rows alone
/// (LayerNorm, the head projection and softmax are all row-local), so the
/// result is invariant to which batch slot the example occupies and to what
/// the other slots contain — the bit-exactness contract the serving
/// micro-batcher relies on.
pub fn head_loss_fwd_ex(
    leaves: &[&Tensor],
    x: &Tensor,
    labels: &IntTensor,
    family: Family,
    b: usize,
    t: usize,
    d: usize,
    n_out: usize,
) -> Result<Vec<Tensor>> {
    let w = head_view(leaves)?;
    let (logits, rows) = head_logits(&w, x, family, b, t, d, n_out);
    let rows_per_ex = rows / b;
    let lab = labels.data();
    ensure!(lab.len() == rows, "labels/rows mismatch: {} vs {rows}", lab.len());
    let mut loss = vec![0.0f32; b];
    let mut correct = vec![0.0f32; b];
    let mut probs_scratch = workspace::take(n_out);
    for bi in 0..b {
        let mut lsum = 0.0f64;
        let mut ncorrect = 0.0f32;
        for ri in 0..rows_per_ex {
            let r = bi * rows_per_ex + ri;
            let lr = &logits[r * n_out..(r + 1) * n_out];
            let (l, hit) = ce_row(lr, lab[r] as usize, &mut probs_scratch);
            lsum += l;
            if hit {
                ncorrect += 1.0;
            }
        }
        loss[bi] = (lsum / rows_per_ex as f64) as f32;
        correct[bi] = ncorrect;
    }
    workspace::give(logits);
    workspace::give(probs_scratch);
    Ok(vec![
        Tensor::from_vec(&[b], loss)?,
        Tensor::from_vec(&[b], correct)?,
    ])
}

/// head_loss_vjp: (dL/dx, db, dln_bias, dln_scale, dw) with loss seed 1.
pub fn head_loss_vjp(
    leaves: &[&Tensor],
    x: &Tensor,
    labels: &IntTensor,
    family: Family,
    b: usize,
    t: usize,
    d: usize,
    n_out: usize,
) -> Result<Vec<Tensor>> {
    let w = head_view(leaves)?;
    let rows_all = b * t;
    let (z, ln_cache) = ln_fwd(w.ln_scale, w.ln_bias, x.data(), rows_all, d);
    let (zc, rows): (Vec<f32>, usize) = if family == Family::Vit {
        let mut out = workspace::take(b * d);
        for bi in 0..b {
            out[bi * d..(bi + 1) * d]
                .copy_from_slice(&z[bi * t * d..bi * t * d + d]);
        }
        workspace::give(z);
        (out, b)
    } else {
        (z, rows_all)
    };
    let logits = linear(&zc, w.w, w.b, rows, d, n_out);
    let (_, _, probs) = ce_rows(&logits, labels.data(), rows, n_out);
    workspace::give(logits);

    // dlogits = (softmax - onehot) / rows
    let mut dlogits = probs;
    let inv_n = 1.0 / rows as f32;
    for (r, &y) in labels.data().iter().enumerate() {
        let row = &mut dlogits[r * n_out..(r + 1) * n_out];
        row[y as usize] -= 1.0;
        for v in row.iter_mut() {
            *v *= inv_n;
        }
    }
    let dw = matmul_tn(&zc, &dlogits, rows, d, n_out);
    let db = col_sum(&dlogits, rows, n_out);
    let dzc = matmul_nt_w(&dlogits, w.w, rows, n_out, d);
    workspace::give(dlogits);
    workspace::give(zc);

    // scatter back to full (b*t, d) rows for the ln_f backward
    let dz: Vec<f32> = if family == Family::Vit {
        let mut out = workspace::take(rows_all * d);
        for bi in 0..b {
            out[bi * t * d..bi * t * d + d]
                .copy_from_slice(&dzc[bi * d..(bi + 1) * d]);
        }
        workspace::give(dzc);
        out
    } else {
        dzc
    };
    let (dx, dln_scale, dln_bias) = ln_bwd(w.ln_scale, &ln_cache, &dz, rows_all, d);
    workspace::give(dz);
    ln_cache.recycle();

    Ok(vec![
        Tensor::from_vec(x.shape(), dx)?,
        Tensor::from_vec(&[n_out], db)?,
        Tensor::from_vec(&[d], dln_bias)?,
        Tensor::from_vec(&[d], dln_scale)?,
        Tensor::from_vec(&[d, n_out], dw)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn ce_loss_uniform_logits_is_log_n() {
        let n_out = 8;
        let logits = vec![0.0f32; 2 * n_out];
        let (loss, _, probs) = ce_rows(&logits, &[3, 5], 2, n_out);
        assert!((loss - (n_out as f32).ln()).abs() < 1e-5);
        for &p in &probs {
            assert!((p - 1.0 / n_out as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn ffn_bwd_matches_finite_difference() {
        let mut rng = Rng::new(4);
        let (rows, d, dr) = (3usize, 4usize, 8usize);
        let rv = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() * 0.5).collect()
        };
        let w1 = rv(&mut rng, d * dr);
        let b1 = rv(&mut rng, dr);
        let w2 = rv(&mut rng, dr * d);
        let b2 = rv(&mut rng, d);
        let x = rv(&mut rng, rows * d);
        let g = rv(&mut rng, rows * d);
        let (_, cache) = ffn_fwd(&w1, &b1, &w2, &b2, &x, rows, d, dr);
        let (dx, dw1, _, _, _) = ffn_bwd(&w1, &w2, &x, &cache, &g, rows, d, dr);

        let probe = |xs: &[f32], w1s: &[f32]| -> f64 {
            let (y, c) = ffn_fwd(w1s, &b1, &w2, &b2, xs, rows, d, dr);
            let s = y.iter().zip(&g).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            c.recycle();
            s
        };
        let eps = 1e-2f32;
        for idx in [0usize, 5, rows * d - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = ((probe(&xp, &w1) - probe(&xm, &w1)) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - dx[idx]).abs() < 2e-2 * dx[idx].abs().max(0.5),
                "dx[{idx}] fd {fd} vs {}",
                dx[idx]
            );
        }
        for idx in [0usize, 7, d * dr - 1] {
            let mut wp = w1.clone();
            wp[idx] += eps;
            let mut wm = w1.clone();
            wm[idx] -= eps;
            let fd = ((probe(&x, &wp) - probe(&x, &wm)) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - dw1[idx]).abs() < 2e-2 * dw1[idx].abs().max(0.5),
                "dw1[{idx}] fd {fd} vs {}",
                dw1[idx]
            );
        }
    }
}
