//! GPT family: token + position embedding (forward + VJP) and the fused
//! quantized LM inference, on top of [`super::blocks`].
//!
//! The token-embedding pair is shared: the encoder-decoder family
//! ([`super::encdec`]) embeds its source and target streams through the
//! same functions.

use super::blocks;
use crate::quant::Fixed;
use crate::tensor::{IntTensor, Tensor};
use anyhow::{ensure, Result};

/// Token embed forward (gpt / encdec decoder / encoder).  Leaves:
/// [wpe (t_max,d), wte (V,d)].
pub fn embed_fwd(
    leaves: &[&Tensor],
    tokens: &IntTensor,
    b: usize,
    t: usize,
    d: usize,
    vocab: usize,
) -> Result<Tensor> {
    ensure!(leaves.len() == 2, "token embed expects 2 leaves");
    let (wpe, wte) = (leaves[0].data(), leaves[1].data());
    ensure!(wpe.len() >= t * d, "wpe too small for sequence length {t}");
    let ids = tokens.data();
    let mut out = vec![0.0f32; b * t * d];
    for bi in 0..b {
        for ti in 0..t {
            let id = ids[bi * t + ti];
            ensure!(
                (0..vocab as i32).contains(&id),
                "token id {id} out of vocab range {vocab}"
            );
            let dst = (bi * t + ti) * d;
            let te = &wte[id as usize * d..(id as usize + 1) * d];
            let pe = &wpe[ti * d..(ti + 1) * d];
            for j in 0..d {
                out[dst + j] = te[j] + pe[j];
            }
        }
    }
    Tensor::from_vec(&[b, t, d], out)
}

/// Token embed VJP (parameter grads only).
pub fn embed_vjp(
    leaves: &[&Tensor],
    tokens: &IntTensor,
    g: &Tensor,
    b: usize,
    t: usize,
    d: usize,
    vocab: usize,
) -> Result<Vec<Tensor>> {
    ensure!(leaves.len() == 2, "token embed expects 2 leaves");
    let t_max = leaves[0].shape()[0];
    let gd = g.data();
    let ids = tokens.data();
    let mut dwpe = vec![0.0f32; t_max * d];
    let mut dwte = vec![0.0f32; vocab * d];
    for bi in 0..b {
        for ti in 0..t {
            let src = (bi * t + ti) * d;
            let id = ids[bi * t + ti] as usize;
            for j in 0..d {
                let v = gd[src + j];
                dwpe[ti * d + j] += v;
                dwte[id * d + j] += v;
            }
        }
    }
    Ok(vec![
        Tensor::from_vec(&[t_max, d], dwpe)?,
        Tensor::from_vec(&[vocab, d], dwte)?,
    ])
}

/// Fused quantized inference for the GPT family: embed → BDIA stack →
/// head reduction (scalar or per-example).
pub(super) fn model_infer(
    ex: &super::NativeExec,
    params: &[&Tensor],
    data: &[crate::runtime::ArgValue],
    per_example: bool,
) -> Result<Vec<Tensor>> {
    let d = ex.dims.d_model;
    let b = ex.dims.batch;
    let f = Fixed::new(ex.dims.lbits);
    let toks = super::want_i32(data, 0, "tokens")?;
    let labels = super::want_i32(data, 1, "labels")?;
    let gamma = super::want_scalar(data, 2, "gamma")?;
    let (em, tower, hd) = ex.split_single_tower(params);
    let x0 = embed_fwd(em, toks, b, ex.dims.seq, d, ex.dims.vocab)?;
    let xk = blocks::stack_infer(
        &tower, x0, gamma, ex.main_block_dims(), false, None, f,
    )?;
    ex.head_reduce(hd, &xk, labels, per_example)
}
