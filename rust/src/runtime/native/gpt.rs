//! GPT family: token + position embedding (forward + VJP) and the fused
//! quantized LM inference, on top of [`super::blocks`].
//!
//! The token-embedding pair is shared: the encoder-decoder family
//! ([`super::encdec`]) embeds its source and target streams through the
//! same functions.

use super::blocks;
use crate::model::Family;
use crate::quant::{self, Fixed};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{ensure, Result};

/// Token embed forward (gpt / encdec decoder / encoder).  Leaves:
/// [wpe (t_max,d), wte (V,d)].
pub fn embed_fwd(
    leaves: &[&Tensor],
    tokens: &IntTensor,
    b: usize,
    t: usize,
    d: usize,
    vocab: usize,
) -> Result<Tensor> {
    ensure!(leaves.len() == 2, "token embed expects 2 leaves");
    let (wpe, wte) = (leaves[0].data(), leaves[1].data());
    ensure!(wpe.len() >= t * d, "wpe too small for sequence length {t}");
    let ids = tokens.data();
    let mut out = vec![0.0f32; b * t * d];
    for bi in 0..b {
        for ti in 0..t {
            let id = ids[bi * t + ti];
            ensure!(
                (0..vocab as i32).contains(&id),
                "token id {id} out of vocab range {vocab}"
            );
            let dst = (bi * t + ti) * d;
            let te = &wte[id as usize * d..(id as usize + 1) * d];
            let pe = &wpe[ti * d..(ti + 1) * d];
            for j in 0..d {
                out[dst + j] = te[j] + pe[j];
            }
        }
    }
    Tensor::from_vec(&[b, t, d], out)
}

/// Token embed VJP (parameter grads only).
pub fn embed_vjp(
    leaves: &[&Tensor],
    tokens: &IntTensor,
    g: &Tensor,
    b: usize,
    t: usize,
    d: usize,
    vocab: usize,
) -> Result<Vec<Tensor>> {
    ensure!(leaves.len() == 2, "token embed expects 2 leaves");
    let t_max = leaves[0].shape()[0];
    let gd = g.data();
    let ids = tokens.data();
    let mut dwpe = vec![0.0f32; t_max * d];
    let mut dwte = vec![0.0f32; vocab * d];
    for bi in 0..b {
        for ti in 0..t {
            let src = (bi * t + ti) * d;
            let id = ids[bi * t + ti] as usize;
            for j in 0..d {
                let v = gd[src + j];
                dwpe[ti * d + j] += v;
                dwte[id * d + j] += v;
            }
        }
    }
    Ok(vec![
        Tensor::from_vec(&[t_max, d], dwpe)?,
        Tensor::from_vec(&[vocab, d], dwte)?,
    ])
}

/// Fused quantized inference for the GPT family: embed → BDIA stack →
/// head reduction (scalar or per-example).
pub(super) fn model_infer(
    ex: &super::NativeExec,
    params: &[&Tensor],
    data: &[crate::runtime::ArgValue],
    per_example: bool,
) -> Result<Vec<Tensor>> {
    let d = ex.dims.d_model;
    let b = ex.dims.batch;
    let f = Fixed::new(ex.dims.lbits);
    let toks = super::want_i32(data, 0, "tokens")?;
    let labels = super::want_i32(data, 1, "labels")?;
    let gamma = super::want_scalar(data, 2, "gamma")?;
    let (em, tower, hd) = ex.split_single_tower(params);
    let x0 = embed_fwd(em, toks, b, ex.dims.seq, d, ex.dims.vocab)?;
    let xk = blocks::stack_infer(
        &tower, x0, gamma, ex.main_block_dims(), false, None, f,
    )?;
    ex.head_reduce(hd, &xk, labels, per_example)
}

/// Read an exact-integer runtime scalar in `1..=max` (prefix lengths, lane
/// counts).
fn want_count(
    data: &[crate::runtime::ArgValue],
    i: usize,
    what: &str,
    max: usize,
) -> Result<usize> {
    let v = super::want_scalar(data, i, what)?;
    ensure!(
        v >= 1.0 && v.fract() == 0.0 && v <= max as f32,
        "{what} must be an integer in 1..={max}, got {v}"
    );
    Ok(v as usize)
}

/// Full-prefix quantized forward returning raw logits `(batch, seq,
/// vocab)` — the reference side of the decode bit-identity invariant, and
/// the prompt-scoring path.  Only the first `len` positions of each lane
/// are forwarded (the declared tokens shape is the maximum); logits rows
/// at `t >= len` stay zero.
pub(super) fn model_logits(
    ex: &super::NativeExec,
    params: &[&Tensor],
    data: &[crate::runtime::ArgValue],
) -> Result<Vec<Tensor>> {
    let d = ex.dims.d_model;
    let b = ex.dims.batch;
    let seq = ex.dims.seq;
    let vocab = ex.dims.vocab;
    let f = Fixed::new(ex.dims.lbits);
    let toks = super::want_i32(data, 0, "tokens")?;
    let t = want_count(data, 1, "len", seq)?;
    let gamma = super::want_scalar(data, 2, "gamma")?;
    // gather the (b, t) prefix out of the (b, seq) tokens buffer
    let ids = toks.data();
    let mut prefix = Vec::with_capacity(b * t);
    for bi in 0..b {
        prefix.extend_from_slice(&ids[bi * seq..bi * seq + t]);
    }
    let ptoks = IntTensor::from_vec(&[b, t], prefix)?;
    let (em, tower, hd) = ex.split_single_tower(params);
    let x0 = embed_fwd(em, &ptoks, b, t, d, ex.dims.vocab)?;
    let bd = blocks::BlockDims {
        b,
        t,
        t_src: 0,
        d,
        heads: ex.dims.n_heads,
        ratio: ex.dims.mlp_ratio,
        causal: true,
    };
    let xk = blocks::stack_infer(&tower, x0, gamma, bd, false, None, f)?;
    let logits = blocks::head_logits_rows(hd, &xk, Family::Gpt, b, t, d, vocab)?;
    // scatter the (b*t, vocab) rows into the full (b, seq, vocab) output
    let mut out = vec![0.0f32; b * seq * vocab];
    for bi in 0..b {
        let src = bi * t * vocab;
        let dst = bi * seq * vocab;
        out[dst..dst + t * vocab]
            .copy_from_slice(&logits.data()[src..src + t * vocab]);
    }
    Ok(vec![Tensor::from_vec(&[b, seq, vocab], out)?])
}

/// One autoregressive decode position: embed the new token per lane at
/// `pos`, run the quantized BDIA stack (eqs. 18, 19, 21) against
/// caller-owned K/V caches, and score head logits for the new row only.
///
/// Data: `[tokens (batch,), kcache (n_blocks,batch,seq,d), vcache (same),
/// pos scalar, lanes scalar, gamma scalar]`; outputs `[logits
/// (batch,vocab), knew (n_blocks,batch,d), vnew (n_blocks,batch,d)]`.
/// Only the first `lanes` lanes are computed (outputs for the rest stay
/// zero); the caller appends knew/vnew at cache row `pos` before the next
/// step.  Every sub-step is row-local (see [`blocks::block_decode_row`]),
/// so per-lane logits are bit-identical to the last row of
/// [`model_logits`] over the same prefix at any thread count, kernel
/// profile and lane packing.
pub(super) fn decode_step(
    ex: &super::NativeExec,
    params: &[&Tensor],
    data: &[crate::runtime::ArgValue],
) -> Result<Vec<Tensor>> {
    let d = ex.dims.d_model;
    let t_max = ex.dims.seq;
    let batch = ex.dims.batch;
    let heads = ex.dims.n_heads;
    let ratio = ex.dims.mlp_ratio;
    let n_blocks = ex.dims.n_blocks;
    let vocab = ex.dims.vocab;
    let f = Fixed::new(ex.dims.lbits);

    let toks = super::want_i32(data, 0, "tokens")?;
    let kcache = super::want_f32(data, 1, "kcache")?;
    let vcache = super::want_f32(data, 2, "vcache")?;
    let pos_f = super::want_scalar(data, 3, "pos")?;
    let b = want_count(data, 4, "lanes", batch)?;
    let gamma = super::want_scalar(data, 5, "gamma")?;
    ensure!(
        pos_f >= 0.0 && pos_f.fract() == 0.0,
        "pos must be a non-negative integer, got {pos_f}"
    );
    let pos = pos_f as usize;
    ensure!(pos < t_max, "pos {pos} out of range (seq {t_max})");

    let (em, tower, hd) = ex.split_single_tower(params);
    ensure!(em.len() == 2, "token embed expects 2 leaves");
    let (wpe, wte) = (em[0].data(), em[1].data());
    let ids = toks.data();
    // embed the single new row per lane: wte[id] + wpe[pos] — the same fp
    // adds as row (bi, pos) of embed_fwd over the full prefix
    let mut x0 = vec![0.0f32; b * d];
    for bi in 0..b {
        let id = ids[bi];
        ensure!(
            (0..vocab as i32).contains(&id),
            "token id {id} out of vocab range {vocab}"
        );
        let te = &wte[id as usize * d..(id as usize + 1) * d];
        let pe = &wpe[pos * d..(pos + 1) * d];
        for j in 0..d {
            x0[bi * d + j] = te[j] + pe[j];
        }
    }
    f.quantize_slice(&mut x0); // eq. 18

    // lanes are outermost within each block's cache slab, so the first
    // `b` active lanes of block k form the contiguous prefix of its slab
    let blk = batch * t_max * d;
    let active = b * t_max * d;
    let lane = b * d;
    let mut knew_all = vec![0.0f32; n_blocks * batch * d];
    let mut vnew_all = vec![0.0f32; n_blocks * batch * d];

    let x0_t = Tensor::from_vec(&[b, d], x0)?;
    let w0 = blocks::BlockW::from_leaves(tower[0], false)?;
    let (h0, kn, vn) = blocks::block_decode_row(
        &w0,
        x0_t.data(),
        &kcache.data()[..active],
        &vcache.data()[..active],
        b,
        pos,
        t_max,
        d,
        heads,
        ratio,
    );
    knew_all[..lane].copy_from_slice(&kn);
    vnew_all[..lane].copy_from_slice(&vn);
    crate::kernels::workspace::give(kn);
    crate::kernels::workspace::give(vn);
    let h0_t = Tensor::from_vec(&[b, d], h0)?;
    let x1 = quant::first_step_quant(&x0_t, &h0_t, f)?; // eq. 19
    let (mut x_prev, mut x_cur) = (x0_t, x1);
    for (k, leaves) in tower.iter().enumerate().skip(1) {
        let wk = blocks::BlockW::from_leaves(leaves, false)?;
        let (h, kn, vn) = blocks::block_decode_row(
            &wk,
            x_cur.data(),
            &kcache.data()[k * blk..k * blk + active],
            &vcache.data()[k * blk..k * blk + active],
            b,
            pos,
            t_max,
            d,
            heads,
            ratio,
        );
        knew_all[k * batch * d..k * batch * d + lane].copy_from_slice(&kn);
        vnew_all[k * batch * d..k * batch * d + lane].copy_from_slice(&vn);
        crate::kernels::workspace::give(kn);
        crate::kernels::workspace::give(vn);
        // eq. 21 at constant gamma — the identical per-element expression
        // as stack_infer, so decode bits match the full re-forward
        let xp = x_prev.data();
        let xc = x_cur.data();
        let mut nxt = vec![0.0f32; lane];
        for (i, nv) in nxt.iter_mut().enumerate() {
            let t1 = f.quantize(gamma * xp[i]);
            let t2 = f.quantize((1.0 - gamma) * xc[i] + (1.0 + gamma) * h[i]);
            *nv = t1 + t2;
        }
        crate::kernels::workspace::give(h);
        x_prev = x_cur;
        x_cur = Tensor::from_vec(&[b, d], nxt)?;
    }
    let logits = blocks::head_logits_rows(hd, &x_cur, Family::Gpt, b, 1, d, vocab)?;
    let mut logits_all = vec![0.0f32; batch * vocab];
    logits_all[..b * vocab].copy_from_slice(logits.data());
    Ok(vec![
        Tensor::from_vec(&[batch, vocab], logits_all)?,
        Tensor::from_vec(&[n_blocks, batch, d], knew_all)?,
        Tensor::from_vec(&[n_blocks, batch, d], vnew_all)?,
    ])
}
