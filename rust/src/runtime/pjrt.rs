//! PJRT/XLA backend (cargo feature `pjrt`): load AOT HLO-text artifacts,
//! compile once via the PJRT CPU client, execute from the hot loop.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client):
//! `PjRtClient::cpu() -> HloModuleProto::from_text_file -> compile ->
//! execute`.  Python is never on this path — the bundle produced by
//! `make artifacts` is all the Rust binary needs.

use super::{ArgValue, Backend, BackendKind, CompiledExec};
use crate::model::{DType, ExecSpec, Manifest};
use crate::tensor::Tensor;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

pub fn tensor_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

fn arg_literal(arg: &ArgValue) -> Result<xla::Literal> {
    match arg {
        ArgValue::F32(t) => tensor_literal(t),
        ArgValue::I32(t) => {
            let lit = xla::Literal::vec1(t.data());
            let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
        ArgValue::Scalar(v) => Ok(xla::Literal::from(*v)),
    }
}

/// The backend: one PJRT CPU client shared by every compiled executable.
pub struct PjrtBackend {
    client: Arc<xla::PjRtClient>,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client: Arc::new(client) })
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn compile(
        &self,
        _manifest: &Manifest,
        exec_name: &str,
        spec: &ExecSpec,
        dir: &Path,
    ) -> Result<Box<dyn CompiledExec>> {
        let path = dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {exec_name}"))?;
        Ok(Box::new(PjrtExec {
            name: exec_name.to_string(),
            spec: spec.clone(),
            exe,
            _client: Arc::clone(&self.client),
        }))
    }
}

struct PjrtExec {
    name: String,
    spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Keeps the PJRT client alive as long as any executable is.
    _client: Arc<xla::PjRtClient>,
}

// `CompiledExec` requires Send + Sync (the serving worker pool shares the
// runtime across threads).  The PJRT CPU client serializes execution behind
// its own locks; the xla wrapper types do not declare it, so we assert it
// here at the FFI boundary.
unsafe impl Send for PjrtExec {}
unsafe impl Sync for PjrtExec {}

impl CompiledExec for PjrtExec {
    fn execute(&self, params: &[&Tensor], data: &[ArgValue]) -> Result<Vec<Tensor>> {
        let mut lits = Vec::with_capacity(params.len() + data.len());
        for p in params {
            lits.push(tensor_literal(p)?);
        }
        for d in data {
            lits.push(arg_literal(d)?);
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.name))?;
        let parts = result.to_tuple()?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.name,
            self.spec.outputs.len(),
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.spec.outputs) {
            ensure!(
                spec.dtype == DType::F32,
                "{}: only f32 outputs supported, got {:?}",
                self.name,
                spec.dtype
            );
            let v = lit.to_vec::<f32>()?;
            out.push(Tensor::from_vec(&spec.shape, v)?);
        }
        Ok(out)
    }
}
