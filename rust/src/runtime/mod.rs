//! Execution-backend subsystem: compile a bundle's executables once, execute
//! them from the training hot loop.
//!
//! The coordinator is backend-agnostic.  Every executable is described by an
//! [`ExecSpec`] (the manifest ABI shared with `python/compile/aot.py`):
//! inputs = [param leaves in manifest order] ++ [data inputs]; outputs are a
//! tuple of host [`Tensor`]s.  A [`Backend`] turns specs into
//! [`CompiledExec`]s; [`Runtime`] owns the compiled set and dispatches by
//! name.
//!
//! Two backends exist:
//!
//! * [`native`] (default) — a pure-Rust interpreter implementing the
//!   transformer forward and VJP math directly on the host tensor type.
//!   Needs no artifacts on disk: bundle manifests are synthesized from the
//!   in-crate config registry (mirroring `python/compile/aot.py::CONFIGS`).
//! * [`pjrt`] (cargo feature `pjrt`) — the original AOT-HLO path: load
//!   `artifacts/<name>/*.hlo.txt`, compile via the PJRT CPU client, execute.
//!
//! Both backends honour the same calling convention, so `Stack`, `Trainer`
//! and the experiment drivers run unchanged on either.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::config::json::Json;
use crate::model::{ArgSpec, DType, ExecSpec, Manifest};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// A data argument for an executable call.
pub enum ArgValue<'a> {
    F32(&'a Tensor),
    I32(&'a IntTensor),
    /// f32 scalar (e.g. the runtime `gamma` input of `model_infer`).
    Scalar(f32),
}

impl ArgValue<'_> {
    fn matches(&self, spec: &ArgSpec) -> bool {
        match (self, spec.dtype) {
            (ArgValue::F32(t), DType::F32) => t.shape() == &spec.shape[..],
            (ArgValue::I32(t), DType::I32) => t.shape() == &spec.shape[..],
            (ArgValue::Scalar(_), DType::F32) => spec.shape.is_empty(),
            _ => false,
        }
    }
}

/// Which execution backend drives a [`Runtime`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust interpreter (no external deps, no artifacts required).
    #[default]
    Native,
    /// PJRT/XLA executor over AOT HLO artifacts (cargo feature `pjrt`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => BackendKind::Native,
            "pjrt" | "xla" => BackendKind::Pjrt,
            _ => bail!("unknown backend '{s}' (native|pjrt)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// One compiled executable, ready to run.
///
/// `Send + Sync` is part of the contract: a [`Runtime`] is shared across the
/// serving worker pool behind an `Arc`, so every backend's executables must
/// be safe to call from multiple threads (the native interpreter is pure;
/// calls carry no mutable state).
pub trait CompiledExec: Send + Sync {
    /// Execute with `params` (flat leaf tensors, manifest order) and `data`
    /// inputs; returns the output tuple as host tensors.
    fn execute(&self, params: &[&Tensor], data: &[ArgValue]) -> Result<Vec<Tensor>>;
}

/// An execution backend: compiles every [`ExecSpec`] of a bundle manifest
/// into a [`CompiledExec`].
pub trait Backend {
    fn kind(&self) -> BackendKind;

    /// Compile one executable.  `dir` is the bundle's artifact directory —
    /// artifact-backed backends read HLO files from it; the native backend
    /// ignores it.
    fn compile(
        &self,
        manifest: &Manifest,
        exec_name: &str,
        spec: &ExecSpec,
        dir: &Path,
    ) -> Result<Box<dyn CompiledExec>>;
}

/// Map an executable name to the `&'static str` a [`crate::obs`] span
/// requires (span names are interned constants so the hot path never
/// allocates).  Unknown names — custom bundles — fold into `exec_other`.
fn static_op_name(name: &str) -> &'static str {
    match name {
        "embed_fwd" => "embed_fwd",
        "enc_embed_fwd" => "enc_embed_fwd",
        "block_fwd" => "block_fwd",
        "enc_block_fwd" => "enc_block_fwd",
        "block_vjp" => "block_vjp",
        "enc_block_vjp" => "enc_block_vjp",
        "head_loss_fwd" => "head_loss_fwd",
        "head_loss_vjp" => "head_loss_vjp",
        "embed_vjp" => "embed_vjp",
        "enc_embed_vjp" => "enc_embed_vjp",
        "model_infer" => "model_infer",
        "model_infer_ex" => "model_infer_ex",
        "model_decode_step" => "model_decode_step",
        "model_logits" => "model_logits",
        _ => "exec_other",
    }
}

/// One compiled executable plus its ABI spec.
pub struct Exec {
    pub name: String,
    pub spec: ExecSpec,
    imp: Box<dyn CompiledExec>,
    /// Invocation counter (relaxed atomic: concurrent serving workers bump
    /// it; exact ordering does not matter, only the totals).
    pub calls: AtomicU64,
}

impl Exec {
    /// Execute with `params` (flat leaf tensors, manifest order) and `data`.
    /// Returns the output tuple as host tensors (shapes from the manifest).
    pub fn call(&self, params: &[&Tensor], data: &[ArgValue]) -> Result<Vec<Tensor>> {
        ensure!(
            data.len() == self.spec.data_inputs.len(),
            "{}: expected {} data inputs, got {}",
            self.name,
            self.spec.data_inputs.len(),
            data.len()
        );
        for (d, spec) in data.iter().zip(&self.spec.data_inputs) {
            ensure!(
                d.matches(spec),
                "{}: data input '{}' shape/dtype mismatch (want {:?} {:?})",
                self.name,
                spec.name,
                spec.dtype,
                spec.shape
            );
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        let _span = crate::span!(static_op_name(&self.name));
        let outs = self
            .imp
            .execute(params, data)
            .with_context(|| format!("executing {}", self.name))?;
        ensure!(
            outs.len() == self.spec.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.name,
            self.spec.outputs.len(),
            outs.len()
        );
        Ok(outs)
    }
}

/// The per-bundle runtime: a backend plus all compiled executables.
pub struct Runtime {
    pub manifest: Manifest,
    pub backend: BackendKind,
    execs: BTreeMap<String, Exec>,
}

impl Runtime {
    /// Load `artifacts/<bundle>/` with the default (native) backend.
    ///
    /// The native backend prefers an on-disk `manifest.json` (so it can run
    /// bundles exported by `make artifacts`) and falls back to the in-crate
    /// config registry when the artifact directory does not exist — a clean
    /// checkout needs no artifacts at all.
    pub fn load(artifacts_dir: &Path, bundle: &str) -> Result<Self> {
        Self::load_with(artifacts_dir, bundle, BackendKind::default())
    }

    /// Load a bundle with an explicit backend choice.
    pub fn load_with(
        artifacts_dir: &Path,
        bundle: &str,
        kind: BackendKind,
    ) -> Result<Self> {
        // the most actionable error first: asking for pjrt on a build
        // without the feature should not send the user to `make artifacts`
        #[cfg(not(feature = "pjrt"))]
        if kind == BackendKind::Pjrt {
            bail!(
                "this binary was built without the 'pjrt' cargo feature; \
                 rebuild with `--features pjrt` (and the xla dependency \
                 enabled in rust/Cargo.toml) or use --backend native"
            );
        }
        let dir = artifacts_dir.join(bundle);
        let manifest_path = dir.join("manifest.json");
        let manifest = if manifest_path.exists() {
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading manifest in {}", dir.display()))?;
            Manifest::from_json(&Json::parse(&text)?)?
        } else {
            match kind {
                BackendKind::Native => native::registry::manifest_for(bundle)
                    .with_context(|| {
                        format!(
                            "bundle '{bundle}': no artifacts at {} and no native \
                             registry entry",
                            dir.display()
                        )
                    })?,
                BackendKind::Pjrt => bail!(
                    "pjrt backend needs AOT artifacts: {} not found (run `make \
                     artifacts`)",
                    manifest_path.display()
                ),
            }
        };
        Self::from_manifest_with(manifest, &dir, kind)
    }

    /// Build a native runtime directly from a manifest (no filesystem).
    /// Used by tests that synthesize ad-hoc model shapes.
    pub fn from_native_manifest(manifest: Manifest) -> Result<Self> {
        Self::from_manifest_with(manifest, Path::new("."), BackendKind::Native)
    }

    pub fn from_manifest_with(
        manifest: Manifest,
        dir: &Path,
        kind: BackendKind,
    ) -> Result<Self> {
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Native => Box::new(native::NativeBackend),
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => Box::new(pjrt::PjrtBackend::new()?),
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => bail!(
                "this binary was built without the 'pjrt' cargo feature; \
                 rebuild with `--features pjrt` (and the xla dependency \
                 enabled in rust/Cargo.toml) or use --backend native"
            ),
        };
        let mut execs = BTreeMap::new();
        for (name, spec) in &manifest.executables {
            let imp = backend
                .compile(&manifest, name, spec, dir)
                .with_context(|| format!("compiling {name} ({})", kind.name()))?;
            execs.insert(
                name.clone(),
                Exec {
                    name: name.clone(),
                    spec: spec.clone(),
                    imp,
                    calls: AtomicU64::new(0),
                },
            );
        }
        Ok(Runtime { manifest, backend: kind, execs })
    }

    pub fn exec(&self, name: &str) -> Result<&Exec> {
        self.execs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no executable '{name}' in bundle"))
    }

    pub fn has_exec(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    pub fn exec_names(&self) -> impl Iterator<Item = &str> {
        self.execs.keys().map(String::as_str)
    }

    /// Total executable invocations (profiling).
    pub fn total_calls(&self) -> u64 {
        self.execs
            .values()
            .map(|e| e.calls.load(Ordering::Relaxed))
            .sum()
    }

    /// Per-executable invocation counts, sorted by name (`bdia info`,
    /// `/stats`).
    pub fn call_counts(&self) -> Vec<(String, u64)> {
        self.execs
            .iter()
            .map(|(n, e)| (n.clone(), e.calls.load(Ordering::Relaxed)))
            .collect()
    }
}

// The serving worker pool shares one `Arc<Runtime>` across threads; keep the
// bound a compile-time fact rather than a convention.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Runtime>()
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argvalue_shape_check() {
        let spec = ArgSpec {
            name: "x".into(),
            dtype: DType::F32,
            shape: vec![2, 3],
        };
        let good = Tensor::zeros(&[2, 3]);
        let bad = Tensor::zeros(&[3, 2]);
        assert!(ArgValue::F32(&good).matches(&spec));
        assert!(!ArgValue::F32(&bad).matches(&spec));
        let scalar_spec = ArgSpec { name: "g".into(), dtype: DType::F32, shape: vec![] };
        assert!(ArgValue::Scalar(0.5).matches(&scalar_spec));
        assert!(!ArgValue::Scalar(0.5).matches(&spec));
    }

    #[test]
    fn backend_kind_parse_roundtrip() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Native);
        assert_eq!(BackendKind::Native.name(), "native");
        assert_eq!(BackendKind::Pjrt.name(), "pjrt");
    }

    #[test]
    fn call_counts_are_atomic_and_shared() {
        let rt = std::sync::Arc::new(
            Runtime::load(Path::new("/nonexistent/artifacts"), "smoke_gpt").unwrap(),
        );
        let tokens = IntTensor::zeros(&[2, 8]);
        let ps = crate::model::ParamStore::init(&rt.manifest, 0);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let rt = std::sync::Arc::clone(&rt);
                let ps = ps.clone();
                let tokens = tokens.clone();
                std::thread::spawn(move || {
                    let e = rt.exec("embed_fwd").unwrap();
                    let refs = ps.refs_for(&e.spec, 0).unwrap();
                    for _ in 0..5 {
                        e.call(&refs, &[ArgValue::I32(&tokens)]).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(rt.total_calls(), 20);
        let counts = rt.call_counts();
        let embed = counts.iter().find(|(n, _)| n == "embed_fwd").unwrap();
        assert_eq!(embed.1, 20);
        assert!(counts.iter().any(|(n, c)| n == "block_fwd" && *c == 0));
    }

    #[test]
    fn native_runtime_loads_without_artifacts() {
        // a clean checkout has no artifacts/ directory at all
        let rt = Runtime::load(Path::new("/nonexistent/artifacts"), "smoke_gpt")
            .expect("native fallback");
        assert_eq!(rt.backend, BackendKind::Native);
        assert!(rt.has_exec("block_fwd"));
        assert!(rt.has_exec("block_vjp"));
        assert!(rt.has_exec("model_infer"));
        assert!(rt.exec("nope").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_is_a_clear_error() {
        let err = Runtime::load_with(
            Path::new("/nonexistent/artifacts"),
            "smoke_gpt",
            BackendKind::Pjrt,
        )
        .unwrap_err();
        // must point at the missing cargo feature, not at `make artifacts`
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt") && msg.contains("feature"), "{msg}");
    }
}
