//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from the
//! training hot loop.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client):
//! `PjRtClient::cpu() -> HloModuleProto::from_text_file -> compile ->
//! execute`.  Python is never on this path — the bundle produced by
//! `make artifacts` is all the Rust binary needs.
//!
//! Calling convention (must mirror `python/compile/aot.py`):
//! inputs = [param leaves in manifest order] ++ [data inputs]; outputs are a
//! tuple, unpacked here into host [`Tensor`]s using the manifest shapes.

use crate::config::json::Json;
use crate::model::{ArgSpec, DType, ExecSpec, Manifest};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A data argument for an executable call.
pub enum ArgValue<'a> {
    F32(&'a Tensor),
    I32(&'a IntTensor),
    /// f32 scalar (e.g. the runtime `gamma` input of `model_infer`).
    Scalar(f32),
}

impl ArgValue<'_> {
    fn matches(&self, spec: &ArgSpec) -> bool {
        match (self, spec.dtype) {
            (ArgValue::F32(t), DType::F32) => t.shape() == &spec.shape[..],
            (ArgValue::I32(t), DType::I32) => t.shape() == &spec.shape[..],
            (ArgValue::Scalar(_), DType::F32) => spec.shape.is_empty(),
            _ => false,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            ArgValue::F32(t) => tensor_literal(t),
            ArgValue::I32(t) => {
                let lit = xla::Literal::vec1(t.data());
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
            ArgValue::Scalar(v) => Ok(xla::Literal::from(*v)),
        }
    }
}

pub fn tensor_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// One compiled executable plus its ABI spec.
pub struct Exec {
    pub name: String,
    pub spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
    /// flop/byte estimate hooks could live here later
    pub calls: std::cell::Cell<u64>,
}

impl Exec {
    /// Execute with `params` (flat leaf tensors, manifest order) and `data`.
    /// Returns the output tuple as host tensors (shapes from the manifest).
    pub fn call(&self, params: &[&Tensor], data: &[ArgValue]) -> Result<Vec<Tensor>> {
        ensure!(
            data.len() == self.spec.data_inputs.len(),
            "{}: expected {} data inputs, got {}",
            self.name,
            self.spec.data_inputs.len(),
            data.len()
        );
        for (d, spec) in data.iter().zip(&self.spec.data_inputs) {
            ensure!(
                d.matches(spec),
                "{}: data input '{}' shape/dtype mismatch (want {:?} {:?})",
                self.name,
                spec.name,
                spec.dtype,
                spec.shape
            );
        }
        let mut lits = Vec::with_capacity(params.len() + data.len());
        for p in params {
            lits.push(tensor_literal(p)?);
        }
        for d in data {
            lits.push(d.to_literal()?);
        }
        self.calls.set(self.calls.get() + 1);
        let bufs = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.name))?;
        self.unpack(result)
    }

    fn unpack(&self, result: xla::Literal) -> Result<Vec<Tensor>> {
        let parts = result.to_tuple()?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.name,
            self.spec.outputs.len(),
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.spec.outputs) {
            ensure!(
                spec.dtype == DType::F32,
                "{}: only f32 outputs supported, got {:?}",
                self.name,
                spec.dtype
            );
            let v = lit.to_vec::<f32>()?;
            out.push(Tensor::from_vec(&spec.shape, v)?);
        }
        Ok(out)
    }
}

/// The per-bundle runtime: a PJRT client plus all compiled executables.
pub struct Runtime {
    pub manifest: Manifest,
    execs: BTreeMap<String, Exec>,
    #[allow(dead_code)]
    client: xla::PjRtClient,
}

impl Runtime {
    /// Load `artifacts/<name>/` — parse the manifest, compile every HLO.
    pub fn load(artifacts_dir: &Path, bundle: &str) -> Result<Self> {
        let dir = artifacts_dir.join(bundle);
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let manifest = Manifest::from_json(&Json::parse(&text)?)?;
        Self::from_manifest(manifest, &dir)
    }

    pub fn from_manifest(manifest: Manifest, dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut execs = BTreeMap::new();
        for (name, spec) in &manifest.executables {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            execs.insert(
                name.clone(),
                Exec {
                    name: name.clone(),
                    spec: spec.clone(),
                    exe,
                    calls: std::cell::Cell::new(0),
                },
            );
        }
        Ok(Runtime { manifest, execs, client })
    }

    pub fn exec(&self, name: &str) -> Result<&Exec> {
        self.execs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no executable '{name}' in bundle"))
    }

    pub fn has_exec(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    pub fn exec_names(&self) -> impl Iterator<Item = &str> {
        self.execs.keys().map(String::as_str)
    }

    /// Total executable invocations (profiling).
    pub fn total_calls(&self) -> u64 {
        self.execs.values().map(|e| e.calls.get()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argvalue_shape_check() {
        let spec = ArgSpec {
            name: "x".into(),
            dtype: DType::F32,
            shape: vec![2, 3],
        };
        let good = Tensor::zeros(&[2, 3]);
        let bad = Tensor::zeros(&[3, 2]);
        assert!(ArgValue::F32(&good).matches(&spec));
        assert!(!ArgValue::F32(&bad).matches(&spec));
        let scalar_spec = ArgSpec { name: "g".into(), dtype: DType::F32, shape: vec![] };
        assert!(ArgValue::Scalar(0.5).matches(&scalar_spec));
        assert!(!ArgValue::Scalar(0.5).matches(&spec));
    }
}
