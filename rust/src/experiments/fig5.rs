//! Figure 5: GPT2 vs BDIA-GPT2 overfitting a *very small* corpus (the
//! paper's 0.05%-of-openwebtext study).  The training pool is restricted to
//! a handful of windows so the model can memorise it; the validation loss
//! separates the two systems late in training.

use super::{arm_config, emit_summary, run_arm, write_series_csv, ExpOpts};
use crate::config::TrainMode;
use anyhow::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let seed = *opts.seeds.first().unwrap_or(&0);
    let mut finals = Vec::new();
    for (label, mode) in [
        ("GPT2", TrainMode::Vanilla),
        ("BDIA-GPT2", TrainMode::BdiaReversible),
    ] {
        let mut cfg = arm_config(opts, "gpt_tiny", "tiny_corpus", mode, seed);
        cfg.train_examples = 48; // tiny window pool => strong overfitting
        let name = format!("fig5_{label}");
        let (log, _acc, _) = run_arm(&cfg, &name)?;
        let rows: Vec<Vec<String>> = log
            .records
            .iter()
            .map(|r| {
                vec![
                    r.step.to_string(),
                    r.train_loss.to_string(),
                    r.val_loss.map_or(String::new(), |v| v.to_string()),
                ]
            })
            .collect();
        write_series_csv(
            &opts.out_dir.join(format!("{name}.csv")),
            &["step", "train_loss", "val_loss"],
            &rows,
        )?;
        let train_end = log.records.last().map(|r| r.train_loss).unwrap_or(f32::NAN);
        finals.push((label, train_end, log.final_val_loss().unwrap_or(f32::NAN)));
    }
    let gap = |(_, tr, va): &(&str, f32, f32)| va - tr;
    let body = format!(
        "12-block GPT2 config, {} steps, 48-window training pool.\n\n\
         | model | final train loss | final val loss | generalization gap |\n\
         |---|---|---|---|\n\
         | {} | {:.4} | {:.4} | {:.4} |\n| {} | {:.4} | {:.4} | {:.4} |\n\n\
         Shape check vs paper Fig. 5: BDIA-GPT2 trains slower (higher train \
         loss) but ends with the lower validation loss / smaller gap.\n\
         Curves: `fig5_*.csv`.",
        opts.steps,
        finals[0].0, finals[0].1, finals[0].2, gap(&finals[0]),
        finals[1].0, finals[1].1, finals[1].2, gap(&finals[1]),
    );
    emit_summary(opts, "Figure 5 — tiny-corpus overfitting (GPT2)", &body)
}
