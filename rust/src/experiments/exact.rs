//! Exactness audit — the paper's *title* claim, verified on a real bundle:
//! forward (eqs. 18-21) → reconstruct (eq. 24) must be bit-identical, with
//! side information costing exactly 1 bit per activation element per block.

use super::{emit_summary, ExpOpts};
use crate::coordinator::{GammaPlan, Stack, StackKind, StackState};
use crate::metrics::fmt_bytes;
use crate::model::ParamStore;
use crate::quant;
use crate::runtime::Runtime;
use crate::tensor::{Rng, Tensor};
use anyhow::{ensure, Result};

pub fn run(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::load(&opts.artifacts_dir, "gpt_tiny")?;
    let dims = rt.manifest.dims.clone();
    let params = ParamStore::init(&rt.manifest, 0);
    let stack = Stack::new(&rt, StackKind::Main)?;
    let mut rng = Rng::new(42);
    let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let plan = GammaPlan::draw(&mut rng, stack.n_blocks, dims.batch, 0.5);

    // record-all oracle
    let mut x0q = x0.clone();
    quant::quantize_activation(&mut x0q, stack.fixed);
    let mut xs = vec![x0q];
    {
        let h0 = stack.debug_call_fwd(&params, 0, &xs[0], None)?;
        xs.push(quant::first_step_quant(&xs[0], &h0, stack.fixed)?);
        for k in 1..stack.n_blocks {
            let h = stack.debug_call_fwd(&params, k, &xs[k], None)?;
            let signs = plan.signs(k)?;
            let (nx, _) =
                quant::bdia_forward_quant(&xs[k - 1], &xs[k], &h, &signs, stack.fixed)?;
            xs.push(nx);
        }
    }

    // production path
    let state = stack.forward_quant(&params, x0, None, &plan)?;
    let rec = stack.reconstruct_all(&params, &state, None, &plan)?;
    let mut max_diff = 0f32;
    let mut exact_blocks = 0usize;
    for (a, b) in xs.iter().zip(&rec) {
        let d = a.max_abs_diff(b)?;
        max_diff = max_diff.max(d);
        if d == 0.0 {
            exact_blocks += 1;
        }
    }
    ensure!(max_diff == 0.0, "NOT bit-exact: max |drift| = {max_diff}");

    let StackState::Reversible { x_last, x_prev, side } = &state else {
        unreachable!()
    };
    let act_bytes = x_last.nbytes() + x_prev.nbytes();
    let side_bytes = side.nbytes();
    let elems = dims.batch * dims.seq * dims.d_model;
    let expect_side = (stack.n_blocks - 1) * elems.div_ceil(64) * 8;
    ensure!(side_bytes == expect_side, "side-info not 1 bit/element/block");

    let store_all = (stack.n_blocks + 1) * x_last.nbytes();
    let body = format!(
        "bundle `gpt_tiny` (K={}, batch={}, T={}, D={}, l={}):\n\n\
         - reconstruction drift over {} activations: **0.0 (bit-exact)** \
           ({} / {} tensors byte-identical)\n\
         - stored boundaries: {} | side info: {} (1 bit/elem/block) | \
           store-all would need: {}\n\
         - activation-memory ratio reversible/store-all: **{:.3}**\n",
        stack.n_blocks,
        dims.batch,
        dims.seq,
        dims.d_model,
        dims.lbits,
        xs.len(),
        exact_blocks,
        xs.len(),
        fmt_bytes(act_bytes),
        fmt_bytes(side_bytes),
        fmt_bytes(store_all),
        (act_bytes + side_bytes) as f64 / store_all as f64,
    );
    emit_summary(opts, "Exactness audit (title claim)", &body)
}
