//! Experiment drivers: one per table/figure in the paper's evaluation
//! (DESIGN.md §6 maps each to its modules).  Every driver writes CSV series
//! + a markdown summary under `results/` and returns the summary string.
//!
//! | driver   | paper artifact | what it regenerates                        |
//! |----------|----------------|--------------------------------------------|
//! | [`fig1`] | Figure 1       | val-acc vs constant inference gamma         |
//! | [`fig2`] | Figure 2       | float-inversion error accumulation by depth |
//! | [`fig3`] | Figure 3 + Table 1 | ViT/RevViT/BDIA curves, acc, memory    |
//! | [`table2`] | Table 2      | gamma-magnitude ablation                    |
//! | [`fig4`] | Figure 4       | translation train/val curves                |
//! | [`fig5`] | Figure 5       | tiny-corpus GPT overfitting curves          |
//! | [`exact`]| (title claim)  | bit-exactness + side-info audit             |

pub mod exact;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod table2;

use crate::api::{Session, TrainOpts};
use crate::config::{TrainConfig, TrainMode};
use crate::data::{make_dataset, Dataset};
use crate::metrics::TrainLog;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Common experiment options (CLI-overridable; defaults sized for the
/// single-CPU testbed — EXPERIMENTS.md records the exact values used).
#[derive(Clone, Debug)]
pub struct ExpOpts {
    pub steps: usize,
    pub seeds: Vec<u64>,
    pub out_dir: PathBuf,
    pub artifacts_dir: PathBuf,
    pub eval_every: usize,
    pub eval_batches: usize,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            steps: 150,
            seeds: vec![0, 1],
            out_dir: PathBuf::from("results"),
            artifacts_dir: PathBuf::from("artifacts"),
            eval_every: 25,
            eval_batches: 4,
        }
    }
}

impl ExpOpts {
    pub fn quick() -> Self {
        ExpOpts { steps: 6, seeds: vec![0], eval_every: 3, eval_batches: 1, ..Default::default() }
    }

    pub fn ensure_out(&self) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("creating {}", self.out_dir.display()))
    }
}

/// Base TrainConfig for a (bundle, mode, seed) arm.
pub fn arm_config(
    opts: &ExpOpts,
    bundle: &str,
    dataset: &str,
    mode: TrainMode,
    seed: u64,
) -> TrainConfig {
    TrainConfig {
        model: bundle.into(),
        mode,
        dataset: dataset.into(),
        steps: opts.steps,
        seed,
        eval_every: opts.eval_every,
        eval_batches: opts.eval_batches,
        log_every: (opts.steps / 20).max(1),
        artifacts_dir: opts.artifacts_dir.clone(),
        ..TrainConfig::default()
    }
}

/// Train one arm end to end through the [`Session`] facade (both the BDIA
/// coordinator and the RevViT baseline engines); returns (log, final val
/// acc, live stored bytes).
pub fn run_arm(cfg: &TrainConfig, run_name: &str) -> Result<(TrainLog, f32, usize)> {
    let mut session = Session::builder().config(cfg.clone()).build()?;
    let report = session.train(&TrainOpts {
        run_name: Some(run_name.to_string()),
        csv_out: None,
    })?;
    let b = session.dataset()?.train_batch(0);
    let stored = session.train_step(&b)?.stored_activation_bytes;
    let acc = report.log.last_eval().map(|(_, a)| a).unwrap_or(0.0);
    Ok((report.log, acc, stored))
}

pub fn dataset_for(
    rt: &crate::runtime::Runtime,
    cfg: &TrainConfig,
) -> Result<Box<dyn Dataset>> {
    make_dataset(cfg, &rt.manifest.dims, rt.manifest.family)
}

/// Write a CSV of (x, series...) rows.
pub fn write_series_csv(
    path: &Path,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut text = header.join(",");
    text.push('\n');
    for r in rows {
        text.push_str(&r.join(","));
        text.push('\n');
    }
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))
}

/// Append a section to results/SUMMARY.md and echo it.
pub fn emit_summary(opts: &ExpOpts, title: &str, body: &str) -> Result<String> {
    opts.ensure_out()?;
    let text = format!("\n## {title}\n\n{body}\n");
    let path = opts.out_dir.join("SUMMARY.md");
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
    f.write_all(text.as_bytes())?;
    println!("{text}");
    Ok(text)
}

/// Dispatch by experiment id ("fig1".."fig5", "table1", "table2", "exact",
/// "all").
pub fn run_experiment(id: &str, opts: &ExpOpts) -> Result<()> {
    match id {
        "fig1" => fig1::run(opts).map(|_| ()),
        "fig2" => fig2::run(opts).map(|_| ()),
        "fig3" | "table1" => fig3::run(opts).map(|_| ()),
        "table2" => table2::run(opts).map(|_| ()),
        "fig4" => fig4::run(opts).map(|_| ()),
        "fig5" => fig5::run(opts).map(|_| ()),
        "exact" => exact::run(opts).map(|_| ()),
        "all" => {
            for id in ["exact", "fig2", "fig1", "table2", "fig3", "fig4", "fig5"] {
                run_experiment(id, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
}
