//! Figure 1: validation accuracy of the family of ODE solvers obtained by
//! fixing a single inference-time gamma in [-0.5, 0.5] — after training a
//! conventional ViT vs a BDIA-ViT.  BDIA training flattens the curve (it
//! trained an *ensemble* of solvers); the vanilla model is peaked at
//! gamma = 0 (it only ever saw one solver).

use super::{arm_config, emit_summary, write_series_csv, ExpOpts};
use crate::api::{EvalOpts, Session, TrainOpts};
use crate::config::TrainMode;
use anyhow::Result;

pub const GAMMAS: [f32; 11] = [
    -0.5, -0.4, -0.3, -0.2, -0.1, 0.0, 0.1, 0.2, 0.3, 0.4, 0.5,
];

pub fn run(opts: &ExpOpts) -> Result<String> {
    let seed = *opts.seeds.first().unwrap_or(&0);
    let mut curves: Vec<(String, Vec<f32>)> = Vec::new();

    for (label, mode) in [("ViT", TrainMode::Vanilla), ("BDIA-ViT", TrainMode::BdiaReversible)]
    {
        let cfg = arm_config(opts, "vit_s10", "synth_cifar10", mode, seed);
        let mut session = Session::builder().config(cfg).build()?;
        session.train(&TrainOpts {
            run_name: Some(format!("fig1_{label}")),
            csv_out: None,
        })?;
        let ds = session.dataset()?; // built once for the whole sweep
        let mut accs = Vec::with_capacity(GAMMAS.len());
        for &g in &GAMMAS {
            let report = session.evaluate_on(
                ds.as_ref(),
                &EvalOpts { gamma: g, batches: Some(opts.eval_batches) },
            )?;
            accs.push(report.acc);
        }
        curves.push((label.to_string(), accs));
    }

    let rows: Vec<Vec<String>> = GAMMAS
        .iter()
        .enumerate()
        .map(|(i, g)| {
            let mut r = vec![g.to_string()];
            for (_, accs) in &curves {
                r.push(accs[i].to_string());
            }
            r
        })
        .collect();
    write_series_csv(
        &opts.out_dir.join("fig1_gamma_sweep.csv"),
        &["gamma", "vit_val_acc", "bdia_vit_val_acc"],
        &rows,
    )?;

    // flatness metric: (max-min) across the sweep, per model
    let spread = |accs: &[f32]| {
        let mx = accs.iter().cloned().fold(f32::MIN, f32::max);
        let mn = accs.iter().cloned().fold(f32::MAX, f32::min);
        mx - mn
    };
    let s_vit = spread(&curves[0].1);
    let s_bdia = spread(&curves[1].1);
    let body = format!(
        "constant inference gamma swept over {:?} after {} training steps.\n\n\
         | model | acc @ gamma=0 | min acc | max acc | spread |\n\
         |---|---|---|---|---|\n\
         | ViT | {:.3} | {:.3} | {:.3} | {:.3} |\n\
         | BDIA-ViT | {:.3} | {:.3} | {:.3} | {:.3} |\n\n\
         Shape check vs paper Fig. 1: BDIA-ViT's curve should be flatter \
         (spread {:.3} vs {:.3}).  Series: `fig1_gamma_sweep.csv`.",
        GAMMAS,
        opts.steps,
        curves[0].1[5],
        curves[0].1.iter().cloned().fold(f32::MAX, f32::min),
        curves[0].1.iter().cloned().fold(f32::MIN, f32::max),
        s_vit,
        curves[1].1[5],
        curves[1].1.iter().cloned().fold(f32::MAX, f32::min),
        curves[1].1.iter().cloned().fold(f32::MIN, f32::max),
        s_bdia,
        s_bdia,
        s_vit,
    );
    emit_summary(opts, "Figure 1 — inference-gamma robustness sweep", &body)
}
