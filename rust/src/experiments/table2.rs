//! Table 2: ablation of the gamma magnitude — BDIA-ViT trained with
//! `|gamma_k| in {0, 0.25, 0.5, 0.6}` (quantization and online backprop OFF,
//! i.e. the float path), evaluated at `E[gamma] = 0`.

use super::{arm_config, emit_summary, run_arm, ExpOpts};
use crate::config::TrainMode;
use crate::metrics::{markdown_table, mean_std};
use anyhow::Result;

pub const MAGNITUDES: [f32; 4] = [0.0, 0.25, 0.5, 0.6];

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut rows = Vec::new();
    for &mag in &MAGNITUDES {
        let mut accs = Vec::new();
        for &seed in &opts.seeds {
            let mut cfg = arm_config(
                opts,
                "vit_s10",
                "synth_cifar10",
                TrainMode::BdiaFloat,
                seed,
            );
            cfg.gamma_mag = mag;
            let name = format!("table2_g{mag}_s{seed}");
            let (_log, acc, _) = run_arm(&cfg, &name)?;
            accs.push(acc);
        }
        let (m, s) = mean_std(&accs);
        rows.push(vec![
            if mag == 0.0 { "0.0 (= ViT)".into() } else { format!("±{mag}") },
            format!("{:.2}±{:.2}", m * 100.0, s * 100.0),
        ]);
    }
    let table = markdown_table(&["{gamma_k}", "val acc (%)"], &rows);
    let body = format!(
        "{} steps x {} seeds, float BDIA path (no quantization, store-all), \
         inference at E[gamma]=0.\n\n{}\n\
         Shape check vs paper Table 2: any |gamma|>0 beats gamma=0, with \
         ±0.5 near the top.",
        opts.steps,
        opts.seeds.len(),
        table
    );
    emit_summary(opts, "Table 2 — gamma-magnitude ablation", &body)
}
