//! Figure 4: encoder-decoder translation — conventional transformer vs
//! BDIA-transformer train/val loss curves on the synthetic transduction
//! grammar (the en→fr stand-in).  BDIA is applied in both stacks, exactly as
//! the paper describes.

use super::{arm_config, emit_summary, run_arm, write_series_csv, ExpOpts};
use crate::config::TrainMode;
use anyhow::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let seed = *opts.seeds.first().unwrap_or(&0);
    let mut finals = Vec::new();
    for (label, mode) in [
        ("transformer", TrainMode::Vanilla),
        ("BDIA-transformer", TrainMode::BdiaReversible),
    ] {
        let mut cfg = arm_config(opts, "encdec_mt", "synth_translation", mode, seed);
        // small training pool so the generalization gap is visible
        cfg.train_examples = 512;
        let name = format!("fig4_{label}");
        let (log, acc, _) = run_arm(&cfg, &name)?;
        let rows: Vec<Vec<String>> = log
            .records
            .iter()
            .map(|r| {
                vec![
                    r.step.to_string(),
                    r.train_loss.to_string(),
                    r.val_loss.map_or(String::new(), |v| v.to_string()),
                ]
            })
            .collect();
        write_series_csv(
            &opts.out_dir.join(format!("{name}.csv")),
            &["step", "train_loss", "val_loss"],
            &rows,
        )?;
        finals.push((label, log.final_val_loss().unwrap_or(f32::NAN), acc));
    }
    let body = format!(
        "6+6 encoder/decoder blocks, {} steps, synthetic transduction task.\n\n\
         | model | final val loss | final val token acc |\n|---|---|---|\n\
         | {} | {:.4} | {:.3} |\n| {} | {:.4} | {:.3} |\n\n\
         Shape check vs paper Fig. 4: BDIA's val loss ends at or below the \
         conventional transformer's. Curves: `fig4_*.csv`.",
        opts.steps, finals[0].0, finals[0].1, finals[0].2, finals[1].0,
        finals[1].1, finals[1].2
    );
    emit_summary(opts, "Figure 4 — translation (encoder-decoder)", &body)
}
