//! Figure 2: accumulated reconstruction error of the *float* inversion
//! (eq. 16) walking from the top transformer block to the bottom of a
//! 12-block BDIA-GPT2, versus the quantized exact path (always 0).
//!
//! The 1/gamma = ±2 factor amplifies f32 rounding error roughly 2x per
//! block — the instability that motivates the paper's quantized design.

use super::{emit_summary, write_series_csv, ExpOpts};
use crate::coordinator::{GammaPlan, Stack, StackKind, StackState};
use crate::model::ParamStore;
use crate::quant;
use crate::runtime::Runtime;
use crate::tensor::{Rng, Tensor};
use anyhow::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::load(&opts.artifacts_dir, "gpt_tiny")?;
    let dims = rt.manifest.dims.clone();
    let params = ParamStore::init(&rt.manifest, 1);
    let stack = Stack::new(&rt, StackKind::Main)?;
    let mut rng = Rng::new(7);
    let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let plan = GammaPlan::draw(&mut rng, stack.n_blocks, dims.batch, 0.5);

    // ---- float path (eq. 10 forward, eq. 16 inversion with drift fed back)
    let StackState::Full { xs } = stack.forward_float(&params, x0.clone(), None, &plan)?
    else {
        unreachable!()
    };
    let k_total = stack.n_blocks;
    let mut float_err = vec![0f32; k_total + 1];
    let mut x_next = xs[k_total].clone();
    let mut x_cur = xs[k_total - 1].clone();
    for k in (1..k_total).rev() {
        let h = stack.debug_call_fwd(&params, k, &x_cur, None)?;
        let rec = quant::bdia_invert_float(&x_next, &x_cur, &h, &plan.gammas[k])?;
        float_err[k - 1] = rec.max_abs_diff(&xs[k - 1])?;
        x_next = x_cur;
        x_cur = rec;
    }

    // ---- quantized path: reconstruct and measure (should be identically 0)
    let state = stack.forward_quant(&params, x0, None, &plan)?;
    let rec_all = stack.reconstruct_all(&params, &state, None, &plan)?;
    // oracle for comparison
    let mut quant_err = vec![0f32; k_total + 1];
    {
        let mut x0q = rec_all[k_total].clone(); // placeholder, replaced below
        let _ = &mut x0q;
    }
    // recompute record-all quantized forward as the oracle
    let mut xq = {
        let mut x = rec_all[0].clone();
        quant::quantize_activation(&mut x, stack.fixed);
        vec![x]
    };
    {
        let h0 = stack.debug_call_fwd(&params, 0, &xq[0], None)?;
        xq.push(quant::first_step_quant(&xq[0], &h0, stack.fixed)?);
        for k in 1..k_total {
            let h = stack.debug_call_fwd(&params, k, &xq[k], None)?;
            let signs = plan.signs(k)?;
            let (nx, _) =
                quant::bdia_forward_quant(&xq[k - 1], &xq[k], &h, &signs, stack.fixed)?;
            xq.push(nx);
        }
    }
    for k in 0..=k_total {
        quant_err[k] = xq[k].max_abs_diff(&rec_all[k])?;
    }

    // CSV: depth index measured from the top (the paper plots error growing
    // as online backprop walks down)
    let rows: Vec<Vec<String>> = (0..k_total)
        .rev()
        .map(|k| {
            vec![
                (k_total - 1 - k).to_string(), // blocks walked
                k.to_string(),                 // activation index
                float_err[k].to_string(),
                quant_err[k].to_string(),
            ]
        })
        .collect();
    write_series_csv(
        &opts.out_dir.join("fig2_error_accumulation.csv"),
        &["blocks_walked", "activation_k", "float_eq16_err", "quant_eq24_err"],
        &rows,
    )?;

    let bottom_float = float_err[0];
    let top_float = float_err[k_total - 2];
    let max_quant = quant_err.iter().fold(0f32, |m, &v| m.max(v));
    let body = format!(
        "12-block GPT2 config, |gamma| = 0.5 per sample per block.\n\n\
         | path | err after 1 block | err at the bottom (x_0) | growth |\n\
         |---|---|---|---|\n\
         | float eq. 16 | {:.3e} | {:.3e} | {:.0}x |\n\
         | quantized eq. 24 | 0 | {} | — |\n\n\
         Shape check vs paper Fig. 2: float error grows multiplicatively with \
         depth; the quantized path is exactly zero everywhere.\n\
         Series: `fig2_error_accumulation.csv`.",
        top_float,
        bottom_float,
        if top_float > 0.0 { bottom_float / top_float } else { f32::NAN },
        max_quant,
    );
    emit_summary(opts, "Figure 2 — inversion error accumulation", &body)
}
