//! Figure 3 + Table 1: ViT vs RevViT vs BDIA-ViT on the two synthetic image
//! datasets — training/validation curves, final accuracy (mean ± std over
//! seeds), and peak training memory (analytic model + live stored bytes).

use super::{arm_config, emit_summary, run_arm, write_series_csv, ExpOpts};
use crate::config::TrainMode;
use crate::metrics::memory::MemoryModel;
use crate::metrics::{fmt_bytes, markdown_table, mean_std};
use crate::model::Family;
use crate::runtime::Runtime;
use anyhow::Result;

const ARMS: [(&str, TrainMode); 3] = [
    ("RevViT", TrainMode::RevVit),
    ("ViT", TrainMode::Vanilla),
    ("BDIA-ViT", TrainMode::BdiaReversible),
];

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut table_rows: Vec<Vec<String>> = Vec::new();

    for (bundle, dataset, tag) in [
        ("vit_s10", "synth_cifar10", "s10"),
        ("vit_s100", "synth_cifar100", "s100"),
    ] {
        let rt = Runtime::load(&opts.artifacts_dir, bundle)?;
        let dims = rt.manifest.dims.clone();
        let params_bytes = rt.manifest.n_params() * 4;
        drop(rt);

        for (label, mode) in ARMS {
            let mut accs = Vec::new();
            let mut live_bytes = 0usize;
            for &seed in &opts.seeds {
                let cfg = arm_config(opts, bundle, dataset, mode, seed);
                let name = format!("fig3_{tag}_{label}_s{seed}");
                let (log, acc, stored) = run_arm(&cfg, &name)?;
                accs.push(acc);
                live_bytes = stored;
                // per-run curve CSV
                let rows: Vec<Vec<String>> = log
                    .records
                    .iter()
                    .map(|r| {
                        vec![
                            r.step.to_string(),
                            r.train_loss.to_string(),
                            r.val_loss.map_or(String::new(), |v| v.to_string()),
                            r.val_acc.map_or(String::new(), |v| v.to_string()),
                        ]
                    })
                    .collect();
                write_series_csv(
                    &opts.out_dir.join(format!("{name}.csv")),
                    &["step", "train_loss", "val_loss", "val_acc"],
                    &rows,
                )?;
            }
            let (m, s) = mean_std(&accs);
            let mm = MemoryModel::new(mode, Family::Vit, &dims, params_bytes);
            table_rows.push(vec![
                tag.to_string(),
                label.to_string(),
                format!("{:.2}±{:.2}", m * 100.0, s * 100.0),
                fmt_bytes(mm.peak_total()),
                fmt_bytes(live_bytes),
            ]);
        }
    }

    let table = markdown_table(
        &["dataset", "model", "val acc (%)", "peak mem (analytic)", "live stored acts"],
        &table_rows,
    );
    let body = format!(
        "{} steps x {} seeds per arm; curves in `fig3_*.csv`.\n\n{}\n\
         Shape checks vs paper Table 1 / Fig. 3: BDIA val acc >= ViT >= RevViT; \
         BDIA/RevViT peak memory well below ViT with BDIA slightly above \
         RevViT (side information).",
        opts.steps,
        opts.seeds.len(),
        table
    );
    emit_summary(opts, "Figure 3 + Table 1 — model comparison", &body)
}
