//! Dynamic micro-batching queue.
//!
//! Requests enqueue as [`Job`]s; workers pull with [`BatchQueue::next_batch`]
//! which coalesces the head-of-line job with queued neighbours that share
//! its gamma (the executable takes one scalar gamma per call) up to the
//! manifest batch dimension, waiting at most `window` for stragglers.  Under
//! concurrent load the queue is rarely empty and batches fill immediately;
//! an idle server degenerates to latency-optimal singleton batches after
//! one window.
//!
//! Admission is bounded: [`BatchQueue::bounded`] caps the backlog, and
//! [`BatchQueue::push`] reports [`PushOutcome::Saturated`] instead of
//! buffering without limit — the server turns that into a prompt `503`
//! with `Retry-After` so clients back off instead of piling on.

use super::wire::Example;
use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued request plus its response channel.
pub struct Job {
    pub example: Example,
    pub gamma: f32,
    pub enqueued: Instant,
    /// Correlation id minted (or echoed) at the front door; carried into
    /// spans, response headers and — in the fleet — the backplane frames.
    pub request_id: String,
    pub resp: Sender<Result<(f32, f32), String>>,
}

/// What happened to a [`BatchQueue::push`]: admitted, bounced off the cap
/// (with the depth/cap pair the `503` body reports), or refused because
/// the queue is shutting down.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    Accepted,
    Saturated { depth: usize, cap: usize },
    ShuttingDown,
}

pub struct BatchQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    cap: usize,
}

struct Inner {
    q: VecDeque<Job>,
    shutdown: bool,
}

impl Default for BatchQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchQueue {
    /// Unbounded queue (admission control off).
    pub fn new() -> Self {
        Self::bounded(0)
    }

    /// Queue admitting at most `cap` waiting jobs; `cap == 0` means
    /// unbounded.
    pub fn bounded(cap: usize) -> Self {
        BatchQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
            cap: if cap == 0 { usize::MAX } else { cap },
        }
    }

    /// The admission cap, or `None` if unbounded.
    pub fn cap(&self) -> Option<usize> {
        if self.cap == usize::MAX {
            None
        } else {
            Some(self.cap)
        }
    }

    /// Try to enqueue a job; saturation and shutdown both leave the job
    /// with the caller (its response channel is untouched).
    pub fn push(&self, job: Job) -> PushOutcome {
        let mut g = self.inner.lock().unwrap();
        if g.shutdown {
            return PushOutcome::ShuttingDown;
        }
        if g.q.len() >= self.cap {
            return PushOutcome::Saturated { depth: g.q.len(), cap: self.cap };
        }
        g.q.push_back(job);
        drop(g);
        self.cv.notify_all();
        PushOutcome::Accepted
    }

    /// Return already-admitted jobs to the *front* of the queue, in their
    /// original order (the router uses this to re-dispatch batches a dead
    /// replica never acknowledged).  Bypasses the admission cap and the
    /// shutdown gate — these jobs were accepted once and still hold live
    /// response channels; re-queueing contiguously also keeps them
    /// γ-coalescible as a unit.
    pub fn push_front_all(&self, jobs: Vec<Job>) {
        if jobs.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for job in jobs.into_iter().rev() {
            g.q.push_front(job);
        }
        drop(g);
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Wake all waiters; subsequent `next_batch` calls drain the backlog
    /// (without waiting out the window) and then return `None`.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Block until work arrives, then coalesce up to `max` same-gamma jobs,
    /// waiting at most `window` past the first pop for the batch to fill.
    /// Returns `None` only at shutdown with an empty queue.
    pub fn next_batch(&self, max: usize, window: Duration) -> Option<Vec<Job>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.q.is_empty() {
                break;
            }
            if g.shutdown {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        let first = g.q.pop_front().unwrap();
        let gkey = first.gamma.to_bits();
        let mut batch = vec![first];
        let deadline = Instant::now() + window;
        loop {
            while batch.len() < max {
                match g.q.front() {
                    Some(j) if j.gamma.to_bits() == gkey => {
                        batch.push(g.q.pop_front().unwrap());
                    }
                    _ => break,
                }
            }
            if batch.len() >= max || g.shutdown {
                break;
            }
            if !g.q.is_empty() {
                // head-of-line job has a different gamma: flush this batch
                // now so the next one can start immediately
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, _timeout) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn job(gamma: f32) -> (Job, mpsc::Receiver<Result<(f32, f32), String>>) {
        let (tx, rx) = mpsc::channel();
        (
            Job {
                example: Example::Tok { tokens: vec![0; 4], labels: vec![0; 4] },
                gamma,
                enqueued: Instant::now(),
                request_id: crate::obs::fresh_request_id(),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn coalesces_queued_same_gamma_jobs() {
        let q = BatchQueue::new();
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let (j, rx) = job(0.0);
            assert_eq!(q.push(j), PushOutcome::Accepted);
            rxs.push(rx);
        }
        let batch = q.next_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let q = BatchQueue::new();
        let rxs: Vec<_> = (0..5)
            .map(|_| {
                let (j, rx) = job(0.5);
                q.push(j);
                rx
            })
            .collect();
        assert_eq!(q.next_batch(2, Duration::ZERO).unwrap().len(), 2);
        assert_eq!(q.next_batch(2, Duration::ZERO).unwrap().len(), 2);
        assert_eq!(q.next_batch(2, Duration::ZERO).unwrap().len(), 1);
        drop(rxs);
    }

    #[test]
    fn gamma_mismatch_splits_batches() {
        let q = BatchQueue::new();
        let (j1, _r1) = job(0.0);
        let (j2, _r2) = job(0.5);
        let (j3, _r3) = job(0.5);
        q.push(j1);
        q.push(j2);
        q.push(j3);
        let b1 = q.next_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b1.len(), 1);
        assert_eq!(b1[0].gamma.to_bits(), 0.0f32.to_bits());
        let b2 = q.next_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b2.len(), 2);
        assert_eq!(b2[0].gamma.to_bits(), 0.5f32.to_bits());
    }

    #[test]
    fn window_waits_for_stragglers() {
        let q = std::sync::Arc::new(BatchQueue::new());
        let (j1, _r1) = job(0.0);
        q.push(j1);
        let q2 = std::sync::Arc::clone(&q);
        let feeder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (j2, r2) = job(0.0);
            q2.push(j2);
            r2
        });
        // generous window: the straggler lands inside it
        let batch = q.next_batch(4, Duration::from_millis(500)).unwrap();
        assert_eq!(batch.len(), 2, "straggler should join the open batch");
        feeder.join().unwrap();
    }

    #[test]
    fn shutdown_drains_then_stops() {
        let q = BatchQueue::new();
        let (j, _r) = job(0.0);
        q.push(j);
        q.shutdown();
        let (j2, _r2) = job(0.0);
        assert_eq!(
            q.push(j2),
            PushOutcome::ShuttingDown,
            "push after shutdown must be rejected"
        );
        // drain without waiting out any window
        let t0 = Instant::now();
        assert_eq!(q.next_batch(4, Duration::from_secs(5)).unwrap().len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1));
        assert!(q.next_batch(4, Duration::from_secs(5)).is_none());
    }

    #[test]
    fn blocks_until_work_arrives() {
        let q = std::sync::Arc::new(BatchQueue::new());
        let q2 = std::sync::Arc::clone(&q);
        let feeder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            let (j, rx) = job(0.25);
            q2.push(j);
            rx
        });
        let batch = q.next_batch(4, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 1);
        feeder.join().unwrap();
    }

    #[test]
    fn saturation_bounces_with_depth_and_cap() {
        let q = BatchQueue::bounded(2);
        assert_eq!(q.cap(), Some(2));
        let (j1, _r1) = job(0.0);
        let (j2, _r2) = job(0.0);
        assert_eq!(q.push(j1), PushOutcome::Accepted);
        assert_eq!(q.push(j2), PushOutcome::Accepted);
        let (j3, _r3) = job(0.0);
        assert_eq!(q.push(j3), PushOutcome::Saturated { depth: 2, cap: 2 });
        // draining makes room again
        assert_eq!(q.next_batch(8, Duration::ZERO).unwrap().len(), 2);
        let (j4, _r4) = job(0.0);
        assert_eq!(q.push(j4), PushOutcome::Accepted);
    }

    #[test]
    fn zero_cap_means_unbounded() {
        let q = BatchQueue::bounded(0);
        assert_eq!(q.cap(), None);
        let mut rxs = Vec::new();
        for _ in 0..10_000 {
            let (j, rx) = job(0.0);
            assert_eq!(q.push(j), PushOutcome::Accepted);
            rxs.push(rx);
        }
        assert_eq!(q.len(), 10_000);
    }

    #[test]
    fn push_front_all_requeues_in_order_and_past_the_cap() {
        let q = BatchQueue::bounded(1);
        let (j1, _r1) = job(0.5);
        assert_eq!(q.push(j1), PushOutcome::Accepted);
        let batch = q.next_batch(1, Duration::ZERO).unwrap();
        // another job sneaks in behind the re-dispatch
        let (j2, _r2) = job(0.25);
        assert_eq!(q.push(j2), PushOutcome::Accepted);
        // re-queue jumps the line (and ignores the cap of 1)
        q.push_front_all(batch);
        assert_eq!(q.len(), 2);
        let b1 = q.next_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b1.len(), 1);
        assert_eq!(b1[0].gamma.to_bits(), 0.5f32.to_bits(), "requeued job first");
        let b2 = q.next_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b2[0].gamma.to_bits(), 0.25f32.to_bits());
    }

    /// Satellite property test: under a deterministic pseudo-random
    /// workload, no dispatched micro-batch ever mixes γ keys or exceeds
    /// the batch dimension, and every admitted job is dispatched exactly
    /// once.
    #[test]
    fn random_workload_never_mixes_gammas_or_overfills() {
        let gammas = [-0.5f32, 0.0, 0.5];
        let mut state: u64 = 0x5eed_cafe_f00d_beef;
        let mut next = || {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        let q = BatchQueue::new();
        let max = 4usize;
        let total = 257usize;
        let mut rxs = Vec::new();
        let mut pushed = 0usize;
        let mut dispatched = 0usize;
        while dispatched < total {
            // interleave bursts of pushes with drains
            let burst = (next() % 5).min(total - pushed);
            for _ in 0..burst {
                let (j, rx) = job(gammas[next() % gammas.len()]);
                assert_eq!(q.push(j), PushOutcome::Accepted);
                rxs.push(rx);
                pushed += 1;
            }
            if pushed == dispatched {
                continue; // nothing queued yet
            }
            let batch = q.next_batch(max, Duration::ZERO).unwrap();
            assert!(!batch.is_empty() && batch.len() <= max, "len {}", batch.len());
            let gkey = batch[0].gamma.to_bits();
            for j in &batch {
                assert_eq!(j.gamma.to_bits(), gkey, "mixed gammas in one batch");
            }
            dispatched += batch.len();
        }
        assert_eq!(dispatched, total);
        assert!(q.is_empty());
    }
}
