//! Wire format + batch assembly for the serving path.
//!
//! A request carries **one example** (plus its labels and the inference
//! gamma) as raw little-endian binary — f32 values travel as IEEE-754 bit
//! patterns, never through decimal text, so the server's response can be
//! bit-identical to a local `model_infer_ex` call.  The batcher packs up to
//! `dims.batch` decoded examples into one executable invocation; unused
//! slots are zero-filled (token id 0 and label 0 are always in range), which
//! is sound because per-example outputs are slot- and neighbour-invariant
//! (see `runtime::native::blocks::head_loss_fwd_ex`).

use crate::data::Batch;
use crate::model::{Dims, Family, ParamStore};
use crate::runtime::{ArgValue, Runtime};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{bail, ensure, Result};

/// One decoded inference request, shaped for the model family.
#[derive(Clone, Debug, PartialEq)]
pub enum Example {
    /// ViT: one image (c*h*w f32) + class label.
    Vit { image: Vec<f32>, label: i32 },
    /// GPT: token sequence + per-position labels.
    Tok { tokens: Vec<i32>, labels: Vec<i32> },
    /// Encoder-decoder: source, shifted target, per-position labels.
    Seq { src: Vec<i32>, tgt_in: Vec<i32>, labels: Vec<i32> },
}

/// Exact request-body length for a family/dims (gamma trailer included).
pub fn body_len(family: Family, dims: &Dims) -> usize {
    4 * match family {
        Family::Vit => dims.channels * dims.image_size * dims.image_size + 1 + 1,
        Family::Gpt => dims.seq + dims.seq + 1,
        Family::EncDec => dims.seq_src + dims.seq + dims.seq + 1,
    }
}

fn put_i32s(out: &mut Vec<u8>, vs: &[i32]) {
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode one example + gamma into a request body.
pub fn encode(example: &Example, gamma: f32) -> Vec<u8> {
    let mut out = Vec::new();
    match example {
        Example::Vit { image, label } => {
            for &v in image {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.extend_from_slice(&label.to_le_bytes());
        }
        Example::Tok { tokens, labels } => {
            put_i32s(&mut out, tokens);
            put_i32s(&mut out, labels);
        }
        Example::Seq { src, tgt_in, labels } => {
            put_i32s(&mut out, src);
            put_i32s(&mut out, tgt_in);
            put_i32s(&mut out, labels);
        }
    }
    out.extend_from_slice(&gamma.to_le_bytes());
    out
}

struct BodyReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl BodyReader<'_> {
    fn f32(&mut self) -> f32 {
        let v = f32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        v
    }

    fn i32s(&mut self, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(i32::from_le_bytes(
                self.buf[self.pos..self.pos + 4].try_into().unwrap(),
            ));
            self.pos += 4;
        }
        out
    }

    fn f32s(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }
}

fn check_ids(what: &str, ids: &[i32], bound: usize) -> Result<()> {
    for &id in ids {
        ensure!(
            (0..bound as i32).contains(&id),
            "{what} value {id} out of range [0, {bound})"
        );
    }
    Ok(())
}

/// Decode and validate a request body against the bundle's family/dims.
pub fn decode(family: Family, dims: &Dims, body: &[u8]) -> Result<(Example, f32)> {
    let want = body_len(family, dims);
    ensure!(
        body.len() == want,
        "bad request body: expected {want} bytes for family {family:?}, got {}",
        body.len()
    );
    let mut r = BodyReader { buf: body, pos: 0 };
    let example = match family {
        Family::Vit => {
            let image =
                r.f32s(dims.channels * dims.image_size * dims.image_size);
            ensure!(
                image.iter().all(|v| v.is_finite()),
                "image contains non-finite values"
            );
            let label = r.i32s(1)[0];
            check_ids("label", &[label], dims.n_classes)?;
            Example::Vit { image, label }
        }
        Family::Gpt => {
            let tokens = r.i32s(dims.seq);
            let labels = r.i32s(dims.seq);
            check_ids("token", &tokens, dims.vocab)?;
            check_ids("label", &labels, dims.vocab)?;
            Example::Tok { tokens, labels }
        }
        Family::EncDec => {
            let src = r.i32s(dims.seq_src);
            let tgt_in = r.i32s(dims.seq);
            let labels = r.i32s(dims.seq);
            check_ids("src token", &src, dims.vocab)?;
            check_ids("tgt token", &tgt_in, dims.vocab)?;
            check_ids("label", &labels, dims.vocab)?;
            Example::Seq { src, tgt_in, labels }
        }
    };
    let gamma = r.f32();
    ensure!(gamma.is_finite(), "gamma must be finite");
    Ok((example, gamma))
}

/// Owned input tensors for one coalesced `model_infer_ex` call.
pub enum AssembledBatch {
    Vit { images: Tensor, labels: IntTensor },
    Tok { tokens: IntTensor, labels: IntTensor },
    Seq { src: IntTensor, tgt_in: IntTensor, labels: IntTensor },
}

impl AssembledBatch {
    /// Data arguments in `model_infer`/`model_infer_ex` ABI order.
    pub fn args(&self, gamma: f32) -> Vec<ArgValue<'_>> {
        match self {
            AssembledBatch::Vit { images, labels } => vec![
                ArgValue::F32(images),
                ArgValue::I32(labels),
                ArgValue::Scalar(gamma),
            ],
            AssembledBatch::Tok { tokens, labels } => vec![
                ArgValue::I32(tokens),
                ArgValue::I32(labels),
                ArgValue::Scalar(gamma),
            ],
            AssembledBatch::Seq { src, tgt_in, labels } => vec![
                ArgValue::I32(src),
                ArgValue::I32(tgt_in),
                ArgValue::I32(labels),
                ArgValue::Scalar(gamma),
            ],
        }
    }
}

/// Pack up to `dims.batch` examples into full batch tensors (zero-filled
/// tail slots).
pub fn assemble(
    family: Family,
    dims: &Dims,
    examples: &[Example],
) -> Result<AssembledBatch> {
    let b = dims.batch;
    ensure!(
        !examples.is_empty() && examples.len() <= b,
        "batch of {} examples does not fit manifest batch {b}",
        examples.len()
    );
    match family {
        Family::Vit => {
            let px = dims.channels * dims.image_size * dims.image_size;
            let mut images = vec![0.0f32; b * px];
            let mut labels = vec![0i32; b];
            for (i, e) in examples.iter().enumerate() {
                let Example::Vit { image, label } = e else {
                    bail!("example/family mismatch (want vit)")
                };
                ensure!(image.len() == px, "image size mismatch");
                images[i * px..(i + 1) * px].copy_from_slice(image);
                labels[i] = *label;
            }
            Ok(AssembledBatch::Vit {
                images: Tensor::from_vec(
                    &[b, dims.channels, dims.image_size, dims.image_size],
                    images,
                )?,
                labels: IntTensor::from_vec(&[b], labels)?,
            })
        }
        Family::Gpt => {
            let t = dims.seq;
            let mut toks = vec![0i32; b * t];
            let mut labs = vec![0i32; b * t];
            for (i, e) in examples.iter().enumerate() {
                let Example::Tok { tokens, labels } = e else {
                    bail!("example/family mismatch (want gpt)")
                };
                ensure!(tokens.len() == t && labels.len() == t, "seq len mismatch");
                toks[i * t..(i + 1) * t].copy_from_slice(tokens);
                labs[i * t..(i + 1) * t].copy_from_slice(labels);
            }
            Ok(AssembledBatch::Tok {
                tokens: IntTensor::from_vec(&[b, t], toks)?,
                labels: IntTensor::from_vec(&[b, t], labs)?,
            })
        }
        Family::EncDec => {
            let (ts, t) = (dims.seq_src, dims.seq);
            let mut srcs = vec![0i32; b * ts];
            let mut tgts = vec![0i32; b * t];
            let mut labs = vec![0i32; b * t];
            for (i, e) in examples.iter().enumerate() {
                let Example::Seq { src, tgt_in, labels } = e else {
                    bail!("example/family mismatch (want encdec)")
                };
                ensure!(
                    src.len() == ts && tgt_in.len() == t && labels.len() == t,
                    "seq len mismatch"
                );
                srcs[i * ts..(i + 1) * ts].copy_from_slice(src);
                tgts[i * t..(i + 1) * t].copy_from_slice(tgt_in);
                labs[i * t..(i + 1) * t].copy_from_slice(labels);
            }
            Ok(AssembledBatch::Seq {
                src: IntTensor::from_vec(&[b, ts], srcs)?,
                tgt_in: IntTensor::from_vec(&[b, t], tgts)?,
                labels: IntTensor::from_vec(&[b, t], labs)?,
            })
        }
    }
}

/// Run one coalesced batch through `model_infer_ex`; returns the per-example
/// (loss, correct) pairs for the occupied slots, in request order.
pub fn infer_batch(
    rt: &Runtime,
    params: &ParamStore,
    examples: &[Example],
    gamma: f32,
) -> Result<Vec<(f32, f32)>> {
    let e = rt.exec("model_infer_ex")?;
    let refs = params.refs_for(&e.spec, 0)?;
    let packed = assemble(rt.manifest.family, &rt.manifest.dims, examples)?;
    let outs = e.call(&refs, &packed.args(gamma))?;
    let (loss, correct) = (outs[0].data(), outs[1].data());
    Ok(examples
        .iter()
        .enumerate()
        .map(|(i, _)| (loss[i], correct[i]))
        .collect())
}

/// Reference path: score a single example exactly as the server would.
pub fn infer_one(
    rt: &Runtime,
    params: &ParamStore,
    example: &Example,
    gamma: f32,
) -> Result<(f32, f32)> {
    Ok(infer_batch(rt, params, std::slice::from_ref(example), gamma)?[0])
}

/// Split a dataset batch into per-slot examples (bench/test payloads).
pub fn examples_from_batch(batch: &Batch) -> Vec<Example> {
    match batch {
        Batch::Image { images, labels } => {
            let b = labels.len();
            let px = images.len() / b;
            (0..b)
                .map(|i| Example::Vit {
                    image: images.data()[i * px..(i + 1) * px].to_vec(),
                    label: labels.data()[i],
                })
                .collect()
        }
        Batch::Lm { tokens, labels } => {
            let b = tokens.shape()[0];
            let t = tokens.shape()[1];
            (0..b)
                .map(|i| Example::Tok {
                    tokens: tokens.data()[i * t..(i + 1) * t].to_vec(),
                    labels: labels.data()[i * t..(i + 1) * t].to_vec(),
                })
                .collect()
        }
        Batch::Seq2Seq { src, tgt_in, labels } => {
            let b = src.shape()[0];
            let ts = src.shape()[1];
            let t = tgt_in.shape()[1];
            (0..b)
                .map(|i| Example::Seq {
                    src: src.data()[i * ts..(i + 1) * ts].to_vec(),
                    tgt_in: tgt_in.data()[i * t..(i + 1) * t].to_vec(),
                    labels: labels.data()[i * t..(i + 1) * t].to_vec(),
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::registry;

    fn gpt_dims() -> Dims {
        registry::manifest_for("smoke_gpt").unwrap().dims
    }

    #[test]
    fn encode_decode_roundtrip_gpt() {
        let dims = gpt_dims();
        let ex = Example::Tok {
            tokens: (0..dims.seq as i32).map(|i| i % dims.vocab as i32).collect(),
            labels: vec![1; dims.seq],
        };
        let body = encode(&ex, 0.5);
        assert_eq!(body.len(), body_len(Family::Gpt, &dims));
        let (back, gamma) = decode(Family::Gpt, &dims, &body).unwrap();
        assert_eq!(back, ex);
        assert_eq!(gamma.to_bits(), 0.5f32.to_bits());
    }

    #[test]
    fn decode_rejects_bad_lengths_and_ranges() {
        let dims = gpt_dims();
        let ex = Example::Tok {
            tokens: vec![0; dims.seq],
            labels: vec![0; dims.seq],
        };
        let body = encode(&ex, 0.0);
        assert!(decode(Family::Gpt, &dims, &body[..body.len() - 1]).is_err());
        let bad = Example::Tok {
            tokens: vec![dims.vocab as i32; dims.seq], // out of range
            labels: vec![0; dims.seq],
        };
        let err = decode(Family::Gpt, &dims, &encode(&bad, 0.0)).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"));
    }

    #[test]
    fn assemble_zero_fills_tail_slots() {
        let dims = gpt_dims();
        let ex = Example::Tok {
            tokens: vec![3; dims.seq],
            labels: vec![4; dims.seq],
        };
        let packed = assemble(Family::Gpt, &dims, &[ex]).unwrap();
        let AssembledBatch::Tok { tokens, labels } = packed else {
            panic!("family")
        };
        assert_eq!(tokens.shape(), &[dims.batch, dims.seq]);
        assert!(tokens.data()[..dims.seq].iter().all(|&v| v == 3));
        assert!(tokens.data()[dims.seq..].iter().all(|&v| v == 0));
        assert!(labels.data()[dims.seq..].iter().all(|&v| v == 0));
    }
}
