//! Concurrent inference serving over `std::net` — the deployment half of
//! the paper's story: BDIA training produces a *standard* transformer at
//! inference (eqs. 18–22), so trained checkpoints can serve traffic from a
//! plain HTTP endpoint with no Python and no external crates.
//!
//! Architecture:
//!
//! ```text
//! TcpListener ──accept──► handler thread (per connection)
//!                              │ decode body → Job{example, gamma, resp}
//!                              ▼
//!                        [BatchQueue]  ◄─ dynamic micro-batching:
//!                              │           coalesce same-gamma jobs up to
//!                              ▼           dims.batch within batch_window
//!                      worker pool (N threads, one Arc<Runtime>)
//!                              │ model_infer_ex → per-slot (loss, correct)
//!                              ▼
//!                        resp channels ──► handler writes 8-byte response
//! ```
//!
//! Endpoints: `POST /infer` (binary example → 8-byte result),
//! `POST /generate` (JSON prompt → chunked stream, one JSON line per
//! token — GPT bundles only; a dedicated scheduler thread batches the
//! decode step across concurrent sessions by position, see [`genserve`]),
//! `GET /healthz`, `GET /stats` (JSON counters + per-exec call counts +
//! latency percentiles + generation gauges), `GET /metrics` (the same
//! counters as a Prometheus text exposition), `POST /shutdown` (graceful
//! drain).  Every response echoes an `X-Request-Id` (client-supplied or
//! minted) and error JSON bodies carry it too.
//!
//! Bit-exactness: per-example outputs are slot/neighbour-invariant in the
//! native backend, so a response from a coalesced batch is bit-identical to
//! a direct single-example `model_infer_ex` call (`tests/serve_smoke.rs`
//! asserts this over real sockets).

pub mod batcher;
pub mod bench;
pub mod client;
mod genserve;
pub mod http;
pub mod stats;
pub mod wire;

use crate::api::events::{EventSink, NullSink, RequestEvent};
use crate::checkpoint;
use crate::model::ParamStore;
use crate::runtime::{BackendKind, Runtime};
use anyhow::{ensure, Context, Result};
use self::batcher::{BatchQueue, Job};
use self::stats::ServeStats;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a handler holds an idle client connection before giving up.
const CONN_READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Latency reservoir size for `/stats` percentiles.
const LATENCY_RESERVOIR: usize = 8192;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    pub backend: BackendKind,
    pub artifacts_dir: PathBuf,
    /// Checkpoint with trained weights; `None` serves seed-initialized
    /// params (the CLI warns loudly).
    pub ckpt: Option<PathBuf>,
    /// 0 picks an ephemeral port (tests / bench self-hosting).
    pub port: u16,
    pub workers: usize,
    /// How long an under-filled batch waits for stragglers.
    pub batch_window: Duration,
    /// Kernel thread-pool parallelism shared by all workers (0 = leave
    /// the process-wide pool configuration untouched / auto).  Responses
    /// are bit-identical at any value.
    pub threads: usize,
    /// Admission cap on queued requests; pushes past it get a prompt
    /// `503` + `Retry-After` instead of unbounded buffering (0 =
    /// unbounded).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "vit_s10".into(),
            backend: BackendKind::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            ckpt: None,
            port: 7878,
            workers: 4,
            batch_window: Duration::from_millis(2),
            threads: 0,
            queue_cap: 1024,
        }
    }
}

struct Shared {
    rt: Runtime,
    params: ParamStore,
    queue: BatchQueue,
    stats: ServeStats,
    shutdown: AtomicBool,
    addr: SocketAddr,
    workers: usize,
    batch_window: Duration,
    /// Request-body cap for this bundle's exact wire format — anything
    /// larger is rejected `413` before allocation.
    max_body: usize,
    /// Per-request observer ([`crate::api::events::EventSink`]); the
    /// default server uses a no-op sink, sessions pass theirs through.
    sink: Arc<dyn EventSink>,
    /// Join point for the `/generate` scheduler thread (present even on
    /// non-GPT bundles, where the endpoint answers `501` instead).
    gen_queue: genserve::GenQueue,
}

/// A running server: worker pool + listener, shut down via [`Server::stop`]
/// (or a client `POST /shutdown`), then reaped with [`Server::join`].
pub struct Server {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Load the bundle (+ optional checkpoint), bind, and spawn the pool.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let rt = Runtime::load_with(&cfg.artifacts_dir, &cfg.model, cfg.backend)
            .with_context(|| format!("loading bundle '{}'", cfg.model))?;
        let params = match &cfg.ckpt {
            Some(path) => {
                let ck = checkpoint::load(path)?;
                ensure!(
                    ck.model == cfg.model,
                    "checkpoint {} was written for model '{}', serving '{}'",
                    path.display(),
                    ck.model,
                    cfg.model
                );
                ensure!(
                    ck.params.matches_manifest(&rt.manifest),
                    "checkpoint {} parameter structure does not match bundle \
                     '{}'",
                    path.display(),
                    cfg.model
                );
                ck.params
            }
            None => ParamStore::init(&rt.manifest, 0),
        };
        Self::start_with_parts(cfg, rt, params, Arc::new(NullSink))
    }

    /// Start with a pre-built runtime, in-memory parameters and an event
    /// sink — the `api::Session` path: a session serves its **current**
    /// (possibly just-trained) weights without a checkpoint round trip,
    /// and request events flow to the session's sink.
    pub fn start_with_parts(
        cfg: ServeConfig,
        rt: Runtime,
        params: ParamStore,
        sink: Arc<dyn EventSink>,
    ) -> Result<Server> {
        ensure!(cfg.workers > 0, "need at least one worker");
        if cfg.threads != 0 {
            // the serving workers share the process-wide kernel pool with
            // everything else; outputs are thread-count invariant
            crate::kernels::pool::set_threads(cfg.threads);
        }
        ensure!(
            rt.has_exec("model_infer_ex"),
            "bundle '{}' has no model_infer_ex executable (re-export artifacts \
             or use a native-registry bundle)",
            cfg.model
        );
        ensure!(
            params.matches_manifest(&rt.manifest),
            "parameter structure does not match bundle '{}'",
            cfg.model
        );
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
        let addr = listener.local_addr()?;
        // /infer bodies are the exact binary wire format; /generate bodies
        // are JSON, so leave digits-and-commas headroom for a full-context
        // prompt
        let gen_body = 128 + 12 * rt.manifest.dims.seq;
        let max_body = wire::body_len(rt.manifest.family, &rt.manifest.dims)
            .max(512)
            .max(gen_body);
        let has_decode = rt.has_exec("model_decode_step");
        let shared = Arc::new(Shared {
            rt,
            params,
            queue: BatchQueue::bounded(cfg.queue_cap),
            stats: ServeStats::new(LATENCY_RESERVOIR),
            shutdown: AtomicBool::new(false),
            addr,
            workers: cfg.workers,
            batch_window: cfg.batch_window,
            max_body,
            sink,
            gen_queue: genserve::GenQueue::new(),
        });
        let mut threads = Vec::with_capacity(cfg.workers + 2);
        if has_decode {
            let sh = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("bdia-genscheduler".into())
                    .spawn(move || genserve::scheduler_loop(&sh))?,
            );
        }
        for wi in 0..cfg.workers {
            let sh = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("bdia-worker-{wi}"))
                    .spawn(move || worker_loop(&sh))?,
            );
        }
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("bdia-listener".into())
                .spawn(move || listener_loop(listener, &sh))?,
        );
        Ok(Server { shared, threads })
    }

    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Begin graceful shutdown: stop accepting, drain the queue, stop
    /// workers.  Idempotent; `join` afterwards to wait it out.
    pub fn stop(&self) {
        initiate_shutdown(&self.shared);
    }

    /// Wait for the listener and all workers to exit.
    pub fn join(self) -> Result<()> {
        for t in self.threads {
            t.join().map_err(|_| anyhow::anyhow!("server thread panicked"))?;
        }
        Ok(())
    }

    /// `stop` + `join`.
    pub fn shutdown(self) -> Result<()> {
        self.stop();
        self.join()
    }
}

/// The shared `503` contract (single-process server and fleet router):
/// `Retry-After` header plus a JSON body naming the queue depth, the cap
/// and the request id, so clients can implement informed backoff and
/// correlate the rejection.  `cap = None` renders as 0 (unbounded).
pub(crate) fn write_503(
    stream: &TcpStream,
    error: &str,
    depth: usize,
    cap: Option<usize>,
    request_id: &str,
) -> Result<()> {
    let body = format!(
        "{{\"error\": \"{error}\", \"request_id\": \"{request_id}\", \
         \"queue_depth\": {depth}, \"queue_cap\": {}, \"retry_after_s\": 1}}",
        cap.unwrap_or(0)
    );
    http::write_response_with(
        stream,
        503,
        "Service Unavailable",
        "application/json",
        &[
            ("Retry-After", "1".to_string()),
            ("X-Request-Id", request_id.to_string()),
        ],
        body.as_bytes(),
    )
}

/// JSON error body carrying the correlation id every error response echoes.
pub(crate) fn error_body(error: &str, request_id: &str) -> String {
    format!(
        "{{\"error\": \"{}\", \"request_id\": \"{request_id}\"}}",
        error.escape_default()
    )
}

fn initiate_shutdown(shared: &Shared) {
    if shared.shutdown.swap(true, Ordering::SeqCst) {
        return; // already shutting down
    }
    shared.queue.shutdown();
    shared.gen_queue.shutdown();
    // poke the blocking accept() so the listener observes the flag
    let _ = TcpStream::connect(shared.addr);
}

fn listener_loop(listener: TcpListener, shared: &Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let sh = Arc::clone(shared);
                // thread-per-connection: connections are short (one request,
                // Connection: close) and the real concurrency limit is the
                // worker pool behind the queue
                let _ = std::thread::Builder::new()
                    .name("bdia-conn".into())
                    .spawn(move || handle_conn(&s, &sh));
            }
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
        }
    }
}

fn worker_loop(shared: &Arc<Shared>) {
    let max_batch = shared.rt.manifest.dims.batch;
    while let Some(batch) =
        shared.queue.next_batch(max_batch, shared.batch_window)
    {
        let _span = crate::span!("serve_batch", n = batch.len(), gamma = batch[0].gamma);
        let gamma = batch[0].gamma;
        let examples: Vec<wire::Example> =
            batch.iter().map(|j| j.example.clone()).collect();
        let result =
            wire::infer_batch(&shared.rt, &shared.params, &examples, gamma);
        shared.stats.record_batch(batch.len());
        match result {
            Ok(per_ex) => {
                for (job, r) in batch.iter().zip(per_ex) {
                    let _ = job.resp.send(Ok(r));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for job in &batch {
                    let _ = job.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

fn handle_conn(stream: &TcpStream, shared: &Arc<Shared>) {
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT)).ok();
    stream.set_nodelay(true).ok();
    let req = match http::read_request_capped(stream, shared.max_body) {
        Ok(r) => r,
        Err(e) => {
            // the request never yielded a client id (bad framing / 413):
            // mint one so even this rejection is correlatable
            let rid = crate::obs::fresh_request_id();
            let _ = http::write_response_with(
                stream,
                e.status,
                e.reason,
                "application/json",
                &[("X-Request-Id", rid.clone())],
                error_body(&format!("{e}"), &rid).as_bytes(),
            );
            return;
        }
    };
    let rid = req.request_id.clone().unwrap_or_else(crate::obs::fresh_request_id);
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/infer") => handle_infer(stream, shared, &req.body, &rid),
        ("POST", "/generate") => {
            genserve::handle_generate(stream, shared, &req.body, &rid)
        }
        ("GET", "/healthz") => {
            let body = format!(
                "{{\"status\": \"ok\", \"model\": \"{}\", \"backend\": \"{}\"}}",
                shared.rt.manifest.name,
                shared.rt.backend.name()
            );
            let _ = http::write_response(
                stream,
                200,
                "OK",
                "application/json",
                body.as_bytes(),
            );
        }
        ("GET", "/stats") => {
            let body = shared.stats.to_json(
                &shared.rt.call_counts(),
                shared.workers,
                shared.queue.len(),
                shared.queue.cap(),
            );
            let _ = http::write_response(
                stream,
                200,
                "OK",
                "application/json",
                body.as_bytes(),
            );
        }
        ("GET", "/metrics") => {
            let body = shared.stats.metrics_text(&shared.rt.call_counts());
            let _ = http::write_response(
                stream,
                200,
                "OK",
                "text/plain; version=0.0.4",
                body.as_bytes(),
            );
        }
        ("POST", "/shutdown") => {
            let _ = http::write_response(
                stream,
                200,
                "OK",
                "text/plain",
                b"shutting down\n",
            );
            initiate_shutdown(shared);
        }
        (_, path) => {
            let _ = http::write_response(
                stream,
                404,
                "Not Found",
                "text/plain",
                format!("no such endpoint: {path}\n").as_bytes(),
            );
        }
    }
}

fn handle_infer(stream: &TcpStream, shared: &Arc<Shared>, body: &[u8], rid: &str) {
    let t0 = Instant::now();
    let _span = crate::span!("serve_request", request_id = rid);
    let m = &shared.rt.manifest;
    let (example, gamma) = match wire::decode(m.family, &m.dims, body) {
        Ok(v) => v,
        Err(e) => {
            shared.stats.record_error();
            shared.sink.on_request(&RequestEvent {
                latency_us: t0.elapsed().as_micros() as u64,
                elapsed_us: crate::obs::now_us(),
                ok: false,
            });
            let _ = http::write_response_with(
                stream,
                400,
                "Bad Request",
                "application/json",
                &[("X-Request-Id", rid.to_string())],
                error_body(&format!("{e:#}"), rid).as_bytes(),
            );
            return;
        }
    };
    let (tx, rx) = mpsc::channel();
    let outcome = shared.queue.push(Job {
        example,
        gamma,
        enqueued: t0,
        request_id: rid.to_string(),
        resp: tx,
    });
    match outcome {
        batcher::PushOutcome::Accepted => {}
        batcher::PushOutcome::Saturated { depth, cap } => {
            shared.sink.on_request(&RequestEvent {
                latency_us: t0.elapsed().as_micros() as u64,
                elapsed_us: crate::obs::now_us(),
                ok: false,
            });
            let _ = write_503(stream, "queue full", depth, Some(cap), rid);
            return;
        }
        batcher::PushOutcome::ShuttingDown => {
            shared.sink.on_request(&RequestEvent {
                latency_us: t0.elapsed().as_micros() as u64,
                elapsed_us: crate::obs::now_us(),
                ok: false,
            });
            let _ = write_503(
                stream,
                "server is shutting down",
                shared.queue.len(),
                shared.queue.cap(),
                rid,
            );
            return;
        }
    }
    let outcome = rx.recv();
    let latency_us = t0.elapsed().as_micros() as u64;
    shared.sink.on_request(&RequestEvent {
        latency_us,
        elapsed_us: crate::obs::now_us(),
        ok: matches!(outcome, Ok(Ok(_))),
    });
    match outcome {
        Ok(Ok((loss, correct))) => {
            let mut out = [0u8; 8];
            out[..4].copy_from_slice(&loss.to_le_bytes());
            out[4..].copy_from_slice(&correct.to_le_bytes());
            shared.stats.record_request();
            shared.stats.record_latency_us(latency_us);
            let _ = http::write_response_with(
                stream,
                200,
                "OK",
                "application/octet-stream",
                &[("X-Request-Id", rid.to_string())],
                &out,
            );
        }
        Ok(Err(msg)) => {
            shared.stats.record_error();
            let _ = http::write_response_with(
                stream,
                500,
                "Internal Server Error",
                "application/json",
                &[("X-Request-Id", rid.to_string())],
                error_body(&msg, rid).as_bytes(),
            );
        }
        Err(_) => {
            shared.stats.record_error();
            let _ = http::write_response_with(
                stream,
                500,
                "Internal Server Error",
                "application/json",
                &[("X-Request-Id", rid.to_string())],
                error_body("worker pool unavailable", rid).as_bytes(),
            );
        }
    }
}
