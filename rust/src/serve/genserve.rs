//! The streaming `/generate` endpoint behind the single-process server:
//! request parsing, the per-tick decode scheduler, and the chunked
//! response writer.
//!
//! ## Scheduling model
//!
//! One dedicated scheduler thread owns every in-flight [`GenSession`] and
//! advances each by **one position per tick** through
//! [`crate::generate::decode_tick`].  Sessions join and leave only
//! *between* ticks (the handler pushes onto [`GenQueue`]; the scheduler
//! drains it at the top of each tick), and within a tick sessions are
//! grouped by position — `model_decode_step` takes one `pos` scalar, so
//! batching is **by shape only**.  Gamma never mixes because the server
//! pins every session to the paper's standard inference γ = 0.0.
//!
//! Because per-lane decode outputs are packing-invariant, a token
//! streamed from a busy server is bit-identical to the same request run
//! through `Session::generate` alone — `tests/generate.rs` asserts this
//! over real sockets.
//!
//! Prompt prefill is tick-batched too: a joining session simply sits at
//! position 0 and emits nothing until its prompt is consumed, so long
//! prompts never stall other sessions' token cadence by more than one
//! decode step.

use super::http;
use super::Shared;
use crate::api::events::{RequestEvent, TokenEvent};
use crate::config::json::Json;
use crate::generate::{decode_tick, GenOpts, GenSession, GenStop};
use anyhow::{bail, Result};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// What the scheduler reports back to a waiting connection handler.
pub(super) enum GenEvent {
    /// One generated token (`us` = wall time of the decode tick that
    /// produced it).
    Token { index: usize, token: i32, us: u64 },
    /// Generation finished; the full generated sequence rides along so the
    /// terminal chunk can echo it.
    Done { stop: GenStop, prompt_len: usize, tokens: Vec<i32> },
    /// The decode step failed (engine error) — the session is dropped.
    Failed { msg: String },
}

/// One in-flight generation owned by the scheduler.
pub(super) struct GenJob {
    session: GenSession,
    events: mpsc::Sender<GenEvent>,
    /// Tokens emitted so far (the event index).
    emitted: usize,
    /// Set when the client hung up or the engine failed; retired at the
    /// end of the tick.
    dead: bool,
}

struct QueueState {
    jobs: Vec<GenJob>,
    shutdown: bool,
}

/// Join point between connection handlers and the scheduler thread.
pub(super) struct GenQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl GenQueue {
    pub(super) fn new() -> Self {
        GenQueue {
            state: Mutex::new(QueueState { jobs: Vec::new(), shutdown: false }),
            cv: Condvar::new(),
        }
    }

    /// Hand a session to the scheduler; `false` once shutdown began.
    fn push(&self, job: GenJob) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return false;
        }
        st.jobs.push(job);
        self.cv.notify_all();
        true
    }

    /// Take every queued join.  Blocks while the scheduler is otherwise
    /// idle (`block`), returning immediately when it has live sessions to
    /// advance.  Second return is the shutdown flag.
    fn drain(&self, block: bool) -> (Vec<GenJob>, bool) {
        let mut st = self.state.lock().unwrap();
        if block {
            while st.jobs.is_empty() && !st.shutdown {
                st = self.cv.wait(st).unwrap();
            }
        }
        (std::mem::take(&mut st.jobs), st.shutdown)
    }

    /// Begin shutdown: refuse new joins and wake the scheduler.
    pub(super) fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.cv.notify_all();
    }
}

/// Parse a `/generate` request body: `{"prompt": [..], "max_tokens": N,
/// "temperature": T, "top_k": K, "seed": S, "eos": E}` — everything but
/// `prompt` optional.  Gamma is **not** a request field: the server pins
/// γ = 0.0 so ticks batch by shape alone.
pub(super) fn parse_request(body: &[u8]) -> Result<(Vec<i32>, GenOpts)> {
    let text = std::str::from_utf8(body)?;
    let j = Json::parse(text)?;
    let prompt = match j.get("prompt")? {
        Json::Arr(a) => a
            .iter()
            .map(|v| v.as_i64().map(|x| x as i32))
            .collect::<Result<Vec<i32>>>()?,
        other => bail!("prompt must be an array of token ids, got {other}"),
    };
    let mut opts = GenOpts::default();
    if let Some(v) = j.opt("max_tokens") {
        opts.max_tokens = v.as_usize()?;
    }
    if let Some(v) = j.opt("temperature") {
        opts.temperature = v.as_f64()? as f32;
    }
    if let Some(v) = j.opt("top_k") {
        opts.top_k = v.as_usize()?;
    }
    if let Some(v) = j.opt("seed") {
        opts.seed = v.as_i64()? as u64;
    }
    if let Some(v) = j.opt("eos") {
        if !matches!(v, Json::Null) {
            opts.eos = Some(v.as_i64()? as i32);
        }
    }
    Ok((prompt, opts))
}

/// The scheduler thread body: drain joins, advance every live session one
/// position, stream tokens, retire finished sessions; exit on shutdown
/// (failing whatever is still queued or in flight).
pub(super) fn scheduler_loop(shared: &Arc<Shared>) {
    let batch = shared.rt.manifest.dims.batch.max(1);
    let mut active: Vec<GenJob> = Vec::new();
    loop {
        let (joined, shutdown) = shared.gen_queue.drain(active.is_empty());
        if shutdown {
            for j in joined.into_iter().chain(active.drain(..)) {
                let _ = j.events.send(GenEvent::Failed {
                    msg: "server is shutting down".into(),
                });
                shared.stats.gen_session_left();
            }
            return;
        }
        active.extend(joined);

        // group by position (one pos scalar per call), then advance each
        // group in lane-sized slices; per-lane outputs are
        // packing-invariant so the grouping never changes results
        active.sort_by_key(|j| j.session.pos());
        let mut i = 0;
        while i < active.len() {
            let pos = active[i].session.pos();
            let mut end = i + 1;
            while end < active.len() && active[end].session.pos() == pos {
                end += 1;
            }
            for start in (i..end).step_by(batch) {
                let jobs = &mut active[start..(start + batch).min(end)];
                tick_slice(shared, jobs);
            }
            i = end;
        }
        for j in &mut active {
            if j.dead {
                continue;
            }
            if let Some(stop) = j.session.stop() {
                j.dead = true;
                let _ = j.events.send(GenEvent::Done {
                    stop,
                    prompt_len: j.session.tokens().len()
                        - j.session.generated().len(),
                    tokens: j.session.generated().to_vec(),
                });
            }
        }
        let before = active.len();
        active.retain(|j| !j.dead);
        for _ in active.len()..before {
            shared.stats.gen_session_left();
        }
    }
}

/// One `model_decode_step` call over a same-position slice of sessions.
fn tick_slice(shared: &Arc<Shared>, jobs: &mut [GenJob]) {
    let _span = crate::span!("generate_tick", n = jobs.len(), pos = jobs[0].session.pos());
    let t0 = Instant::now();
    let mut sessions: Vec<&mut GenSession> =
        jobs.iter_mut().map(|j| &mut j.session).collect();
    let emitted = decode_tick(&shared.rt, &shared.params, &mut sessions);
    drop(sessions);
    match emitted {
        Ok(toks) => {
            let us = t0.elapsed().as_micros() as u64;
            for (j, tok) in jobs.iter_mut().zip(toks) {
                if let Some(token) = tok {
                    shared.stats.record_tokens(1);
                    let index = j.emitted;
                    j.emitted += 1;
                    if j.events.send(GenEvent::Token { index, token, us }).is_err()
                    {
                        // client hung up mid-stream: abandon the session
                        j.dead = true;
                    }
                }
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for j in jobs.iter_mut() {
                let _ = j.events.send(GenEvent::Failed { msg: msg.clone() });
                j.dead = true;
            }
        }
    }
}

/// The `POST /generate` connection handler: parse, join the scheduler,
/// stream one JSON line per token as a chunk, close with a terminal
/// summary chunk.
pub(super) fn handle_generate(
    stream: &TcpStream,
    shared: &Arc<Shared>,
    body: &[u8],
    rid: &str,
) {
    let t0 = Instant::now();
    let _span = crate::span!("serve_request", request_id = rid, endpoint = "generate");
    if !shared.rt.has_exec("model_decode_step") {
        let body = format!(
            "{{\"error\": \"generation requires a GPT-family model; '{}' is \
             {:?}\", \"request_id\": \"{rid}\"}}",
            shared.rt.manifest.name, shared.rt.manifest.family
        );
        let _ = http::write_response_with(
            stream,
            501,
            "Not Implemented",
            "application/json",
            &[("X-Request-Id", rid.to_string())],
            body.as_bytes(),
        );
        return;
    }
    let fail = |status: u16, reason: &str, msg: &str| {
        shared.stats.record_error();
        shared.sink.on_request(&RequestEvent {
            latency_us: t0.elapsed().as_micros() as u64,
            elapsed_us: crate::obs::now_us(),
            ok: false,
        });
        let body = format!(
            "{{\"error\": \"{}\", \"request_id\": \"{rid}\"}}\n",
            msg.replace('"', "'")
        );
        let _ = http::write_response_with(
            stream,
            status,
            reason,
            "application/json",
            &[("X-Request-Id", rid.to_string())],
            body.as_bytes(),
        );
    };
    let (prompt, opts) = match parse_request(body) {
        Ok(v) => v,
        Err(e) => return fail(400, "Bad Request", &format!("{e:#}")),
    };
    let session = match GenSession::new(&shared.rt, &prompt, opts) {
        Ok(s) => s,
        Err(e) => return fail(400, "Bad Request", &format!("{e:#}")),
    };
    let (tx, rx) = mpsc::channel();
    shared.stats.gen_session_joined();
    let accepted = shared.gen_queue.push(GenJob {
        session,
        events: tx,
        emitted: 0,
        dead: false,
    });
    if !accepted {
        shared.stats.gen_session_left();
        return fail(503, "Service Unavailable", "server is shutting down");
    }
    let head = http::write_chunked_head_with(
        stream,
        200,
        "OK",
        "application/json",
        &[("X-Request-Id", rid.to_string())],
    );
    if head.is_err() {
        return; // scheduler notices the dropped receiver on next token
    }
    loop {
        match rx.recv() {
            Ok(GenEvent::Token { index, token, us }) => {
                shared.sink.on_token(&TokenEvent {
                    index,
                    token,
                    latency_us: us,
                    elapsed_us: crate::obs::now_us(),
                });
                let line = format!("{{\"index\": {index}, \"token\": {token}}}\n");
                if http::write_chunk(stream, line.as_bytes()).is_err() {
                    // dropping rx makes the scheduler abandon the session
                    return;
                }
            }
            Ok(GenEvent::Done { stop, prompt_len, tokens }) => {
                let toks: Vec<String> =
                    tokens.iter().map(|t| t.to_string()).collect();
                let line = format!(
                    "{{\"done\": true, \"stop\": \"{}\", \"prompt_len\": \
                     {prompt_len}, \"tokens\": [{}]}}\n",
                    stop.name(),
                    toks.join(", ")
                );
                let _ = http::write_chunk(stream, line.as_bytes());
                let _ = http::finish_chunked(stream);
                let latency_us = t0.elapsed().as_micros() as u64;
                shared.stats.record_request();
                shared.stats.record_latency_us(latency_us);
                shared.sink.on_request(&RequestEvent {
                    latency_us,
                    elapsed_us: crate::obs::now_us(),
                    ok: true,
                });
                return;
            }
            Ok(GenEvent::Failed { msg }) => {
                let line = format!(
                    "{{\"error\": \"{}\"}}\n",
                    msg.replace('"', "'").replace('\n', " ")
                );
                let _ = http::write_chunk(stream, line.as_bytes());
                let _ = http::finish_chunked(stream);
                shared.stats.record_error();
                shared.sink.on_request(&RequestEvent {
                    latency_us: t0.elapsed().as_micros() as u64,
                    elapsed_us: crate::obs::now_us(),
                    ok: false,
                });
                return;
            }
            Err(_) => {
                // scheduler dropped the sender without a terminal event
                let _ = http::write_chunk(
                    stream,
                    b"{\"error\": \"generation scheduler exited\"}\n",
                );
                let _ = http::finish_chunked(stream);
                shared.stats.record_error();
                shared.sink.on_request(&RequestEvent {
                    latency_us: t0.elapsed().as_micros() as u64,
                    elapsed_us: crate::obs::now_us(),
                    ok: false,
                });
                return;
            }
        }
    }
}
