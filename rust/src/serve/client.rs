//! Minimal blocking HTTP client for the serving endpoints — used by
//! `bdia bench-serve`, the smoke tests, and anyone driving a `bdia serve`
//! instance from Rust.  One connection per request (`Connection: close`).

use super::http;
use anyhow::{ensure, Context, Result};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);
const IO_TIMEOUT: Duration = Duration::from_secs(60);

fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    let stream = TcpStream::connect_timeout(&addr, CONNECT_TIMEOUT)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    stream.set_nodelay(true).ok();
    http::write_request(&stream, method, path, body)?;
    http::read_response(&stream)
}

pub fn get(addr: SocketAddr, path: &str) -> Result<(u16, Vec<u8>)> {
    request(addr, "GET", path, b"")
}

pub fn post(addr: SocketAddr, path: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    request(addr, "POST", path, body)
}

/// POST an encoded example to `/infer`; returns the per-example
/// (loss, correct) pair, decoded from its raw little-endian bit patterns.
pub fn infer(addr: SocketAddr, body: &[u8]) -> Result<(f32, f32)> {
    let (status, resp) = post(addr, "/infer", body)?;
    ensure!(
        status == 200,
        "server returned {status}: {}",
        String::from_utf8_lossy(&resp)
    );
    ensure!(resp.len() == 8, "bad /infer response length {}", resp.len());
    Ok((
        f32::from_le_bytes(resp[0..4].try_into().unwrap()),
        f32::from_le_bytes(resp[4..8].try_into().unwrap()),
    ))
}

/// Ask the server to shut down gracefully.
pub fn shutdown(addr: SocketAddr) -> Result<()> {
    let (status, _) = post(addr, "/shutdown", b"")?;
    ensure!(status == 200, "shutdown returned {status}");
    Ok(())
}
