//! Serving statistics: request/batch/error counters, throughput since
//! start, plus a fixed-capacity latency reservoir with percentile
//! summaries.  Counters are relaxed atomics (the handlers and workers run
//! on many threads); the reservoir is a small mutex-guarded ring.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

pub struct ServeStats {
    requests: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    batched_examples: AtomicU64,
    lat_us: Mutex<Ring>,
    /// All-time worst latency (µs) — tracked outside the reservoir so the
    /// true maximum survives after the ring wraps.
    max_us: AtomicU64,
    /// Total latencies ever recorded (`> capacity` ⇒ the ring wrapped and
    /// the percentiles describe a recent window, not the full history).
    recorded: AtomicU64,
    /// Server start time — the denominator of the throughput numbers.
    started: Instant,
}

struct Ring {
    buf: Vec<u64>,
    next: usize,
    len: usize,
}

/// Latency summary in milliseconds.  Mean/percentiles describe the
/// reservoir window; `max_ms` is the all-time maximum since start.
#[derive(Clone, Copy, Debug)]
pub struct LatencySummary {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// State of the fixed-capacity latency reservoir behind the percentiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReservoirInfo {
    /// Samples currently held (≤ capacity).
    pub samples: usize,
    pub capacity: usize,
    /// True once the ring has wrapped: percentiles describe only the most
    /// recent `capacity` requests.
    pub saturated: bool,
}

/// Nearest-rank percentile over a sorted sample, `q` in [0, 1].
pub fn percentile_us(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl ServeStats {
    pub fn new(reservoir: usize) -> Self {
        ServeStats {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_examples: AtomicU64::new(0),
            lat_us: Mutex::new(Ring {
                buf: vec![0; reservoir.max(1)],
                next: 0,
                len: 0,
            }),
            max_us: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Seconds since the server started.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Completed requests per second since start.
    pub fn requests_per_sec(&self) -> f64 {
        self.requests() as f64 / self.uptime_s().max(1e-9)
    }

    /// Examples pushed through the executable per second since start
    /// (requests carry one example each, so this tracks `requests_per_sec`
    /// minus in-flight work).
    pub fn examples_per_sec(&self) -> f64 {
        self.batched_examples.load(Ordering::Relaxed) as f64
            / self.uptime_s().max(1e-9)
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One coalesced executable call covering `n` requests.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_examples.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn record_latency_us(&self, us: u64) {
        self.max_us.fetch_max(us, Ordering::Relaxed);
        self.recorded.fetch_add(1, Ordering::Relaxed);
        let mut r = self.lat_us.lock().unwrap();
        let cap = r.buf.len();
        let slot = r.next;
        r.buf[slot] = us;
        r.next = (slot + 1) % cap;
        r.len = (r.len + 1).min(cap);
    }

    /// Reservoir occupancy + whether the ring has wrapped — surfaced in
    /// `/stats` so a window-limited p99 cannot silently mislead.
    pub fn reservoir(&self) -> ReservoirInfo {
        let r = self.lat_us.lock().unwrap();
        ReservoirInfo {
            samples: r.len,
            capacity: r.buf.len(),
            saturated: self.recorded.load(Ordering::Relaxed) > r.buf.len() as u64,
        }
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Mean number of requests served per executable call — the headline
    /// "is dynamic batching engaging" number.
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_examples.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn latency(&self) -> Option<LatencySummary> {
        let r = self.lat_us.lock().unwrap();
        if r.len == 0 {
            return None;
        }
        let mut xs: Vec<u64> = r.buf[..r.len].to_vec();
        drop(r);
        xs.sort_unstable();
        let mean = xs.iter().sum::<u64>() as f64 / xs.len() as f64;
        Some(LatencySummary {
            mean_ms: mean / 1e3,
            p50_ms: percentile_us(&xs, 0.50) as f64 / 1e3,
            p90_ms: percentile_us(&xs, 0.90) as f64 / 1e3,
            p99_ms: percentile_us(&xs, 0.99) as f64 / 1e3,
            max_ms: self.max_us.load(Ordering::Relaxed) as f64 / 1e3,
        })
    }

    /// Render the `/stats` JSON document (hand-rolled — no serde offline).
    /// `queue_depth`/`queue_cap` describe the admission queue at render
    /// time (`None` cap = unbounded, rendered as 0).
    pub fn to_json(
        &self,
        exec_calls: &[(String, u64)],
        workers: usize,
        queue_depth: usize,
        queue_cap: Option<usize>,
    ) -> String {
        let lat = self.latency();
        let fmt_lat = |l: Option<LatencySummary>| match l {
            Some(l) => format!(
                "{{\"mean\": {:.3}, \"p50\": {:.3}, \"p90\": {:.3}, \
                 \"p99\": {:.3}, \"max\": {:.3}}}",
                l.mean_ms, l.p50_ms, l.p90_ms, l.p99_ms, l.max_ms
            ),
            None => "null".to_string(),
        };
        let res = self.reservoir();
        let calls: Vec<String> = exec_calls
            .iter()
            .map(|(n, c)| format!("\"{n}\": {c}"))
            .collect();
        let ws = crate::kernels::workspace::stats();
        format!(
            "{{\"requests\": {}, \"errors\": {}, \"batches\": {}, \
             \"mean_batch\": {:.4}, \"workers\": {workers}, \
             \"queue\": {{\"depth\": {queue_depth}, \"cap\": {}}}, \
             \"uptime_s\": {:.3}, \"requests_per_sec\": {:.3}, \
             \"examples_per_sec\": {:.3}, \"kernel_threads\": {}, \
             \"tune_profile\": \"{}\", \
             \"workspace\": {{\"hits\": {}, \"misses\": {}, \
             \"keyed_hits\": {}, \"keyed_builds\": {}}}, \
             \"latency_ms\": {}, \
             \"latency_reservoir\": {{\"samples\": {}, \"capacity\": {}, \
             \"saturated\": {}}}, \"exec_calls\": {{{}}}}}",
            self.requests(),
            self.errors(),
            self.batches(),
            self.mean_batch(),
            queue_cap.unwrap_or(0),
            self.uptime_s(),
            self.requests_per_sec(),
            self.examples_per_sec(),
            crate::kernels::pool::threads(),
            crate::kernels::profile::active_id(),
            ws.hits,
            ws.misses,
            ws.keyed_hits,
            ws.keyed_builds,
            fmt_lat(lat),
            res.samples,
            res.capacity,
            res.saturated,
            calls.join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::Json;

    #[test]
    fn counters_and_mean_batch() {
        let s = ServeStats::new(16);
        assert_eq!(s.mean_batch(), 0.0);
        s.record_batch(1);
        s.record_batch(3);
        for _ in 0..4 {
            s.record_request();
        }
        assert_eq!(s.requests(), 4);
        assert_eq!(s.batches(), 2);
        assert!((s.mean_batch() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles_and_ring_wrap() {
        let s = ServeStats::new(8);
        assert!(s.latency().is_none());
        assert_eq!(
            s.reservoir(),
            ReservoirInfo { samples: 0, capacity: 8, saturated: false }
        );
        for us in 1..=100u64 {
            s.record_latency_us(us * 1000);
        }
        let l = s.latency().unwrap();
        // ring keeps the last 8 samples: 93..=100 ms
        assert!(l.p50_ms >= 93.0 && l.p99_ms <= 100.0, "{l:?}");
        assert!(l.mean_ms >= 93.0 && l.mean_ms <= 100.0);
        // the wrapped window cannot hide the all-time worst request
        assert_eq!(l.max_ms, 100.0, "{l:?}");
        assert_eq!(
            s.reservoir(),
            ReservoirInfo { samples: 8, capacity: 8, saturated: true }
        );
    }

    #[test]
    fn max_survives_wrap_even_when_window_is_faster() {
        // one slow outlier, then enough fast requests to evict it from
        // the ring: p99 describes the window, max still tells the truth
        let s = ServeStats::new(4);
        s.record_latency_us(500_000);
        for _ in 0..10 {
            s.record_latency_us(1_000);
        }
        let l = s.latency().unwrap();
        assert!(l.p99_ms <= 1.0, "{l:?}");
        assert_eq!(l.max_ms, 500.0, "{l:?}");
        assert!(s.reservoir().saturated);
    }

    #[test]
    fn reservoir_not_saturated_before_wrap() {
        let s = ServeStats::new(8);
        for _ in 0..8 {
            s.record_latency_us(1_000);
        }
        // exactly full but never overwritten: percentiles still cover the
        // entire history
        assert_eq!(
            s.reservoir(),
            ReservoirInfo { samples: 8, capacity: 8, saturated: false }
        );
        s.record_latency_us(1_000);
        assert!(s.reservoir().saturated);
    }

    #[test]
    fn stats_json_parses_with_in_repo_parser() {
        let s = ServeStats::new(4);
        s.record_request();
        s.record_batch(2);
        s.record_latency_us(1500);
        let j = s.to_json(&[("model_infer_ex".into(), 1)], 4, 3, Some(1024));
        let parsed = Json::parse(&j).expect("valid json");
        assert_eq!(parsed.get("requests").unwrap().as_usize().unwrap(), 1);
        // admission queue state surfaces for backpressure diagnosis
        let queue = parsed.get("queue").unwrap();
        assert_eq!(queue.get("depth").unwrap().as_usize().unwrap(), 3);
        assert_eq!(queue.get("cap").unwrap().as_usize().unwrap(), 1024);
        // max + reservoir state surface so a wrapped p99 can't mislead
        assert!(
            parsed.get("latency_ms").unwrap().get("max").unwrap().as_f64().unwrap()
                >= 1.5
        );
        let res = parsed.get("latency_reservoir").unwrap();
        assert_eq!(res.get("samples").unwrap().as_usize().unwrap(), 1);
        assert_eq!(res.get("capacity").unwrap().as_usize().unwrap(), 4);
        // throughput + kernel-pool configuration surface in /stats
        assert!(parsed.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(parsed.get("requests_per_sec").unwrap().as_f64().unwrap() >= 0.0);
        assert!(parsed.get("examples_per_sec").unwrap().as_f64().unwrap() >= 0.0);
        assert!(parsed.get("kernel_threads").unwrap().as_usize().unwrap() >= 1);
        // the active kernel profile id surfaces alongside the pool config
        assert!(!parsed.get("tune_profile").unwrap().as_str().unwrap().is_empty());
        assert!(parsed.get("workspace").unwrap().get("hits").is_ok());
        assert!(parsed.get("workspace").unwrap().get("keyed_hits").is_ok());
        assert_eq!(
            parsed
                .get("exec_calls")
                .unwrap()
                .get("model_infer_ex")
                .unwrap()
                .as_usize()
                .unwrap(),
            1
        );
        assert!(parsed.get("mean_batch").unwrap().as_f64().unwrap() > 1.9);
    }

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile_us(&[], 0.5), 0);
        assert_eq!(percentile_us(&[7], 0.99), 7);
        let xs: Vec<u64> = (0..100).collect();
        assert_eq!(percentile_us(&xs, 0.0), 0);
        assert_eq!(percentile_us(&xs, 1.0), 99);
    }
}
