//! `bdia bench-serve`: load generator + verifier for the serving path.
//!
//! Self-hosts a server on an ephemeral port (or targets `--addr`), fires
//! `requests` inference calls from `concurrency` client threads over real
//! `TcpStream`s, then reports throughput, client-side latency percentiles,
//! the server's mean coalesced batch size (is dynamic batching engaging?),
//! and — the important part — verifies every response is bit-identical to a
//! direct local `model_infer_ex` call on the same parameters.

use super::{client, stats, wire, ServeConfig, Server};
use crate::checkpoint;
use crate::config::json::Json;
use crate::config::TrainConfig;
use crate::data::make_dataset;
use crate::model::{Family, ParamStore};
use crate::runtime::{BackendKind, Runtime};
use anyhow::{ensure, Context, Result};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchOpts {
    pub model: String,
    pub backend: BackendKind,
    pub artifacts_dir: PathBuf,
    pub ckpt: Option<PathBuf>,
    /// Target an already-running server; `None` self-hosts one.
    pub addr: Option<SocketAddr>,
    /// Worker pool size for the self-hosted server.
    pub workers: usize,
    pub requests: usize,
    pub concurrency: usize,
    pub gamma: f32,
    pub batch_window: Duration,
    /// Kernel thread-pool size for the self-hosted server (0 = auto).
    pub threads: usize,
    /// Compare responses against local inference (assumes the server runs
    /// the same params: same --ckpt, or both seed-initialized).
    pub verify: bool,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            model: "vit_s10".into(),
            backend: BackendKind::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            ckpt: None,
            addr: None,
            workers: 4,
            requests: 256,
            concurrency: 8,
            gamma: 0.0,
            batch_window: Duration::from_millis(2),
            threads: 0,
            verify: true,
        }
    }
}

/// Headline numbers, returned so tests/CLI can assert on them.
#[derive(Clone, Copy, Debug)]
pub struct BenchSummary {
    pub requests: usize,
    pub errors: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    pub mismatches: usize,
}

/// Synthetic dataset that matches a model family (bench payloads).
pub fn default_dataset(family: Family) -> &'static str {
    match family {
        Family::Vit => "synth_cifar10",
        Family::Gpt => "tiny_corpus",
        Family::EncDec => "synth_translation",
    }
}

pub fn run(opts: &BenchOpts) -> Result<BenchSummary> {
    // local reference runtime: payload generation + verification
    let rt =
        Runtime::load_with(&opts.artifacts_dir, &opts.model, opts.backend)?;
    let params = match &opts.ckpt {
        Some(p) => {
            let ck = checkpoint::load(p)?;
            ensure!(ck.model == opts.model, "checkpoint/model mismatch");
            ensure!(
                ck.params.matches_manifest(&rt.manifest),
                "checkpoint {} does not match bundle '{}'",
                p.display(),
                opts.model
            );
            ck.params
        }
        None => ParamStore::init(&rt.manifest, 0),
    };

    // self-host unless pointed at an external server
    let (server, addr) = match opts.addr {
        Some(a) => (None, a),
        None => {
            let srv = Server::start(ServeConfig {
                model: opts.model.clone(),
                backend: opts.backend,
                artifacts_dir: opts.artifacts_dir.clone(),
                ckpt: opts.ckpt.clone(),
                port: 0,
                workers: opts.workers,
                batch_window: opts.batch_window,
                threads: opts.threads,
                ..ServeConfig::default()
            })?;
            let a = srv.addr();
            println!(
                "bench-serve: self-hosted {} on {a} ({} workers, window {:?})",
                opts.model, opts.workers, opts.batch_window
            );
            (Some(srv), a)
        }
    };

    let summary = run_against(opts, &rt, &params, addr);
    if let Some(srv) = server {
        client::shutdown(addr).context("graceful shutdown")?;
        srv.join()?;
    }
    summary
}

/// Fire the load at an already-running server and verify against the given
/// reference runtime + parameters.  `api::Session::bench_serve` self-hosts
/// through the session (its live, possibly just-trained weights) and calls
/// this; [`run`] wraps it with checkpoint loading + self-hosting for the
/// standalone path.  The server must stay up until this returns (it reads
/// `/stats` at the end).
pub fn run_against(
    opts: &BenchOpts,
    rt: &Runtime,
    params: &ParamStore,
    addr: SocketAddr,
) -> Result<BenchSummary> {
    ensure!(opts.requests > 0 && opts.concurrency > 0, "need requests > 0");
    let family = rt.manifest.family;

    // build a pool of distinct payloads from the held-out split
    let cfg = TrainConfig {
        model: opts.model.clone(),
        dataset: default_dataset(family).into(),
        ..TrainConfig::default()
    };
    let ds = make_dataset(&cfg, &rt.manifest.dims, family)?;
    let pool_target = opts.requests.min(64);
    let nvb = ds.n_val_batches().max(1);
    let mut pool = Vec::new();
    let mut bi = 0usize;
    while pool.len() < pool_target {
        pool.extend(wire::examples_from_batch(&ds.val_batch(bi % nvb)));
        bi += 1;
    }
    pool.truncate(pool_target);
    let bodies: Arc<Vec<Vec<u8>>> = Arc::new(
        pool.iter().map(|e| wire::encode(e, opts.gamma)).collect(),
    );

    // fire the load
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for tid in 0..opts.concurrency {
        let bodies = Arc::clone(&bodies);
        let (requests, conc) = (opts.requests, opts.concurrency);
        handles.push(std::thread::spawn(move || {
            let mut out: Vec<(usize, u64, Result<(f32, f32), String>)> =
                Vec::new();
            let mut i = tid;
            while i < requests {
                let body = &bodies[i % bodies.len()];
                let t = Instant::now();
                let res = client::infer(addr, body).map_err(|e| format!("{e:#}"));
                out.push((i, t.elapsed().as_micros() as u64, res));
                i += conc;
            }
            out
        }));
    }
    let mut results = Vec::with_capacity(opts.requests);
    for h in handles {
        results.extend(h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))?);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // server-side stats (the caller shuts the server down after we return)
    let (_, stats_body) = client::get(addr, "/stats")?;
    let stats_json = String::from_utf8_lossy(&stats_body).to_string();
    let mean_batch = Json::parse(&stats_json)
        .ok()
        .and_then(|j| j.get("mean_batch").ok().and_then(|v| v.as_f64().ok()))
        .unwrap_or(0.0);

    // client-side latency summary
    let mut lat: Vec<u64> = results.iter().map(|(_, us, _)| *us).collect();
    lat.sort_unstable();
    let errors = results.iter().filter(|(_, _, r)| r.is_err()).count();
    if let Some((i, _, Err(e))) = results.iter().find(|(_, _, r)| r.is_err()) {
        eprintln!("first error (request {i}): {e}");
    }

    // bit-exactness verification against direct local inference
    let mut mismatches = 0usize;
    if opts.verify {
        let expected: Vec<(f32, f32)> = pool
            .iter()
            .map(|e| wire::infer_one(rt, params, e, opts.gamma))
            .collect::<Result<_>>()?;
        for (i, _, r) in &results {
            if let Ok((loss, correct)) = r {
                let (el, ec) = expected[i % expected.len()];
                if loss.to_bits() != el.to_bits() || correct.to_bits() != ec.to_bits()
                {
                    mismatches += 1;
                }
            }
        }
    }

    let ok = results.len() - errors;
    let summary = BenchSummary {
        requests: results.len(),
        errors,
        wall_s,
        throughput_rps: ok as f64 / wall_s.max(1e-9),
        mean_batch,
        mismatches,
    };

    println!(
        "bench-serve: {} requests ({} errors) in {:.2}s -> {:.1} req/s",
        summary.requests, summary.errors, summary.wall_s, summary.throughput_rps
    );
    if !lat.is_empty() {
        println!(
            "  latency ms: mean {:.2}  p50 {:.2}  p90 {:.2}  p99 {:.2}",
            lat.iter().sum::<u64>() as f64 / lat.len() as f64 / 1e3,
            stats::percentile_us(&lat, 0.50) as f64 / 1e3,
            stats::percentile_us(&lat, 0.90) as f64 / 1e3,
            stats::percentile_us(&lat, 0.99) as f64 / 1e3,
        );
    }
    println!(
        "  mean coalesced batch {:.2} ({})",
        summary.mean_batch,
        if summary.mean_batch > 1.0 {
            "dynamic batching engaged"
        } else {
            "no coalescing observed"
        }
    );
    if opts.verify {
        println!(
            "  verification: {}/{} responses bit-identical to direct \
             model_infer_ex",
            ok - summary.mismatches,
            ok
        );
    }
    println!("  server stats: {stats_json}");
    Ok(summary)
}
