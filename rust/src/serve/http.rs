//! Minimal HTTP/1.1 framing over `std::net` — just enough protocol for the
//! inference server and its load generator: one request per connection
//! (`Connection: close`), `Content-Length` bodies, no chunked encoding, no
//! keep-alive.  No external crates, by construction.

use anyhow::{ensure, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Upper bound on accepted bodies — a full ViT image is ~12KB, so 16MB is
/// generous headroom for any registered bundle.
const MAX_BODY: usize = 16 << 20;
/// Start line / header line length cap (bounds per-connection memory).
const MAX_LINE: u64 = 8 << 10;
/// Header count cap.
const MAX_HEADERS: usize = 64;

pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Read one `\n`-terminated line of at most `MAX_LINE` bytes — a client
/// streaming an endless unterminated line gets an error, not an OOM.
fn read_line_capped(r: &mut impl BufRead) -> Result<String> {
    let mut line = String::new();
    let n = r
        .take(MAX_LINE)
        .read_line(&mut line)
        .context("reading protocol line")?;
    ensure!(n > 0, "connection closed mid-request");
    ensure!(
        line.ends_with('\n') || (n as u64) < MAX_LINE,
        "protocol line exceeds {MAX_LINE} bytes"
    );
    Ok(line)
}

/// Read one request (start line + headers + `Content-Length` body).
pub fn read_request(stream: &TcpStream) -> Result<Request> {
    let mut r = BufReader::new(stream);
    let line = read_line_capped(&mut r)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_string();
    let path = parts.next().context("request line missing path")?.to_string();
    let content_len = read_headers(&mut r)?;
    ensure!(content_len <= MAX_BODY, "request body too large ({content_len})");
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).context("reading request body")?;
    Ok(Request { method, path, body })
}

/// Consume header lines until the blank separator; returns Content-Length.
fn read_headers(r: &mut impl BufRead) -> Result<usize> {
    let mut content_len = 0usize;
    for _ in 0..MAX_HEADERS {
        let h = read_line_capped(r)?;
        let h = h.trim();
        if h.is_empty() {
            return Ok(content_len);
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    anyhow::bail!("too many headers (> {MAX_HEADERS})")
}

/// Write a response with status, content type and body.
pub fn write_response(
    stream: &TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    let mut s = stream;
    write!(
        s,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    s.write_all(body)?;
    s.flush()?;
    Ok(())
}

/// Client side: write one request.
pub fn write_request(
    stream: &TcpStream,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<()> {
    let mut s = stream;
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: bdia\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    )?;
    s.write_all(body)?;
    s.flush()?;
    Ok(())
}

/// Client side: read one response; returns (status, body).
pub fn read_response(stream: &TcpStream) -> Result<(u16, Vec<u8>)> {
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    r.read_line(&mut line).context("reading status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .context("malformed status line")?
        .parse()
        .context("non-numeric status")?;
    let content_len = read_headers(&mut r)?;
    ensure!(content_len <= MAX_BODY, "response body too large");
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).context("reading response body")?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn request_response_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let req = read_request(&stream).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/echo");
            write_response(&stream, 200, "OK", "application/octet-stream", &req.body)
                .unwrap();
        });
        let stream = TcpStream::connect(addr).unwrap();
        write_request(&stream, "POST", "/echo", b"\x01\x02\x03").unwrap();
        let (status, body) = read_response(&stream).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"\x01\x02\x03");
        server.join().unwrap();
    }
}
