//! Minimal HTTP/1.1 framing over `std::net` — just enough protocol for the
//! inference server and its load generator: one request per connection
//! (`Connection: close`), `Content-Length` bodies, plus chunked
//! transfer-encoding on the *response* side only (the streaming
//! `/generate` endpoint emits one chunk per token).  No keep-alive.  No
//! external crates, by construction.
//!
//! The request reader is hardened against hostile inputs: header lines,
//! header counts and body sizes are all bounded, and the body buffer grows
//! incrementally as bytes actually arrive — a lying `Content-Length` can
//! never reserve memory up front.  Failures carry a typed [`HttpError`]
//! with the status the server should answer (`400`/`413`/`431`), so the
//! single-process server and the fleet router front door share one
//! rejection contract.

use anyhow::{ensure, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Default upper bound on accepted bodies — a full ViT image is ~12KB, so
/// 16MB is generous headroom for any registered bundle.  Servers that know
/// their exact wire format pass a tighter cap to [`read_request_capped`].
pub const MAX_BODY: usize = 16 << 20;
/// Start line / header line length cap (bounds per-connection memory).
const MAX_LINE: u64 = 8 << 10;
/// Header count cap.
const MAX_HEADERS: usize = 64;
/// Body copy granularity: memory is committed per chunk received, never
/// from the declared Content-Length.
const BODY_CHUNK: usize = 8 << 10;

pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Client-supplied `X-Request-Id` (sanitized), if any.  Handlers echo
    /// it instead of minting a fresh id so callers can correlate retries,
    /// logs and spans across the router/replica split.
    pub request_id: Option<String>,
}

/// A typed request-read failure: the status line the server should answer
/// with plus a human-readable detail for the response body.
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub reason: &'static str,
    pub detail: String,
}

impl HttpError {
    fn bad(detail: impl Into<String>) -> Self {
        HttpError { status: 400, reason: "Bad Request", detail: detail.into() }
    }

    fn too_large(declared: usize, cap: usize) -> Self {
        HttpError {
            status: 413,
            reason: "Payload Too Large",
            detail: format!(
                "declared body of {declared} bytes exceeds this endpoint's \
                 limit of {cap} bytes"
            ),
        }
    }

    fn header_overflow(detail: impl Into<String>) -> Self {
        HttpError {
            status: 431,
            reason: "Request Header Fields Too Large",
            detail: detail.into(),
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}: {}", self.status, self.reason, self.detail)
    }
}

impl std::error::Error for HttpError {}

/// Read one `\n`-terminated line of at most `MAX_LINE` bytes — a client
/// streaming an endless unterminated line gets an error, not an OOM.
fn read_line_capped(r: &mut impl BufRead) -> std::result::Result<String, HttpError> {
    let mut line = String::new();
    let n = r
        .take(MAX_LINE)
        .read_line(&mut line)
        .map_err(|e| HttpError::bad(format!("reading protocol line: {e}")))?;
    if n == 0 {
        return Err(HttpError::bad("connection closed mid-request"));
    }
    if !line.ends_with('\n') && (n as u64) >= MAX_LINE {
        return Err(HttpError::header_overflow(format!(
            "protocol line exceeds {MAX_LINE} bytes"
        )));
    }
    Ok(line)
}

/// Read one request with the default [`MAX_BODY`] cap, as a plain `anyhow`
/// error (the status classification is flattened into the message).
pub fn read_request(stream: &TcpStream) -> Result<Request> {
    read_request_capped(stream, MAX_BODY).map_err(|e| anyhow::anyhow!("{e}"))
}

/// Read one request (start line + headers + `Content-Length` body),
/// rejecting bodies over `max_body` with a typed `413` **before** any
/// allocation happens — the declared length is checked first, and the
/// bytes that do arrive are committed chunk by chunk.
pub fn read_request_capped(
    stream: &TcpStream,
    max_body: usize,
) -> std::result::Result<Request, HttpError> {
    let mut r = BufReader::new(stream);
    let line = read_line_capped(&mut r)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| HttpError::bad("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| HttpError::bad("request line missing path"))?
        .to_string();
    let (content_len, request_id) = read_headers(&mut r)?;
    if content_len > max_body {
        return Err(HttpError::too_large(content_len, max_body));
    }
    let body = read_body(&mut r, content_len)?;
    Ok(Request { method, path, body, request_id })
}

/// Incremental body read: the buffer grows with received bytes only, and a
/// connection that closes short of its declared length is a `400`, not a
/// hang or a partial success.
fn read_body(
    r: &mut impl BufRead,
    content_len: usize,
) -> std::result::Result<Vec<u8>, HttpError> {
    let mut body = Vec::with_capacity(content_len.min(BODY_CHUNK));
    let mut chunk = [0u8; BODY_CHUNK];
    while body.len() < content_len {
        let want = (content_len - body.len()).min(BODY_CHUNK);
        let n = r
            .read(&mut chunk[..want])
            .map_err(|e| HttpError::bad(format!("reading request body: {e}")))?;
        if n == 0 {
            return Err(HttpError::bad(format!(
                "connection closed after {} of {} declared body bytes",
                body.len(),
                content_len
            )));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    Ok(body)
}

/// Consume header lines until the blank separator; returns
/// `(Content-Length, sanitized X-Request-Id)`.
fn read_headers(
    r: &mut impl BufRead,
) -> std::result::Result<(usize, Option<String>), HttpError> {
    let mut content_len = 0usize;
    let mut request_id = None;
    for _ in 0..MAX_HEADERS {
        let h = read_line_capped(r)?;
        let h = h.trim();
        if h.is_empty() {
            return Ok((content_len, request_id));
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim();
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v
                    .trim()
                    .parse()
                    .map_err(|_| HttpError::bad("bad Content-Length"))?;
            } else if k.eq_ignore_ascii_case("x-request-id") {
                request_id = sanitize_request_id(v.trim());
            }
        }
    }
    Err(HttpError::header_overflow(format!("too many headers (> {MAX_HEADERS})")))
}

/// Accept a client-supplied request id only if it is short and URL/JSON
/// safe; anything else is ignored and a fresh id gets minted instead.
fn sanitize_request_id(v: &str) -> Option<String> {
    let ok = !v.is_empty()
        && v.len() <= 64
        && v.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_');
    if ok { Some(v.to_string()) } else { None }
}

/// Write a response with status, content type and body.
pub fn write_response(
    stream: &TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    write_response_with(stream, status, reason, content_type, &[], body)
}

/// [`write_response`] plus extra headers (e.g. `Retry-After` on a `503`).
pub fn write_response_with(
    stream: &TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    let mut s = stream;
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    s.write_all(head.as_bytes())?;
    s.write_all(body)?;
    s.flush()?;
    Ok(())
}

/// Start a chunked (streaming) response: status line + headers, no body
/// yet.  Follow with any number of [`write_chunk`] calls and one
/// [`finish_chunked`].
pub fn write_chunked_head(
    stream: &TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
) -> Result<()> {
    write_chunked_head_with(stream, status, reason, content_type, &[])
}

/// [`write_chunked_head`] with extra response headers (e.g. the
/// `X-Request-Id` echo on the streaming `/generate` endpoint).
pub fn write_chunked_head_with(
    stream: &TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, String)],
) -> Result<()> {
    let mut s = stream;
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n"
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    s.write_all(head.as_bytes())?;
    s.flush()?;
    Ok(())
}

/// Write one chunk and flush it — the flush is the point: each token of a
/// streaming generation reaches the client as soon as it is decoded.
/// Empty payloads are skipped (a zero-length chunk would terminate the
/// stream).
pub fn write_chunk(stream: &TcpStream, data: &[u8]) -> Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    let mut s = stream;
    write!(s, "{:x}\r\n", data.len())?;
    s.write_all(data)?;
    s.write_all(b"\r\n")?;
    s.flush()?;
    Ok(())
}

/// Terminate a chunked response (the zero-length chunk, no trailers).
pub fn finish_chunked(stream: &TcpStream) -> Result<()> {
    let mut s = stream;
    s.write_all(b"0\r\n\r\n")?;
    s.flush()?;
    Ok(())
}

/// Client side: read a chunked response; returns (status, chunks) with
/// every chunk's payload kept separate — the streaming tests assert on
/// chunk boundaries, not just the concatenated body.
pub fn read_chunked_response(stream: &TcpStream) -> Result<(u16, Vec<Vec<u8>>)> {
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    r.read_line(&mut line).context("reading status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .context("malformed status line")?
        .parse()
        .context("non-numeric status")?;
    let mut chunked = false;
    loop {
        let mut h = String::new();
        let n = r.read_line(&mut h).context("reading response header")?;
        ensure!(n > 0, "connection closed inside response headers");
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("transfer-encoding")
                && v.trim().eq_ignore_ascii_case("chunked")
            {
                chunked = true;
            }
        }
    }
    ensure!(chunked, "response is not chunked (status {status})");
    let mut chunks = Vec::new();
    let mut total = 0usize;
    loop {
        let mut size_line = String::new();
        let n = r.read_line(&mut size_line).context("reading chunk size")?;
        ensure!(n > 0, "connection closed before the terminal chunk");
        let size = usize::from_str_radix(size_line.trim(), 16)
            .with_context(|| format!("bad chunk size line {size_line:?}"))?;
        total += size;
        ensure!(total <= MAX_BODY, "chunked response exceeds {MAX_BODY} bytes");
        let mut data = vec![0u8; size];
        r.read_exact(&mut data).context("reading chunk payload")?;
        let mut crlf = [0u8; 2];
        r.read_exact(&mut crlf).context("reading chunk terminator")?;
        ensure!(&crlf == b"\r\n", "chunk payload not CRLF-terminated");
        if size == 0 {
            return Ok((status, chunks));
        }
        chunks.push(data);
    }
}

/// Client side: write one request.
pub fn write_request(
    stream: &TcpStream,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<()> {
    write_request_with(stream, method, path, &[], body)
}

/// [`write_request`] plus extra headers (e.g. `X-Request-Id`).
pub fn write_request_with(
    stream: &TcpStream,
    method: &str,
    path: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    let mut s = stream;
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: bdia\r\nContent-Length: {}\r\n\
         Connection: close\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    s.write_all(head.as_bytes())?;
    s.write_all(body)?;
    s.flush()?;
    Ok(())
}

/// Client side: read one response; returns (status, body).
pub fn read_response(stream: &TcpStream) -> Result<(u16, Vec<u8>)> {
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    r.read_line(&mut line).context("reading status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .context("malformed status line")?
        .parse()
        .context("non-numeric status")?;
    let (content_len, _) = read_headers(&mut r).map_err(|e| anyhow::anyhow!("{e}"))?;
    ensure!(content_len <= MAX_BODY, "response body too large");
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).context("reading response body")?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{Shutdown, TcpListener};

    #[test]
    fn request_response_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let req = read_request(&stream).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/echo");
            write_response(&stream, 200, "OK", "application/octet-stream", &req.body)
                .unwrap();
        });
        let stream = TcpStream::connect(addr).unwrap();
        write_request(&stream, "POST", "/echo", b"\x01\x02\x03").unwrap();
        let (status, body) = read_response(&stream).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"\x01\x02\x03");
        server.join().unwrap();
    }

    #[test]
    fn chunked_response_roundtrip_preserves_chunk_boundaries() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = read_request(&stream).unwrap();
            write_chunked_head(&stream, 200, "OK", "application/json").unwrap();
            write_chunk(&stream, b"{\"token\": 3}\n").unwrap();
            write_chunk(&stream, b"").unwrap(); // skipped, not a terminator
            write_chunk(&stream, b"{\"token\": 9}\n").unwrap();
            finish_chunked(&stream).unwrap();
        });
        let stream = TcpStream::connect(addr).unwrap();
        write_request(&stream, "POST", "/generate", b"{}").unwrap();
        let (status, chunks) = read_chunked_response(&stream).unwrap();
        assert_eq!(status, 200);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0], b"{\"token\": 3}\n");
        assert_eq!(chunks[1], b"{\"token\": 9}\n");
        server.join().unwrap();
    }

    #[test]
    fn non_chunked_response_rejected_by_chunked_reader() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = read_request(&stream).unwrap();
            write_response(&stream, 200, "OK", "text/plain", b"plain").unwrap();
        });
        let stream = TcpStream::connect(addr).unwrap();
        write_request(&stream, "GET", "/", b"").unwrap();
        assert!(read_chunked_response(&stream).is_err());
        server.join().unwrap();
    }

    #[test]
    fn extra_headers_survive_the_wire() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = read_request(&stream).unwrap();
            write_response_with(
                &stream,
                503,
                "Service Unavailable",
                "application/json",
                &[("Retry-After", "1".to_string())],
                b"{}",
            )
            .unwrap();
        });
        let stream = TcpStream::connect(addr).unwrap();
        write_request(&stream, "GET", "/", b"").unwrap();
        // read the raw response so the header itself is visible
        let mut raw = Vec::new();
        (&stream).read_to_end(&mut raw).unwrap();
        let text = String::from_utf8_lossy(&raw);
        assert!(text.starts_with("HTTP/1.1 503"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        server.join().unwrap();
    }

    #[test]
    fn client_request_id_is_captured_and_sanitized() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            for expect in [Some("abc-123_X".to_string()), None, None] {
                let (stream, _) = listener.accept().unwrap();
                let req = read_request(&stream).unwrap();
                assert_eq!(req.request_id, expect);
                write_response(&stream, 200, "OK", "text/plain", b"ok").unwrap();
            }
        });
        let ids = ["abc-123_X".to_string(), "no spaces".to_string(), "a".repeat(65)];
        for id in ids {
            let stream = TcpStream::connect(addr).unwrap();
            let hdr = [("X-Request-Id", id)];
            write_request_with(&stream, "POST", "/x", &hdr, b"").unwrap();
            read_response(&stream).unwrap();
        }
        server.join().unwrap();
    }

    #[test]
    fn oversized_declared_body_is_rejected_as_413() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            // the rejection must come from the declared length alone — no
            // body bytes were ever sent, so a reader that allocated or
            // waited for them would hang here instead of erroring
            let err = read_request_capped(&stream, 1024).unwrap_err();
            assert_eq!(err.status, 413);
            assert!(err.detail.contains("1024"), "{err}");
        });
        let stream = TcpStream::connect(addr).unwrap();
        let mut s = &stream;
        write!(s, "POST /infer HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n")
            .unwrap();
        s.flush().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn header_flood_is_rejected_as_431() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let err = read_request_capped(&stream, MAX_BODY).unwrap_err();
            assert_eq!(err.status, 431);
        });
        let stream = TcpStream::connect(addr).unwrap();
        let mut s = &stream;
        write!(s, "GET / HTTP/1.1\r\n").unwrap();
        for i in 0..100 {
            write!(s, "X-Flood-{i}: y\r\n").unwrap();
        }
        write!(s, "\r\n").unwrap();
        s.flush().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn endless_header_line_is_rejected_as_431() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let err = read_request_capped(&stream, MAX_BODY).unwrap_err();
            assert_eq!(err.status, 431);
        });
        let stream = TcpStream::connect(addr).unwrap();
        let mut s = &stream;
        let long = "a".repeat(3 * (MAX_LINE as usize));
        write!(s, "GET /{long} HTTP/1.1\r\n\r\n").unwrap();
        s.flush().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn truncated_body_is_a_400_not_a_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let err = read_request_capped(&stream, MAX_BODY).unwrap_err();
            assert_eq!(err.status, 400);
            assert!(err.detail.contains("3 of 10"), "{err}");
        });
        let stream = TcpStream::connect(addr).unwrap();
        let mut s = &stream;
        write!(s, "POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap();
        s.flush().unwrap();
        stream.shutdown(Shutdown::Write).unwrap();
        server.join().unwrap();
    }
}
