//! Packed side-information bitsets (paper eq. 20).
//!
//! BDIA with gamma = +/-0.5 loses exactly 1 bit per activation element per
//! block (the parity of `x_{k-1}/2^-l`); the forward pass stores it here and
//! the backward pass consumes it in the eq.-24 reconstruction.  Packing is
//! 64 elements/word, so the memory cost is `B*T*D/8` bytes per block — the
//! "lightweight side information" the paper's Table 1 accounts for.

/// A fixed-length packed bit vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    pub fn zeros(len: usize) -> Self {
        BitVec { words: vec![0; len.div_ceil(64)], len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1u64 << b;
        } else {
            self.words[w] &= !(1u64 << b);
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Bytes occupied by the packed payload (memory accounting).
    pub fn nbytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Flip bit i (failure-injection tests corrupt side info through this).
    pub fn flip(&mut self, i: usize) {
        let cur = self.get(i);
        self.set(i, !cur);
    }

    /// Build from element parities in one pass.
    pub fn from_parities(parities: impl Iterator<Item = u8>) -> Self {
        let mut words: Vec<u64> = Vec::new();
        let mut cur = 0u64;
        let mut nbits = 0usize;
        let mut len = 0usize;
        for p in parities {
            if p & 1 == 1 {
                cur |= 1u64 << nbits;
            }
            nbits += 1;
            len += 1;
            if nbits == 64 {
                words.push(cur);
                cur = 0;
                nbits = 0;
            }
        }
        if nbits > 0 {
            words.push(cur);
        }
        BitVec { words, len }
    }
}

/// Side information for a whole training step: one `BitVec` per transformer
/// block index that required it (`k = 1..K-1` stores `s_{k-1}`).
#[derive(Clone, Debug, Default)]
pub struct SideInfoStore {
    bits: Vec<Option<BitVec>>,
}

impl SideInfoStore {
    pub fn new(n_blocks: usize) -> Self {
        SideInfoStore { bits: vec![None; n_blocks] }
    }

    pub fn put(&mut self, block: usize, bv: BitVec) {
        self.bits[block] = Some(bv);
    }

    pub fn take(&mut self, block: usize) -> Option<BitVec> {
        self.bits[block].take()
    }

    pub fn get(&self, block: usize) -> Option<&BitVec> {
        self.bits[block].as_ref()
    }

    pub fn get_mut(&mut self, block: usize) -> Option<&mut BitVec> {
        self.bits[block].as_mut()
    }

    /// Total packed bytes currently held (Table-1 accounting).
    pub fn nbytes(&self) -> usize {
        self.bits.iter().flatten().map(BitVec::nbytes).sum()
    }

    pub fn clear(&mut self) {
        for b in &mut self.bits {
            *b = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut bv = BitVec::zeros(130);
        bv.set(0, true);
        bv.set(64, true);
        bv.set(129, true);
        assert!(bv.get(0) && bv.get(64) && bv.get(129));
        assert!(!bv.get(1) && !bv.get(63) && !bv.get(128));
        assert_eq!(bv.count_ones(), 3);
        bv.set(64, false);
        assert_eq!(bv.count_ones(), 2);
    }

    #[test]
    fn from_parities_matches_set() {
        let ps: Vec<u8> = (0..200).map(|i| (i % 3 == 0) as u8).collect();
        let bv = BitVec::from_parities(ps.iter().copied());
        assert_eq!(bv.len(), 200);
        for (i, &p) in ps.iter().enumerate() {
            assert_eq!(bv.get(i), p == 1, "bit {i}");
        }
    }

    #[test]
    fn nbytes_is_packed() {
        // 1 bit per element: 512 elements -> 64 bytes, not 512
        assert_eq!(BitVec::zeros(512).nbytes(), 64);
        assert_eq!(BitVec::zeros(65).nbytes(), 16);
    }

    #[test]
    fn flip_inverts() {
        let mut bv = BitVec::zeros(10);
        bv.flip(3);
        assert!(bv.get(3));
        bv.flip(3);
        assert!(!bv.get(3));
    }

    #[test]
    fn store_put_take() {
        let mut st = SideInfoStore::new(4);
        st.put(2, BitVec::zeros(128));
        assert_eq!(st.nbytes(), 16);
        assert!(st.get(2).is_some());
        let bv = st.take(2).unwrap();
        assert_eq!(bv.len(), 128);
        assert!(st.get(2).is_none());
        assert_eq!(st.nbytes(), 0);
    }
}
