//! Exact bit-level reversible BDIA arithmetic (paper §4.3, eqs. 17-24).
//!
//! This module is the numerical core of the paper's claim: with activations
//! on the fixed-point grid `2^-l` and gamma in {+0.5, -0.5}, the BDIA update
//!
//!   `x_{k+1} = Q_l[gamma (x_{k-1} + s_{k-1} 2^-l)]
//!            + Q_l[(1-gamma) x_k + (1+gamma) h_k(x_k)]`          (eq. 21)
//!
//! is *losslessly* invertible given the 1-bit parity side information
//! `s_{k-1}` (eq. 20), because `gamma (x_{k-1} + s 2^-l)` is already on-grid
//! (eq. 23).  Everything here runs in i64 grid units: the forward combine and
//! the eq.-24 reconstruction are exact integer arithmetic, not float ops.
//!
//! The second quantized term `Q_l[(1-gamma) x_k + (1+gamma) h_k]` only needs
//! to be *deterministic*: the backward pass recomputes the byte-identical
//! f64 expression from the identical `x_k` and the HLO-recomputed `h_k`
//! (forward and reconstruction share the exact same formula below).
//!
//! Per-sample gamma: each batch row carries its own sign (the paper draws
//! gamma per training sample per block), so all entry points take
//! `signs: &[i8]` of length `batch` and tensors shaped `(batch, ...)`.
//!
//! The float (non-quantized) path — eq. 10 forward / eq. 16 inversion — is
//! also here; it reproduces the paper's Fig.-2 error accumulation and serves
//! the Table-2 ablation (|gamma| != 0.5, quantization off).

pub mod fixed;
pub mod sideinfo;

pub use fixed::Fixed;
pub use sideinfo::{BitVec, SideInfoStore};

use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Per-sample BDIA coefficients gamma_b = signs[b] * 0.5.
#[inline]
pub fn gamma_of_sign(sign: i8) -> f64 {
    0.5 * sign as f64
}

/// f32 represents integers exactly only below 2^24: any on-grid activation
/// must satisfy `|x| < 2^(24-l)` or the stored f32 silently drops the low
/// bit and bit-exactness is lost.  The combine checks this and fails loudly
/// instead (found by `prop_single_step_roundtrip_bit_exact`).
pub const UNIT_HEADROOM: i64 = 1 << 24;

#[inline]
fn check_headroom(n: i64) -> Result<i64> {
    ensure!(
        n.abs() < UNIT_HEADROOM,
        "activation magnitude {} grid units exceeds the f32 exact-integer \
         headroom 2^24; lower lbits or normalise activations",
        n
    );
    Ok(n)
}

fn per_sample(x: &Tensor, signs: &[i8]) -> Result<usize> {
    let b = *x
        .shape()
        .first()
        .ok_or_else(|| anyhow::anyhow!("batched tensor required"))?;
    ensure!(b == signs.len(), "batch {} != signs {}", b, signs.len());
    ensure!(x.len() % b == 0, "ragged batch");
    Ok(x.len() / b)
}

/// eq. 18: clamp the embedding output onto the grid, `x0 <- Q_l[x0]`.
pub fn quantize_activation(x: &mut Tensor, f: Fixed) {
    f.quantize_slice(x.data_mut());
}

/// eq. 19: `x1 = x0 + Q_l[h0(x0)]` (x0 already on-grid).
pub fn first_step_quant(x0: &Tensor, h0: &Tensor, f: Fixed) -> Result<Tensor> {
    ensure!(x0.shape() == h0.shape(), "shape mismatch");
    let mut data = Vec::with_capacity(x0.len());
    for (&x, &h) in x0.data().iter().zip(h0.data()) {
        let n = check_headroom(f.to_units(x as f64) + f.to_units(h as f64))?;
        data.push(f.from_units(n));
    }
    Tensor::from_vec(x0.shape(), data)
}

/// eqs. 20-21 forward: returns `(x_{k+1}, s_{k-1})`.
///
/// `x_prev = x_{k-1}`, `x = x_k` (both on-grid), `h = h_k(x_k)` from the HLO
/// block executable; `signs[b]` is the gamma sign for batch row b.
pub fn bdia_forward_quant(
    x_prev: &Tensor,
    x: &Tensor,
    h: &Tensor,
    signs: &[i8],
    f: Fixed,
) -> Result<(Tensor, BitVec)> {
    ensure!(x_prev.shape() == x.shape() && x.shape() == h.shape(), "shape mismatch");
    let per = per_sample(x, signs)?;
    let mut out = vec![0f32; x.len()];
    let mut parities = vec![0u8; x.len()];
    let (xp, xc, hc) = (x_prev.data(), x.data(), h.data());
    let scale = f.scale();
    let step = f.step();
    let mut max_mag = 0i64;
    // branch-free inner loop (hot path: this runs per element per block per
    // step); overflow is OR-accumulated and checked once at the end.
    for (b, &sign) in signs.iter().enumerate() {
        let gamma = gamma_of_sign(sign);
        let s64 = sign as i64;
        let (c_skip, c_h) = (1.0 - gamma, 1.0 + gamma);
        let base = b * per;
        for i in base..base + per {
            let sp = xp[i] as f64 * scale;
            let n_prev = (sp.abs() + 0.5).floor().copysign(sp) as i64;
            debug_assert_eq!(f.from_units(n_prev), xp[i], "x_prev off-grid");
            let s = n_prev & 1; // two's-complement parity == rem_euclid(2)
            parities[i] = s as u8;
            // eq. 23: gamma (x_{k-1} + s 2^-l) is on-grid; integer-exact.
            // (n_prev + s) is even; arithmetic shift divides exactly.
            let t1 = s64 * ((n_prev + s) >> 1);
            let s2 = (c_skip * xc[i] as f64 + c_h * hc[i] as f64) * scale;
            let t2 = (s2.abs() + 0.5).floor().copysign(s2) as i64;
            let n = t1 + t2;
            max_mag |= n.abs();
            out[i] = (n as f64 * step) as f32;
        }
    }
    check_headroom(max_mag)?;
    let bits = BitVec::from_parities(parities.into_iter());
    Ok((Tensor::from_vec(x.shape(), out)?, bits))
}

/// eq. 24 reconstruction: `x_{k-1}` from `(x_{k+1}, x_k, h_k, s_{k-1})`.
///
/// Exact inverse of [`bdia_forward_quant`] by integer arithmetic; `h` must be
/// the block output recomputed from the *same* `x_k` by the *same*
/// executable (deterministic), which the coordinator guarantees.
pub fn bdia_reconstruct_quant(
    x_next: &Tensor,
    x: &Tensor,
    h: &Tensor,
    s_prev: &BitVec,
    signs: &[i8],
    f: Fixed,
) -> Result<Tensor> {
    ensure!(x_next.shape() == x.shape() && x.shape() == h.shape(), "shape mismatch");
    ensure!(s_prev.len() == x.len(), "side info length mismatch");
    let per = per_sample(x, signs)?;
    let mut out = vec![0f32; x.len()];
    let (xn, xc, hc) = (x_next.data(), x.data(), h.data());
    let scale = f.scale();
    let step = f.step();
    // NOTE on integrity: `n_prev = 2*sign*(n_next - t2) - s` has parity `s`
    // *identically* (the first term is even), so parity cannot detect
    // corrupted inputs — a flipped side bit silently shifts the element by
    // one grid step (see prop_bit_flip_shifts_one_element_one_step).
    // End-to-end integrity is therefore asserted by the bitwise round-trip
    // tests, not by a runtime check here.
    for (b, &sign) in signs.iter().enumerate() {
        let gamma = gamma_of_sign(sign);
        let s64 = sign as i64;
        let (c_skip, c_h) = (1.0 - gamma, 1.0 + gamma);
        let base = b * per;
        for i in base..base + per {
            let sn = xn[i] as f64 * scale;
            let n_next = (sn.abs() + 0.5).floor().copysign(sn) as i64;
            let s2 = (c_skip * xc[i] as f64 + c_h * hc[i] as f64) * scale;
            let t2 = (s2.abs() + 0.5).floor().copysign(s2) as i64;
            let s = s_prev.get(i) as i64;
            // invert eq. 21: n_prev = 2*sign*(n_next - t2) - s
            let n_prev = 2 * s64 * (n_next - t2) - s;
            out[i] = (n_prev as f64 * step) as f32;
        }
    }
    Tensor::from_vec(x.shape(), out)
}

// ---------------------------------------------------------------------------
// Float (non-quantized) path: eq. 10 / eq. 16
// ---------------------------------------------------------------------------

/// eq. 10: `x_{k+1} = gamma x_{k-1} + (1-gamma) x_k + (1+gamma) h_k` in f32.
/// `gammas[b]` may be any magnitude (Table-2 ablation: 0, ±0.25, ±0.5, ±0.6).
pub fn bdia_forward_float(
    x_prev: &Tensor,
    x: &Tensor,
    h: &Tensor,
    gammas: &[f32],
) -> Result<Tensor> {
    ensure!(x_prev.shape() == x.shape() && x.shape() == h.shape(), "shape mismatch");
    let per = per_sample(x, &vec![0i8; gammas.len()])
        .or_else(|_| per_sample(x, &vec![0i8; gammas.len()]))?;
    let mut out = vec![0f32; x.len()];
    let (xp, xc, hc) = (x_prev.data(), x.data(), h.data());
    for (b, &g) in gammas.iter().enumerate() {
        let base = b * per;
        for i in base..base + per {
            out[i] = g * xp[i] + (1.0 - g) * xc[i] + (1.0 + g) * hc[i];
        }
    }
    Tensor::from_vec(x.shape(), out)
}

/// eq. 16: float inversion `x_{k-1} = x_{k+1}/gamma - (1-gamma)/gamma x_k -
/// (1+gamma)/gamma h_k`.  NOT exact — the 1/gamma = ±2 factor amplifies f32
/// rounding error multiplicatively down the stack (the paper's Fig. 2);
/// [`bdia_reconstruct_quant`] exists precisely to eliminate this.
pub fn bdia_invert_float(
    x_next: &Tensor,
    x: &Tensor,
    h: &Tensor,
    gammas: &[f32],
) -> Result<Tensor> {
    ensure!(x_next.shape() == x.shape() && x.shape() == h.shape(), "shape mismatch");
    ensure!(gammas.iter().all(|&g| g != 0.0), "eq. 16 undefined for gamma = 0");
    let per = per_sample(x, &vec![0i8; gammas.len()])?;
    let mut out = vec![0f32; x.len()];
    let (xn, xc, hc) = (x_next.data(), x.data(), h.data());
    for (b, &g) in gammas.iter().enumerate() {
        let base = b * per;
        for i in base..base + per {
            out[i] = xn[i] / g - (1.0 - g) / g * xc[i] - (1.0 + g) / g * hc[i];
        }
    }
    Tensor::from_vec(x.shape(), out)
}

/// Per-sample row scaling: `out[b, ...] = coeffs[b] * t[b, ...]` — used by
/// the backward recursion for the (1±gamma_b) gradient coefficients.
pub fn scale_rows(t: &Tensor, coeffs: &[f32]) -> Result<Tensor> {
    let b = coeffs.len();
    ensure!(!t.shape().is_empty() && t.shape()[0] == b, "batch mismatch");
    let per = t.len() / b;
    let mut out = vec![0f32; t.len()];
    for (bi, &c) in coeffs.iter().enumerate() {
        let base = bi * per;
        for i in base..base + per {
            out[i] = c * t.data()[i];
        }
    }
    Tensor::from_vec(t.shape(), out)
}

/// In-place fused: `acc[b,...] += c1[b] * g[b,...]` (backward hot path).
pub fn axpy_rows(acc: &mut Tensor, coeffs: &[f32], g: &Tensor) -> Result<()> {
    ensure!(acc.shape() == g.shape(), "shape mismatch");
    let b = coeffs.len();
    ensure!(acc.shape()[0] == b, "batch mismatch");
    let per = acc.len() / b;
    let gd = g.data();
    let ad = acc.data_mut();
    for (bi, &c) in coeffs.iter().enumerate() {
        let base = bi * per;
        for i in base..base + per {
            ad[i] += c * gd[i];
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    const F: Fixed = Fixed::new(9);

    fn grid_tensor(shape: &[usize], rng: &mut Rng, scale: f32) -> Tensor {
        let mut t = Tensor::normal(shape, scale, rng);
        F.quantize_slice(t.data_mut());
        t
    }

    #[test]
    fn forward_output_on_grid() {
        let mut rng = Rng::new(0);
        let xp = grid_tensor(&[2, 8], &mut rng, 3.0);
        let x = grid_tensor(&[2, 8], &mut rng, 3.0);
        let h = Tensor::normal(&[2, 8], 1.0, &mut rng); // h arbitrary f32
        let (out, _) = bdia_forward_quant(&xp, &x, &h, &[1, -1], F).unwrap();
        assert!(out.data().iter().all(|&v| F.is_on_grid(v)));
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        // THE paper claim: forward (eq. 21) then reconstruct (eq. 24) is the
        // identity, bit for bit, for both gamma signs.
        let mut rng = Rng::new(1);
        for trial in 0..50 {
            let xp = grid_tensor(&[4, 16], &mut rng, 5.0);
            let x = grid_tensor(&[4, 16], &mut rng, 5.0);
            let h = Tensor::normal(&[4, 16], 2.0, &mut rng);
            let signs = [1i8, -1, 1, -1];
            let (xn, s) = bdia_forward_quant(&xp, &x, &h, &signs, F).unwrap();
            let rec = bdia_reconstruct_quant(&xn, &x, &h, &s, &signs, F).unwrap();
            assert_eq!(rec.data(), xp.data(), "trial {trial}: drift detected");
        }
    }

    #[test]
    fn roundtrip_exact_with_large_magnitudes() {
        // headroom: |x| up to ~2^14 still exact on the l=9 grid in f32
        let mut rng = Rng::new(2);
        let xp = grid_tensor(&[1, 32], &mut rng, 10_000.0);
        let x = grid_tensor(&[1, 32], &mut rng, 10_000.0);
        let h = Tensor::normal(&[1, 32], 100.0, &mut rng);
        let (xn, s) = bdia_forward_quant(&xp, &x, &h, &[1], F).unwrap();
        let rec = bdia_reconstruct_quant(&xn, &x, &h, &s, &[1], F).unwrap();
        assert_eq!(rec.data(), xp.data());
    }

    #[test]
    fn side_bits_match_parity() {
        let mut rng = Rng::new(3);
        let xp = grid_tensor(&[2, 8], &mut rng, 2.0);
        let x = grid_tensor(&[2, 8], &mut rng, 2.0);
        let h = Tensor::normal(&[2, 8], 1.0, &mut rng);
        let (_, s) = bdia_forward_quant(&xp, &x, &h, &[1, 1], F).unwrap();
        for (i, &v) in xp.data().iter().enumerate() {
            let n = F.units_of_exact(v).unwrap();
            assert_eq!(s.get(i), Fixed::parity_units(n) == 1);
        }
    }

    #[test]
    fn corrupted_side_info_changes_reconstruction() {
        let mut rng = Rng::new(4);
        let xp = grid_tensor(&[1, 16], &mut rng, 2.0);
        let x = grid_tensor(&[1, 16], &mut rng, 2.0);
        let h = Tensor::normal(&[1, 16], 1.0, &mut rng);
        let (xn, mut s) = bdia_forward_quant(&xp, &x, &h, &[1], F).unwrap();
        s.flip(5);
        let rec = bdia_reconstruct_quant(&xn, &x, &h, &s, &[1], F).unwrap();
        // flipped parity shifts element 5 by exactly one grid step
        assert!((rec.data()[5] - xp.data()[5]).abs() > 0.0);
        assert_eq!(
            (rec.data()[5] - xp.data()[5]).abs(),
            F.step() as f32
        );
    }

    #[test]
    fn float_invert_matches_forward_approximately() {
        let mut rng = Rng::new(5);
        let xp = Tensor::normal(&[2, 8], 1.0, &mut rng);
        let x = Tensor::normal(&[2, 8], 1.0, &mut rng);
        let h = Tensor::normal(&[2, 8], 1.0, &mut rng);
        let gammas = [0.5f32, -0.5];
        let xn = bdia_forward_float(&xp, &x, &h, &gammas).unwrap();
        let rec = bdia_invert_float(&xn, &x, &h, &gammas).unwrap();
        // float path is approximately invertible (one step) ...
        assert!(rec.max_abs_diff(&xp).unwrap() < 1e-5);
        // ... but NOT exactly, in general (that's Fig. 2's point; the exact
        // path's test asserts == instead).
    }

    #[test]
    fn float_invert_rejects_gamma_zero() {
        let t = Tensor::zeros(&[1, 4]);
        assert!(bdia_invert_float(&t, &t, &t, &[0.0]).is_err());
    }

    #[test]
    fn first_step_matches_eq19() {
        let mut rng = Rng::new(6);
        let x0 = grid_tensor(&[1, 8], &mut rng, 1.0);
        let h0 = Tensor::normal(&[1, 8], 1.0, &mut rng);
        let x1 = first_step_quant(&x0, &h0, F).unwrap();
        for i in 0..8 {
            let expect = x0.data()[i] + F.quantize(h0.data()[i]);
            assert_eq!(x1.data()[i], expect);
        }
    }

    #[test]
    fn scale_axpy_rows() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let s = scale_rows(&t, &[2.0, -1.0]).unwrap();
        assert_eq!(s.data(), &[2.0, 4.0, -3.0, -4.0]);
        let mut acc = Tensor::zeros(&[2, 2]);
        axpy_rows(&mut acc, &[1.0, 0.5], &t).unwrap();
        assert_eq!(acc.data(), &[1.0, 2.0, 1.5, 2.0]);
    }

    #[test]
    fn gamma0_float_forward_is_plain_residual() {
        let mut rng = Rng::new(7);
        let xp = Tensor::normal(&[1, 4], 1.0, &mut rng);
        let x = Tensor::normal(&[1, 4], 1.0, &mut rng);
        let h = Tensor::normal(&[1, 4], 1.0, &mut rng);
        let out = bdia_forward_float(&xp, &x, &h, &[0.0]).unwrap();
        for i in 0..4 {
            assert!((out.data()[i] - (x.data()[i] + h.data()[i])).abs() < 1e-6);
        }
    }
}
