//! Fixed-point grid arithmetic (paper eq. 17): `Q_l[y] = round(y/2^-l) 2^-l`.
//!
//! All persistent activations in BDIA training live on the grid `2^-l`
//! (l = 9 in the paper).  f32 represents `n * 2^-l` exactly for |n| < 2^24,
//! so grid values round-trip f32 <-> i64 *losslessly*; the BDIA combine and
//! the eq.-24 reconstruction are computed in i64 grid units, which is what
//! makes the reversibility claim *bit-level* rather than approximate.
//!
//! Rounding rule: half away from zero — matching the Pallas kernel
//! (`python/compile/kernels/bdia_update.py::quantize`) bit for bit.

use anyhow::{bail, Result};

/// Grid descriptor for precision `2^-l`.
///
/// `scale`/`step` are cached at construction: computing `2^l` via `powi`
/// per element made the hot combine ~25x slower than the float path
/// (EXPERIMENTS.md §Perf L3 iteration 2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Fixed {
    pub lbits: u32,
    scale_cached: f64,
    step_cached: f64,
}

impl Fixed {
    pub const fn new(lbits: u32) -> Self {
        // 2^l / 2^-l as const-constructible IEEE-754 bit patterns
        let scale = f64::from_bits(((1023 + lbits as u64) & 0x7ff) << 52);
        let step = f64::from_bits(((1023 - lbits as u64) & 0x7ff) << 52);
        Fixed { lbits, scale_cached: scale, step_cached: step }
    }

    /// Grid step `2^-l`.
    #[inline]
    pub fn step(&self) -> f64 {
        self.step_cached
    }

    /// Grid scale `2^l`.
    #[inline]
    pub fn scale(&self) -> f64 {
        self.scale_cached
    }

    /// Round a real value to grid units (round half away from zero).
    #[inline]
    pub fn to_units(&self, y: f64) -> i64 {
        let scaled = y * self.scale();
        let r = scaled.abs() + 0.5;
        let m = r.floor() as i64;
        if scaled < 0.0 {
            -m
        } else {
            m
        }
    }

    /// Exact unit count of an on-grid f32 (errors if off-grid).
    #[inline]
    pub fn units_of_exact(&self, x: f32) -> Result<i64> {
        let scaled = x as f64 * self.scale();
        let n = scaled.round() as i64;
        if n as f64 != scaled {
            bail!("value {} is not on the 2^-{} grid", x, self.lbits);
        }
        Ok(n)
    }

    /// Grid units -> f32 (exact for |n| < 2^24).
    #[inline]
    pub fn from_units(&self, n: i64) -> f32 {
        (n as f64 * self.step()) as f32
    }

    /// Q_l[y] as f32 (eq. 17).
    #[inline]
    pub fn quantize(&self, y: f32) -> f32 {
        self.from_units(self.to_units(y as f64))
    }

    /// Parity bit of an on-grid value (eq. 20): |n| mod 2, via rem_euclid so
    /// negative unit counts behave (parity(n) = parity(-n)).
    #[inline]
    pub fn parity_units(n: i64) -> u8 {
        (n.rem_euclid(2)) as u8
    }

    /// Whether an f32 lies exactly on the grid.
    pub fn is_on_grid(&self, x: f32) -> bool {
        self.units_of_exact(x).is_ok()
    }

    /// Quantize a whole slice in place (eq. 18 `x0 <- Q_l[x0]`).
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.quantize(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const F: Fixed = Fixed::new(9);

    #[test]
    fn step_scale() {
        assert_eq!(F.step(), 1.0 / 512.0);
        assert_eq!(F.scale(), 512.0);
    }

    #[test]
    fn round_half_away_from_zero() {
        // 0.5 units -> 1 unit; -0.5 units -> -1 unit (matches the kernel)
        let half = F.step() / 2.0;
        assert_eq!(F.to_units(half), 1);
        assert_eq!(F.to_units(-half), -1);
        assert_eq!(F.to_units(3.0 * half), 2);
        assert_eq!(F.to_units(-3.0 * half), -2);
    }

    #[test]
    fn quantize_error_bound() {
        let mut rng = crate::tensor::Rng::new(0);
        for _ in 0..10_000 {
            let y = rng.normal() * 10.0;
            let q = F.quantize(y);
            assert!((q - y).abs() <= F.step() as f32 / 2.0 + 1e-9);
            assert!(F.is_on_grid(q));
        }
    }

    #[test]
    fn units_roundtrip_exact() {
        for n in [-(1 << 23), -12345, -1, 0, 1, 777, (1 << 23)] {
            let x = F.from_units(n);
            assert_eq!(F.units_of_exact(x).unwrap(), n);
        }
        assert!(F.units_of_exact(0.001).is_err()); // off grid
    }

    #[test]
    fn parity_of_negatives() {
        assert_eq!(Fixed::parity_units(-3), 1);
        assert_eq!(Fixed::parity_units(-2), 0);
        assert_eq!(Fixed::parity_units(3), 1);
        assert_eq!(Fixed::parity_units(0), 0);
    }

    #[test]
    fn quantize_idempotent() {
        let mut rng = crate::tensor::Rng::new(1);
        for _ in 0..1000 {
            let q = F.quantize(rng.normal() * 5.0);
            assert_eq!(F.quantize(q), q);
        }
    }

    #[test]
    fn other_lbits() {
        // Remark 2: gamma = +/-0.25 wants 2 side bits; grid still exact
        let f7 = Fixed::new(7);
        assert_eq!(f7.to_units(1.0), 128);
        assert_eq!(f7.quantize(0.5), 0.5);
    }
}
