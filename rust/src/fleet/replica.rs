//! Replica worker: one process (or test thread) holding a full model
//! replica, serving γ-pure micro-batches the router sends over the
//! length-prefixed frame backplane.
//!
//! Replicas are weight-free at launch: the handshake's `FLEET_WELCOME`
//! carries the router's canonical-order parameter blob, so every replica
//! serves exactly the weights the router holds (the fleet's bit-exactness
//! hinges on this — there is no checkpoint to drift).  Liveness uses the
//! same heartbeat frames as `dist`: a beat thread keeps the router's read
//! deadline from tripping while the replica computes.

use crate::dist::transport::{
    self, get_u32, get_u64, op, put_u32, put_u64, read_frame_into, try_heartbeat,
    write_frame, Link,
};
use crate::dist::unflatten_from;
use crate::model::ParamStore;
use crate::runtime::{BackendKind, Runtime};
use crate::serve::wire;
use anyhow::{bail, ensure, Context, Result};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    pub model: String,
    pub backend: BackendKind,
    pub artifacts_dir: PathBuf,
    /// Router backplane address (`host:port`).
    pub rendezvous: String,
    /// Kernel pool threads (0 = leave untouched).
    pub threads: usize,
    /// Frame deadline / heartbeat base, mirroring `dist`'s semantics.
    pub deadline: Duration,
    /// How long to keep retrying the initial connect.
    pub connect_timeout: Duration,
    /// Persisted kernel profile (`bdia tune`) to serve under.  Results are
    /// bit-identical under any profile; a bad file warns and falls back to
    /// the default profile.
    pub tune_profile: Option<PathBuf>,
    /// Fault injection for tests: serve this many batches, then drop the
    /// connection *without acknowledging* the next one.
    pub die_after_batches: Option<usize>,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig {
            model: "vit_s10".into(),
            backend: BackendKind::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            rendezvous: String::new(),
            threads: 0,
            deadline: Duration::from_secs(10),
            connect_timeout: transport::CONNECT_TIMEOUT,
            tune_profile: None,
            die_after_batches: None,
        }
    }
}

/// Process entry point (`bdia serve --replica --rendezvous ...`): load the
/// bundle, join the router, serve until `FLEET_GOODBYE` or router death.
pub fn run(cfg: &ReplicaConfig) -> Result<()> {
    let rt = Runtime::load_with(&cfg.artifacts_dir, &cfg.model, cfg.backend)
        .with_context(|| format!("loading bundle '{}'", cfg.model))?;
    ensure!(
        rt.has_exec("model_infer_ex"),
        "bundle '{}' has no model_infer_ex executable",
        cfg.model
    );
    if cfg.threads != 0 {
        crate::kernels::pool::set_threads(cfg.threads);
    }
    if let Some(path) = &cfg.tune_profile {
        match crate::kernels::KernelProfile::load(path) {
            Ok(p) => crate::kernels::profile::set_active(p, Some(path.clone())),
            Err(e) => {
                eprintln!(
                    "warning: ignoring tune profile: {e:#}; continuing with \
                     the default profile"
                );
                crate::kernels::profile::reset_active();
            }
        }
    }
    let stream = connect_with_retry(&cfg.rendezvous, cfg.connect_timeout)?;
    serve_connection(stream, &rt, cfg.deadline, cfg.die_after_batches)
}

/// Connect to the router backplane, retrying until `give_up` (the router
/// may still be binding when a locally spawned replica starts).
pub fn connect_with_retry(rendezvous: &str, give_up: Duration) -> Result<TcpStream> {
    let addr: SocketAddr = rendezvous
        .to_socket_addrs()
        .with_context(|| format!("resolving rendezvous '{rendezvous}'"))?
        .next()
        .with_context(|| format!("rendezvous '{rendezvous}' resolved to nothing"))?;
    let deadline = Instant::now() + give_up;
    loop {
        match TcpStream::connect_timeout(&addr, Duration::from_secs(2)) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| {
                        format!("connecting to fleet router at {addr} (gave up)")
                    });
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Handshake + serve loop over an already-connected backplane stream.
/// Public so `tests/fleet.rs` can run replicas as in-process threads
/// against a router without spawning child processes.
pub fn serve_connection(
    stream: TcpStream,
    rt: &Runtime,
    deadline: Duration,
    die_after_batches: Option<usize>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let params = handshake(&stream, rt)?;
    // fresh parameter set for this connection: invalidate cached weight
    // transposes keyed on prior allocations
    crate::kernels::workspace::bump_weight_generation();
    let mut link = Link::new(stream, 0, deadline)?;

    // beat thread: keeps the router's read deadline alive while this
    // replica is busy inside model_infer_ex
    let stop = Arc::new(AtomicBool::new(false));
    let writer = link.writer();
    let beat = (deadline / 4).max(Duration::from_millis(10));
    let stop2 = Arc::clone(&stop);
    let beat_thread = std::thread::Builder::new()
        .name("bdia-replica-beat".into())
        .spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                std::thread::sleep(beat);
                if !try_heartbeat(&writer) {
                    break;
                }
            }
        })?;
    let result = serve_loop(&mut link, rt, &params, die_after_batches);
    stop.store(true, Ordering::SeqCst);
    let _ = beat_thread.join();
    result
}

/// Send `FLEET_HELLO`, receive the parameter blob, build the store.
fn handshake(stream: &TcpStream, rt: &Runtime) -> Result<ParamStore> {
    // bounded handshake reads: a bad peer fails fast instead of hanging
    stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
    let mut hello = Vec::new();
    put_u32(&mut hello, transport::MAGIC);
    put_u32(&mut hello, transport::PROTO_VERSION);
    let name = rt.manifest.name.as_bytes();
    put_u32(&mut hello, name.len() as u32);
    hello.extend_from_slice(name);
    let mut w = stream.try_clone().context("cloning backplane stream")?;
    write_frame(&mut w, op::FLEET_HELLO, &hello).context("sending FLEET_HELLO")?;

    let mut payload = Vec::new();
    let mut r = stream.try_clone().context("cloning backplane stream")?;
    let opcode = loop {
        let opcode =
            read_frame_into(&mut r, &mut payload).context("awaiting FLEET_WELCOME")?;
        if opcode != op::HEARTBEAT {
            break opcode;
        }
    };
    if opcode == op::FLEET_GOODBYE {
        bail!(
            "router refused this replica: {}",
            String::from_utf8_lossy(&payload)
        );
    }
    ensure!(
        opcode == op::FLEET_WELCOME,
        "expected FLEET_WELCOME, got opcode {opcode}"
    );
    let mut pos = 0;
    let n = get_u64(&payload, &mut pos)? as usize;
    ensure!(
        payload.len() == 8 + n * 4,
        "FLEET_WELCOME length mismatch: header says {n} params, payload \
         holds {} bytes",
        payload.len()
    );
    let mut flat = vec![0f32; n];
    transport::get_f32s(&payload, &mut pos, n, &mut flat)?;
    let mut store = ParamStore::init(&rt.manifest, 0);
    unflatten_from(&mut store, &flat)
        .context("router parameter blob does not fit this bundle")?;
    Ok(store)
}

fn serve_loop(
    link: &mut Link,
    rt: &Runtime,
    params: &ParamStore,
    die_after_batches: Option<usize>,
) -> Result<()> {
    let mut buf = Vec::new();
    let mut answered = 0usize;
    loop {
        let opcode = match link.recv_into(&mut buf, "fleet serve") {
            Ok(opc) => opc,
            Err(e) => {
                // router gone (shutdown without GOODBYE, or crash): exit
                // quietly — the replica holds no state worth saving
                if e.downcast_ref::<crate::dist::DistError>().is_some() {
                    return Ok(());
                }
                return Err(e);
            }
        };
        match opcode {
            op::FLEET_GOODBYE => return Ok(()),
            op::FLEET_INFER => {
                if die_after_batches == Some(answered) {
                    // fault injection: drop the connection with this batch
                    // un-acked — the router must re-dispatch it
                    return Ok(());
                }
                let (batch_id, examples, gamma, ids) = decode_infer(rt, &buf)?;
                let per_ex = {
                    let _span = crate::span!(
                        "replica_infer",
                        batch_id = batch_id,
                        n = examples.len(),
                        request_id = ids.join(",")
                    );
                    wire::infer_batch(rt, params, &examples, gamma)?
                };
                let mut out = Vec::with_capacity(12 + per_ex.len() * 8 + 8);
                put_u64(&mut out, batch_id);
                put_u32(&mut out, per_ex.len() as u32);
                for (loss, correct) in &per_ex {
                    out.extend_from_slice(&loss.to_le_bytes());
                    out.extend_from_slice(&correct.to_le_bytes());
                }
                put_u64(&mut out, infer_calls(rt));
                link.send(op::FLEET_RESULT, &out, "fleet result")?;
                answered += 1;
            }
            other => bail!("unexpected opcode {other} on fleet backplane"),
        }
    }
}

/// Parse + validate one `FLEET_INFER` payload: `batch_id, n, n ×
/// wire-encoded examples, n × (len, request_id)` — every example must
/// carry the same γ bits (the router's sticky batching is re-checked at
/// the protocol boundary) and `n` must fit the manifest batch dimension.
/// The trailing correlation ids let replica-side spans share the
/// `request_id` the router's front door minted.
pub fn decode_infer(
    rt: &Runtime,
    payload: &[u8],
) -> Result<(u64, Vec<wire::Example>, f32, Vec<String>)> {
    let m = &rt.manifest;
    let chunk = wire::body_len(m.family, &m.dims);
    let mut pos = 0;
    let batch_id = get_u64(payload, &mut pos)?;
    let n = get_u32(payload, &mut pos)? as usize;
    ensure!(n >= 1, "empty FLEET_INFER batch");
    ensure!(
        n <= m.dims.batch,
        "FLEET_INFER batch of {n} exceeds manifest batch dim {}",
        m.dims.batch
    );
    ensure!(
        payload.len() >= 12 + n * chunk,
        "FLEET_INFER length mismatch: {n} examples of {chunk} bytes, got \
         {} payload bytes",
        payload.len()
    );
    let mut examples = Vec::with_capacity(n);
    let mut gamma_bits: Option<u32> = None;
    for i in 0..n {
        let body = &payload[12 + i * chunk..12 + (i + 1) * chunk];
        let (ex, gamma) = wire::decode(m.family, &m.dims, body)?;
        match gamma_bits {
            None => gamma_bits = Some(gamma.to_bits()),
            Some(bits) => ensure!(
                bits == gamma.to_bits(),
                "FLEET_INFER mixes gamma keys ({} vs {})",
                f32::from_bits(bits),
                gamma
            ),
        }
        examples.push(ex);
    }
    let gamma = f32::from_bits(gamma_bits.unwrap());
    pos = 12 + n * chunk;
    let mut ids = Vec::with_capacity(n);
    for _ in 0..n {
        let len = get_u32(payload, &mut pos)? as usize;
        ensure!(
            payload.len() >= pos + len,
            "FLEET_INFER request id overruns the payload"
        );
        ids.push(String::from_utf8_lossy(&payload[pos..pos + len]).into_owned());
        pos += len;
    }
    ensure!(pos == payload.len(), "FLEET_INFER has trailing bytes");
    Ok((batch_id, examples, gamma, ids))
}

fn infer_calls(rt: &Runtime) -> u64 {
    rt.call_counts()
        .iter()
        .find(|(name, _)| name == "model_infer_ex")
        .map(|(_, c)| *c)
        .unwrap_or(0)
}

/// Options for spawning local replica child processes.
#[derive(Clone, Debug)]
pub struct ReplicaSpawnOpts {
    pub model: String,
    pub backend: String,
    pub artifacts: PathBuf,
    pub threads: usize,
    pub fleet_timeout_s: f64,
    /// Kernel profile path to forward to every replica (`--tune-profile`).
    pub tune_profile: Option<PathBuf>,
}

/// Re-exec `current_exe` as `n` replica processes pointed at the router's
/// backplane — the `bdia serve --replicas N` single-command path.  The
/// caller wraps the children in a `dist::WorkerRanks`-style guard; unlike
/// rank workers these carry no `--rank` (replicas are interchangeable).
pub fn spawn_local_replicas(
    backplane: SocketAddr,
    n: usize,
    opts: &ReplicaSpawnOpts,
) -> Result<Vec<Child>> {
    ensure!(n >= 1, "a fleet needs at least one replica");
    let exe = std::env::current_exe().context("locating current executable")?;
    let mut children = Vec::with_capacity(n);
    for i in 0..n {
        let mut cmd = Command::new(&exe);
        // `--replica --model` leads the argv so process greps (CI's
        // kill-one-replica step) can target replicas unambiguously
        cmd.arg("serve")
            .arg("--replica")
            .arg("--model")
            .arg(&opts.model)
            .arg("--rendezvous")
            .arg(backplane.to_string())
            .arg("--backend")
            .arg(&opts.backend)
            .arg("--artifacts")
            .arg(&opts.artifacts)
            .arg("--threads")
            .arg(opts.threads.to_string())
            .arg("--fleet-timeout-s")
            .arg(opts.fleet_timeout_s.to_string());
        if let Some(p) = &opts.tune_profile {
            cmd.arg("--tune-profile").arg(p);
        }
        let child = cmd
            // replicas stay quiet on stdout (the router narrates) but keep
            // stderr attached so their failures are visible
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning replica {i}"))?;
        children.push(child);
    }
    Ok(children)
}
