//! The fleet front door: one HTTP process, N model replicas behind it.
//!
//! ```text
//! clients ──POST /infer──► router handler threads
//!                               │ decode → Job{example, gamma, resp}
//!                               ▼
//!                         [BatchQueue]  (bounded; overflow → 503)
//!                               │ γ-sticky micro-batches
//!                               ▼
//!                          dispatcher ──pick least-outstanding──┐
//!                               │                               │
//!                     per-replica worker threads (backplane links)
//!                        FLEET_INFER ──► replica ──► FLEET_RESULT
//! ```
//!
//! Invariants: a dispatched batch never mixes γ keys and never splits
//! across replicas (it rides the queue's sticky coalescing, and the
//! replica re-validates at the protocol boundary); results return to the
//! exact requests that sent them (each [`Job`] keeps its own response
//! channel through dispatch).  A replica death mid-batch does not lose
//! the batch: un-acked assignments are re-queued at the *front* of the
//! queue and re-dispatched to a surviving replica, so every successful
//! response stays bit-exact and clients see added latency, not errors.

use crate::api::events::{EventSink, NullSink, RequestEvent};
use crate::checkpoint;
use crate::dist::flatten_into;
use crate::dist::transport::{
    self, get_u32, get_u64, op, put_u32, put_u64, read_frame_into, try_heartbeat,
    write_frame, Link,
};
use crate::model::ParamStore;
use crate::runtime::{BackendKind, Runtime};
use crate::serve::batcher::{BatchQueue, Job, PushOutcome};
use crate::serve::stats::ServeStats;
use crate::serve::{error_body, http, wire, write_503};
use super::registry::{Assignment, Registry, ReplicaEntry};
use super::stats::{fleet_metrics_text, fleet_stats_json, RouterCounters};
use anyhow::{ensure, Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a handler holds an idle client connection before giving up.
const CONN_READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Latency reservoir size for the router's end-to-end `/stats` view.
const LATENCY_RESERVOIR: usize = 8192;
/// Dispatcher back-off while no replica is live (a joining replica is
/// picked up within one tick).
const NO_REPLICA_RETRY: Duration = Duration::from_millis(25);

#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub model: String,
    pub backend: BackendKind,
    pub artifacts_dir: PathBuf,
    /// Checkpoint with trained weights; `None` serves seed-initialized
    /// params (the CLI warns loudly).
    pub ckpt: Option<PathBuf>,
    /// Front-door HTTP port; 0 picks an ephemeral port.
    pub port: u16,
    /// Backplane bind address for replicas; `None` binds an ephemeral
    /// loopback port (single-command local fleets).
    pub backplane: Option<String>,
    /// How long an under-filled batch waits for stragglers.
    pub batch_window: Duration,
    /// Admission cap (0 = unbounded); overflow gets `503 Retry-After`.
    pub queue_cap: usize,
    /// Backplane frame deadline; a replica silent for this long (no
    /// result, no heartbeat) is evicted.
    pub deadline: Duration,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            model: "vit_s10".into(),
            backend: BackendKind::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            ckpt: None,
            port: 7878,
            backplane: None,
            batch_window: Duration::from_millis(2),
            queue_cap: 1024,
            deadline: Duration::from_secs(10),
        }
    }
}

struct FleetShared {
    rt: Runtime,
    /// Prebuilt `FLEET_WELCOME` payload: every admitted replica receives
    /// the router's exact weights, the root of fleet bit-exactness.
    params_blob: Vec<u8>,
    queue: BatchQueue,
    stats: ServeStats,
    counters: RouterCounters,
    registry: Registry,
    shutdown: AtomicBool,
    addr: SocketAddr,
    backplane_addr: SocketAddr,
    batch_window: Duration,
    deadline: Duration,
    max_body: usize,
    batch_seq: AtomicU64,
    sink: Arc<dyn EventSink>,
    /// Per-replica worker threads, joined on shutdown.
    replica_threads: Mutex<Vec<JoinHandle<()>>>,
}

/// A running fleet router; stop with [`Router::stop`] (or `POST
/// /shutdown`), then reap with [`Router::join`].
pub struct Router {
    shared: Arc<FleetShared>,
    threads: Vec<JoinHandle<()>>,
}

impl Router {
    /// Load the bundle (+ optional checkpoint), bind both doors, start.
    pub fn start(cfg: FleetConfig) -> Result<Router> {
        let rt = Runtime::load_with(&cfg.artifacts_dir, &cfg.model, cfg.backend)
            .with_context(|| format!("loading bundle '{}'", cfg.model))?;
        let params = match &cfg.ckpt {
            Some(path) => {
                let ck = checkpoint::load(path)?;
                ensure!(
                    ck.model == cfg.model,
                    "checkpoint {} was written for model '{}', serving '{}'",
                    path.display(),
                    ck.model,
                    cfg.model
                );
                ensure!(
                    ck.params.matches_manifest(&rt.manifest),
                    "checkpoint {} parameter structure does not match bundle \
                     '{}'",
                    path.display(),
                    cfg.model
                );
                ck.params
            }
            None => ParamStore::init(&rt.manifest, 0),
        };
        Self::start_with_parts(cfg, rt, params, Arc::new(NullSink))
    }

    /// Start with a pre-built runtime, in-memory parameters and an event
    /// sink — the `api::Session::serve_fleet` path: the fleet serves the
    /// session's **current** weights, which the handshake pushes to every
    /// replica.
    pub fn start_with_parts(
        cfg: FleetConfig,
        rt: Runtime,
        params: ParamStore,
        sink: Arc<dyn EventSink>,
    ) -> Result<Router> {
        ensure!(
            rt.has_exec("model_infer_ex"),
            "bundle '{}' has no model_infer_ex executable",
            cfg.model
        );
        ensure!(
            params.matches_manifest(&rt.manifest),
            "parameter structure does not match bundle '{}'",
            cfg.model
        );
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("binding front door 127.0.0.1:{}", cfg.port))?;
        let addr = listener.local_addr()?;
        let backplane_bind = cfg.backplane.as_deref().unwrap_or("127.0.0.1:0");
        let backplane = TcpListener::bind(backplane_bind)
            .with_context(|| format!("binding backplane {backplane_bind}"))?;
        let backplane_addr = backplane.local_addr()?;

        let mut flat = Vec::new();
        flatten_into(&params, &mut flat);
        let mut params_blob = Vec::with_capacity(8 + flat.len() * 4);
        put_u64(&mut params_blob, flat.len() as u64);
        transport::put_f32s(&mut params_blob, &flat);

        let max_body =
            wire::body_len(rt.manifest.family, &rt.manifest.dims).max(512);
        let stats = ServeStats::new(LATENCY_RESERVOIR);
        let counters = RouterCounters::new(stats.registry());
        let shared = Arc::new(FleetShared {
            rt,
            params_blob,
            queue: BatchQueue::bounded(cfg.queue_cap),
            stats,
            counters,
            registry: Registry::new(),
            shutdown: AtomicBool::new(false),
            addr,
            backplane_addr,
            batch_window: cfg.batch_window,
            deadline: cfg.deadline,
            max_body,
            batch_seq: AtomicU64::new(0),
            sink,
            replica_threads: Mutex::new(Vec::new()),
        });
        let mut threads = Vec::with_capacity(3);
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("bdia-fleet-dispatch".into())
                .spawn(move || dispatcher_loop(&sh))?,
        );
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("bdia-fleet-accept".into())
                .spawn(move || backplane_loop(backplane, &sh))?,
        );
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("bdia-fleet-listener".into())
                .spawn(move || listener_loop(listener, &sh))?,
        );
        Ok(Router { shared, threads })
    }

    /// Front-door HTTP address.
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Backplane address replicas join (`--rendezvous` target).
    pub fn backplane_addr(&self) -> SocketAddr {
        self.shared.backplane_addr
    }

    /// Currently live replicas.
    pub fn live_replicas(&self) -> usize {
        self.shared.registry.counts().0
    }

    /// Block until at least `n` replicas are live (admission is
    /// asynchronous — locally spawned replicas take a moment to load
    /// their bundle and join).
    pub fn wait_ready(&self, n: usize, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            let live = self.live_replicas();
            if live >= n {
                return Ok(());
            }
            ensure!(
                Instant::now() < deadline,
                "fleet not ready: {live}/{n} replicas live after {timeout:?}"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Begin graceful shutdown: stop accepting, drain the queue through
    /// the surviving replicas, dismiss them with `FLEET_GOODBYE`.
    pub fn stop(&self) {
        initiate_shutdown(&self.shared);
    }

    /// Wait for every router thread (listener, acceptor, dispatcher,
    /// per-replica workers) to exit.
    pub fn join(self) -> Result<()> {
        for t in self.threads {
            t.join().map_err(|_| anyhow::anyhow!("router thread panicked"))?;
        }
        // dispatcher is done: nothing will be handed to replicas anymore,
        // so closing the registry lets every worker drain and exit
        self.shared.registry.close();
        let workers: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.shared.replica_threads.lock().unwrap());
        for t in workers {
            t.join()
                .map_err(|_| anyhow::anyhow!("replica worker thread panicked"))?;
        }
        Ok(())
    }

    /// `stop` + `join`.
    pub fn shutdown(self) -> Result<()> {
        self.stop();
        self.join()
    }
}

fn initiate_shutdown(shared: &FleetShared) {
    if shared.shutdown.swap(true, Ordering::SeqCst) {
        return; // already shutting down
    }
    shared.queue.shutdown();
    // poke both blocking accept()s so the loops observe the flag
    let _ = TcpStream::connect(shared.addr);
    let _ = TcpStream::connect(shared.backplane_addr);
}

// ---------------------------------------------------------------------
// dispatch: queue → least-outstanding live replica
// ---------------------------------------------------------------------

fn dispatcher_loop(shared: &Arc<FleetShared>) {
    let max_batch = shared.rt.manifest.dims.batch;
    'batches: while let Some(jobs) =
        shared.queue.next_batch(max_batch, shared.batch_window)
    {
        let mut jobs = jobs;
        loop {
            let Some(entry) = shared.registry.pick() else {
                if shared.shutdown.load(Ordering::SeqCst) {
                    fail_jobs(&jobs, "shutting down with no live replicas");
                    continue 'batches; // drain remaining queue the same way
                }
                std::thread::sleep(NO_REPLICA_RETRY);
                continue;
            };
            entry.outstanding.fetch_add(jobs.len(), Ordering::SeqCst);
            let batch_id = shared.batch_seq.fetch_add(1, Ordering::SeqCst);
            match entry.send(Assignment { batch_id, jobs }) {
                Ok(()) => break,
                Err(a) => {
                    // evicted between pick and send: undo and re-pick
                    entry.outstanding.fetch_sub(a.jobs.len(), Ordering::SeqCst);
                    jobs = a.jobs;
                }
            }
        }
    }
}

fn fail_jobs(jobs: &[Job], msg: &str) {
    for j in jobs {
        let _ = j.resp.send(Err(msg.to_string()));
    }
}

// ---------------------------------------------------------------------
// backplane: replica admission + per-replica workers
// ---------------------------------------------------------------------

fn backplane_loop(listener: TcpListener, shared: &Arc<FleetShared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let sh = Arc::clone(shared);
                let handle = std::thread::Builder::new()
                    .name("bdia-fleet-replica".into())
                    .spawn(move || replica_session(s, &sh));
                if let Ok(h) = handle {
                    shared.replica_threads.lock().unwrap().push(h);
                }
            }
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
        }
    }
}

/// One replica's lifetime on the router side: handshake, admission,
/// dispatch/ack loop, eviction or goodbye.
fn replica_session(stream: TcpStream, shared: &Arc<FleetShared>) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "unknown".into());
    let link = match admit_replica(&stream, shared, &peer) {
        Ok(link) => link,
        Err(e) => {
            eprintln!("fleet: rejected replica {peer}: {e:#}");
            return;
        }
    };
    let (tx, rx) = mpsc::channel();
    let entry = shared.registry.admit(peer, tx);
    replica_worker(shared, &entry, link, &rx);
}

/// Validate `FLEET_HELLO` and push the parameter blob.  A mismatched
/// peer gets a `FLEET_GOODBYE` naming the reason instead of silence.
fn admit_replica(
    stream: &TcpStream,
    shared: &Arc<FleetShared>,
    peer: &str,
) -> Result<Link> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
    let mut r = stream.try_clone().context("cloning backplane stream")?;
    let mut payload = Vec::new();
    let opcode = read_frame_into(&mut r, &mut payload)
        .with_context(|| format!("reading FLEET_HELLO from {peer}"))?;
    let reject = |reason: String| -> Result<Link> {
        let mut w = stream.try_clone().context("cloning backplane stream")?;
        let _ = write_frame(&mut w, op::FLEET_GOODBYE, reason.as_bytes());
        anyhow::bail!(reason)
    };
    if opcode != op::FLEET_HELLO {
        return reject(format!("expected FLEET_HELLO, got opcode {opcode}"));
    }
    let mut pos = 0;
    let magic = get_u32(&payload, &mut pos)?;
    if magic != transport::MAGIC {
        return reject(format!("not a bdia replica (bad magic {magic:#x})"));
    }
    let version = get_u32(&payload, &mut pos)?;
    if version != transport::PROTO_VERSION {
        return reject(format!(
            "protocol version mismatch: replica {version}, router {}",
            transport::PROTO_VERSION
        ));
    }
    let name_len = get_u32(&payload, &mut pos)? as usize;
    ensure!(payload.len() == pos + name_len, "malformed FLEET_HELLO");
    let model = String::from_utf8_lossy(&payload[pos..]).into_owned();
    if model != shared.rt.manifest.name {
        return reject(format!(
            "model mismatch: replica loaded '{model}', fleet serves '{}'",
            shared.rt.manifest.name
        ));
    }
    let mut w = stream.try_clone().context("cloning backplane stream")?;
    write_frame(&mut w, op::FLEET_WELCOME, &shared.params_blob)
        .with_context(|| format!("sending FLEET_WELCOME to {peer}"))?;
    Link::new(
        stream.try_clone().context("cloning backplane stream")?,
        0,
        shared.deadline,
    )
}

fn replica_worker(
    shared: &Arc<FleetShared>,
    entry: &Arc<ReplicaEntry>,
    mut link: Link,
    rx: &Receiver<Assignment>,
) {
    let writer = link.writer();
    let beat = (shared.deadline / 4).max(Duration::from_millis(10));
    let mut buf = Vec::new();
    loop {
        match rx.recv_timeout(beat) {
            Ok(assign) => {
                if !process_assignment(shared, entry, &mut link, &mut buf, assign)
                {
                    drain_and_requeue(shared, entry, rx);
                    return;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // idle tick: prove the router is alive to the replica, and
                // notice a silently dead replica before dispatching to it
                if !try_heartbeat(&writer) {
                    evict(shared, entry, "connection closed while idle");
                    drain_and_requeue(shared, entry, rx);
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // registry closed at shutdown; queued assignments were
                // drained by recv before the channel reported disconnect
                let _ = link.send(op::FLEET_GOODBYE, &[], "fleet goodbye");
                return;
            }
        }
    }
}

/// Ship one assignment and wait for its ack.  `false` means the replica
/// is gone: the caller re-queues and exits.  The assignment's jobs are
/// answered (success path) or pushed back to the queue front (failure
/// path) — never dropped.
fn process_assignment(
    shared: &Arc<FleetShared>,
    entry: &Arc<ReplicaEntry>,
    link: &mut Link,
    buf: &mut Vec<u8>,
    assign: Assignment,
) -> bool {
    let Assignment { batch_id, jobs } = assign;
    let _span = crate::span!("fleet_dispatch", batch_id = batch_id, n = jobs.len());
    let gamma = jobs[0].gamma;
    let mut payload = Vec::with_capacity(12 + jobs.len() * shared.max_body);
    put_u64(&mut payload, batch_id);
    put_u32(&mut payload, jobs.len() as u32);
    for j in &jobs {
        payload.extend_from_slice(&wire::encode(&j.example, gamma));
    }
    // correlation ids ride the frame so replica spans share the request_id
    // a client saw in its `X-Request-Id` response header
    for j in &jobs {
        put_u32(&mut payload, j.request_id.len() as u32);
        payload.extend_from_slice(j.request_id.as_bytes());
    }
    let t0 = Instant::now();
    if let Err(e) = link.send(op::FLEET_INFER, &payload, "fleet infer") {
        evict(shared, entry, &format!("dispatch failed: {e:#}"));
        requeue(shared, entry, jobs);
        return false;
    }
    // the replica's beat thread keeps this read alive during compute;
    // recv_into skips those heartbeats transparently
    let per_ex = loop {
        match link.recv_into(buf, "fleet result") {
            Ok(op::FLEET_RESULT) => match parse_result(buf, batch_id, jobs.len()) {
                Ok(v) => break v,
                Err(e) => {
                    evict(shared, entry, &format!("bad FLEET_RESULT: {e:#}"));
                    requeue(shared, entry, jobs);
                    return false;
                }
            },
            Ok(other) => {
                evict(shared, entry, &format!("unexpected opcode {other}"));
                requeue(shared, entry, jobs);
                return false;
            }
            Err(e) => {
                evict(shared, entry, &format!("no result: {e:#}"));
                requeue(shared, entry, jobs);
                return false;
            }
        }
    };
    let (pairs, infer_calls) = per_ex;
    entry.stats.rtt_us.push(t0.elapsed().as_micros() as u64);
    entry.stats.requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);
    entry.stats.batches.fetch_add(1, Ordering::Relaxed);
    entry.stats.infer_calls.store(infer_calls, Ordering::Relaxed);
    for (job, r) in jobs.iter().zip(pairs) {
        let _ = job.resp.send(Ok(r));
    }
    entry.outstanding.fetch_sub(jobs.len(), Ordering::SeqCst);
    true
}

/// Parse one `FLEET_RESULT`: batch id + per-slot pairs + the replica's
/// cumulative `model_infer_ex` count.
fn parse_result(
    buf: &[u8],
    want_id: u64,
    want_n: usize,
) -> Result<(Vec<(f32, f32)>, u64)> {
    let mut pos = 0;
    let got_id = get_u64(buf, &mut pos)?;
    ensure!(got_id == want_id, "batch id mismatch: sent {want_id}, got {got_id}");
    let n = get_u32(buf, &mut pos)? as usize;
    ensure!(n == want_n, "result count mismatch: sent {want_n}, got {n}");
    ensure!(buf.len() == 12 + n * 8 + 8, "FLEET_RESULT length mismatch");
    let mut pairs = Vec::with_capacity(n);
    for i in 0..n {
        let at = 12 + i * 8;
        let loss = f32::from_le_bytes(buf[at..at + 4].try_into().unwrap());
        let correct = f32::from_le_bytes(buf[at + 4..at + 8].try_into().unwrap());
        pairs.push((loss, correct));
    }
    let mut tail = 12 + n * 8;
    let infer_calls = get_u64(buf, &mut tail)?;
    Ok((pairs, infer_calls))
}

fn evict(shared: &Arc<FleetShared>, entry: &Arc<ReplicaEntry>, reason: &str) {
    if shared.registry.evict(entry, reason) {
        shared.counters.evictions.inc();
        eprintln!(
            "fleet: evicted replica {} ({}): {reason}",
            entry.id, entry.peer
        );
    }
}

/// Return a dead replica's un-acked jobs to the head of the queue and
/// account for them — in-flight requests survive the death, they just
/// run again elsewhere.
fn requeue(shared: &Arc<FleetShared>, entry: &Arc<ReplicaEntry>, jobs: Vec<Job>) {
    let n = jobs.len();
    entry.outstanding.fetch_sub(n, Ordering::SeqCst);
    entry.stats.redispatched.fetch_add(n as u64, Ordering::Relaxed);
    shared.counters.redispatched.add(n as u64);
    shared.queue.push_front_all(jobs);
}

/// After eviction, drain assignments the dispatcher managed to enqueue
/// before the channel closed — those must be re-dispatched too.
fn drain_and_requeue(
    shared: &Arc<FleetShared>,
    entry: &Arc<ReplicaEntry>,
    rx: &Receiver<Assignment>,
) {
    while let Ok(a) = rx.try_recv() {
        requeue(shared, entry, a.jobs);
    }
}

// ---------------------------------------------------------------------
// front door
// ---------------------------------------------------------------------

fn listener_loop(listener: TcpListener, shared: &Arc<FleetShared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let sh = Arc::clone(shared);
                let _ = std::thread::Builder::new()
                    .name("bdia-fleet-conn".into())
                    .spawn(move || handle_conn(&s, &sh));
            }
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
        }
    }
}

fn handle_conn(stream: &TcpStream, shared: &Arc<FleetShared>) {
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT)).ok();
    stream.set_nodelay(true).ok();
    let req = match http::read_request_capped(stream, shared.max_body) {
        Ok(r) => r,
        Err(e) => {
            let rid = crate::obs::fresh_request_id();
            let _ = http::write_response_with(
                stream,
                e.status,
                e.reason,
                "application/json",
                &[("X-Request-Id", rid.clone())],
                error_body(&e.to_string(), &rid).as_bytes(),
            );
            return;
        }
    };
    let rid = req.request_id.clone().unwrap_or_else(crate::obs::fresh_request_id);
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/infer") => handle_infer(stream, shared, &req.body, &rid),
        ("POST", "/generate") => {
            // decode batching is per-position state the router does not
            // shard yet; answer with a clear contract instead of a
            // connection-level failure
            let body = format!(
                "{{\"error\": \"generation is single-process in this PR; \
                 use `bdia serve` without `--replicas`\", \"request_id\": \
                 \"{rid}\"}}\n"
            );
            let _ = http::write_response_with(
                stream,
                501,
                "Not Implemented",
                "application/json",
                &[("X-Request-Id", rid.clone())],
                body.as_bytes(),
            );
        }
        ("GET", "/healthz") => {
            let (live, evicted) = shared.registry.counts();
            let body = format!(
                "{{\"status\": \"{}\", \"model\": \"{}\", \"backend\": \
                 \"{}\", \"replicas_live\": {live}, \"replicas_evicted\": \
                 {evicted}}}",
                if live > 0 { "ok" } else { "no-replicas" },
                shared.rt.manifest.name,
                shared.rt.backend.name()
            );
            let _ = http::write_response(
                stream,
                200,
                "OK",
                "application/json",
                body.as_bytes(),
            );
        }
        ("GET", "/stats") => {
            let body = fleet_stats_json(
                &shared.stats,
                &shared.counters,
                &shared.registry.entries(),
                shared.queue.len(),
                shared.queue.cap(),
            );
            let _ = http::write_response(
                stream,
                200,
                "OK",
                "application/json",
                body.as_bytes(),
            );
        }
        ("GET", "/metrics") => {
            let body = fleet_metrics_text(
                &shared.stats,
                &shared.rt.call_counts(),
                &shared.registry.entries(),
            );
            let _ = http::write_response(
                stream,
                200,
                "OK",
                "text/plain; version=0.0.4",
                body.as_bytes(),
            );
        }
        ("POST", "/shutdown") => {
            let _ = http::write_response(
                stream,
                200,
                "OK",
                "text/plain",
                b"shutting down\n",
            );
            initiate_shutdown(shared);
        }
        (_, path) => {
            let _ = http::write_response(
                stream,
                404,
                "Not Found",
                "text/plain",
                format!("no such endpoint: {path}\n").as_bytes(),
            );
        }
    }
}

fn handle_infer(
    stream: &TcpStream,
    shared: &Arc<FleetShared>,
    body: &[u8],
    rid: &str,
) {
    let t0 = Instant::now();
    let _span = crate::span!("fleet_request", request_id = rid);
    let m = &shared.rt.manifest;
    let (example, gamma) = match wire::decode(m.family, &m.dims, body) {
        Ok(v) => v,
        Err(e) => {
            shared.stats.record_error();
            shared.sink.on_request(&RequestEvent {
                latency_us: t0.elapsed().as_micros() as u64,
                elapsed_us: crate::obs::now_us(),
                ok: false,
            });
            let _ = http::write_response_with(
                stream,
                400,
                "Bad Request",
                "application/json",
                &[("X-Request-Id", rid.to_string())],
                error_body(&format!("{e:#}"), rid).as_bytes(),
            );
            return;
        }
    };
    let (tx, rx) = mpsc::channel();
    let outcome = shared.queue.push(Job {
        example,
        gamma,
        enqueued: t0,
        resp: tx,
        request_id: rid.to_string(),
    });
    match outcome {
        PushOutcome::Accepted => {}
        PushOutcome::Saturated { depth, cap } => {
            shared.counters.rejected_503.inc();
            shared.stats.record_error();
            shared.sink.on_request(&RequestEvent {
                latency_us: t0.elapsed().as_micros() as u64,
                elapsed_us: crate::obs::now_us(),
                ok: false,
            });
            let _ = write_503(stream, "queue full", depth, Some(cap), rid);
            return;
        }
        PushOutcome::ShuttingDown => {
            shared.counters.rejected_503.inc();
            shared.sink.on_request(&RequestEvent {
                latency_us: t0.elapsed().as_micros() as u64,
                elapsed_us: crate::obs::now_us(),
                ok: false,
            });
            let _ = write_503(
                stream,
                "server is shutting down",
                shared.queue.len(),
                shared.queue.cap(),
                rid,
            );
            return;
        }
    }
    // bounded wait: if every replica is dead and none re-joins, the
    // client gets a 503 instead of a hang
    let request_timeout = (shared.deadline * 6).max(Duration::from_secs(60));
    let outcome = rx.recv_timeout(request_timeout);
    let latency_us = t0.elapsed().as_micros() as u64;
    shared.sink.on_request(&RequestEvent {
        latency_us,
        elapsed_us: crate::obs::now_us(),
        ok: matches!(outcome, Ok(Ok(_))),
    });
    match outcome {
        Ok(Ok((loss, correct))) => {
            let mut out = [0u8; 8];
            out[..4].copy_from_slice(&loss.to_le_bytes());
            out[4..].copy_from_slice(&correct.to_le_bytes());
            shared.stats.record_request();
            shared.stats.record_latency_us(latency_us);
            let _ = http::write_response_with(
                stream,
                200,
                "OK",
                "application/octet-stream",
                &[("X-Request-Id", rid.to_string())],
                &out,
            );
        }
        Ok(Err(msg)) => {
            shared.stats.record_error();
            let _ = http::write_response_with(
                stream,
                500,
                "Internal Server Error",
                "application/json",
                &[("X-Request-Id", rid.to_string())],
                error_body(&msg, rid).as_bytes(),
            );
        }
        Err(_) => {
            shared.stats.record_error();
            shared.counters.rejected_503.inc();
            let _ = write_503(
                stream,
                "no replica answered in time",
                shared.queue.len(),
                shared.queue.cap(),
                rid,
            );
        }
    }
}
