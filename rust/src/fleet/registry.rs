//! Replica membership: admission, health, eviction and least-outstanding
//! selection.
//!
//! Each connected replica gets a [`ReplicaEntry`] holding its health
//! state, in-flight request count, per-replica counters/latency reservoir
//! and the channel its worker thread pulls [`Assignment`]s from.  Evicted
//! entries are kept (dead) in the registry so `/stats` can report their
//! history and `/healthz` can count them; a recovered replica re-joins as
//! a *new* entry.

use crate::serve::batcher::Job;
use super::stats::ReplicaStats;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

/// One γ-pure micro-batch bound for a single replica.  The jobs keep
/// their response channels: acknowledging the batch means answering every
/// one of them.
pub struct Assignment {
    pub batch_id: u64,
    pub jobs: Vec<Job>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Health {
    Live,
    Evicted { reason: String },
}

pub struct ReplicaEntry {
    /// Stable id (admission order); re-admissions get fresh ids.
    pub id: usize,
    /// Peer address, for operators reading `/stats`.
    pub peer: String,
    health: Mutex<Health>,
    /// Requests dispatched but not yet answered — the load-balancing key.
    pub outstanding: AtomicUsize,
    /// Dispatch channel; taken (set to `None`) on eviction or shutdown so
    /// the dispatcher can never hand work to a dead replica.
    tx: Mutex<Option<Sender<Assignment>>>,
    pub stats: ReplicaStats,
}

impl ReplicaEntry {
    pub fn is_live(&self) -> bool {
        matches!(*self.health.lock().unwrap(), Health::Live)
    }

    pub fn health(&self) -> Health {
        self.health.lock().unwrap().clone()
    }

    /// Try to hand this replica a batch; `Err` returns the assignment to
    /// the caller when the entry was evicted between `pick` and `send`.
    pub fn send(&self, a: Assignment) -> Result<(), Assignment> {
        let g = self.tx.lock().unwrap();
        match &*g {
            Some(tx) => tx.send(a).map_err(|e| e.0),
            None => Err(a),
        }
    }
}

#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Arc<ReplicaEntry>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit a freshly handshaken replica; returns its entry.
    pub fn admit(&self, peer: String, tx: Sender<Assignment>) -> Arc<ReplicaEntry> {
        let mut g = self.entries.lock().unwrap();
        let entry = Arc::new(ReplicaEntry {
            id: g.len(),
            peer,
            health: Mutex::new(Health::Live),
            outstanding: AtomicUsize::new(0),
            tx: Mutex::new(Some(tx)),
            stats: ReplicaStats::new(),
        });
        g.push(Arc::clone(&entry));
        entry
    }

    /// Mark a replica dead and close its dispatch channel.  Idempotent;
    /// returns true on the first (effective) eviction.
    pub fn evict(&self, entry: &ReplicaEntry, reason: &str) -> bool {
        let mut h = entry.health.lock().unwrap();
        let first = matches!(*h, Health::Live);
        if first {
            *h = Health::Evicted { reason: reason.to_string() };
        }
        drop(h);
        entry.tx.lock().unwrap().take();
        first
    }

    /// Least-outstanding-requests selection over live replicas (ties go
    /// to the lowest id, keeping placement deterministic under equal
    /// load).
    pub fn pick(&self) -> Option<Arc<ReplicaEntry>> {
        let g = self.entries.lock().unwrap();
        g.iter()
            .filter(|e| e.is_live())
            .min_by_key(|e| (e.outstanding.load(Ordering::SeqCst), e.id))
            .map(Arc::clone)
    }

    /// (live, evicted) counts, for `/healthz`.
    pub fn counts(&self) -> (usize, usize) {
        let g = self.entries.lock().unwrap();
        let live = g.iter().filter(|e| e.is_live()).count();
        (live, g.len() - live)
    }

    /// Snapshot of every entry ever admitted (live and evicted).
    pub fn entries(&self) -> Vec<Arc<ReplicaEntry>> {
        self.entries.lock().unwrap().clone()
    }

    /// Close every dispatch channel (shutdown): worker threads observe
    /// `Disconnected` after draining already-queued assignments.
    pub fn close(&self) {
        for e in self.entries.lock().unwrap().iter() {
            e.tx.lock().unwrap().take();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn pick_prefers_least_outstanding_then_lowest_id() {
        let reg = Registry::new();
        let (tx0, _rx0) = mpsc::channel();
        let (tx1, _rx1) = mpsc::channel();
        let a = reg.admit("a".into(), tx0);
        let b = reg.admit("b".into(), tx1);
        assert_eq!(reg.pick().unwrap().id, a.id, "tie goes to lowest id");
        a.outstanding.store(3, Ordering::SeqCst);
        assert_eq!(reg.pick().unwrap().id, b.id);
        b.outstanding.store(5, Ordering::SeqCst);
        assert_eq!(reg.pick().unwrap().id, a.id);
    }

    #[test]
    fn eviction_is_sticky_and_closes_the_channel() {
        let reg = Registry::new();
        let (tx, rx) = mpsc::channel();
        let a = reg.admit("a".into(), tx);
        assert_eq!(reg.counts(), (1, 0));
        assert!(reg.evict(&a, "deadline"));
        assert!(!reg.evict(&a, "again"), "second eviction is a no-op");
        assert_eq!(reg.counts(), (0, 1));
        assert!(reg.pick().is_none());
        assert_eq!(a.health(), Health::Evicted { reason: "deadline".into() });
        // the worker side observes the closed channel
        assert!(rx.try_recv().is_err());
        // sending to an evicted entry returns the assignment
        let asg = Assignment { batch_id: 7, jobs: Vec::new() };
        assert_eq!(a.send(asg).unwrap_err().batch_id, 7);
    }

    #[test]
    fn readmission_is_a_new_entry() {
        let reg = Registry::new();
        let (tx0, _rx0) = mpsc::channel();
        let a = reg.admit("host:1".into(), tx0);
        reg.evict(&a, "killed");
        let (tx1, _rx1) = mpsc::channel();
        let b = reg.admit("host:1".into(), tx1);
        assert_ne!(a.id, b.id);
        assert_eq!(reg.counts(), (1, 1));
        assert_eq!(reg.entries().len(), 2, "history is retained for /stats");
    }
}
