//! # `bdia::fleet` — sharded serving: one front door, many replicas
//!
//! The paper's deployment pitch is that a BDIA-trained transformer is
//! *architecturally standard* at inference (E\[γ\] = 0), so scaling it out
//! is plain replica fan-out: this module puts a **router** in front of N
//! **replica** processes, each holding a full copy of the model.
//!
//! * [`router::Router`] — accepts the existing `POST /infer` HTTP surface
//!   unchanged, does sticky γ-keyed micro-batching *before* dispatch (a
//!   batch never mixes γ keys and never splits across replicas), picks
//!   the least-outstanding live replica, applies bounded admission
//!   (`503 Retry-After` past the queue cap), and merges per-replica
//!   latency/counters into one fleet `/stats` view.
//! * [`replica::run`] — a weight-free worker: it receives the router's
//!   exact parameter blob in the `FLEET_WELCOME` handshake frame, so
//!   every replica bit-matches the router's weights by construction.
//! * [`registry::Registry`] — membership: admission, heartbeat-based
//!   eviction, re-admission on recovery.  A dead replica's un-acked
//!   batches are re-queued at the queue *front* and re-dispatched, so
//!   in-flight requests survive replica death.
//!
//! The backplane speaks `dist::transport` length-prefixed frames
//! (`FLEET_*` opcodes) and reuses its heartbeat machinery in both
//! directions: replicas beat while computing so the router's deadline
//! never trips on a slow-but-alive worker; the router beats while idle so
//! replicas can tell a quiet router from a dead one.
//!
//! Bit-exactness is the signature invariant: `wire::infer_batch` outputs
//! are slot/neighbour-invariant, so a response computed by any replica in
//! any coalesced batch is bit-identical to a direct single-example
//! `model_infer_ex` call — `bdia bench-serve --replicas N` verifies every
//! response against local inference, and `tests/fleet.rs` holds this
//! through mid-load replica death.

pub mod registry;
pub mod replica;
pub mod router;
pub mod stats;

pub use registry::Registry;
pub use replica::{spawn_local_replicas, ReplicaConfig, ReplicaSpawnOpts};
pub use router::{FleetConfig, Router};
