//! Fleet-wide observability: per-replica counters + RTT reservoirs,
//! merged into the router's single `/stats` document.
//!
//! The invariant the acceptance tests pin: the top-level `requests` and
//! `batches` totals are *computed as* the sum over the per-replica
//! breakdown, so the merged view can never disagree with its parts.

use crate::obs::Counter;
use crate::serve::stats::{percentile_us, LatencySummary, ServeStats};
use super::registry::{Health, ReplicaEntry};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-replica RTT reservoir capacity.
const RTT_RESERVOIR: usize = 2048;

/// Small fixed-capacity sample ring (the `ServeStats` reservoir is
/// private to its own percentile pipeline; replicas need one each).
pub struct Reservoir {
    ring: Mutex<(Vec<u64>, usize, usize)>, // (buf, next, len)
}

impl Default for Reservoir {
    fn default() -> Self {
        Self::new(RTT_RESERVOIR)
    }
}

impl Reservoir {
    pub fn new(capacity: usize) -> Self {
        Reservoir { ring: Mutex::new((vec![0; capacity.max(1)], 0, 0)) }
    }

    pub fn push(&self, us: u64) {
        let mut g = self.ring.lock().unwrap();
        let cap = g.0.len();
        let slot = g.1;
        g.0[slot] = us;
        g.1 = (slot + 1) % cap;
        g.2 = (g.2 + 1).min(cap);
    }

    /// Current samples (unordered).
    pub fn samples(&self) -> Vec<u64> {
        let g = self.ring.lock().unwrap();
        g.0[..g.2].to_vec()
    }
}

/// Counters one replica accumulates over its lifetime (survive eviction —
/// `/stats` reports dead replicas' history too).
#[derive(Default)]
pub struct ReplicaStats {
    /// Requests answered (batch sizes summed).
    pub requests: AtomicU64,
    /// Batches answered.
    pub batches: AtomicU64,
    /// Cumulative `model_infer_ex` calls the replica reported.
    pub infer_calls: AtomicU64,
    /// Requests this replica left un-acked that were re-dispatched.
    pub redispatched: AtomicU64,
    /// Backplane round-trip times (dispatch → result), µs.
    pub rtt_us: Reservoir,
}

impl ReplicaStats {
    pub fn new() -> Self {
        Self::default()
    }
}

fn fmt_latency(l: Option<LatencySummary>) -> String {
    match l {
        Some(l) => format!(
            "{{\"mean\": {:.3}, \"p50\": {:.3}, \"p90\": {:.3}, \
             \"p99\": {:.3}, \"max\": {:.3}}}",
            l.mean_ms, l.p50_ms, l.p90_ms, l.p99_ms, l.max_ms
        ),
        None => "null".to_string(),
    }
}

fn fmt_rtt(samples: &mut [u64]) -> String {
    if samples.is_empty() {
        return "null".to_string();
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<u64>() as f64 / samples.len() as f64;
    format!(
        "{{\"mean\": {:.3}, \"p50\": {:.3}, \"p99\": {:.3}}}",
        mean / 1e3,
        percentile_us(samples, 0.50) as f64 / 1e3,
        percentile_us(samples, 0.99) as f64 / 1e3
    )
}

/// Counters the router itself owns (not attributable to one replica).
/// Registered in the router's [`crate::obs::Registry`] so they surface on
/// the fleet `GET /metrics` exposition alongside the admission counters.
pub struct RouterCounters {
    /// Requests bounced with `503` (saturation or shutdown).
    pub rejected_503: Counter,
    /// Requests re-queued after their replica died un-acked.
    pub redispatched: Counter,
    /// Replicas evicted since start.
    pub evictions: Counter,
}

impl RouterCounters {
    pub fn new(registry: &crate::obs::Registry) -> Self {
        let rejected_503 = registry.counter("bdia_router_rejected_503_total", "503 rejections");
        let redispatched = registry.counter("bdia_router_redispatched_total", "un-acked requeues");
        let evictions = registry.counter("bdia_router_evictions_total", "replicas evicted");
        RouterCounters { rejected_503, redispatched, evictions }
    }
}

/// Render the fleet `/stats` document.  `router` carries the end-to-end
/// request view (client-observed latency, error count); per-replica rows
/// come from the registry snapshot.  Top-level `requests`/`batches` are
/// sums over the per-replica rows by construction.
pub fn fleet_stats_json(
    router: &ServeStats,
    counters: &RouterCounters,
    entries: &[std::sync::Arc<ReplicaEntry>],
    queue_depth: usize,
    queue_cap: Option<usize>,
) -> String {
    let mut total_requests = 0u64;
    let mut total_batches = 0u64;
    let mut pooled: Vec<u64> = Vec::new();
    let mut live = 0usize;
    let mut rows: Vec<String> = Vec::with_capacity(entries.len());
    for e in entries {
        let requests = e.stats.requests.load(Ordering::Relaxed);
        let batches = e.stats.batches.load(Ordering::Relaxed);
        total_requests += requests;
        total_batches += batches;
        let mut rtt = e.stats.rtt_us.samples();
        pooled.extend_from_slice(&rtt);
        let (state, reason) = match e.health() {
            Health::Live => {
                live += 1;
                ("live".to_string(), "null".to_string())
            }
            Health::Evicted { reason } => {
                ("evicted".to_string(), format!("\"{}\"", reason.escape_default()))
            }
        };
        rows.push(format!(
            "{{\"id\": {}, \"peer\": \"{}\", \"state\": \"{state}\", \
             \"evict_reason\": {reason}, \"outstanding\": {}, \
             \"requests\": {requests}, \"batches\": {batches}, \
             \"infer_calls\": {}, \"redispatched\": {}, \"rtt_ms\": {}}}",
            e.id,
            e.peer.escape_default(),
            e.outstanding.load(Ordering::SeqCst),
            e.stats.infer_calls.load(Ordering::Relaxed),
            e.stats.redispatched.load(Ordering::Relaxed),
            fmt_rtt(&mut rtt)
        ));
    }
    let mean_batch = if total_batches == 0 {
        0.0
    } else {
        total_requests as f64 / total_batches as f64
    };
    format!(
        "{{\"requests\": {total_requests}, \"errors\": {}, \
         \"batches\": {total_batches}, \"mean_batch\": {mean_batch:.4}, \
         \"rejected_503\": {}, \"redispatched\": {}, \"evictions\": {}, \
         \"queue\": {{\"depth\": {queue_depth}, \"cap\": {}}}, \
         \"uptime_s\": {:.3}, \"requests_per_sec\": {:.3}, \
         \"tune_profile\": \"{}\", \
         \"latency_ms\": {}, \"fleet_rtt_ms\": {}, \
         \"replicas\": {{\"live\": {live}, \"evicted\": {}, \
         \"per_replica\": [{}]}}}}",
        router.errors(),
        counters.rejected_503.get(),
        counters.redispatched.get(),
        counters.evictions.get(),
        queue_cap.unwrap_or(0),
        router.uptime_s(),
        router.requests_per_sec(),
        crate::kernels::profile::active_id(),
        fmt_latency(router.latency()),
        fmt_rtt(&mut pooled),
        entries.len() - live,
        rows.join(", ")
    )
}

/// Render the fleet `GET /metrics` exposition: the router's own registry
/// (admission counters, client-observed latency, router counters, the
/// process-wide registry) plus labeled per-replica request/batch families
/// and a live-replica gauge.
pub fn fleet_metrics_text(
    router: &ServeStats,
    exec_calls: &[(String, u64)],
    entries: &[std::sync::Arc<ReplicaEntry>],
) -> String {
    let mut out = router.metrics_text(exec_calls);
    let mut live = 0u64;
    let mut reqs = String::new();
    let mut batches = String::new();
    for e in entries {
        if matches!(e.health(), Health::Live) {
            live += 1;
        }
        let id = e.id;
        let r = e.stats.requests.load(Ordering::Relaxed);
        let b = e.stats.batches.load(Ordering::Relaxed);
        let _ = writeln!(reqs, "bdia_replica_requests_total{{replica=\"{id}\"}} {r}");
        let _ = writeln!(batches, "bdia_replica_batches_total{{replica=\"{id}\"}} {b}");
    }
    out.push_str("# HELP bdia_replica_requests_total requests answered per replica\n");
    out.push_str("# TYPE bdia_replica_requests_total counter\n");
    out.push_str(&reqs);
    out.push_str("# HELP bdia_replica_batches_total batches answered per replica\n");
    out.push_str("# TYPE bdia_replica_batches_total counter\n");
    out.push_str(&batches);
    out.push_str("# HELP bdia_replicas_live replicas currently live\n");
    out.push_str("# TYPE bdia_replicas_live gauge\n");
    let _ = writeln!(out, "bdia_replicas_live {live}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::Json;
    use super::super::registry::{Assignment, Registry};
    use std::sync::mpsc;

    #[test]
    fn reservoir_wraps_and_reports_window() {
        let r = Reservoir::new(4);
        assert!(r.samples().is_empty());
        for us in 1..=10u64 {
            r.push(us);
        }
        let mut s = r.samples();
        s.sort_unstable();
        assert_eq!(s, vec![7, 8, 9, 10]);
    }

    #[test]
    fn totals_equal_sum_of_per_replica_counts() {
        let reg = Registry::new();
        let (tx0, _rx0) = mpsc::channel::<Assignment>();
        let (tx1, _rx1) = mpsc::channel::<Assignment>();
        let a = reg.admit("a".into(), tx0);
        let b = reg.admit("b".into(), tx1);
        a.stats.requests.store(5, Ordering::Relaxed);
        a.stats.batches.store(2, Ordering::Relaxed);
        a.stats.rtt_us.push(1500);
        b.stats.requests.store(3, Ordering::Relaxed);
        b.stats.batches.store(3, Ordering::Relaxed);
        reg.evict(&b, "test \"eviction\"");
        let router = ServeStats::new(8);
        let counters = RouterCounters::new(router.registry());
        counters.rejected_503.add(4);
        let j = fleet_stats_json(&router, &counters, &reg.entries(), 1, Some(64));
        let parsed = Json::parse(&j).expect("valid json");
        assert_eq!(parsed.get("requests").unwrap().as_usize().unwrap(), 8);
        assert_eq!(parsed.get("batches").unwrap().as_usize().unwrap(), 5);
        assert_eq!(parsed.get("rejected_503").unwrap().as_usize().unwrap(), 4);
        assert_eq!(
            parsed.get("queue").unwrap().get("cap").unwrap().as_usize().unwrap(),
            64
        );
        // the router's active kernel profile id surfaces fleet-wide
        assert!(!parsed.get("tune_profile").unwrap().as_str().unwrap().is_empty());
        let reps = parsed.get("replicas").unwrap();
        assert_eq!(reps.get("live").unwrap().as_usize().unwrap(), 1);
        assert_eq!(reps.get("evicted").unwrap().as_usize().unwrap(), 1);
        let rows = reps.get("per_replica").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        // the invariant the acceptance criteria pin: top-level totals are
        // the sum over this array
        let sum: usize = rows
            .iter()
            .map(|r| r.get("requests").unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(sum, 8);
        assert!(
            (parsed.get("mean_batch").unwrap().as_f64().unwrap() - 1.6).abs() < 1e-9
        );
    }

    #[test]
    fn fleet_metrics_exposition_passes_the_checker() {
        let reg = Registry::new();
        let (tx, _rx) = mpsc::channel::<Assignment>();
        let a = reg.admit("a".into(), tx);
        a.stats.requests.store(5, Ordering::Relaxed);
        a.stats.batches.store(2, Ordering::Relaxed);
        let router = ServeStats::new(8);
        let counters = RouterCounters::new(router.registry());
        counters.evictions.inc();
        let execs = [("model_infer_ex".to_string(), 2u64)];
        let text = fleet_metrics_text(&router, &execs, &reg.entries());
        crate::obs::prom::check(&text).expect("valid exposition");
        assert!(text.contains("bdia_router_evictions_total 1"), "{text}");
        assert!(text.contains("bdia_replica_requests_total{replica="), "{text}");
        assert!(text.contains("bdia_replicas_live 1"), "{text}");
    }
}
