//! # bdia — exact bit-level reversible transformer training
//!
//! Reproduction of "On Exact Bit-level Reversible Transformers Without
//! Changing Architectures" (Zhang, Lewis, Kleijn, 2024) as a three-layer
//! Rust + JAX + Pallas system. See DESIGN.md for the architecture and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! - [`runtime`]: PJRT client executing AOT HLO artifacts (L2/L1 outputs)
//! - [`coordinator`]: the paper's contribution — BDIA reversible training
//! - [`quant`]: exact fixed-point BDIA arithmetic (eqs. 17-24)
//! - [`baseline`]: vanilla + RevViT comparators
pub mod config;
pub mod tensor;
pub mod quant;
pub mod runtime;
pub mod model;
pub mod coordinator;
pub mod baseline;
pub mod optim;
pub mod data;
pub mod metrics;
pub mod experiments;
pub mod bench;
