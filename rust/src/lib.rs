//! # bdia — exact bit-level reversible transformer training
//!
//! Reproduction of "On Exact Bit-level Reversible Transformers Without
//! Changing Architectures" (Zhang, Lewis, Kleijn, 2024).  See `rust/README.md`
//! for the layer map, backend selection and how to run the tier-1 suite.
//!
//! Layer map:
//! - [`api`]: the embeddable facade — `Session`/`SessionBuilder` over the
//!   whole lifecycle (train / evaluate / infer / save / resume / serve /
//!   bench), typed `ModelId`, the structured `ApiError` taxonomy and the
//!   `EventSink` observer; the CLI, experiments and bench suite are thin
//!   clients of it
//! - [`kernels`]: deterministic parallel compute core — cache-blocked,
//!   multi-threaded matmul/layernorm/attention kernels (row-partitioned
//!   parallelism only, bit-identical at any thread count), persistent
//!   thread pool, thread-local workspace arena, and the autotuning layer:
//!   per-shape `KernelProfile`s searched by `bdia tune`, persisted as
//!   versioned JSON, bit-exact by construction for every legal setting
//! - [`runtime`]: pluggable execution backends behind one ABI — the default
//!   pure-Rust `native` interpreter (no deps, no artifacts) and the
//!   feature-gated `pjrt` PJRT/XLA executor for AOT HLO bundles
//! - [`coordinator`]: the paper's contribution — BDIA reversible training
//! - [`quant`]: exact fixed-point BDIA arithmetic (eqs. 17-24)
//! - [`baseline`]: vanilla + RevViT comparators
//! - [`checkpoint`]: versioned, checksummed binary persistence of trained
//!   state (params + optimizer + step), bit-exact round trips
//! - [`generate`]: autoregressive decoding — per-session KV-cache
//!   workspace, deterministic greedy/temperature/top-k sampling, and a
//!   lane-packed `decode_tick` whose incremental logits are bit-identical
//!   to a full re-forward of the prefix at any thread count or profile
//! - [`serve`]: concurrent inference serving over `std::net` — dynamic
//!   micro-batching, worker pool, streaming `/generate`, `/healthz` +
//!   `/stats`, load generator
//! - [`dist`]: deterministic data-parallel training over pure-std TCP —
//!   rendezvous handshake, rank-ordered collectives (bit-identical summed
//!   gradients at every world size), in-process multi-rank harness and
//!   multi-process launcher
//! - [`fleet`]: sharded serving — one HTTP router fanning γ-keyed
//!   micro-batches over N full model replicas (weights pushed at
//!   handshake), with heartbeat eviction, un-acked batch re-dispatch,
//!   bounded admission and a merged fleet `/stats` view
//! - [`obs`]: observability substrate — lock-light metric registry
//!   (counters/gauges/power-of-two histograms) behind `/stats` and the
//!   Prometheus `/metrics` endpoints, `obs::span!` tracing with Chrome
//!   trace export and cross-rank timeline merge (`bdia trace`), and
//!   request-id correlation through serve and fleet; non-interfering by
//!   construction (timestamps never enter compute)
pub mod api;
pub mod config;
pub mod tensor;
pub mod quant;
pub mod kernels;
pub mod runtime;
pub mod model;
pub mod coordinator;
pub mod baseline;
pub mod optim;
pub mod data;
pub mod metrics;
pub mod experiments;
pub mod bench;
pub mod checkpoint;
pub mod generate;
pub mod serve;
pub mod dist;
pub mod fleet;
pub mod obs;

// Compile-check the README's Rust examples (the "Library use" section) as
// doctests, so the documented API surface cannot rot.
#[cfg(doctest)]
#[doc = include_str!("../README.md")]
struct ReadmeDoctests;
