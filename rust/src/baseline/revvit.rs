//! RevViT [19] baseline: two-stream reversible transformer.
//!
//! The comparator the paper evaluates (Table 1, Fig. 3).  Each block couples
//! two activation streams through the attention and FFN sub-branches
//!
//!   `y1 = x1 + F(x2)`   with `F = Attn(LN1(.))`
//!   `y2 = x2 + G(y1)`   with `G = FFN(LN2(.))`
//!
//! which inverts in float arithmetic as `x2 = y2 - G(y1); x1 = y1 - F(x2)` —
//! memory O(1) in depth like BDIA, but (a) the *architecture* differs from a
//! standard transformer at inference (the paper's criticism), and (b) the
//! inversion is float, not bit-exact (small drift accumulates; the
//! `inversion_drift` diagnostic measures it, cf. Fig. 2's motivation).
//!
//! Streams are initialised by duplicating the embedding (`x1 = x2 = x0`) and
//! fused by averaging before the head — the standard RevNet-style choice.
//! Uses the `attn_*`/`ffn_*` sub-branch executables exported per bundle.

use crate::config::TrainConfig;
use crate::coordinator::trainer::accumulate_leaves;
use crate::data::{Batch, Dataset};
use crate::metrics::{Record, TrainLog};
use crate::model::{Family, ParamStore};
use crate::optim::{clip_global_norm, Optimizer};
use crate::runtime::{ArgValue, Exec, Runtime};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};

pub struct RevVitTrainer {
    pub rt: Runtime,
    pub params: ParamStore,
    grads: ParamStore,
    pub opt: Optimizer,
    pub cfg: TrainConfig,
    family: Family,
    step: usize,
    /// max |x - x_reconstructed| seen during the last backward (float drift)
    pub inversion_drift: f32,
}

struct RevState {
    y1: Tensor,
    y2: Tensor,
}

impl RevVitTrainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let rt = Runtime::load_with(&cfg.artifacts_dir, &cfg.model, cfg.backend)
            .with_context(|| {
                format!("loading bundle '{}' ({})", cfg.model, cfg.backend.name())
            })?;
        Self::with_runtime(cfg, rt)
    }

    pub fn with_runtime(cfg: TrainConfig, rt: Runtime) -> Result<Self> {
        let family = rt.manifest.family;
        if family == Family::EncDec {
            bail!("RevViT baseline supports vit/gpt bundles only");
        }
        ensure!(
            rt.has_exec("attn_fwd") && rt.has_exec("ffn_fwd"),
            "bundle '{}' lacks the attn/ffn sub-branch executables",
            cfg.model
        );
        let params = ParamStore::init(&rt.manifest, cfg.seed);
        let grads = params.zeros_like();
        let opt = Optimizer::new(&cfg, &params);
        Ok(RevVitTrainer {
            rt,
            params,
            grads,
            opt,
            cfg,
            family,
            step: 0,
            inversion_drift: 0.0,
        })
    }

    pub fn n_params(&self) -> usize {
        self.params.n_params()
    }

    fn branch(&self, exec: &Exec, k: usize, x: &Tensor) -> Result<Tensor> {
        let refs = self.params.refs_for(&exec.spec, k)?;
        Ok(exec.call(&refs, &[ArgValue::F32(x)])?.remove(0))
    }

    /// (out, dx, dparams) from a sub-branch vjp.
    fn branch_vjp(
        &self,
        exec: &Exec,
        k: usize,
        x: &Tensor,
        g: &Tensor,
    ) -> Result<(Tensor, Tensor, Vec<Tensor>)> {
        let refs = self.params.refs_for(&exec.spec, k)?;
        let mut outs = exec.call(&refs, &[ArgValue::F32(x), ArgValue::F32(g)])?;
        let out = outs.remove(0);
        let dx = outs.remove(0);
        Ok((out, dx, outs))
    }

    fn embed(&self, batch: &Batch) -> Result<Tensor> {
        let e = self.rt.exec("embed_fwd")?;
        let refs = self.params.refs_for(&e.spec, 0)?;
        let out = match (self.family, batch) {
            (Family::Vit, Batch::Image { images, .. }) => {
                e.call(&refs, &[ArgValue::F32(images)])?
            }
            (Family::Gpt, Batch::Lm { tokens, .. }) => {
                e.call(&refs, &[ArgValue::I32(tokens)])?
            }
            _ => bail!("batch type does not match model family"),
        };
        Ok(out.into_iter().next().unwrap())
    }

    fn forward(&self, batch: &Batch) -> Result<(RevState, f32, f32)> {
        let attn = self.rt.exec("attn_fwd")?;
        let ffn = self.rt.exec("ffn_fwd")?;
        let x0 = self.embed(batch)?;
        let mut x1 = x0.clone();
        let mut x2 = x0;
        for k in 0..self.rt.manifest.dims.n_blocks {
            let f = self.branch(attn, k, &x2)?;
            x1.add_assign(&f)?; // y1 = x1 + F(x2)
            let g = self.branch(ffn, k, &x1)?;
            x2.add_assign(&g)?; // y2 = x2 + G(y1)
        }
        // fuse streams, run head
        let mut fused = x1.clone();
        fused.add_assign(&x2)?;
        fused.scale(0.5);
        let head = self.rt.exec("head_loss_fwd")?;
        let refs = self.params.refs_for(&head.spec, 0)?;
        let labels = labels_of(batch);
        let outs = head.call(&refs, &[ArgValue::F32(&fused), ArgValue::I32(labels)])?;
        let loss = outs[0].scalar_value()?;
        let ncorrect = outs[1].scalar_value()?;
        Ok((RevState { y1: x1, y2: x2 }, loss, ncorrect))
    }

    fn backward(&mut self, batch: &Batch, state: RevState) -> Result<()> {
        let attn = self.rt.exec("attn_vjp")?;
        let ffn = self.rt.exec("ffn_vjp")?;
        // head
        let mut fused = state.y1.clone();
        fused.add_assign(&state.y2)?;
        fused.scale(0.5);
        let hv = self.rt.exec("head_loss_vjp")?;
        let refs = self.params.refs_for(&hv.spec, 0)?;
        let labels = labels_of(batch);
        let mut outs = hv.call(&refs, &[ArgValue::F32(&fused), ArgValue::I32(labels)])?;
        let dfused = outs.remove(0);
        accumulate_leaves(&mut self.grads, "head", 0, &outs)?;

        let mut gy1 = dfused.clone();
        gy1.scale(0.5);
        let mut gy2 = dfused;
        gy2.scale(0.5);

        let (mut y1, mut y2) = (state.y1, state.y2);
        for k in (0..self.rt.manifest.dims.n_blocks).rev() {
            // invert: x2 = y2 - G(y1); grads of G at y1 with seed gy2
            let (g_out, dg_y1, dgp) = self.branch_vjp(ffn, k, &y1, &gy2)?;
            accumulate_leaves(&mut self.grads, "block", k, &dgp)?;
            let mut x2 = y2;
            x2.axpy(-1.0, &g_out)?;
            let mut gz1 = gy1;
            gz1.add_assign(&dg_y1)?; // gz1 = gy1 + JG^T gy2

            // invert: x1 = y1 - F(x2); grads of F at x2 with seed gz1
            let (f_out, df_x2, dfp) = self.branch_vjp(attn, k, &x2, &gz1)?;
            accumulate_leaves(&mut self.grads, "block", k, &dfp)?;
            let mut x1 = y1;
            x1.axpy(-1.0, &f_out)?;
            let mut gx2 = gy2;
            gx2.add_assign(&df_x2)?; // gx2 = gy2 + JF^T gz1

            y1 = x1;
            y2 = x2;
            gy1 = gz1;
            gy2 = gx2;
        }
        // streams were duplicated from x0: dx0 = gx1 + gx2
        let mut dx0 = gy1;
        dx0.add_assign(&gy2)?;
        // drift diagnostic: reconstructed x1 vs x2 should both equal x0
        self.inversion_drift = y1.max_abs_diff(&y2).unwrap_or(f32::NAN);

        let ev = self.rt.exec("embed_vjp")?;
        let refs = self.params.refs_for(&ev.spec, 0)?;
        let douts = match (self.family, batch) {
            (Family::Vit, Batch::Image { images, .. }) => {
                ev.call(&refs, &[ArgValue::F32(images), ArgValue::F32(&dx0)])?
            }
            (Family::Gpt, Batch::Lm { tokens, .. }) => {
                ev.call(&refs, &[ArgValue::I32(tokens), ArgValue::F32(&dx0)])?
            }
            _ => bail!("batch type mismatch"),
        };
        accumulate_leaves(&mut self.grads, "embed", 0, &douts)?;
        Ok(())
    }

    pub fn train_step(&mut self, batch: &Batch) -> Result<crate::coordinator::StepStats> {
        self.grads.zero();
        let (state, loss, ncorrect) = self.forward(batch)?;
        let stored = state.y1.nbytes() + state.y2.nbytes();
        let acc = ncorrect / batch.n_predictions() as f32;
        self.backward(batch, state)?;
        let grad_norm = match self.cfg.grad_clip {
            Some(c) => clip_global_norm(&mut self.grads, c),
            None => self.grads.global_norm(),
        };
        ensure!(grad_norm.is_finite(), "RevViT grad diverged at step {}", self.step);
        self.opt.step(&mut self.params, &self.grads)?;
        self.step += 1;
        Ok(crate::coordinator::StepStats {
            loss,
            acc,
            grad_norm,
            stored_activation_bytes: stored,
        })
    }

    /// Validation with the RevViT architecture itself (it has no standard-
    /// transformer inference form — the paper's core criticism).
    pub fn evaluate(&self, data: &dyn Dataset, n_batches: usize) -> Result<(f32, f32)> {
        let n = n_batches.min(data.n_val_batches()).max(1);
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut total = 0usize;
        for i in 0..n {
            let batch = data.val_batch(i);
            let (_, loss, nc) = self.forward(&batch)?;
            loss_sum += loss as f64;
            correct += nc as f64;
            total += batch.n_predictions();
        }
        Ok(((loss_sum / n as f64) as f32, (correct / total.max(1) as f64) as f32))
    }

    /// Completed optimization steps.
    pub fn step(&self) -> usize {
        self.step
    }

    pub fn run(&mut self, data: &dyn Dataset, run_name: &str) -> Result<TrainLog> {
        self.run_observed(data, run_name, &crate::api::events::NullSink)
    }

    /// [`RevVitTrainer::run`] with progress reported through an
    /// [`EventSink`](crate::api::events::EventSink).  RevViT evaluates
    /// with its own reversible architecture (no inference gamma exists —
    /// the paper's core criticism), so eval events report gamma 0.0.
    pub fn run_observed(
        &mut self,
        data: &dyn Dataset,
        run_name: &str,
        sink: &dyn crate::api::events::EventSink,
    ) -> Result<TrainLog> {
        use crate::api::events::{EvalEvent, StepEvent};
        let mut log = TrainLog::new(run_name);
        let steps = self.cfg.steps;
        for step in 0..steps {
            let batch = data.train_batch(step);
            let t0 = std::time::Instant::now();
            let stats = self.train_step(&batch)?;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            sink.on_step(&StepEvent {
                step,
                loss: stats.loss,
                acc: stats.acc,
                grad_norm: stats.grad_norm,
                ms,
                elapsed_us: crate::obs::now_us(),
            });
            let eval_due = self.cfg.eval_every > 0
                && (step % self.cfg.eval_every == self.cfg.eval_every - 1
                    || step + 1 == steps);
            let (val_loss, val_acc) = if eval_due {
                let (l, a) = self.evaluate(data, self.cfg.eval_batches)?;
                sink.on_eval(&EvalEvent {
                    step: step + 1,
                    gamma: 0.0,
                    loss: l,
                    acc: a,
                    elapsed_us: crate::obs::now_us(),
                });
                (Some(l), Some(a))
            } else {
                (None, None)
            };
            if step % self.cfg.log_every == 0 || eval_due || step + 1 == steps {
                log.push(Record {
                    step,
                    train_loss: stats.loss,
                    train_acc: stats.acc,
                    val_loss,
                    val_acc,
                    grad_norm: stats.grad_norm,
                    ms_per_step: ms,
                });
            }
        }
        Ok(log)
    }
}

fn labels_of(batch: &Batch) -> &crate::tensor::IntTensor {
    match batch {
        Batch::Image { labels, .. } => labels,
        Batch::Lm { labels, .. } => labels,
        Batch::Seq2Seq { labels, .. } => labels,
    }
}
