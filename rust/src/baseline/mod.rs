//! Baseline training systems the paper compares against (Table 1, Fig. 3):
//!
//! * the conventional store-all transformer lives in the main coordinator as
//!   [`crate::config::TrainMode::Vanilla`] (gamma = 0 float path — exactly
//!   the standard update),
//! * [`revvit`] — the RevViT [19] two-stream reversible architecture with
//!   float (non-exact) inversion.

pub mod revvit;

pub use revvit::RevVitTrainer;
