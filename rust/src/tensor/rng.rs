//! Deterministic RNG substrate (no external crates available offline).
//!
//! SplitMix64 core — tiny, fast, and passes BigCrush for this use (parameter
//! init, data synthesis, per-sample gamma draws).  Every consumer owns its
//! own stream (`Rng::new(seed)` / `fork`), so experiment repetitions are
//! exactly reproducible from the config seed.

/// SplitMix64 generator with Box–Muller normal sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box–Muller draw
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Snapshot the full generator state (checkpointing).  Restoring via
    /// [`Rng::restore`] resumes the exact draw sequence, including the
    /// cached Box–Muller spare.
    pub fn state(&self) -> (u64, Option<f32>) {
        (self.state, self.spare)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn restore(state: u64, spare: Option<f32>) -> Self {
        Rng { state, spare }
    }

    /// Derive an independent stream (e.g. per worker, per experiment arm).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD1342543DE82EF95))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> exactly representable f32 in [0,1)
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // rejection-free: bias negligible for n << 2^64
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fair coin: +1 or -1 (the per-sample/per-block gamma sign draw).
    pub fn sign(&mut self) -> i8 {
        if self.next_u64() & 1 == 0 {
            1
        } else {
            -1
        }
    }

    /// Fisher–Yates shuffle of indices 0..n (epoch permutation).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            idx.swap(i, j);
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_snapshot_resumes_exact_sequence() {
        let mut a = Rng::new(11);
        for _ in 0..7 {
            a.normal(); // odd count: leaves a Box–Muller spare cached
        }
        let (state, spare) = a.state();
        let mut b = Rng::restore(state, spare);
        for _ in 0..100 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// Snapshot taken *mid Box–Muller pair* (the cached spare is `Some`):
    /// restore must resume bit-exactly, spare first.  Checkpoint resume
    /// and per-rank γ-stream derivation both lean on this.
    #[test]
    fn snapshot_mid_box_muller_pair_resumes_bitwise() {
        let mut a = Rng::new(3);
        a.normal(); // one draw of the pair consumed, the spare is cached
        let (state, spare) = a.state();
        assert!(
            spare.is_some(),
            "after an odd number of normal() draws the spare must be cached"
        );
        let mut b = Rng::restore(state, spare);
        // the very next draw is the cached spare itself, then the streams
        // continue in lockstep through fresh pairs
        for i in 0..64 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits(), "draw {i}");
        }
        // restoring with the spare dropped would NOT resume the sequence
        let mut a2 = Rng::new(3);
        let first = a2.normal();
        let (s2, sp2) = a2.state();
        let spare_val = sp2.expect("spare cached");
        let mut truncated = Rng::restore(s2, None);
        assert_ne!(
            truncated.normal().to_bits(),
            spare_val.to_bits(),
            "dropping the spare must be observable (first draw {first})"
        );
    }

    /// `fork` is a pure function of the parent *state*: forking from a
    /// snapshot-restored parent yields bit-identical child streams, and
    /// deriving a fork from a clone leaves the parent untouched.  This is
    /// what lets any rank derive any micro-batch's γ stream without
    /// replaying draws (`coordinator::Trainer::gamma_stream`).
    #[test]
    fn fork_streams_stable_across_snapshots() {
        let mut parent = Rng::new(9);
        parent.normal(); // leave a spare cached: snapshots mid-pair too
        let (state, spare) = parent.state();
        for tag in [0u64, 1, 7, u64::MAX] {
            let mut from_live = parent.clone().fork(tag);
            let mut from_snapshot = Rng::restore(state, spare).fork(tag);
            for i in 0..32 {
                assert_eq!(
                    from_live.next_u64(),
                    from_snapshot.next_u64(),
                    "tag {tag} draw {i}"
                );
                assert_eq!(
                    from_live.normal().to_bits(),
                    from_snapshot.normal().to_bits(),
                    "tag {tag} normal {i}"
                );
            }
        }
        // clone-then-fork never advances the parent
        assert_eq!(parent.state(), (state, spare));
        // distinct tags give distinct streams off the same parent state
        let mut f1 = parent.clone().fork(1);
        let mut f2 = parent.clone().fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn fork_diverges() {
        let mut a = Rng::new(7);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn sign_is_balanced() {
        let mut r = Rng::new(3);
        let pos = (0..100_000).filter(|_| r.sign() > 0).count();
        assert!((pos as f64 / 100_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(4);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
