//! Host tensor substrate: a small row-major `f32`/`i32` tensor used on the
//! coordinator hot path (activations, gradients, parameters).
//!
//! Deliberately minimal — the heavy math lives in the AOT HLO executables;
//! the host side only needs shape bookkeeping, elementwise combines for the
//! BDIA update (which must run in Rust for exact fixed-point control), and
//! parameter/optimizer storage.

pub mod rng;

pub use rng::Rng;

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Filled with N(0, std) draws from `rng`.
    pub fn normal(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?} mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn scalar_value(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("scalar_value on tensor of {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    /// self += other (shapes must match).
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("add_assign shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += *b;
        }
        Ok(())
    }

    /// self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("axpy shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * *b;
        }
        Ok(())
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// L2 norm of the flattened tensor.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
    }

    /// Max |a-b| between two tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("diff shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs())))
    }

    /// Bytes occupied by the payload (the unit of memory accounting).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Row-major i32 tensor (token ids, labels).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        IntTensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<i32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_ones_shapes() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&v| v == 0.0));
        assert_eq!(Tensor::ones(&[4]).data(), &[1.0; 4]);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
    }

    #[test]
    fn axpy_and_add() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]).unwrap();
        a.axpy(2.0, &b).unwrap();
        assert_eq!(a.data(), &[3.0, 4.0, 5.0]);
        a.add_assign(&b).unwrap();
        assert_eq!(a.data(), &[4.0, 5.0, 6.0]);
        let c = Tensor::zeros(&[4]);
        assert!(a.add_assign(&c).is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[2, 3]);
        assert!(t.clone().reshape(&[6]).is_ok());
        assert!(t.reshape(&[5]).is_err());
    }

    #[test]
    fn normal_is_seed_deterministic() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let a = Tensor::normal(&[16], 0.02, &mut r1);
        let b = Tensor::normal(&[16], 0.02, &mut r2);
        assert_eq!(a, b);
        let mut r3 = Rng::new(43);
        assert_ne!(a, Tensor::normal(&[16], 0.02, &mut r3));
    }

    #[test]
    fn norm_and_diff() {
        let a = Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap();
        assert!((a.norm() - 5.0).abs() < 1e-6);
        let b = Tensor::from_vec(&[2], vec![3.0, 5.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(Tensor::scalar(2.5).scalar_value().unwrap(), 2.5);
        assert!(Tensor::zeros(&[2]).scalar_value().is_err());
    }
}
