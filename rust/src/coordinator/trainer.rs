//! The BDIA training coordinator: full training loop over AOT executables.
//!
//! Composes embed -> stack(s) -> head around the [`Stack`] engine, owns the
//! parameters/optimizer/gradient accumulators, and exposes the evaluation
//! path (fused `model_infer`, gamma as a runtime input).  Python is never on
//! this path.

use super::stack::{GammaPlan, Stack, StackKind, StackState};
use crate::checkpoint::{self, CheckpointRef, RngSnapshot};
use crate::config::{TrainConfig, TrainMode};
use crate::data::{Batch, Dataset};
use crate::dist::{self, Collective, DistRole};
use crate::metrics::{Record, TrainLog};
use crate::model::{Family, ParamStore};
use crate::optim::{clip_global_norm, Optimizer};
use crate::runtime::{ArgValue, Runtime};
use crate::tensor::{Rng, Tensor};
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// Everything the forward pass hands to the backward pass.
pub struct ForwardState {
    pub main: StackState,
    pub enc: Option<StackState>,
    /// encoder output = cross-attention memory (encdec only)
    pub mem: Option<Tensor>,
    pub loss: f32,
    pub ncorrect: f32,
    pub main_plan: GammaPlan,
    pub enc_plan: Option<GammaPlan>,
}

impl ForwardState {
    /// Persistent activation bytes held for backward (live Table-1 number).
    pub fn stored_bytes(&self) -> usize {
        self.main.stored_bytes()
            + self.enc.as_ref().map_or(0, StackState::stored_bytes)
            + self.mem.as_ref().map_or(0, Tensor::nbytes)
    }
}

/// Parameter groups pinned by `freeze_embed` (names that exist vary by
/// family; missing ones are no-ops everywhere they are consulted).
const FROZEN_EMBED: &[&str] = &["embed", "enc_embed"];

#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub loss: f32,
    pub acc: f32,
    pub grad_norm: f32,
    pub stored_activation_bytes: usize,
}

pub struct Trainer {
    pub rt: Runtime,
    pub params: ParamStore,
    grads: ParamStore,
    pub opt: Optimizer,
    pub cfg: TrainConfig,
    pub family: Family,
    /// Base of every per-micro-batch γ stream: micro `m` draws its gamma
    /// plan from `rng_gamma.clone().fork(m)` — a *pure* function of the
    /// (checkpointed) base state and the global micro index, so any rank
    /// derives any micro's stream without replaying earlier draws.
    rng_gamma: Rng,
    step: usize,
    /// Data-parallel wiring; `None` behaves exactly like rank 0 of 1.
    dist: Option<DistRole>,
    /// Reusable global-step buffers (gradient fold + per-micro
    /// contribution, each ~n_params floats) — reallocating them every
    /// optimization step would churn megabytes on real models.
    fold_buf: Vec<f32>,
    contrib_buf: Vec<f32>,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let rt = Runtime::load_with(&cfg.artifacts_dir, &cfg.model, cfg.backend)
            .with_context(|| {
                format!("loading bundle '{}' ({})", cfg.model, cfg.backend.name())
            })?;
        Self::with_runtime(cfg, rt)
    }

    pub fn with_runtime(cfg: TrainConfig, rt: Runtime) -> Result<Self> {
        if cfg.mode == TrainMode::RevVit {
            bail!("RevViT uses baseline::revvit::RevVitTrainer");
        }
        if cfg.mode == TrainMode::BdiaReversible {
            ensure!(
                cfg.gamma_mag == 0.5,
                "exact bit-level reversibility requires |gamma| = 0.5 \
                 (paper §4.3); got {} — use mode=bdia_float for the ablation",
                cfg.gamma_mag
            );
        }
        let family = rt.manifest.family;
        let params = ParamStore::init(&rt.manifest, cfg.seed);
        let grads = params.zeros_like();
        let mut opt = Optimizer::new(&cfg, &params);
        if cfg.freeze_embed {
            opt.set_frozen(FROZEN_EMBED.iter().map(|s| s.to_string()).collect());
        }
        let rng_gamma = Rng::new(cfg.seed ^ 0xbd1a_bd1a);
        let mut trainer = Trainer {
            rt,
            params,
            grads,
            opt,
            cfg,
            family,
            rng_gamma,
            step: 0,
            dist: None,
            fold_buf: Vec::new(),
            contrib_buf: Vec::new(),
        };
        // fine-tuning: load the full checkpoint (params + optimizer + step
        // + gamma RNG) exactly like --resume would.  Carried in the config
        // so every rank of a spawned world applies it before attach (rank
        // 0's broadcast then re-confirms the same bytes).
        if let Some(path) = trainer.cfg.init_from.clone() {
            trainer.load_checkpoint(&path).with_context(|| {
                format!("init_from checkpoint {}", path.display())
            })?;
        }
        Ok(trainer)
    }

    pub fn n_params(&self) -> usize {
        self.params.n_params()
    }

    /// Completed optimization steps (nonzero after a checkpoint resume).
    pub fn step(&self) -> usize {
        self.step
    }

    // ------------------------------------------------------------------
    // checkpointing
    // ------------------------------------------------------------------

    /// Write the full training state — parameters, optimizer moments, step
    /// counter and the gamma RNG — so a resumed run is bit-identical to an
    /// uninterrupted one.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let _span = crate::span!("checkpoint", step = self.step);
        let (state, spare) = self.rng_gamma.state();
        let (t, m, v) = self.opt.state();
        checkpoint::save(
            path,
            &CheckpointRef {
                model: &self.cfg.model,
                step: self.step as u64,
                rng_gamma: RngSnapshot { state, spare },
                params: &self.params,
                opt: Some((t, m, v)),
            },
        )
        .with_context(|| format!("saving checkpoint {}", path.display()))
    }

    /// Restore state saved by [`Trainer::save_checkpoint`]: parameters
    /// always; optimizer moments only when present, so inference-only
    /// exports still load for evaluation.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let ck = checkpoint::load(path)?;
        ensure!(
            ck.model == self.cfg.model,
            "checkpoint {} was written for model '{}' but this run uses '{}'",
            path.display(),
            ck.model,
            self.cfg.model
        );
        ensure!(
            self.params.same_structure(&ck.params),
            "checkpoint {} parameter structure does not match bundle '{}'",
            path.display(),
            self.cfg.model
        );
        self.params = ck.params;
        crate::kernels::workspace::bump_weight_generation();
        self.step = ck.step as usize;
        self.rng_gamma = Rng::restore(ck.rng_gamma.state, ck.rng_gamma.spare);
        if let Some(o) = ck.opt {
            self.opt.restore(o.t, o.m, o.v)?;
        }
        Ok(())
    }

    /// The γ-RNG base state `(state, box-muller spare)` — checkpoint
    /// provenance for `bdia info` / `bdia eval --ckpt`.
    pub fn rng_gamma_state(&self) -> (u64, Option<f32>) {
        self.rng_gamma.state()
    }

    /// Groups excluded from the optimizer update and the all-reduce
    /// payload under `freeze_embed` (empty otherwise).
    fn frozen_groups(&self) -> &'static [&'static str] {
        if self.cfg.freeze_embed {
            FROZEN_EMBED
        } else {
            &[]
        }
    }

    /// Zero the gradients of frozen groups in place, so the clip norm —
    /// and therefore the update applied to every trainable weight — is a
    /// pure function of trainable gradients, identical on every rank and
    /// at every world size.
    fn zero_frozen_grads(&mut self) {
        for g in self.frozen_groups() {
            if let Some(insts) = self.grads.groups.get_mut(*g) {
                for inst in insts {
                    for t in inst {
                        t.data_mut().fill(0.0);
                    }
                }
            }
        }
    }

    /// Floats in the distributed gradient payload (frozen groups ride
    /// neither the reduce nor the broadcast).
    fn payload_len(&self) -> usize {
        let skip = self.frozen_groups();
        self.params
            .groups
            .iter()
            .filter(|(k, _)| !skip.contains(&k.as_str()))
            .map(|(_, insts)| {
                insts.iter().flatten().map(|t| t.len()).sum::<usize>()
            })
            .sum()
    }

    fn effective_gamma(&self) -> f32 {
        match self.cfg.mode {
            TrainMode::Vanilla => 0.0,
            _ => self.cfg.gamma_mag,
        }
    }

    /// The γ stream of global micro-batch `m`: forked by value off the
    /// checkpointed base, never advancing it.  Pure in `(base state, m)`,
    /// which is what lets an N-rank world consume exactly the same γ
    /// sequence as a single process ([`crate::dist`] module docs).
    fn gamma_stream(&self, micro: u64) -> Rng {
        self.rng_gamma.clone().fork(micro)
    }

    // ------------------------------------------------------------------
    // distribution (data-parallel; None == rank 0 of a world of 1)
    // ------------------------------------------------------------------

    /// This rank's index and the world size.
    pub fn dist_shape(&self) -> (usize, usize) {
        self.dist.as_ref().map_or((0, 1), |d| (d.rank, d.world))
    }

    /// True on the rank that owns evaluation, logging and checkpoints.
    pub fn is_rank0(&self) -> bool {
        self.dist_shape().0 == 0
    }

    pub fn has_dist(&self) -> bool {
        self.dist.is_some()
    }

    /// Leave the world (dropping this rank's sockets and heartbeat) while
    /// keeping all local training state.  On rank 0 that state is the last
    /// *completed* step — a failed collective never commits — so a
    /// subsequent [`Trainer::attach_dist`] on a rebuilt world re-broadcasts
    /// it and training resumes bit-identically (the restart policy's path).
    pub fn detach_dist(&mut self) {
        self.dist = None;
    }

    /// Mutable access to the attached collective (fault-injection hooks
    /// and liveness control); `None` when no world is attached.
    pub fn collective_mut(&mut self) -> Option<&mut Collective> {
        self.dist.as_mut().map(|d| &mut d.coll)
    }

    /// Join a data-parallel world: validate the shape against the config,
    /// then broadcast rank 0's full training state (params, optimizer
    /// moments, step, γ-RNG base) so a checkpoint resumed on rank 0 alone
    /// reaches every worker bit-exactly before the first step.
    pub fn attach_dist(&mut self, role: DistRole) -> Result<()> {
        ensure!(role.rank < role.world, "rank {} out of world {}", role.rank, role.world);
        ensure!(
            self.cfg.ranks.max(1) == role.world,
            "config says ranks={}, attached world has {} ranks",
            self.cfg.ranks.max(1),
            role.world
        );
        let a = self.cfg.accum();
        ensure!(
            a % role.world == 0,
            "grad_accum {a} must be a multiple of the world size {} \
             (round-robin micro-batch ownership)",
            role.world
        );
        self.dist = Some(role);
        self.dist_sync()
    }

    /// Broadcast rank 0's training state to the world and barrier.
    fn dist_sync(&mut self) -> Result<()> {
        let Some(mut d) = self.dist.take() else { return Ok(()) };
        if d.world > 1 {
            let blob =
                if d.rank == 0 { self.encode_state() } else { Vec::new() };
            let blob = d.coll.broadcast_blob(blob).context("dist state sync")?;
            if d.rank != 0 {
                self.decode_state(&blob)
                    .context("applying rank 0's broadcast training state")?;
            }
            d.coll.barrier()?;
            // observability only: tag spans with this rank and estimate the
            // hub-relative clock offset so `bdia trace` can merge per-rank
            // trace files onto one timeline
            crate::obs::set_rank(d.rank as u64);
            d.coll.clock_sync().context("clock sync for trace merge")?;
        }
        self.dist = Some(d);
        Ok(())
    }

    /// Serialize the full training state for the world sync — the exact
    /// checkpoint wire format ([`checkpoint::to_bytes`]), so there is one
    /// serializer to keep in lockstep with the state set and the broadcast
    /// arrives CRC-verified.
    fn encode_state(&self) -> Vec<u8> {
        let (state, spare) = self.rng_gamma.state();
        let (t, m, v) = self.opt.state();
        checkpoint::to_bytes(&CheckpointRef {
            model: &self.cfg.model,
            step: self.step as u64,
            rng_gamma: RngSnapshot { state, spare },
            params: &self.params,
            opt: Some((t, m, v)),
        })
    }

    fn decode_state(&mut self, blob: &[u8]) -> Result<()> {
        let ck = checkpoint::from_bytes(blob)
            .context("decoding rank 0's broadcast training state")?;
        ensure!(
            ck.model == self.cfg.model,
            "rank 0 broadcast state for model '{}', this rank runs '{}'",
            ck.model,
            self.cfg.model
        );
        ensure!(
            self.params.same_structure(&ck.params),
            "broadcast parameter structure does not match bundle '{}'",
            self.cfg.model
        );
        self.params = ck.params;
        crate::kernels::workspace::bump_weight_generation();
        self.step = ck.step as usize;
        self.rng_gamma = Rng::restore(ck.rng_gamma.state, ck.rng_gamma.spare);
        let o = ck
            .opt
            .ok_or_else(|| anyhow::anyhow!("broadcast state lacks optimizer moments"))?;
        self.opt.restore(o.t, o.m, o.v)?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // embed / head plumbing (family-specific)
    // ------------------------------------------------------------------

    fn embed_forward(&self, batch: &Batch) -> Result<Tensor> {
        let e = self.rt.exec("embed_fwd")?;
        let refs = self.params.refs_for(&e.spec, 0)?;
        let out = match (self.family, batch) {
            (Family::Vit, Batch::Image { images, .. }) => {
                e.call(&refs, &[ArgValue::F32(images)])?
            }
            (Family::Gpt, Batch::Lm { tokens, .. }) => {
                e.call(&refs, &[ArgValue::I32(tokens)])?
            }
            (Family::EncDec, Batch::Seq2Seq { tgt_in, .. }) => {
                e.call(&refs, &[ArgValue::I32(tgt_in)])?
            }
            _ => bail!("batch type does not match model family"),
        };
        Ok(out.into_iter().next().unwrap())
    }

    fn enc_embed_forward(&self, batch: &Batch) -> Result<Tensor> {
        let e = self.rt.exec("enc_embed_fwd")?;
        let refs = self.params.refs_for(&e.spec, 0)?;
        let Batch::Seq2Seq { src, .. } = batch else {
            bail!("encoder needs a seq2seq batch")
        };
        Ok(e.call(&refs, &[ArgValue::I32(src)])?.remove(0))
    }

    fn head_loss(&self, x: &Tensor, batch: &Batch) -> Result<(f32, f32)> {
        let e = self.rt.exec("head_loss_fwd")?;
        let refs = self.params.refs_for(&e.spec, 0)?;
        let labels = batch_labels(batch);
        let outs = e.call(&refs, &[ArgValue::F32(x), ArgValue::I32(labels)])?;
        Ok((outs[0].scalar_value()?, outs[1].scalar_value()?))
    }

    /// (dL/dx_K, head grads)
    fn head_vjp(&self, x: &Tensor, batch: &Batch) -> Result<(Tensor, Vec<Tensor>)> {
        let e = self.rt.exec("head_loss_vjp")?;
        let refs = self.params.refs_for(&e.spec, 0)?;
        let labels = batch_labels(batch);
        let mut outs = e.call(&refs, &[ArgValue::F32(x), ArgValue::I32(labels)])?;
        let dx = outs.remove(0);
        Ok((dx, outs))
    }

    fn embed_vjp(&self, exec: &str, batch: &Batch, g: &Tensor) -> Result<Vec<Tensor>> {
        let e = self.rt.exec(exec)?;
        let refs = self.params.refs_for(&e.spec, 0)?;
        let outs = match (self.family, batch, exec) {
            (Family::Vit, Batch::Image { images, .. }, _) => {
                e.call(&refs, &[ArgValue::F32(images), ArgValue::F32(g)])?
            }
            (Family::Gpt, Batch::Lm { tokens, .. }, _) => {
                e.call(&refs, &[ArgValue::I32(tokens), ArgValue::F32(g)])?
            }
            (Family::EncDec, Batch::Seq2Seq { tgt_in, .. }, "embed_vjp") => {
                e.call(&refs, &[ArgValue::I32(tgt_in), ArgValue::F32(g)])?
            }
            (Family::EncDec, Batch::Seq2Seq { src, .. }, "enc_embed_vjp") => {
                e.call(&refs, &[ArgValue::I32(src), ArgValue::F32(g)])?
            }
            _ => bail!("batch type does not match model family"),
        };
        Ok(outs)
    }

    // ------------------------------------------------------------------
    // forward / backward / step
    // ------------------------------------------------------------------

    /// Forward pass with the γ streams of this step's first micro-batch.
    /// Single-batch callers (bench probes, tests) treat the batch as the
    /// whole global step; the accumulation/distribution loop in
    /// [`Trainer::train_step_global`] calls [`Trainer::forward_micro`]
    /// with explicit global micro indices instead.
    pub fn forward(&mut self, batch: &Batch) -> Result<ForwardState> {
        let micro = (self.step * self.cfg.accum()) as u64;
        self.forward_micro(batch, micro)
    }

    /// Forward pass for global micro-batch `micro`: gamma plans come from
    /// the stream forked by that index (encoder plan first, then the main
    /// plan, from the same stream).
    pub fn forward_micro(&mut self, batch: &Batch, micro: u64) -> Result<ForwardState> {
        let _span = crate::span!("fwd", micro = micro);
        let quantized = self.cfg.mode == TrainMode::BdiaReversible;
        let mut stream = self.gamma_stream(micro);
        let mag = self.effective_gamma();
        let batch_dim = self.rt.manifest.dims.batch;
        let (enc, mem, enc_plan) = if self.family == Family::EncDec {
            let plan = GammaPlan::draw(
                &mut stream,
                self.rt.manifest.dims.n_enc_blocks,
                batch_dim,
                mag,
            );
            let enc_stack = Stack::new(&self.rt, StackKind::Encoder)?;
            let xe = self.enc_embed_forward(batch)?;
            let state = if quantized {
                enc_stack.forward_quant(&self.params, xe, None, &plan)?
            } else {
                enc_stack.forward_float(&self.params, xe, None, &plan)?
            };
            let mem = state.output().clone();
            (Some(state), Some(mem), Some(plan))
        } else {
            (None, None, None)
        };

        let plan = GammaPlan::draw(
            &mut stream,
            self.rt.manifest.dims.n_blocks,
            batch_dim,
            mag,
        );
        let stack = Stack::new(&self.rt, StackKind::Main)?;
        let x0 = self.embed_forward(batch)?;
        let state = if quantized {
            stack.forward_quant(&self.params, x0, mem.as_ref(), &plan)?
        } else {
            stack.forward_float(&self.params, x0, mem.as_ref(), &plan)?
        };
        let (loss, ncorrect) = self.head_loss(state.output(), batch)?;
        Ok(ForwardState {
            main: state,
            enc,
            mem,
            loss,
            ncorrect,
            main_plan: plan,
            enc_plan,
        })
    }

    /// Backward + gradient accumulation into `self.grads`.
    pub fn backward(&mut self, batch: &Batch, fs: ForwardState) -> Result<()> {
        let _span = crate::span!("bwd", step = self.step);
        // head
        let (gx_last, dhead) = self.head_vjp(fs.main.output(), batch)?;
        accumulate_leaves(&mut self.grads, "head", 0, &dhead)?;

        // main stack (online reconstruction in reversible mode)
        let stack = Stack::new(&self.rt, StackKind::Main)?;
        let sg = stack.backward(
            &self.params,
            fs.main,
            fs.mem.as_ref(),
            &fs.main_plan,
            gx_last,
        )?;
        for (k, dp) in sg.dparams.iter().enumerate() {
            accumulate_leaves(&mut self.grads, "block", k, dp)?;
        }
        let dembed = self.embed_vjp("embed_vjp", batch, &sg.dx0)?;
        accumulate_leaves(&mut self.grads, "embed", 0, &dembed)?;

        // encoder stack driven by the accumulated cross-attention grads
        if let Some(enc_state) = fs.enc {
            let dmem = sg
                .dmem
                .ok_or_else(|| anyhow::anyhow!("decoder produced no dmem"))?;
            let enc_stack = Stack::new(&self.rt, StackKind::Encoder)?;
            let esg = enc_stack.backward(
                &self.params,
                enc_state,
                None,
                fs.enc_plan.as_ref().expect("enc plan"),
                dmem,
            )?;
            for (k, dp) in esg.dparams.iter().enumerate() {
                accumulate_leaves(&mut self.grads, "enc_block", k, dp)?;
            }
            let deemb = self.embed_vjp("enc_embed_vjp", batch, &esg.dx0)?;
            accumulate_leaves(&mut self.grads, "enc_embed", 0, &deemb)?;
        }
        Ok(())
    }

    /// One full optimization step on a caller-supplied batch, treated as
    /// the entire global step (no accumulation, no collectives).
    pub fn train_step(&mut self, batch: &Batch) -> Result<StepStats> {
        self.grads.zero();
        let fs = self.forward(batch)?;
        let loss = fs.loss;
        let acc = fs.ncorrect / batch.n_predictions() as f32;
        let stored = fs.stored_bytes();
        self.backward(batch, fs)?;
        self.finish_step(loss, acc, stored)
    }

    /// One *global* optimization step: consume `cfg.accum()` micro-batches
    /// (this rank owns `micro = step·A + round·world + rank`), all-reduce
    /// the micro-gradients in global micro order, and apply the identical
    /// optimizer update on every rank.  With `accum() == 1` and no
    /// attached world this is exactly [`Trainer::train_step`] on
    /// `data.train_batch(step)`.
    pub fn train_step_global(&mut self, data: &dyn Dataset) -> Result<StepStats> {
        let a = self.cfg.accum();
        let (rank, world) = self.dist_shape();
        ensure!(
            a % world == 0,
            "grad_accum {a} must be a multiple of the world size {world}"
        );
        if a == 1 && world == 1 {
            let batch = data.train_batch(self.step);
            return self.train_step(&batch);
        }
        let rounds = a / world;
        let n = self.payload_len();
        // rank 0 folds micro contributions serially in global micro order;
        // slots n and n+1 carry (Σ loss, Σ ncorrect) through the same pipe
        let mut fold = std::mem::take(&mut self.fold_buf);
        fold.clear();
        fold.resize(n + 2, 0.0);
        let mut contrib = std::mem::take(&mut self.contrib_buf);
        let mut stored = 0usize;
        let mut n_pred = 1usize;
        for round in 0..rounds {
            let micro = self.step * a + round * world + rank;
            let batch = data.train_batch(micro);
            n_pred = batch.n_predictions();
            self.grads.zero();
            let fs = self.forward_micro(&batch, micro as u64)?;
            let (loss_m, ncorrect_m) = (fs.loss, fs.ncorrect);
            stored = stored.max(fs.stored_bytes());
            self.backward(&batch, fs)?;
            contrib.clear();
            dist::flatten_into_except(
                &self.grads,
                self.frozen_groups(),
                &mut contrib,
            );
            contrib.push(loss_m);
            contrib.push(ncorrect_m);
            self.reduce_round(&mut fold, &contrib)?;
        }
        if rank == 0 {
            // mean over the global step's micro-batches (grads and the
            // loss/ncorrect slots alike); workers receive the bytes below
            let inv = a as f32;
            for x in fold.iter_mut() {
                *x /= inv;
            }
        }
        self.bcast(&mut fold)?;
        let loss = fold[n];
        let acc = fold[n + 1] / n_pred as f32;
        dist::unflatten_from_except(
            &mut self.grads,
            self.frozen_groups(),
            &fold[..n],
        )?;
        self.fold_buf = fold;
        self.contrib_buf = contrib;
        self.finish_step(loss, acc, stored)
    }

    fn reduce_round(&mut self, fold: &mut [f32], contrib: &[f32]) -> Result<()> {
        let _span =
            crate::span!("all_reduce", step = self.step, rank = self.dist_shape().0);
        match self.dist.as_mut() {
            Some(d) => d.coll.reduce_sum_rank_ordered(fold, contrib),
            None => {
                ensure!(fold.len() == contrib.len(), "reduce length mismatch");
                for (f, c) in fold.iter_mut().zip(contrib) {
                    *f += *c;
                }
                Ok(())
            }
        }
    }

    fn bcast(&mut self, buf: &mut [f32]) -> Result<()> {
        match self.dist.as_mut() {
            Some(d) => d.coll.broadcast(buf),
            None => Ok(()),
        }
    }

    /// Shared step tail: clip/normalize gradients, guard divergence, apply
    /// the optimizer, advance the step counter.
    fn finish_step(&mut self, loss: f32, acc: f32, stored: usize) -> Result<StepStats> {
        // frozen groups contribute exactly nothing to the clip norm (their
        // local grads may hold a stale micro contribution after the
        // payload-excluded all-reduce)
        self.zero_frozen_grads();
        let grad_norm = match self.cfg.grad_clip {
            Some(c) => clip_global_norm(&mut self.grads, c),
            None => self.grads.global_norm(),
        };
        ensure!(grad_norm.is_finite(), "gradient norm diverged at step {}", self.step);
        {
            let _span = crate::span!("optimizer", step = self.step);
            self.opt.step(&mut self.params, &self.grads)?;
        }
        self.step += 1;
        Ok(StepStats { loss, acc, grad_norm, stored_activation_bytes: stored })
    }

    /// Borrow the gradient accumulator (tests compare grads across modes).
    pub fn grads(&self) -> &ParamStore {
        &self.grads
    }

    // ------------------------------------------------------------------
    // evaluation (fused quantized inference, eqs. 18-22; Fig.-1 sweep)
    // ------------------------------------------------------------------

    /// Mean (val_loss, val_acc) over `n_batches` held-out batches with a
    /// constant inference gamma (0 = the paper's standard inference).
    pub fn evaluate(&self, data: &dyn Dataset, n_batches: usize, gamma: f32)
        -> Result<(f32, f32)> {
        evaluate_params(&self.rt, &self.params, data, n_batches, gamma)
    }

    /// Full training loop with periodic evaluation; returns the log.
    ///
    /// Resume-aware: after [`Trainer::load_checkpoint`] the loop continues
    /// from the restored step (training batches are pure functions of the
    /// step index, so the replayed schedule is identical).  With
    /// `cfg.save_every > 0`, a step-stamped checkpoint plus a rolling
    /// `<run_name>-latest.ckpt` land in `cfg.ckpt_dir`.
    pub fn run(&mut self, data: &dyn Dataset, run_name: &str) -> Result<TrainLog> {
        self.run_observed(data, run_name, &crate::api::events::NullSink)
    }

    /// [`Trainer::run`] with progress reported through an
    /// [`EventSink`](crate::api::events::EventSink): one event per step,
    /// per evaluation pass (carrying the inference gamma, always 0.0 on
    /// this loop) and per checkpoint written.  The sink is the only
    /// progress channel — the loop itself never prints.
    pub fn run_observed(
        &mut self,
        data: &dyn Dataset,
        run_name: &str,
        sink: &dyn crate::api::events::EventSink,
    ) -> Result<TrainLog> {
        use crate::api::events::{CheckpointEvent, EvalEvent, StepEvent};
        let mut log = TrainLog::new(run_name);
        let steps = self.cfg.steps;
        while self.step < steps {
            let step = self.step;
            let t0 = std::time::Instant::now();
            let stats = {
                let _span = crate::span!("train_step", step = step);
                self.train_step_global(data)?
            };
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            sink.on_step(&StepEvent {
                step,
                loss: stats.loss,
                acc: stats.acc,
                grad_norm: stats.grad_norm,
                ms,
                elapsed_us: crate::obs::now_us(),
            });
            // evaluation and checkpointing are rank 0's job; workers keep
            // stepping (their next collective waits for rank 0 anyway)
            let eval_due = self.is_rank0()
                && self.cfg.eval_every > 0
                && (step % self.cfg.eval_every == self.cfg.eval_every - 1
                    || step + 1 == steps);
            let (val_loss, val_acc) = if eval_due {
                let (l, a) = self.evaluate(data, self.cfg.eval_batches, 0.0)?;
                sink.on_eval(&EvalEvent {
                    step: self.step,
                    gamma: 0.0,
                    loss: l,
                    acc: a,
                    elapsed_us: crate::obs::now_us(),
                });
                (Some(l), Some(a))
            } else {
                (None, None)
            };
            if step % self.cfg.log_every == 0 || eval_due || step + 1 == steps {
                log.push(Record {
                    step,
                    train_loss: stats.loss,
                    train_acc: stats.acc,
                    val_loss,
                    val_acc,
                    grad_norm: stats.grad_norm,
                    ms_per_step: ms,
                });
            }
            if self.is_rank0()
                && self.cfg.save_every > 0
                && (self.step % self.cfg.save_every == 0 || self.step == steps)
            {
                let stamped = self
                    .cfg
                    .ckpt_dir
                    .join(format!("{run_name}-step{}.ckpt", self.step));
                self.save_checkpoint(&stamped)?;
                let latest =
                    self.cfg.ckpt_dir.join(format!("{run_name}-latest.ckpt"));
                self.save_checkpoint(&latest)?;
                sink.on_checkpoint(&CheckpointEvent {
                    step: self.step,
                    path: latest,
                });
            }
        }
        if let Some(d) = self.dist.as_mut() {
            // leave the world in lockstep before any rank drops its sockets
            d.coll.barrier()?;
        }
        Ok(log)
    }
}

/// Shared fused-inference evaluation (used by Trainer and RevVit's probes).
pub fn evaluate_params(
    rt: &Runtime,
    params: &ParamStore,
    data: &dyn Dataset,
    n_batches: usize,
    gamma: f32,
) -> Result<(f32, f32)> {
    let e = rt.exec("model_infer")?;
    let refs = params.refs_for(&e.spec, 0)?;
    let n = n_batches.min(data.n_val_batches()).max(1);
    let mut loss_sum = 0f64;
    let mut correct = 0f64;
    let mut total = 0usize;
    for i in 0..n {
        let batch = data.val_batch(i);
        let outs = match &batch {
            Batch::Image { images, labels } => e.call(
                &refs,
                &[ArgValue::F32(images), ArgValue::I32(labels), ArgValue::Scalar(gamma)],
            )?,
            Batch::Lm { tokens, labels } => e.call(
                &refs,
                &[ArgValue::I32(tokens), ArgValue::I32(labels), ArgValue::Scalar(gamma)],
            )?,
            Batch::Seq2Seq { src, tgt_in, labels } => e.call(
                &refs,
                &[
                    ArgValue::I32(src),
                    ArgValue::I32(tgt_in),
                    ArgValue::I32(labels),
                    ArgValue::Scalar(gamma),
                ],
            )?,
        };
        loss_sum += outs[0].scalar_value()? as f64;
        correct += outs[1].scalar_value()? as f64;
        total += batch.n_predictions();
    }
    Ok(((loss_sum / n as f64) as f32, (correct / total.max(1) as f64) as f32))
}

fn batch_labels(batch: &Batch) -> &crate::tensor::IntTensor {
    match batch {
        Batch::Image { labels, .. } => labels,
        Batch::Lm { labels, .. } => labels,
        Batch::Seq2Seq { labels, .. } => labels,
    }
}

/// grads[group][instance][leaf] += deltas[leaf]
pub fn accumulate_leaves(
    grads: &mut ParamStore,
    group: &str,
    instance: usize,
    deltas: &[Tensor],
) -> Result<()> {
    let inst = grads.leaves_mut(group, instance);
    ensure!(
        inst.len() == deltas.len(),
        "grad leaf count mismatch for {group}[{instance}]: {} vs {}",
        inst.len(),
        deltas.len()
    );
    for (t, d) in inst.iter_mut().zip(deltas) {
        t.add_assign(d)?;
    }
    Ok(())
}
