//! The paper's system contribution: the BDIA reversible training coordinator.
//!
//! * [`stack`] — the per-tower engine: BDIA forward recorder (eqs. 18-21),
//!   exact eq.-24 reconstruction, online-backprop adjoint scheduler.
//! * [`trainer`] — the full training loop (embed/head plumbing, gradient
//!   accumulation, optimizer, fused-inference evaluation with runtime gamma).
//!
//! Modes (see [`crate::config::TrainMode`]):
//! * `BdiaReversible` — the paper's headline system: quantized activations,
//!   1-bit side info, O(1)-in-depth activation memory.
//! * `BdiaFloat` — BDIA regularization only (Table-2 ablation; stores all).
//! * `Vanilla` — conventional transformer (gamma = 0, stores all).
//! * RevViT lives in [`crate::baseline::revvit`].

pub mod stack;
pub mod trainer;

pub use stack::{GammaPlan, Stack, StackKind, StackState};
pub use trainer::{evaluate_params, ForwardState, StepStats, Trainer};
