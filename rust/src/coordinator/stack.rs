//! Stack engine: BDIA forward/backward over one tower of transformer blocks.
//!
//! This is the paper's system contribution (§4): the *online back-propagation
//! scheduler*.  The forward pass stores only the two boundary activations
//! `(x_{K-1}, x_K)` plus 1-bit side information per block (quantized mode);
//! the backward pass walks blocks top-down, reconstructing `x_{k-1}` exactly
//! (eq. 24) while propagating the two-term BDIA adjoint recursion
//!
//!   `dL/dx_k     += (1-gamma_k) dL/dx_{k+1} + J_h^T [(1+gamma_k) dL/dx_{k+1}]`
//!   `dL/dx_{k-1} += gamma_k dL/dx_{k+1}`
//!
//! with the straight-through convention through `Q_l` (the paper's implicit
//! choice).  The `block_vjp` executable returns `(h, dx, [dmem], dparams...)`
//! so one call per block serves both the reconstruction (h) and the adjoint.
//!
//! Float mode (quantization off, store-all) implements eq. 10 and the same
//! adjoint — it is both the Table-2 ablation path and, with gamma = 0, the
//! exact conventional-transformer baseline.

use crate::quant::{self, BitVec, Fixed, SideInfoStore};
use crate::runtime::{ArgValue, Exec, Runtime};
use crate::tensor::Tensor;
use anyhow::{ensure, Context, Result};

/// Identifies which tower of blocks we operate on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StackKind {
    /// decoder / self stack: "block_fwd"/"block_vjp", group "block"
    Main,
    /// encoder stack (encdec only): "enc_block_fwd"/"enc_block_vjp"
    Encoder,
}

impl StackKind {
    pub fn fwd_exec(&self) -> &'static str {
        match self {
            StackKind::Main => "block_fwd",
            StackKind::Encoder => "enc_block_fwd",
        }
    }

    pub fn vjp_exec(&self) -> &'static str {
        match self {
            StackKind::Main => "block_vjp",
            StackKind::Encoder => "enc_block_vjp",
        }
    }

    pub fn group(&self) -> &'static str {
        match self {
            StackKind::Main => "block",
            StackKind::Encoder => "enc_block",
        }
    }
}

/// Per-step BDIA randomness for one stack: `gammas[k][b]` for blocks
/// `k = 1..K-1` (block 0 uses the plain Euler step, eq. 19/6).
#[derive(Clone, Debug)]
pub struct GammaPlan {
    /// per-block, per-sample gamma values (0.0 => plain residual)
    pub gammas: Vec<Vec<f32>>,
}

impl GammaPlan {
    /// Draw signs * magnitude per sample per block (paper §4.2).
    pub fn draw(rng: &mut crate::tensor::Rng, n_blocks: usize, batch: usize,
                magnitude: f32) -> Self {
        let gammas = (0..n_blocks)
            .map(|k| {
                (0..batch)
                    .map(|_| if k == 0 || magnitude == 0.0 {
                        0.0
                    } else {
                        magnitude * rng.sign() as f32
                    })
                    .collect()
            })
            .collect();
        GammaPlan { gammas }
    }

    /// Constant gamma across blocks and samples (Fig.-1 inference sweep).
    pub fn constant(n_blocks: usize, batch: usize, gamma: f32) -> Self {
        let mut gammas = vec![vec![gamma; batch]; n_blocks];
        gammas[0] = vec![0.0; batch];
        GammaPlan { gammas }
    }

    /// Signs (+1/-1) for the quantized path; errors if |gamma| != 0.5.
    pub fn signs(&self, k: usize) -> Result<Vec<i8>> {
        self.gammas[k]
            .iter()
            .map(|&g| {
                ensure!(
                    g == 0.5 || g == -0.5,
                    "exact reversibility requires gamma = +/-0.5, got {g} \
                     (use float mode for other magnitudes)"
                );
                Ok(if g > 0.0 { 1i8 } else { -1 })
            })
            .collect()
    }
}

/// What the forward pass keeps for the backward pass.
pub enum StackState {
    /// Quantized reversible mode: boundaries + side info (eq. 20-21).
    Reversible {
        x_last: Tensor,
        x_prev: Tensor,
        side: SideInfoStore,
    },
    /// Float mode: all inter-block activations x_0..x_K (store-all).
    Full { xs: Vec<Tensor> },
}

impl StackState {
    /// Persistent activation bytes actually held (live accounting).
    pub fn stored_bytes(&self) -> usize {
        match self {
            StackState::Reversible { x_last, x_prev, side } => {
                x_last.nbytes() + x_prev.nbytes() + side.nbytes()
            }
            StackState::Full { xs } => xs.iter().map(Tensor::nbytes).sum(),
        }
    }

    pub fn output(&self) -> &Tensor {
        match self {
            StackState::Reversible { x_last, .. } => x_last,
            StackState::Full { xs } => xs.last().expect("nonempty stack"),
        }
    }
}

/// Gradients produced by a stack backward.
pub struct StackGrads {
    /// dL/dx_0 (flows into the embedding vjp)
    pub dx0: Tensor,
    /// dL/dmem accumulated over blocks (encdec decoder only)
    pub dmem: Option<Tensor>,
    /// per-block parameter grads, `[block][leaf]`
    pub dparams: Vec<Vec<Tensor>>,
}

pub struct Stack<'rt> {
    pub kind: StackKind,
    pub n_blocks: usize,
    pub has_mem: bool,
    fwd: &'rt Exec,
    vjp: &'rt Exec,
    #[allow(dead_code)]
    rt: &'rt Runtime,
    pub fixed: Fixed,
}

impl<'rt> Stack<'rt> {
    pub fn new(rt: &'rt Runtime, kind: StackKind) -> Result<Self> {
        let n_blocks = match kind {
            StackKind::Main => rt.manifest.dims.n_blocks,
            StackKind::Encoder => rt.manifest.dims.n_enc_blocks,
        };
        ensure!(n_blocks >= 2, "BDIA stack needs >= 2 blocks, got {n_blocks}");
        let has_mem = kind == StackKind::Main
            && rt.manifest.family == crate::model::Family::EncDec;
        Ok(Stack {
            kind,
            n_blocks,
            has_mem,
            fwd: rt.exec(kind.fwd_exec())?,
            vjp: rt.exec(kind.vjp_exec())?,
            rt,
            fixed: Fixed::new(rt.manifest.dims.lbits),
        })
    }

    /// Public access to the block-forward executable (experiment drivers,
    /// Fig.-2 reconstruction probes, tests).
    pub fn debug_call_fwd(
        &self,
        params: &crate::model::ParamStore,
        k: usize,
        x: &Tensor,
        mem: Option<&Tensor>,
    ) -> Result<Tensor> {
        self.call_fwd(params, k, x, mem)
    }

    fn call_fwd(
        &self,
        params: &crate::model::ParamStore,
        k: usize,
        x: &Tensor,
        mem: Option<&Tensor>,
    ) -> Result<Tensor> {
        let refs = params.refs_for(&self.fwd.spec, k)?;
        let mut data = vec![ArgValue::F32(x)];
        if let Some(m) = mem {
            data.push(ArgValue::F32(m));
        }
        Ok(self
            .fwd
            .call(&refs, &data)
            .with_context(|| format!("{} block {k}", self.kind.fwd_exec()))?
            .remove(0))
    }

    /// (h, dx, dmem?, dparams...) from the fused vjp executable.
    fn call_vjp(
        &self,
        params: &crate::model::ParamStore,
        k: usize,
        x: &Tensor,
        mem: Option<&Tensor>,
        seed: &Tensor,
    ) -> Result<(Tensor, Tensor, Option<Tensor>, Vec<Tensor>)> {
        let refs = params.refs_for(&self.vjp.spec, k)?;
        let mut data = vec![ArgValue::F32(x)];
        if let Some(m) = mem {
            data.push(ArgValue::F32(m));
        }
        data.push(ArgValue::F32(seed));
        let mut outs = self
            .vjp
            .call(&refs, &data)
            .with_context(|| format!("{} block {k}", self.kind.vjp_exec()))?;
        let h = outs.remove(0);
        let dx = outs.remove(0);
        let dmem = if self.has_mem { Some(outs.remove(0)) } else { None };
        Ok((h, dx, dmem, outs))
    }

    // -----------------------------------------------------------------
    // forward
    // -----------------------------------------------------------------

    /// Quantized reversible forward (eqs. 18-21). `x0` is quantized in
    /// place-of-copy (eq. 18) before the first block.
    pub fn forward_quant(
        &self,
        params: &crate::model::ParamStore,
        mut x0: Tensor,
        mem: Option<&Tensor>,
        plan: &GammaPlan,
    ) -> Result<StackState> {
        quant::quantize_activation(&mut x0, self.fixed); // eq. 18
        let h0 = self.call_fwd(params, 0, &x0, mem)?;
        let x1 = quant::first_step_quant(&x0, &h0, self.fixed)?; // eq. 19
        let mut side = SideInfoStore::new(self.n_blocks);
        let (mut x_prev, mut x_cur) = (x0, x1);
        for k in 1..self.n_blocks {
            let h = self.call_fwd(params, k, &x_cur, mem)?;
            let signs = plan.signs(k)?;
            let (x_next, bits) =
                quant::bdia_forward_quant(&x_prev, &x_cur, &h, &signs, self.fixed)?;
            side.put(k, bits); // s_{k-1}, consumed when backward visits k
            x_prev = x_cur;
            x_cur = x_next;
        }
        Ok(StackState::Reversible { x_last: x_cur, x_prev, side })
    }

    /// Float forward (eq. 10), storing all activations.  With all gammas 0
    /// this is exactly the conventional transformer forward.
    pub fn forward_float(
        &self,
        params: &crate::model::ParamStore,
        x0: Tensor,
        mem: Option<&Tensor>,
        plan: &GammaPlan,
    ) -> Result<StackState> {
        let mut xs = Vec::with_capacity(self.n_blocks + 1);
        let h0 = self.call_fwd(params, 0, &x0, mem)?;
        let mut x1 = x0.clone();
        x1.add_assign(&h0)?;
        xs.push(x0);
        xs.push(x1);
        for k in 1..self.n_blocks {
            let h = self.call_fwd(params, k, &xs[k], mem)?;
            let x_next =
                quant::bdia_forward_float(&xs[k - 1], &xs[k], &h, &plan.gammas[k])?;
            xs.push(x_next);
        }
        Ok(StackState::Full { xs })
    }

    // -----------------------------------------------------------------
    // backward
    // -----------------------------------------------------------------

    /// Online backward over the stack.  `gx_last` = dL/dx_K from the head
    /// (or the accumulated dmem for an encoder stack).
    ///
    /// In `Reversible` mode activations are *reconstructed* (eq. 24) — the
    /// memory story of the paper; in `Full` mode they are read from storage.
    /// Both modes propagate the identical adjoint, so their gradients agree
    /// bit-for-bit when fed the same activations (asserted by tests).
    pub fn backward(
        &self,
        params: &crate::model::ParamStore,
        state: StackState,
        mem: Option<&Tensor>,
        plan: &GammaPlan,
        gx_last: Tensor,
    ) -> Result<StackGrads> {
        match state {
            StackState::Reversible { x_last, x_prev, mut side } => self
                .backward_reversible(params, x_last, x_prev, &mut side, mem, plan, gx_last),
            StackState::Full { xs } => self.backward_full(params, &xs, mem, plan, gx_last),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn backward_reversible(
        &self,
        params: &crate::model::ParamStore,
        x_last: Tensor,
        x_prev: Tensor,
        side: &mut SideInfoStore,
        mem: Option<&Tensor>,
        plan: &GammaPlan,
        gx_last: Tensor,
    ) -> Result<StackGrads> {
        let k_total = self.n_blocks;
        let mut dparams: Vec<Vec<Tensor>> = vec![Vec::new(); k_total];
        let mut dmem_acc: Option<Tensor> = None;

        // window: x_next = x_{k+1}, x_cur = x_k while visiting block step k
        let mut x_next = x_last;
        let mut x_cur = x_prev;
        // gx = dL/dx_{k+1}; gx_mid = partial dL/dx_k
        let mut gx = gx_last;
        let mut gx_mid = Tensor::zeros(gx.shape());

        for k in (1..k_total).rev() {
            let gammas = &plan.gammas[k];
            let coeff_seed: Vec<f32> = gammas.iter().map(|g| 1.0 + g).collect();
            let coeff_skip: Vec<f32> = gammas.iter().map(|g| 1.0 - g).collect();

            let seed = quant::scale_rows(&gx, &coeff_seed)?;
            let (h, dx, dmem, dp) = self.call_vjp(params, k, &x_cur, mem, &seed)?;
            dparams[k] = dp;
            if let Some(dm) = dmem {
                match &mut dmem_acc {
                    Some(acc) => acc.add_assign(&dm)?,
                    None => dmem_acc = Some(dm),
                }
            }

            // adjoint recursion
            quant::axpy_rows(&mut gx_mid, &coeff_skip, &gx)?;
            gx_mid.add_assign(&dx)?;
            let gx_prev = quant::scale_rows(&gx, gammas)?;

            // exact reconstruction of x_{k-1} (eq. 24)
            let bits: BitVec = side
                .take(k)
                .ok_or_else(|| anyhow::anyhow!("missing side info for block {k}"))?;
            let signs = plan.signs(k)?;
            let x_rec = quant::bdia_reconstruct_quant(
                &x_next, &x_cur, &h, &bits, &signs, self.fixed,
            )?;

            x_next = x_cur;
            x_cur = x_rec;
            gx = gx_mid;
            gx_mid = gx_prev;
        }

        // block 0: x_1 = x_0 + Q[h_0(x_0)] — STE through Q
        let (_h0, dx0, dmem0, dp0) = self.call_vjp(params, 0, &x_cur, mem, &gx)?;
        dparams[0] = dp0;
        if let Some(dm) = dmem0 {
            match &mut dmem_acc {
                Some(acc) => acc.add_assign(&dm)?,
                None => dmem_acc = Some(dm),
            }
        }
        let mut dx_total = gx; // dL/dx_1 passes straight through the residual
        dx_total.add_assign(&gx_mid)?; // gamma contribution from step 1
        dx_total.add_assign(&dx0)?;
        Ok(StackGrads { dx0: dx_total, dmem: dmem_acc, dparams })
    }

    fn backward_full(
        &self,
        params: &crate::model::ParamStore,
        xs: &[Tensor],
        mem: Option<&Tensor>,
        plan: &GammaPlan,
        gx_last: Tensor,
    ) -> Result<StackGrads> {
        let k_total = self.n_blocks;
        ensure!(xs.len() == k_total + 1, "activation store mismatch");
        let mut dparams: Vec<Vec<Tensor>> = vec![Vec::new(); k_total];
        let mut dmem_acc: Option<Tensor> = None;
        let mut gx = gx_last;
        let mut gx_mid = Tensor::zeros(gx.shape());

        for k in (1..k_total).rev() {
            let gammas = &plan.gammas[k];
            let coeff_seed: Vec<f32> = gammas.iter().map(|g| 1.0 + g).collect();
            let coeff_skip: Vec<f32> = gammas.iter().map(|g| 1.0 - g).collect();
            let seed = quant::scale_rows(&gx, &coeff_seed)?;
            let (_h, dx, dmem, dp) = self.call_vjp(params, k, &xs[k], mem, &seed)?;
            dparams[k] = dp;
            if let Some(dm) = dmem {
                match &mut dmem_acc {
                    Some(acc) => acc.add_assign(&dm)?,
                    None => dmem_acc = Some(dm),
                }
            }
            quant::axpy_rows(&mut gx_mid, &coeff_skip, &gx)?;
            gx_mid.add_assign(&dx)?;
            let gx_prev = quant::scale_rows(&gx, gammas)?;
            gx = gx_mid;
            gx_mid = gx_prev;
        }

        let (_h0, dx0, dmem0, dp0) = self.call_vjp(params, 0, &xs[0], mem, &gx)?;
        dparams[0] = dp0;
        if let Some(dm) = dmem0 {
            match &mut dmem_acc {
                Some(acc) => acc.add_assign(&dm)?,
                None => dmem_acc = Some(dm),
            }
        }
        let mut dx_total = gx;
        dx_total.add_assign(&gx_mid)?;
        dx_total.add_assign(&dx0)?;
        Ok(StackGrads { dx0: dx_total, dmem: dmem_acc, dparams })
    }

    /// Reconstruct every activation from boundaries + side info WITHOUT
    /// back-propagating — used by the Fig.-2 analogue and exactness tests.
    /// Returns `xs[0..=K]` (reconstructed where k < K-1).
    pub fn reconstruct_all(
        &self,
        params: &crate::model::ParamStore,
        state: &StackState,
        mem: Option<&Tensor>,
        plan: &GammaPlan,
    ) -> Result<Vec<Tensor>> {
        match state {
            StackState::Full { xs } => Ok(xs.clone()),
            StackState::Reversible { x_last, x_prev, side } => {
                let mut rev = vec![x_last.clone(), x_prev.clone()];
                let mut x_next = x_last.clone();
                let mut x_cur = x_prev.clone();
                for k in (1..self.n_blocks).rev() {
                    let h = self.call_fwd(params, k, &x_cur, mem)?;
                    let bits = side
                        .get(k)
                        .ok_or_else(|| anyhow::anyhow!("missing side info {k}"))?;
                    let signs = plan.signs(k)?;
                    let x_rec = quant::bdia_reconstruct_quant(
                        &x_next, &x_cur, &h, bits, &signs, self.fixed,
                    )?;
                    rev.push(x_rec.clone());
                    x_next = x_cur;
                    x_cur = x_rec;
                }
                rev.reverse();
                Ok(rev)
            }
        }
    }
}
