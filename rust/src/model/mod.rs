//! Model metadata + parameter storage, mirroring the AOT manifest ABI.
//!
//! The manifest (DESIGN.md §8) is the contract with `python/compile/aot.py`:
//! parameter groups ("embed", "block", "head", plus "enc_embed"/"enc_block"
//! for encoder-decoder) each list their leaves (name, shape, init) in flatten
//! order; every executable declares which groups (and how many instances) it
//! consumes followed by its data inputs.
//!
//! [`ParamStore`] owns the actual weights: `group -> instances -> leaves`
//! ("block" has `n_blocks` instances).  Initialisation runs in Rust from the
//! manifest's init specs so experiment seeds are fully coordinator-owned.

use crate::config::json::Json;
use crate::tensor::{Rng, Tensor};
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Init {
    Normal(f32),
    Zeros,
    Ones,
}

impl Init {
    fn parse(s: &str) -> Result<Self> {
        if s == "zeros" {
            Ok(Init::Zeros)
        } else if s == "ones" {
            Ok(Init::Ones)
        } else if let Some(std) = s.strip_prefix("normal:") {
            Ok(Init::Normal(std.parse()?))
        } else {
            bail!("unknown init spec '{s}'")
        }
    }
}

#[derive(Clone, Debug)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ExecSpec {
    pub file: String,
    /// [(group, instance count)] — input leaves in this order.
    pub param_layout: Vec<(String, usize)>,
    pub data_inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// Static model dimensions from the manifest (subset the runtime needs).
#[derive(Clone, Debug)]
pub struct Dims {
    pub d_model: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub n_enc_blocks: usize,
    pub mlp_ratio: usize,
    pub batch: usize,
    pub lbits: u32,
    pub image_size: usize,
    pub patch: usize,
    pub channels: usize,
    pub n_classes: usize,
    pub seq: usize,
    pub seq_src: usize,
    pub vocab: usize,
}

impl Dims {
    /// Sequence length seen by the (decoder) blocks.
    pub fn tokens(&self, family: Family) -> usize {
        match family {
            Family::Vit => (self.image_size / self.patch).pow(2) + 1,
            _ => self.seq,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Vit,
    Gpt,
    EncDec,
}

impl Family {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "vit" => Ok(Family::Vit),
            "gpt" => Ok(Family::Gpt),
            "encdec" => Ok(Family::EncDec),
            _ => bail!("unknown family '{s}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub family: Family,
    pub dims: Dims,
    pub param_groups: BTreeMap<String, Vec<LeafSpec>>,
    pub executables: BTreeMap<String, ExecSpec>,
}

impl Manifest {
    pub fn from_json(j: &Json) -> Result<Self> {
        let dims_j = j.get("dims")?;
        let u = |k: &str| -> Result<usize> { dims_j.get(k)?.as_usize() };
        let dims = Dims {
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            n_blocks: u("n_blocks")?,
            n_enc_blocks: u("n_enc_blocks")?,
            mlp_ratio: u("mlp_ratio")?,
            batch: u("batch")?,
            lbits: u("lbits")? as u32,
            image_size: u("image_size")?,
            patch: u("patch")?,
            channels: u("channels")?,
            n_classes: u("n_classes")?,
            seq: u("seq")?,
            seq_src: u("seq_src")?,
            vocab: u("vocab")?,
        };
        let mut param_groups = BTreeMap::new();
        for (g, leaves) in j.get("param_groups")?.as_obj()? {
            let mut v = Vec::new();
            for leaf in leaves.as_arr()? {
                v.push(LeafSpec {
                    name: leaf.get("name")?.as_str()?.to_string(),
                    shape: leaf.get("shape")?.usize_vec()?,
                    init: Init::parse(leaf.get("init")?.as_str()?)?,
                });
            }
            param_groups.insert(g.clone(), v);
        }
        let mut executables = BTreeMap::new();
        for (name, e) in j.get("executables")?.as_obj()? {
            let mut layout = Vec::new();
            for pair in e.get("param_layout")?.as_arr()? {
                let pair = pair.as_arr()?;
                ensure!(pair.len() == 2, "bad param_layout entry");
                layout.push((pair[0].as_str()?.to_string(), pair[1].as_usize()?));
            }
            let parse_args = |key: &str| -> Result<Vec<ArgSpec>> {
                let mut v = Vec::new();
                for (i, a) in e.get(key)?.as_arr()?.iter().enumerate() {
                    v.push(ArgSpec {
                        name: a
                            .opt("name")
                            .map(|n| n.as_str().map(String::from))
                            .transpose()?
                            .unwrap_or_else(|| format!("out{i}")),
                        dtype: DType::parse(a.get("dtype")?.as_str()?)?,
                        shape: a.get("shape")?.usize_vec()?,
                    });
                }
                Ok(v)
            };
            executables.insert(
                name.clone(),
                ExecSpec {
                    file: e.get("file")?.as_str()?.to_string(),
                    param_layout: layout,
                    data_inputs: parse_args("data_inputs")?,
                    outputs: parse_args("outputs")?,
                },
            );
        }
        Ok(Manifest {
            name: j.get("name")?.as_str()?.to_string(),
            family: Family::parse(j.get("family")?.as_str()?)?,
            dims,
            param_groups,
            executables,
        })
    }

    /// Number of weight instances a group has in the full model.
    pub fn group_instances(&self, group: &str) -> usize {
        match group {
            "block" => self.dims.n_blocks,
            "enc_block" => self.dims.n_enc_blocks,
            _ => 1,
        }
    }

    /// Total trainable parameter count.
    pub fn n_params(&self) -> usize {
        self.param_groups
            .iter()
            .map(|(g, leaves)| {
                self.group_instances(g)
                    * leaves
                        .iter()
                        .map(|l| l.shape.iter().product::<usize>())
                        .sum::<usize>()
            })
            .sum()
    }
}

/// Owned model weights: `group -> instances -> leaves` (flatten order).
#[derive(Clone)]
pub struct ParamStore {
    pub groups: BTreeMap<String, Vec<Vec<Tensor>>>,
}

impl ParamStore {
    /// Initialise from the manifest's init specs with a coordinator seed.
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        // a new parameter set may reuse freed allocations: invalidate any
        // cached weight transposes keyed on old pointers
        crate::kernels::workspace::bump_weight_generation();
        let mut rng = Rng::new(seed);
        let mut groups = BTreeMap::new();
        for (g, leaves) in &manifest.param_groups {
            let n = manifest.group_instances(g);
            let mut instances = Vec::with_capacity(n);
            for _ in 0..n {
                let mut inst = Vec::with_capacity(leaves.len());
                for leaf in leaves {
                    inst.push(match leaf.init {
                        Init::Zeros => Tensor::zeros(&leaf.shape),
                        Init::Ones => Tensor::ones(&leaf.shape),
                        Init::Normal(std) => Tensor::normal(&leaf.shape, std, &mut rng),
                    });
                }
                instances.push(inst);
            }
            groups.insert(g.clone(), instances);
        }
        ParamStore { groups }
    }

    /// Same structure, all zeros (gradient accumulators, optimizer moments).
    pub fn zeros_like(&self) -> Self {
        let groups = self
            .groups
            .iter()
            .map(|(g, insts)| {
                (
                    g.clone(),
                    insts
                        .iter()
                        .map(|inst| inst.iter().map(|t| Tensor::zeros(t.shape())).collect())
                        .collect(),
                )
            })
            .collect();
        ParamStore { groups }
    }

    pub fn leaves(&self, group: &str, instance: usize) -> &[Tensor] {
        &self.groups[group][instance]
    }

    pub fn leaves_mut(&mut self, group: &str, instance: usize) -> &mut Vec<Tensor> {
        self.groups.get_mut(group).unwrap().get_mut(instance).unwrap()
    }

    /// Flat references for an executable whose layout references a *single*
    /// instance per group entry (fwd/vjp component calls).  `block_instance`
    /// selects which block's weights to bind for count-1 "block"/"enc_block"
    /// entries.
    pub fn refs_for(
        &self,
        spec: &ExecSpec,
        block_instance: usize,
    ) -> Result<Vec<&Tensor>> {
        let mut out = Vec::new();
        for (group, count) in &spec.param_layout {
            let insts = self
                .groups
                .get(group)
                .ok_or_else(|| anyhow::anyhow!("no param group '{group}'"))?;
            if *count == 1 && insts.len() > 1 {
                ensure!(
                    block_instance < insts.len(),
                    "block instance {} out of range ({})",
                    block_instance,
                    insts.len()
                );
                out.extend(insts[block_instance].iter());
            } else {
                ensure!(
                    *count == insts.len() || (*count == 1 && insts.len() == 1),
                    "layout wants {} instances of '{group}', store has {}",
                    count,
                    insts.len()
                );
                for inst in insts.iter().take(*count) {
                    out.extend(inst.iter());
                }
            }
        }
        Ok(out)
    }

    /// Visit every tensor with a stable ordering (optimizer state pairing).
    pub fn for_each_mut(&mut self, mut f: impl FnMut(&mut Tensor)) {
        for insts in self.groups.values_mut() {
            for inst in insts {
                for t in inst {
                    f(t);
                }
            }
        }
    }

    /// Zip-visit two stores with identical structure (p, g) -> ().
    pub fn zip2_mut(
        &mut self,
        other: &mut ParamStore,
        mut f: impl FnMut(&mut Tensor, &mut Tensor),
    ) {
        for (insts_a, insts_b) in self.groups.values_mut().zip(other.groups.values_mut()) {
            for (ia, ib) in insts_a.iter_mut().zip(insts_b.iter_mut()) {
                for (ta, tb) in ia.iter_mut().zip(ib.iter_mut()) {
                    f(ta, tb);
                }
            }
        }
    }

    pub fn n_params(&self) -> usize {
        let mut n = 0;
        for insts in self.groups.values() {
            for inst in insts {
                for t in inst {
                    n += t.len();
                }
            }
        }
        n
    }

    /// Payload bytes (memory accounting: params, grads, moments).
    pub fn nbytes(&self) -> usize {
        self.n_params() * std::mem::size_of::<f32>()
    }

    /// True when this store has exactly the groups, instance counts and
    /// leaf shapes the manifest prescribes — cheap checkpoint validation
    /// (no throwaway parameter initialisation).
    pub fn matches_manifest(&self, manifest: &Manifest) -> bool {
        if self.groups.len() != manifest.param_groups.len() {
            return false;
        }
        manifest.param_groups.iter().all(|(g, leaves)| {
            self.groups.get(g).is_some_and(|insts| {
                insts.len() == manifest.group_instances(g)
                    && insts.iter().all(|inst| {
                        inst.len() == leaves.len()
                            && inst
                                .iter()
                                .zip(leaves)
                                .all(|(t, l)| t.shape() == &l.shape[..])
                    })
            })
        })
    }

    /// True when `other` has identical groups, instance counts and leaf
    /// shapes (checkpoint-load validation).
    pub fn same_structure(&self, other: &ParamStore) -> bool {
        if self.groups.len() != other.groups.len() {
            return false;
        }
        self.groups.iter().zip(&other.groups).all(|((ga, ia), (gb, ib))| {
            ga == gb
                && ia.len() == ib.len()
                && ia.iter().zip(ib).all(|(la, lb)| {
                    la.len() == lb.len()
                        && la.iter().zip(lb).all(|(ta, tb)| ta.shape() == tb.shape())
                })
        })
    }

    /// Accumulate `other` into `self` (gradient accumulation).
    pub fn accumulate(&mut self, other: &ParamStore) -> Result<()> {
        for (g, insts) in &mut self.groups {
            let oinsts = other
                .groups
                .get(g)
                .ok_or_else(|| anyhow::anyhow!("missing group '{g}'"))?;
            for (inst, oinst) in insts.iter_mut().zip(oinsts) {
                for (t, ot) in inst.iter_mut().zip(oinst) {
                    t.add_assign(ot)?;
                }
            }
        }
        Ok(())
    }

    /// Set all tensors to zero (reset grad accumulators between steps).
    pub fn zero(&mut self) {
        self.for_each_mut(|t| t.fill(0.0));
    }

    /// Global L2 norm over all leaves (grad-clip).
    pub fn global_norm(&self) -> f32 {
        let mut sq = 0.0f64;
        for insts in self.groups.values() {
            for inst in insts {
                for t in inst {
                    for &v in t.data() {
                        sq += (v as f64) * (v as f64);
                    }
                }
            }
        }
        sq.sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        let text = r#"{
          "name": "toy", "family": "gpt",
          "dims": {"d_model": 4, "n_heads": 2, "n_blocks": 3,
                   "n_enc_blocks": 0, "mlp_ratio": 2, "batch": 2, "lbits": 9,
                   "image_size": 32, "patch": 4, "channels": 3,
                   "n_classes": 10, "seq": 8, "seq_src": 0, "vocab": 16},
          "param_groups": {
            "embed": [{"name": "wte", "shape": [16, 4], "init": "normal:0.02"},
                       {"name": "wpe", "shape": [8, 4], "init": "normal:0.02"}],
            "block": [{"name": "ln.scale", "shape": [4], "init": "ones"},
                       {"name": "ln.bias", "shape": [4], "init": "zeros"}],
            "head": [{"name": "w", "shape": [4, 16], "init": "normal:0.02"}]
          },
          "executables": {
            "block_fwd": {"file": "block_fwd.hlo.txt",
              "param_layout": [["block", 1]],
              "data_inputs": [{"name": "x", "dtype": "f32", "shape": [2, 8, 4]}],
              "outputs": [{"dtype": "f32", "shape": [2, 8, 4]}]},
            "model_infer": {"file": "model_infer.hlo.txt",
              "param_layout": [["embed", 1], ["block", 3], ["head", 1]],
              "data_inputs": [{"name": "gamma", "dtype": "f32", "shape": []}],
              "outputs": [{"dtype": "f32", "shape": []}]}
          },
          "source_hash": "x"
        }"#;
        Manifest::from_json(&Json::parse(text).unwrap()).unwrap()
    }

    #[test]
    fn manifest_parses() {
        let m = toy_manifest();
        assert_eq!(m.family, Family::Gpt);
        assert_eq!(m.dims.n_blocks, 3);
        assert_eq!(m.group_instances("block"), 3);
        assert_eq!(m.group_instances("embed"), 1);
        // wte 64 + wpe 32 + 3*(4+4) + head 64 = 184
        assert_eq!(m.n_params(), 184);
        let e = &m.executables["block_fwd"];
        assert_eq!(e.param_layout, vec![("block".to_string(), 1)]);
        assert_eq!(e.data_inputs[0].dtype, DType::F32);
    }

    #[test]
    fn param_store_init_and_refs() {
        let m = toy_manifest();
        let ps = ParamStore::init(&m, 1);
        assert_eq!(ps.n_params(), m.n_params());
        // ones/zeros init honored
        assert_eq!(ps.leaves("block", 0)[0].data(), &[1.0; 4]); // ln.scale
        assert_eq!(ps.leaves("block", 0)[1].data(), &[0.0; 4]); // ln.bias
        // refs for single-block exec bind the requested instance
        let spec = &m.executables["block_fwd"];
        let refs = ps.refs_for(spec, 2).unwrap();
        assert_eq!(refs.len(), 2);
        // refs for full-model exec bind everything in layout order
        let spec = &m.executables["model_infer"];
        let refs = ps.refs_for(spec, 0).unwrap();
        assert_eq!(refs.len(), 2 + 3 * 2 + 1);
    }

    #[test]
    fn init_seed_reproducible() {
        let m = toy_manifest();
        let a = ParamStore::init(&m, 7);
        let b = ParamStore::init(&m, 7);
        let c = ParamStore::init(&m, 8);
        assert_eq!(a.leaves("embed", 0)[0], b.leaves("embed", 0)[0]);
        assert_ne!(a.leaves("embed", 0)[0], c.leaves("embed", 0)[0]);
    }

    #[test]
    fn structure_checks() {
        let m = toy_manifest();
        let ps = ParamStore::init(&m, 1);
        assert!(ps.matches_manifest(&m));
        assert!(ps.same_structure(&ps.zeros_like()));
        let mut other = ps.zeros_like();
        other.groups.get_mut("head").unwrap()[0][0] =
            Tensor::zeros(&[5, 5]); // wrong leaf shape
        assert!(!ps.same_structure(&other));
        assert!(!other.matches_manifest(&m));
        let mut missing = ps.zeros_like();
        missing.groups.remove("head");
        assert!(!ps.same_structure(&missing));
        assert!(!missing.matches_manifest(&m));
    }

    #[test]
    fn zeros_like_and_accumulate() {
        let m = toy_manifest();
        let ps = ParamStore::init(&m, 1);
        let mut g = ps.zeros_like();
        assert_eq!(g.n_params(), ps.n_params());
        assert_eq!(g.global_norm(), 0.0);
        g.accumulate(&ps).unwrap();
        g.accumulate(&ps).unwrap();
        let mut expect = 0.0f64;
        for insts in ps.groups.values() {
            for inst in insts {
                for t in inst {
                    for &v in t.data() {
                        expect += 4.0 * (v as f64) * (v as f64);
                    }
                }
            }
        }
        assert!((g.global_norm() as f64 - expect.sqrt()).abs() < 1e-4);
        g.zero();
        assert_eq!(g.global_norm(), 0.0);
    }

    #[test]
    fn dims_tokens() {
        let m = toy_manifest();
        assert_eq!(m.dims.tokens(Family::Gpt), 8);
        assert_eq!(m.dims.tokens(Family::Vit), 65); // (32/4)^2 + 1
    }
}
