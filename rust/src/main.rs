//! `bdia` — CLI for the reversible-transformer training framework.
//!
//! ```text
//! bdia train  --config configs/vit_s10_bdia.json [--backend native|pjrt]
//!             [--threads N] [--save-every K] [--ckpt-dir D]
//!             [--resume ckpt] [key=value ...]
//! bdia eval   --model vit_s10 --gamma 0.0 [--ckpt path] [key=value ...]
//! bdia serve  --model vit_s10 --ckpt path [--port P] [--workers N]
//!             [--threads N] [--batch-window-us U]
//! bdia bench-serve --model vit_s10 [--requests N] [--concurrency C]
//!             [--workers N] [--addr host:port] [--ckpt path]
//! bdia bench  [--families vit_s10,gpt_tiny,encdec_mt] [--threads N]
//!             [--quick] [--out BENCH_3.json]
//! bdia repro  <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all>
//!             [--steps N] [--seeds 0,1,2] [--quick]
//! bdia info   --model vit_s10       # bundle inventory + call counts
//! ```
//!
//! The default backend is the dependency-free pure-Rust `native`
//! interpreter; `--backend pjrt` selects the AOT-HLO/XLA path (requires the
//! `pjrt` cargo feature and `make artifacts`).  `--threads` sizes the
//! deterministic kernel pool — results are bit-identical at any value.
//!
//! (Argument parsing is in-repo — no clap offline — see `parse_flags`.)

use anyhow::{bail, ensure, Context, Result};
use bdia::baseline::RevVitTrainer;
use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::{run_experiment, ExpOpts};
use bdia::metrics::fmt_bytes;
use bdia::metrics::memory::MemoryModel;
use bdia::runtime::{BackendKind, Runtime};
use bdia::serve::bench::BenchOpts;
use bdia::serve::{ServeConfig, Server};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Split argv into (`--flag value` map, bare `key=value` overrides, rest).
fn parse_flags(
    args: &[String],
) -> (BTreeMap<String, String>, Vec<String>, Vec<String>) {
    let mut flags = BTreeMap::new();
    let mut overrides = Vec::new();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            }
        } else if a.contains('=') {
            overrides.push(a.clone());
            i += 1;
        } else {
            rest.push(a.clone());
            i += 1;
        }
    }
    (flags, overrides, rest)
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let (flags, overrides, rest) = parse_flags(&argv[1..]);

    match cmd.as_str() {
        "train" => cmd_train(&flags, &overrides),
        "eval" => cmd_eval(&flags, &overrides),
        "serve" => cmd_serve(&flags),
        "bench-serve" => cmd_bench_serve(&flags),
        "bench" => cmd_bench(&flags),
        "repro" => cmd_repro(&flags, &rest),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `bdia help`)"),
    }
}

fn load_config(
    flags: &BTreeMap<String, String>,
    overrides: &[String],
) -> Result<TrainConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => TrainConfig::load(std::path::Path::new(path))?,
        None => TrainConfig::default(),
    };
    if let Some(m) = flags.get("model") {
        cfg.model = m.clone();
    }
    if let Some(b) = flags.get("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if let Some(k) = flags.get("save-every") {
        cfg.save_every = k.parse().context("--save-every must be an integer")?;
    }
    if let Some(d) = flags.get("ckpt-dir") {
        cfg.ckpt_dir = PathBuf::from(d);
    }
    if let Some(t) = flags.get("threads") {
        cfg.threads = t.parse().context("--threads must be an integer")?;
    }
    for kv in overrides {
        cfg.override_kv(kv)?;
    }
    // size the deterministic kernel pool (0 = auto); bit-identical results
    // at any value, so this is purely a speed knob
    bdia::kernels::pool::set_threads(cfg.threads);
    Ok(cfg)
}

/// Parse a standalone `--threads` flag (commands without a TrainConfig).
fn parse_threads(flags: &BTreeMap<String, String>) -> Result<usize> {
    flags
        .get("threads")
        .map(|t| t.parse())
        .transpose()
        .context("--threads must be an integer")
        .map(|t| t.unwrap_or(0))
}

fn cmd_train(flags: &BTreeMap<String, String>, overrides: &[String]) -> Result<()> {
    let cfg = load_config(flags, overrides)?;
    println!(
        "training {} | backend={} | mode={} | dataset={} | steps={} | seed={}",
        cfg.model,
        cfg.backend.name(),
        cfg.mode.name(),
        cfg.dataset,
        cfg.steps,
        cfg.seed
    );
    let run_name = flags
        .get("name")
        .cloned()
        .unwrap_or_else(|| format!("{}_{}", cfg.model, cfg.mode.name()));
    if cfg.save_every > 0 {
        println!(
            "checkpoints: every {} steps into {}",
            cfg.save_every,
            cfg.ckpt_dir.display()
        );
    }

    let log = if cfg.mode == TrainMode::RevVit {
        ensure!(
            cfg.save_every == 0 && !flags.contains_key("resume"),
            "checkpointing is supported by the BDIA/vanilla trainer only \
             (RevViT baseline has no persistence)"
        );
        let mut tr = RevVitTrainer::new(cfg.clone())?;
        println!("params: {}", tr.n_params());
        let ds = bdia::experiments::dataset_for(&tr.rt, &cfg)?;
        let log = tr.run(ds.as_ref(), &run_name)?;
        report_live(&log);
        log
    } else {
        let mut tr = Trainer::new(cfg.clone())?;
        if let Some(path) = flags.get("resume") {
            tr.load_checkpoint(std::path::Path::new(path))?;
            println!("resumed from {} at step {}", path, tr.step());
        }
        println!("params: {}", tr.n_params());
        let mm = MemoryModel::new(
            cfg.mode,
            tr.family,
            &tr.rt.manifest.dims,
            tr.n_params() * 4,
        );
        println!("peak training memory (analytic): {}", fmt_bytes(mm.peak_total()));
        let ds = bdia::experiments::dataset_for(&tr.rt, &cfg)?;
        let log = tr.run(ds.as_ref(), &run_name)?;
        report_live(&log);
        log
    };
    let out = PathBuf::from("results").join(format!("{run_name}.csv"));
    log.write_csv(&out)?;
    println!("log written to {}", out.display());
    Ok(())
}

fn report_live(log: &bdia::metrics::TrainLog) {
    if let Some(r) = log.last() {
        println!(
            "final: step {} train_loss {:.4} val_loss {} val_acc {} ({:.0} ms/step)",
            r.step,
            r.train_loss,
            r.val_loss.map_or("-".into(), |v| format!("{v:.4}")),
            r.val_acc.map_or("-".into(), |v| format!("{v:.3}")),
            log.mean_ms_per_step()
        );
    }
}

fn cmd_eval(flags: &BTreeMap<String, String>, overrides: &[String]) -> Result<()> {
    let cfg = load_config(flags, overrides)?;
    let gamma: f32 = flags
        .get("gamma")
        .map(|g| g.parse())
        .transpose()
        .context("--gamma must be a float")?
        .unwrap_or(0.0);
    let n_batches: usize = flags
        .get("batches")
        .map(|b| b.parse())
        .transpose()
        .context("--batches must be an integer")?
        .unwrap_or(cfg.eval_batches);
    let mut tr = Trainer::new(cfg.clone())?;
    let provenance = match flags.get("ckpt") {
        Some(path) => {
            tr.load_checkpoint(std::path::Path::new(path))?;
            format!("checkpoint {path}, step {}", tr.step())
        }
        None => {
            eprintln!(
                "warning: no --ckpt given — scoring FRESHLY-SEEDED (untrained) \
                 parameters.\nwarning: pass --ckpt <file> to evaluate weights \
                 produced by `bdia train save_every=K`."
            );
            format!("untrained seed {}", cfg.seed)
        }
    };
    let ds = bdia::experiments::dataset_for(&tr.rt, &cfg)?;
    let (loss, acc) = tr.evaluate(ds.as_ref(), n_batches, gamma)?;
    println!(
        "{} @ gamma={gamma}: val_loss {loss:.4} val_acc {acc:.4} ({provenance})",
        cfg.model
    );
    Ok(())
}

fn cmd_serve(flags: &BTreeMap<String, String>) -> Result<()> {
    let cfg = ServeConfig {
        model: flags.get("model").cloned().unwrap_or_else(|| "vit_s10".into()),
        backend: flags
            .get("backend")
            .map(|b| BackendKind::parse(b))
            .transpose()?
            .unwrap_or_default(),
        artifacts_dir: flags
            .get("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts")),
        ckpt: flags.get("ckpt").map(PathBuf::from),
        port: flags
            .get("port")
            .map(|p| p.parse())
            .transpose()
            .context("--port must be an integer")?
            .unwrap_or(7878),
        workers: flags
            .get("workers")
            .map(|w| w.parse())
            .transpose()
            .context("--workers must be an integer")?
            .unwrap_or(4),
        batch_window: Duration::from_micros(
            flags
                .get("batch-window-us")
                .map(|w| w.parse())
                .transpose()
                .context("--batch-window-us must be an integer")?
                .unwrap_or(2000),
        ),
        threads: parse_threads(flags)?,
    };
    if cfg.ckpt.is_none() {
        eprintln!(
            "warning: no --ckpt given — serving FRESHLY-SEEDED (untrained) \
             parameters."
        );
    }
    let model = cfg.model.clone();
    let workers = cfg.workers;
    let window = cfg.batch_window;
    let server = Server::start(cfg)?;
    println!(
        "bdia serve: {model} on http://{} ({workers} workers, batch window \
         {window:?})",
        server.addr()
    );
    println!("endpoints: POST /infer  GET /healthz  GET /stats  POST /shutdown");
    server.join()
}

/// Resolve `host:port` (hostnames included, e.g. `localhost:7878`) to a
/// socket address.
fn resolve_addr(s: &str) -> Result<std::net::SocketAddr> {
    use std::net::ToSocketAddrs;
    s.to_socket_addrs()
        .with_context(|| format!("--addr '{s}' must be host:port"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("--addr '{s}' resolved to no address"))
}

fn cmd_bench_serve(flags: &BTreeMap<String, String>) -> Result<()> {
    let defaults = BenchOpts::default();
    let opts = BenchOpts {
        model: flags.get("model").cloned().unwrap_or(defaults.model),
        backend: flags
            .get("backend")
            .map(|b| BackendKind::parse(b))
            .transpose()?
            .unwrap_or_default(),
        artifacts_dir: flags
            .get("artifacts")
            .map(PathBuf::from)
            .unwrap_or(defaults.artifacts_dir),
        ckpt: flags.get("ckpt").map(PathBuf::from),
        addr: flags.get("addr").map(|a| resolve_addr(a)).transpose()?,
        workers: flags
            .get("workers")
            .map(|w| w.parse())
            .transpose()
            .context("--workers")?
            .unwrap_or(defaults.workers),
        requests: flags
            .get("requests")
            .map(|r| r.parse())
            .transpose()
            .context("--requests")?
            .unwrap_or(defaults.requests),
        concurrency: flags
            .get("concurrency")
            .map(|c| c.parse())
            .transpose()
            .context("--concurrency")?
            .unwrap_or(defaults.concurrency),
        gamma: flags
            .get("gamma")
            .map(|g| g.parse())
            .transpose()
            .context("--gamma")?
            .unwrap_or(defaults.gamma),
        batch_window: flags
            .get("batch-window-us")
            .map(|w| w.parse().map(Duration::from_micros))
            .transpose()
            .context("--batch-window-us")?
            .unwrap_or(defaults.batch_window),
        threads: parse_threads(flags)?,
        verify: !flags.contains_key("no-verify"),
    };
    let summary = bdia::serve::bench::run(&opts)?;
    ensure!(summary.errors == 0, "{} requests failed", summary.errors);
    ensure!(
        summary.mismatches == 0,
        "{} responses were NOT bit-identical to direct inference",
        summary.mismatches
    );
    Ok(())
}

fn cmd_bench(flags: &BTreeMap<String, String>) -> Result<()> {
    let quick = flags.contains_key("quick");
    let mut opts = bdia::bench::suite::SuiteOpts::new(quick);
    if let Some(f) = flags.get("families") {
        opts.families = f.split(',').map(str::to_string).collect();
    }
    opts.threads = parse_threads(flags)?;
    if let Some(o) = flags.get("out") {
        opts.out = PathBuf::from(o);
    }
    let report = bdia::bench::suite::run(&opts)?;
    ensure!(
        report.all_finite(),
        "bench produced non-finite timings — kernel regression?"
    );
    Ok(())
}

fn cmd_repro(flags: &BTreeMap<String, String>, rest: &[String]) -> Result<()> {
    let Some(id) = rest.first() else {
        bail!("usage: bdia repro <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all>")
    };
    let mut opts = if flags.contains_key("quick") {
        ExpOpts::quick()
    } else {
        ExpOpts::default()
    };
    if let Some(s) = flags.get("steps") {
        opts.steps = s.parse().context("--steps")?;
    }
    if let Some(s) = flags.get("seeds") {
        opts.seeds = s
            .split(',')
            .map(|x| x.parse().context("--seeds"))
            .collect::<Result<_>>()?;
    }
    if let Some(d) = flags.get("out") {
        opts.out_dir = PathBuf::from(d);
    }
    if let Some(d) = flags.get("artifacts") {
        opts.artifacts_dir = PathBuf::from(d);
    }
    println!(
        "repro {id}: steps={} seeds={:?} out={}",
        opts.steps,
        opts.seeds,
        opts.out_dir.display()
    );
    run_experiment(id, &opts)
}

fn cmd_info(flags: &BTreeMap<String, String>) -> Result<()> {
    let model = flags
        .get("model")
        .cloned()
        .unwrap_or_else(|| "vit_s10".into());
    let dir = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let backend = flags
        .get("backend")
        .map(|b| BackendKind::parse(b))
        .transpose()?
        .unwrap_or_default();
    bdia::kernels::pool::set_threads(parse_threads(flags)?);
    let rt = Runtime::load_with(&dir, &model, backend)?;
    let m = &rt.manifest;
    println!(
        "bundle {} (family {:?}, backend {})",
        m.name,
        m.family,
        rt.backend.name()
    );
    let ws = bdia::kernels::workspace::stats();
    println!(
        "  kernels: threads={} (auto={}, workers spawned={}), workspace \
         hits={} misses={}",
        bdia::kernels::pool::threads(),
        bdia::kernels::pool::auto_threads(),
        bdia::kernels::pool::spawned_workers(),
        ws.hits,
        ws.misses
    );
    println!(
        "  dims: d_model={} heads={} K={} K_enc={} batch={} l={}",
        m.dims.d_model, m.dims.n_heads, m.dims.n_blocks, m.dims.n_enc_blocks,
        m.dims.batch, m.dims.lbits
    );
    println!("  params: {}", m.n_params());
    println!("  executables (calls this process):");
    for (name, calls) in rt.call_counts() {
        println!("    {name}  calls={calls}");
    }
    for mode in [
        TrainMode::Vanilla,
        TrainMode::BdiaReversible,
        TrainMode::RevVit,
    ] {
        let mm = MemoryModel::new(mode, m.family, &m.dims, m.n_params() * 4);
        println!(
            "  peak training memory [{}]: {}",
            mode.name(),
            fmt_bytes(mm.peak_total())
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "bdia — exact bit-level reversible transformer training (BDIA)\n\n\
         USAGE:\n  bdia train --config configs/<f>.json \
         [--backend native|pjrt] [--threads N] [--save-every K] \
         [--ckpt-dir D] [--resume <ckpt>] [key=value ...]\n  \
         bdia eval  --model <bundle> --gamma <g> [--ckpt <file>]\n  \
         bdia serve --model <bundle> --ckpt <file> [--port P] [--workers N] \
         [--threads N] [--batch-window-us U]\n  \
         bdia bench-serve --model <bundle> [--requests N] [--concurrency C] \
         [--workers N] [--gamma g] [--addr host:port] [--ckpt <file>] \
         [--no-verify]\n  \
         bdia bench [--families a,b,c] [--threads N] [--quick] \
         [--out BENCH_3.json]\n  \
         bdia repro <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all> \
         [--quick] [--steps N] [--seeds 0,1]\n  \
         bdia info  --model <bundle> [--backend native|pjrt]\n\n\
         Config keys (key=value overrides): model, backend (native|pjrt), \
         mode (bdia|bdia_float|vanilla|revvit), gamma_mag, dataset, steps, \
         lr, optimizer (adam|setadam), seed, eval_every, eval_batches, \
         train_examples, val_examples, artifacts_dir, save_every, ckpt_dir, \
         threads\n\n\
         Threads: the native backend runs on a deterministic kernel pool \
         (row-partitioned parallelism only) — losses, gradients and served \
         bytes are bit-identical at any --threads value; 0 = auto.\n\
         Checkpoints: `train save_every=K` writes <run>-step<N>.ckpt + \
         <run>-latest.ckpt under ckpt_dir (versioned, CRC-checked, bit-exact \
         round trip); `eval --ckpt` / `serve --ckpt` load them.\n\
         Serving: `serve` exposes POST /infer (binary example -> 8-byte \
         loss/correct), GET /healthz, GET /stats, POST /shutdown, with \
         dynamic micro-batching across concurrent requests; `bench-serve` \
         load-tests a server (self-hosted on an ephemeral port unless --addr \
         is given) and verifies responses are bit-identical to direct \
         inference.\n\
         Benchmarks: `bench` times fwd/bwd/infer per model family at 1 and \
         N threads and writes BENCH_3.json.\n\n\
         The native backend is pure Rust and needs no artifacts; pjrt needs \
         the `pjrt` cargo feature plus `make artifacts`."
    );
}
