//! `bdia` — CLI for the reversible-transformer training framework.
//!
//! ```text
//! bdia train  --config configs/vit_s10_bdia.json [--backend native|pjrt]
//!             [key=value ...]
//! bdia eval   --model vit_s10 --gamma 0.0 [key=value ...]
//! bdia repro  <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all>
//!             [--steps N] [--seeds 0,1,2] [--quick]
//! bdia info   --model vit_s10       # bundle inventory
//! ```
//!
//! The default backend is the dependency-free pure-Rust `native`
//! interpreter; `--backend pjrt` selects the AOT-HLO/XLA path (requires the
//! `pjrt` cargo feature and `make artifacts`).
//!
//! (Argument parsing is in-repo — no clap offline — see `parse_flags`.)

use anyhow::{bail, Context, Result};
use bdia::baseline::RevVitTrainer;
use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::{run_experiment, ExpOpts};
use bdia::metrics::fmt_bytes;
use bdia::metrics::memory::MemoryModel;
use bdia::runtime::{BackendKind, Runtime};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Split argv into (`--flag value` map, bare `key=value` overrides, rest).
fn parse_flags(
    args: &[String],
) -> (BTreeMap<String, String>, Vec<String>, Vec<String>) {
    let mut flags = BTreeMap::new();
    let mut overrides = Vec::new();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            }
        } else if a.contains('=') {
            overrides.push(a.clone());
            i += 1;
        } else {
            rest.push(a.clone());
            i += 1;
        }
    }
    (flags, overrides, rest)
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let (flags, overrides, rest) = parse_flags(&argv[1..]);

    match cmd.as_str() {
        "train" => cmd_train(&flags, &overrides),
        "eval" => cmd_eval(&flags, &overrides),
        "repro" => cmd_repro(&flags, &rest),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `bdia help`)"),
    }
}

fn load_config(
    flags: &BTreeMap<String, String>,
    overrides: &[String],
) -> Result<TrainConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => TrainConfig::load(std::path::Path::new(path))?,
        None => TrainConfig::default(),
    };
    if let Some(m) = flags.get("model") {
        cfg.model = m.clone();
    }
    if let Some(b) = flags.get("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    for kv in overrides {
        cfg.override_kv(kv)?;
    }
    Ok(cfg)
}

fn cmd_train(flags: &BTreeMap<String, String>, overrides: &[String]) -> Result<()> {
    let cfg = load_config(flags, overrides)?;
    println!(
        "training {} | backend={} | mode={} | dataset={} | steps={} | seed={}",
        cfg.model,
        cfg.backend.name(),
        cfg.mode.name(),
        cfg.dataset,
        cfg.steps,
        cfg.seed
    );
    let run_name = flags
        .get("name")
        .cloned()
        .unwrap_or_else(|| format!("{}_{}", cfg.model, cfg.mode.name()));

    let log = if cfg.mode == TrainMode::RevVit {
        let mut tr = RevVitTrainer::new(cfg.clone())?;
        println!("params: {}", tr.n_params());
        let ds = bdia::experiments::dataset_for(&tr.rt, &cfg)?;
        let log = tr.run(ds.as_ref(), &run_name)?;
        report_live(&log);
        log
    } else {
        let mut tr = Trainer::new(cfg.clone())?;
        println!("params: {}", tr.n_params());
        let mm = MemoryModel::new(
            cfg.mode,
            tr.family,
            &tr.rt.manifest.dims,
            tr.n_params() * 4,
        );
        println!("peak training memory (analytic): {}", fmt_bytes(mm.peak_total()));
        let ds = bdia::experiments::dataset_for(&tr.rt, &cfg)?;
        let log = tr.run(ds.as_ref(), &run_name)?;
        report_live(&log);
        log
    };
    let out = PathBuf::from("results").join(format!("{run_name}.csv"));
    log.write_csv(&out)?;
    println!("log written to {}", out.display());
    Ok(())
}

fn report_live(log: &bdia::metrics::TrainLog) {
    if let Some(r) = log.last() {
        println!(
            "final: step {} train_loss {:.4} val_loss {} val_acc {} ({:.0} ms/step)",
            r.step,
            r.train_loss,
            r.val_loss.map_or("-".into(), |v| format!("{v:.4}")),
            r.val_acc.map_or("-".into(), |v| format!("{v:.3}")),
            log.mean_ms_per_step()
        );
    }
}

fn cmd_eval(flags: &BTreeMap<String, String>, overrides: &[String]) -> Result<()> {
    let cfg = load_config(flags, overrides)?;
    let gamma: f32 = flags
        .get("gamma")
        .map(|g| g.parse())
        .transpose()
        .context("--gamma must be a float")?
        .unwrap_or(0.0);
    let n_batches: usize = flags
        .get("batches")
        .map(|b| b.parse())
        .transpose()
        .context("--batches must be an integer")?
        .unwrap_or(cfg.eval_batches);
    let tr = Trainer::new(cfg.clone())?;
    let ds = bdia::experiments::dataset_for(&tr.rt, &cfg)?;
    let (loss, acc) = tr.evaluate(ds.as_ref(), n_batches, gamma)?;
    println!(
        "{} @ gamma={gamma}: val_loss {loss:.4} val_acc {acc:.4} (params seed {})",
        cfg.model, cfg.seed
    );
    Ok(())
}

fn cmd_repro(flags: &BTreeMap<String, String>, rest: &[String]) -> Result<()> {
    let Some(id) = rest.first() else {
        bail!("usage: bdia repro <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all>")
    };
    let mut opts = if flags.contains_key("quick") {
        ExpOpts::quick()
    } else {
        ExpOpts::default()
    };
    if let Some(s) = flags.get("steps") {
        opts.steps = s.parse().context("--steps")?;
    }
    if let Some(s) = flags.get("seeds") {
        opts.seeds = s
            .split(',')
            .map(|x| x.parse().context("--seeds"))
            .collect::<Result<_>>()?;
    }
    if let Some(d) = flags.get("out") {
        opts.out_dir = PathBuf::from(d);
    }
    if let Some(d) = flags.get("artifacts") {
        opts.artifacts_dir = PathBuf::from(d);
    }
    println!(
        "repro {id}: steps={} seeds={:?} out={}",
        opts.steps,
        opts.seeds,
        opts.out_dir.display()
    );
    run_experiment(id, &opts)
}

fn cmd_info(flags: &BTreeMap<String, String>) -> Result<()> {
    let model = flags
        .get("model")
        .cloned()
        .unwrap_or_else(|| "vit_s10".into());
    let dir = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let backend = flags
        .get("backend")
        .map(|b| BackendKind::parse(b))
        .transpose()?
        .unwrap_or_default();
    let rt = Runtime::load_with(&dir, &model, backend)?;
    let m = &rt.manifest;
    println!(
        "bundle {} (family {:?}, backend {})",
        m.name,
        m.family,
        rt.backend.name()
    );
    println!(
        "  dims: d_model={} heads={} K={} K_enc={} batch={} l={}",
        m.dims.d_model, m.dims.n_heads, m.dims.n_blocks, m.dims.n_enc_blocks,
        m.dims.batch, m.dims.lbits
    );
    println!("  params: {}", m.n_params());
    println!("  executables:");
    for name in rt.exec_names() {
        println!("    {name}");
    }
    for mode in [
        TrainMode::Vanilla,
        TrainMode::BdiaReversible,
        TrainMode::RevVit,
    ] {
        let mm = MemoryModel::new(mode, m.family, &m.dims, m.n_params() * 4);
        println!(
            "  peak training memory [{}]: {}",
            mode.name(),
            fmt_bytes(mm.peak_total())
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "bdia — exact bit-level reversible transformer training (BDIA)\n\n\
         USAGE:\n  bdia train --config configs/<f>.json \
         [--backend native|pjrt] [key=value ...]\n  \
         bdia eval  --model <bundle> --gamma <g>\n  \
         bdia repro <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all> \
         [--quick] [--steps N] [--seeds 0,1]\n  \
         bdia info  --model <bundle> [--backend native|pjrt]\n\n\
         Config keys (key=value overrides): model, backend (native|pjrt), \
         mode (bdia|bdia_float|vanilla|revvit), gamma_mag, dataset, steps, \
         lr, optimizer (adam|setadam), seed, eval_every, eval_batches, \
         train_examples, val_examples, artifacts_dir\n\n\
         The native backend is pure Rust and needs no artifacts; pjrt needs \
         the `pjrt` cargo feature plus `make artifacts`."
    );
}
