//! `bdia` — CLI for the reversible-transformer training framework.
//!
//! ```text
//! bdia train  --config configs/vit_s10_bdia.json [--backend native|pjrt]
//!             [--threads N] [--save-every K] [--ckpt-dir D]
//!             [--resume ckpt] [--init-from ckpt [--freeze-embed]]
//!             [--ranks N [--rank k --rendezvous host:port]]
//!             [key=value ...]
//! bdia eval   --model vit_s10 --gamma 0.0 [--ckpt path] [key=value ...]
//! bdia generate --model gpt_tiny [--ckpt path] [--prompt 1,2,3]
//!             [--max-tokens N] [--temperature T] [--top-k K] [--seed S]
//!             [--eos E] [key=value ...]
//! bdia serve  --model vit_s10 --ckpt path [--port P] [--workers N]
//!             [--threads N] [--batch-window-us U] [--queue-cap Q]
//!             [--replicas N [--rendezvous host:port]]
//! bdia serve  --replica --model vit_s10 --rendezvous host:port
//! bdia bench-serve --model vit_s10 [--requests N] [--concurrency C]
//!             [--workers N] [--addr host:port] [--ckpt path]
//!             [--replicas N]
//! bdia bench  [--families vit_s10,gpt_tiny,encdec_mt] [--threads N]
//!             [--quick] [--out BENCH_10.json] [--tune-profile p.json]
//! bdia tune   --model vit_s10 [--threads N] [--quick]
//!             [--out profile.json] [key=value ...]
//! bdia repro  <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all>
//!             [--steps N] [--seeds 0,1,2] [--quick]
//! bdia info   --model vit_s10       # bundle inventory + call counts
//! bdia trace  [--out merged.json] [--require fwd,bwd,...] <rank traces>
//! bdia metrics-check [file]         # validate a /metrics exposition
//! ```
//!
//! `train`, `eval`, `serve`, `bench-serve`, `bench` and `info` all accept
//! `--tune-profile <json>` to run under a persisted kernel profile from
//! `bdia tune` (bit-identical results, different wall time).
//!
//! `train`, `serve` and `generate` accept `--trace-out <file>` to record
//! spans and export Chrome trace-event JSON on exit (one file per rank
//! under `--ranks`; align them with `bdia trace`).  Tracing never touches
//! compute — results are bit-identical with it on or off.
//!
//! Every subcommand is a thin client of `bdia::api::Session` — the CLI
//! owns flag parsing and printing, nothing else.  Flags accept both
//! `--flag value` and `--flag=value`; unknown flags are rejected with a
//! "did you mean" hint, and a value-taking flag followed by another flag
//! is an error instead of silently reading as `true`.
//!
//! (Argument parsing is in-repo — no clap offline — see `parse_flags`.)

use anyhow::{bail, ensure, Context, Result};
use bdia::api::{
    suggest, ApiError, EvalOpts, FleetOpts, ModelId, ServeBenchOpts, ServeOpts,
    Session, SessionBuilder, StdoutSink, TrainOpts,
};
use bdia::config::RankFailurePolicy;
use bdia::dist::{Rendezvous, WorkerRanks, MAX_RESTARTS};
use bdia::metrics::fmt_bytes;
use bdia::runtime::BackendKind;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// One flag a subcommand accepts.
#[derive(Clone, Copy)]
struct Flag {
    name: &'static str,
    takes_value: bool,
}

/// Value-taking flag (`--name VALUE` or `--name=VALUE`).
const fn v(name: &'static str) -> Flag {
    Flag { name, takes_value: true }
}

/// Boolean flag (presence means `true`).
const fn b(name: &'static str) -> Flag {
    Flag { name, takes_value: false }
}

const TRAIN_FLAGS: &[Flag] = &[
    v("config"),
    v("model"),
    v("backend"),
    v("threads"),
    v("save-every"),
    v("ckpt-dir"),
    v("resume"),
    v("init-from"),
    b("freeze-embed"),
    v("name"),
    v("ranks"),
    v("rank"),
    v("rendezvous"),
    v("dist-timeout-s"),
    v("on-rank-failure"),
    v("tune-profile"),
    v("trace-out"),
];
const EVAL_FLAGS: &[Flag] = &[
    v("config"),
    v("model"),
    v("backend"),
    v("threads"),
    v("gamma"),
    v("batches"),
    v("ckpt"),
    v("tune-profile"),
];
const SERVE_FLAGS: &[Flag] = &[
    v("model"),
    v("backend"),
    v("artifacts"),
    v("ckpt"),
    v("port"),
    v("workers"),
    v("batch-window-us"),
    v("threads"),
    v("queue-cap"),
    v("replicas"),
    b("replica"),
    v("rendezvous"),
    v("fleet-timeout-s"),
    v("tune-profile"),
    v("trace-out"),
];
const BENCH_SERVE_FLAGS: &[Flag] = &[
    v("model"),
    v("backend"),
    v("artifacts"),
    v("ckpt"),
    v("addr"),
    v("workers"),
    v("requests"),
    v("concurrency"),
    v("gamma"),
    v("batch-window-us"),
    v("threads"),
    v("queue-cap"),
    v("replicas"),
    v("fleet-timeout-s"),
    b("no-verify"),
    v("tune-profile"),
];
const BENCH_FLAGS: &[Flag] =
    &[v("families"), v("threads"), v("out"), b("quick"), v("tune-profile")];
const TUNE_FLAGS: &[Flag] = &[
    v("config"),
    v("model"),
    v("backend"),
    v("threads"),
    v("artifacts"),
    v("ckpt"),
    v("out"),
    b("quick"),
];
const REPRO_FLAGS: &[Flag] =
    &[v("steps"), v("seeds"), v("out"), v("artifacts"), b("quick")];
const INFO_FLAGS: &[Flag] = &[
    v("model"),
    v("artifacts"),
    v("backend"),
    v("threads"),
    v("ckpt"),
    v("tune-profile"),
];
const GENERATE_FLAGS: &[Flag] = &[
    v("config"),
    v("model"),
    v("backend"),
    v("artifacts"),
    v("threads"),
    v("ckpt"),
    v("tune-profile"),
    v("prompt"),
    v("max-tokens"),
    v("temperature"),
    v("top-k"),
    v("seed"),
    v("eos"),
    v("trace-out"),
];
const TRACE_FLAGS: &[Flag] = &[v("out"), v("require")];
const METRICS_CHECK_FLAGS: &[Flag] = &[];

struct Parsed {
    flags: BTreeMap<String, String>,
    overrides: Vec<String>,
    rest: Vec<String>,
}

/// Split argv into recognized `--flag [value]` pairs, bare `key=value`
/// config overrides, and positional arguments — validated against the
/// subcommand's flag spec.
///
/// Rules that make typos loud instead of silent:
/// * unknown `--flag` is an error with a closest-match hint;
/// * a value-taking flag must get a value (`--ckpt-dir --resume x` is an
///   error, not `ckpt-dir=true`); `--flag=value` always works;
/// * a boolean flag given `=value` is an error.
fn parse_flags(cmd: &str, args: &[String], spec: &[Flag]) -> Result<Parsed> {
    let mut p = Parsed {
        flags: BTreeMap::new(),
        overrides: Vec::new(),
        rest: Vec::new(),
    };
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(body) = a.strip_prefix("--") {
            let (name, inline) = match body.split_once('=') {
                Some((n, val)) => (n, Some(val)),
                None => (body, None),
            };
            let Some(f) = spec.iter().find(|f| f.name == name) else {
                let mut msg = format!("unknown flag --{name} for `bdia {cmd}`");
                if let Some(s) = suggest(name, spec.iter().map(|f| f.name)) {
                    msg.push_str(&format!(" (did you mean --{s}?)"));
                }
                bail!("{msg}; see `bdia help`");
            };
            if f.takes_value {
                let value = match inline {
                    Some(val) => val.to_string(),
                    None => {
                        let next = args.get(i + 1);
                        match next {
                            Some(n) if !n.starts_with("--") => {
                                i += 1;
                                n.clone()
                            }
                            Some(n) => bail!(
                                "flag --{name} requires a value, got flag \
                                 '{n}' (use --{name}=VALUE if the value \
                                 really starts with '--')"
                            ),
                            None => bail!("flag --{name} requires a value"),
                        }
                    }
                };
                p.flags.insert(name.to_string(), value);
            } else {
                ensure!(
                    inline.is_none(),
                    "flag --{name} takes no value (got --{name}={})",
                    inline.unwrap_or_default()
                );
                p.flags.insert(name.to_string(), "true".into());
            }
        } else if a.contains('=') {
            p.overrides.push(a.clone());
        } else {
            p.rest.push(a.clone());
        }
        i += 1;
    }
    Ok(p)
}

/// Parse an optional typed flag value with a uniform error message.
fn flag_val<T>(flags: &BTreeMap<String, String>, name: &str) -> Result<Option<T>>
where
    T: std::str::FromStr,
    T::Err: std::error::Error + Send + Sync + 'static,
{
    flags
        .get(name)
        .map(|raw| raw.parse::<T>())
        .transpose()
        .with_context(|| {
            format!("invalid value for --{name}: '{}'", flags[name])
        })
}

/// What a subcommand accepts beyond its `--flag`s.
#[derive(Clone, Copy, PartialEq)]
enum Extras {
    /// Flags only.
    None,
    /// Flags + bare `key=value` config overrides (train / eval).
    Overrides,
    /// Flags + positional arguments (repro's experiment id).
    Positionals,
}

fn reject_extras(cmd: &str, p: &Parsed, extras: Extras) -> Result<()> {
    if extras != Extras::Overrides {
        ensure!(
            p.overrides.is_empty(),
            "`bdia {cmd}` takes no key=value overrides (got '{}')",
            p.overrides[0]
        );
    }
    if extras != Extras::Positionals {
        ensure!(
            p.rest.is_empty(),
            "unexpected argument '{}' for `bdia {cmd}`",
            p.rest[0]
        );
    }
    Ok(())
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        print_help();
        return Ok(());
    }
    let args = &argv[1..];

    match cmd.as_str() {
        "train" => cmd_train(&parsed("train", args, TRAIN_FLAGS, Extras::Overrides)?),
        "eval" => cmd_eval(&parsed("eval", args, EVAL_FLAGS, Extras::Overrides)?),
        "generate" => cmd_generate(&parsed(
            "generate",
            args,
            GENERATE_FLAGS,
            Extras::Overrides,
        )?),
        "serve" => cmd_serve(&parsed("serve", args, SERVE_FLAGS, Extras::None)?),
        "bench-serve" => cmd_bench_serve(&parsed(
            "bench-serve",
            args,
            BENCH_SERVE_FLAGS,
            Extras::None,
        )?),
        "bench" => cmd_bench(&parsed("bench", args, BENCH_FLAGS, Extras::None)?),
        "tune" => {
            cmd_tune(&parsed("tune", args, TUNE_FLAGS, Extras::Overrides)?)
        }
        "repro" => {
            cmd_repro(&parsed("repro", args, REPRO_FLAGS, Extras::Positionals)?)
        }
        "info" => cmd_info(&parsed("info", args, INFO_FLAGS, Extras::None)?),
        "trace" => {
            cmd_trace(&parsed("trace", args, TRACE_FLAGS, Extras::Positionals)?)
        }
        "metrics-check" => cmd_metrics_check(&parsed(
            "metrics-check",
            args,
            METRICS_CHECK_FLAGS,
            Extras::Positionals,
        )?),
        "help" => {
            print_help();
            Ok(())
        }
        other => {
            let known = [
                "train",
                "eval",
                "generate",
                "serve",
                "bench-serve",
                "bench",
                "tune",
                "repro",
                "info",
                "trace",
                "metrics-check",
            ];
            match suggest(other, known) {
                Some(s) => bail!("unknown command '{other}' (did you mean '{s}'?)"),
                None => bail!("unknown command '{other}' (try `bdia help`)"),
            }
        }
    }
}

fn parsed(
    cmd: &str,
    args: &[String],
    spec: &[Flag],
    extras: Extras,
) -> Result<Parsed> {
    let p = parse_flags(cmd, args, spec)?;
    reject_extras(cmd, &p, extras)?;
    Ok(p)
}

/// Shared builder plumbing: config file, model, backend, threads,
/// artifacts dir, checkpoint, `key=value` overrides — everything else is
/// per-subcommand.
fn builder_from(p: &Parsed) -> Result<SessionBuilder> {
    let mut b = Session::builder();
    if let Some(path) = p.flags.get("config") {
        b = b.config_file(path);
    }
    if let Some(m) = p.flags.get("model") {
        b = b.model_name(m.as_str());
    }
    if let Some(be) = p.flags.get("backend") {
        b = b.backend(BackendKind::parse(be)?);
    }
    if let Some(dir) = p.flags.get("artifacts") {
        b = b.artifacts_dir(dir);
    }
    if let Some(t) = flag_val::<usize>(&p.flags, "threads")? {
        b = b.threads(t);
    }
    if let Some(path) = p.flags.get("ckpt") {
        b = b.checkpoint(path);
    }
    if let Some(path) = p.flags.get("tune-profile") {
        b = b.tune_profile(path);
    }
    for kv in &p.overrides {
        b = b.override_kv(kv);
    }
    Ok(b)
}

/// `--trace-out`: enable full span tracing for the process lifetime and
/// return the export path.  Tracing never feeds timestamps into compute,
/// so the run's bytes are identical with or without this flag.
fn trace_out(p: &Parsed) -> Option<PathBuf> {
    let path = p.flags.get("trace-out").map(PathBuf::from)?;
    bdia::obs::set_level(bdia::obs::SPANS);
    Some(path)
}

/// Export the span ring as Chrome trace-event JSON, if requested.
fn export_trace(path: Option<&Path>) -> Result<()> {
    if let Some(path) = path {
        bdia::obs::export_chrome_trace(path)?;
        println!("trace written to {}", path.display());
    }
    Ok(())
}

/// Per-rank trace file name: `trace.json` stays as-is in a 1-rank world
/// and becomes `trace.rank<k>.json` when several ranks export side by
/// side (feed the set to `bdia trace` to align them on rank 0's clock).
fn rank_trace_path(base: &Path, world: usize, rank: usize) -> PathBuf {
    if world <= 1 {
        return base.to_path_buf();
    }
    let s = base.to_string_lossy();
    let stem = s.strip_suffix(".json").unwrap_or(&s);
    PathBuf::from(format!("{stem}.rank{rank}.json"))
}

fn cmd_train(p: &Parsed) -> Result<()> {
    let trace = trace_out(p);
    let rank_flag = flag_val::<usize>(&p.flags, "rank")?;
    let my_rank = rank_flag.unwrap_or(0);
    let sink: Arc<dyn bdia::api::EventSink> = if my_rank == 0 {
        Arc::new(StdoutSink { every: 0 })
    } else {
        // workers stay quiet; rank 0 narrates the run
        Arc::new(bdia::api::NullSink)
    };
    let mut b = builder_from(p)?.event_sink(sink);
    if let Some(k) = flag_val::<usize>(&p.flags, "save-every")? {
        b = b.save_every(k);
    }
    if let Some(d) = p.flags.get("ckpt-dir") {
        b = b.ckpt_dir(d);
    }
    if let Some(n) = flag_val::<usize>(&p.flags, "ranks")? {
        b = b.ranks(n);
    }
    if let Some(k) = rank_flag {
        b = b.rank(k);
    }
    if let Some(a) = p.flags.get("rendezvous") {
        b = b.rendezvous(a);
    }
    if let Some(t) = flag_val::<f64>(&p.flags, "dist-timeout-s")? {
        b = b.dist_timeout_s(t);
    }
    if let Some(pol) = p.flags.get("on-rank-failure") {
        b = b.on_rank_failure(RankFailurePolicy::parse(pol)?);
    }
    if let Some(path) = p.flags.get("init-from") {
        b = b.init_from(path);
    }
    if p.flags.contains_key("freeze-embed") {
        b = b.freeze_embed(true);
    }
    let mut session = b.build()?;
    if my_rank == 0 {
        if let Some(path) = session.config().init_from.clone() {
            println!(
                "fine-tune: initialized from {} ({}{})",
                path.display(),
                provenance_line(&session),
                if session.config().freeze_embed {
                    "; embedding frozen"
                } else {
                    ""
                }
            );
        }
    }
    if let Some(path) = p.flags.get("resume") {
        // in a multi-rank world only rank 0 needs the file: its restored
        // state is broadcast to every worker when the world attaches
        if my_rank == 0 {
            session.resume(Path::new(path))?;
            println!("resumed from {} at step {}", path, session.step());
        }
    }

    // single-command local mode: `--ranks N` without `--rank` binds the
    // rendezvous here (ephemeral port unless --rendezvous pins one), then
    // re-execs this invocation once per worker rank and proceeds as rank 0
    let world = session.config().ranks;
    let spawn_mode = world > 1 && rank_flag.is_none();
    let bind = p.flags.get("rendezvous").map_or("127.0.0.1:0", String::as_str);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut children = WorkerRanks::default();
    if spawn_mode {
        let rdv = Rendezvous::bind(bind, world)?;
        let addr = rdv.addr();
        children.0 = bdia::dist::spawn_worker_ranks(addr, world, &argv)?;
        println!("dist: world size {world}, rendezvous {addr}, spawned ranks 1..{world}");
        session.connect_dist(Some(rdv))?;
    }

    let cfg = session.config().clone();
    if my_rank == 0 {
        println!(
            "training {} | backend={} | mode={} | dataset={} | steps={} | seed={}",
            cfg.model,
            cfg.backend.name(),
            cfg.mode.name(),
            cfg.dataset,
            cfg.steps,
            cfg.seed
        );
        if cfg.ranks > 1 {
            println!(
                "dist: {} ranks, {} micro-batch(es)/step, rank-ordered \
                 all-reduce (bit-identical at any world size)",
                cfg.ranks,
                cfg.accum()
            );
        }
        if cfg.save_every > 0 {
            println!(
                "checkpoints: every {} steps into {} (rank 0 writes)",
                cfg.save_every,
                cfg.ckpt_dir.display()
            );
        }
        println!("params: {}", session.n_params());
        let info = session.describe();
        if let Some((_, bytes)) =
            info.peak_memory.iter().find(|(m, _)| *m == cfg.mode.name())
        {
            println!("peak training memory (analytic): {}", fmt_bytes(*bytes));
        }
    }

    let run_name = p
        .flags
        .get("name")
        .cloned()
        .unwrap_or_else(|| format!("{}_{}", cfg.model, cfg.mode.name()));
    // the CSV log is rank 0's artifact (workers would race on the file)
    let csv_out = (my_rank == 0)
        .then(|| PathBuf::from("results").join(format!("{run_name}.csv")));
    let opts = TrainOpts { run_name: Some(run_name), csv_out: csv_out.clone() };

    // a lost rank surfaces as ApiError::Dist within ~2 deadlines (never a
    // hang); under --on-rank-failure=restart the world is rebuilt and
    // training resumes from the last completed step — bit-identically,
    // because a failed step never commits and the fresh world re-receives
    // rank 0's state at attach time
    let policy = cfg.on_rank_failure;
    let mut restarts = 0usize;
    let report = loop {
        match session.train(&opts) {
            Ok(report) => break report,
            Err(ApiError::Dist(m))
                if policy == RankFailurePolicy::Restart && restarts < MAX_RESTARTS =>
            {
                restarts += 1;
                eprintln!(
                    "dist: {m}; restarting world ({restarts}/{MAX_RESTARTS}) \
                     from step {}",
                    session.step()
                );
                session.detach_dist();
                if spawn_mode {
                    children.discard();
                    let rdv = Rendezvous::bind(bind, world)?;
                    let addr = rdv.addr();
                    children.0 = bdia::dist::spawn_worker_ranks(addr, world, &argv)?;
                    eprintln!("dist: respawned ranks 1..{world} at {addr}");
                    session.connect_dist(Some(rdv))?;
                }
                // manual mode: the next train() re-runs the rendezvous
                // itself; restarted workers reconnect the same way
            }
            Err(e) => return Err(e.into()),
        }
    };
    if my_rank == 0 {
        if let Some(r) = report.log.last() {
            println!(
                "final: step {} train_loss {:.4} val_loss {} val_acc {} ({:.0} ms/step)",
                r.step,
                r.train_loss,
                r.val_loss.map_or("-".into(), |x| format!("{x:.4}")),
                r.val_acc.map_or("-".into(), |x| format!("{x:.3}")),
                report.mean_ms_per_step
            );
        }
        if let Some(csv) = &csv_out {
            println!("log written to {}", csv.display());
        }
    }
    if let Some(base) = &trace {
        let path = rank_trace_path(base, world, my_rank);
        bdia::obs::export_chrome_trace(&path)?;
        if my_rank == 0 {
            println!(
                "trace written to {} (align ranks with `bdia trace`)",
                path.display()
            );
        }
    }
    children.reap()?;
    Ok(())
}

/// "step N, gamma-rng 0x…" — the checkpoint provenance a resumed trainer
/// would continue from (the state is decoded on every load; print it so
/// fine-tune users can see it).
fn provenance_line(session: &Session) -> String {
    match session.gamma_rng_state() {
        Some((state, spare)) => format!(
            "step {}, gamma-rng 0x{state:016x}{}",
            session.step(),
            spare.map_or(String::new(), |s| format!(" (spare {s})"))
        ),
        None => format!("step {}", session.step()),
    }
}

/// Warn when a subcommand is about to score freshly-seeded weights.
/// Checked *after* build so any loading path — `--ckpt`, an `init_from`
/// config key, or a config file — suppresses it.
fn warn_if_untrained(session: &Session, verb: &str) {
    if session.resumed_from().is_none() && session.step() == 0 {
        eprintln!(
            "warning: no --ckpt given — {verb} FRESHLY-SEEDED (untrained) \
             parameters.\nwarning: pass --ckpt <file> to use weights \
             produced by `bdia train save_every=K`."
        );
    }
}

fn cmd_eval(p: &Parsed) -> Result<()> {
    let session = builder_from(p)?.build()?;
    warn_if_untrained(&session, "scoring");
    if let Some(path) = session.resumed_from() {
        println!(
            "checkpoint: {} ({})",
            path.display(),
            provenance_line(&session)
        );
    }
    let report = session.evaluate(&EvalOpts {
        gamma: flag_val::<f32>(&p.flags, "gamma")?.unwrap_or(0.0),
        batches: flag_val::<usize>(&p.flags, "batches")?,
    })?;
    println!(
        "{} @ gamma={}: val_loss {:.4} val_acc {:.4} ({})",
        session.model(),
        report.gamma,
        report.loss,
        report.acc,
        report.provenance
    );
    Ok(())
}

/// `bdia generate`: autoregressive decoding on a GPT-family bundle —
/// tokens print as they land (same incremental KV-cache path `serve`'s
/// `/generate` endpoint batches).
fn cmd_generate(p: &Parsed) -> Result<()> {
    use std::io::Write;
    let trace = trace_out(p);
    let session = builder_from(p)?.build()?;
    warn_if_untrained(&session, "generating with");
    let prompt: Vec<i32> = match p.flags.get("prompt") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<i32>()
                    .with_context(|| format!("--prompt token '{}'", x.trim()))
            })
            .collect::<Result<_>>()?,
        None => vec![0],
    };
    let opts = bdia::api::GenOpts {
        max_tokens: flag_val::<usize>(&p.flags, "max-tokens")?.unwrap_or(32),
        temperature: flag_val::<f32>(&p.flags, "temperature")?.unwrap_or(0.0),
        top_k: flag_val::<usize>(&p.flags, "top-k")?.unwrap_or(0),
        seed: flag_val::<u64>(&p.flags, "seed")?.unwrap_or(0),
        eos: flag_val::<i32>(&p.flags, "eos")?,
        ..bdia::api::GenOpts::default()
    };
    print!("{} |", prompt.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" "));
    let _ = std::io::stdout().flush();
    let report = session.generate_stream(&prompt, &opts, |e| {
        print!(" {}", e.token);
        let _ = std::io::stdout().flush();
    })?;
    println!();
    println!(
        "generated {} token(s) in {:.1} ms prefill + {:.1} ms decode \
         ({:.1} tok/s, stop: {})",
        report.tokens.len(),
        report.prefill_ms,
        report.token_ms.iter().sum::<f64>(),
        report.tokens_per_s(),
        report.stop.name()
    );
    export_trace(trace.as_deref())
}

fn cmd_serve(p: &Parsed) -> Result<()> {
    let trace = trace_out(p);
    if p.flags.contains_key("replica") {
        cmd_serve_replica(p)?;
        return export_trace(trace.as_deref());
    }
    if let Some(n) = flag_val::<usize>(&p.flags, "replicas")? {
        cmd_serve_fleet(p, n)?;
        return export_trace(trace.as_deref());
    }
    if !p.flags.contains_key("ckpt") {
        eprintln!(
            "warning: no --ckpt given — serving FRESHLY-SEEDED (untrained) \
             parameters."
        );
    }
    let session = builder_from(p)?.build()?;
    let opts = ServeOpts {
        port: flag_val::<u16>(&p.flags, "port")?.unwrap_or(7878),
        workers: flag_val::<usize>(&p.flags, "workers")?.unwrap_or(4),
        batch_window: Duration::from_micros(
            flag_val::<u64>(&p.flags, "batch-window-us")?.unwrap_or(2000),
        ),
        queue_cap: flag_val::<usize>(&p.flags, "queue-cap")?.unwrap_or(1024),
    };
    let handle = session.serve(&opts)?;
    println!(
        "bdia serve: {} on http://{} ({} workers, batch window {:?})",
        session.model(),
        handle.addr(),
        opts.workers,
        opts.batch_window
    );
    println!(
        "endpoints: POST /infer  POST /generate (GPT, chunked streaming)  \
         GET /healthz  GET /stats  GET /metrics  POST /shutdown"
    );
    // the server owns its own runtime + a param clone; free the session's
    // training state (grads, optimizer moments) for the serve lifetime
    drop(session);
    handle.join()?;
    export_trace(trace.as_deref())
}

/// Eviction deadline / heartbeat base for the fleet backplane.
fn fleet_deadline(p: &Parsed) -> Result<Duration> {
    Ok(Duration::from_secs_f64(
        flag_val::<f64>(&p.flags, "fleet-timeout-s")?.unwrap_or(10.0),
    ))
}

/// `bdia serve --replica`: run one fleet replica that joins a router's
/// backplane.  This is the process `spawn_local_replicas` re-execs, and
/// the multi-host entry point (point --rendezvous at a remote router's
/// backplane).  Weights arrive over the wire, so no --ckpt here.
fn cmd_serve_replica(p: &Parsed) -> Result<()> {
    ensure!(
        !p.flags.contains_key("replicas"),
        "--replica (join a fleet) and --replicas (run a fleet) are \
         mutually exclusive"
    );
    let model = p
        .flags
        .get("model")
        .context("--replica requires --model <bundle>")?
        .clone();
    let rendezvous = p
        .flags
        .get("rendezvous")
        .context("--replica requires --rendezvous <router backplane host:port>")?
        .clone();
    let cfg = bdia::fleet::ReplicaConfig {
        model,
        backend: match p.flags.get("backend") {
            Some(s) => BackendKind::parse(s)?,
            None => BackendKind::default(),
        },
        artifacts_dir: p
            .flags
            .get("artifacts")
            .map_or_else(|| PathBuf::from("artifacts"), PathBuf::from),
        rendezvous,
        threads: flag_val::<usize>(&p.flags, "threads")?.unwrap_or(0),
        deadline: fleet_deadline(p)?,
        tune_profile: p.flags.get("tune-profile").map(PathBuf::from),
        ..bdia::fleet::ReplicaConfig::default()
    };
    bdia::fleet::replica::run(&cfg)
}

/// `bdia serve --replicas N`: run the fleet router and, unless
/// --rendezvous pins a backplane for externally launched replicas, spawn
/// N local replica processes against it.
fn cmd_serve_fleet(p: &Parsed, n: usize) -> Result<()> {
    ensure!(n >= 1, "--replicas must be >= 1");
    if !p.flags.contains_key("ckpt") {
        eprintln!(
            "warning: no --ckpt given — serving FRESHLY-SEEDED (untrained) \
             parameters."
        );
    }
    let session = builder_from(p)?.build()?;
    let opts = FleetOpts {
        port: flag_val::<u16>(&p.flags, "port")?.unwrap_or(7878),
        backplane: p.flags.get("rendezvous").cloned(),
        batch_window: Duration::from_micros(
            flag_val::<u64>(&p.flags, "batch-window-us")?.unwrap_or(2000),
        ),
        queue_cap: flag_val::<usize>(&p.flags, "queue-cap")?.unwrap_or(1024),
        deadline: fleet_deadline(p)?,
    };
    let handle = session.serve_fleet(&opts)?;
    let mut children = WorkerRanks::default();
    if p.flags.contains_key("rendezvous") {
        println!(
            "fleet: waiting for {n} external replicas to join backplane {} \
             (`bdia serve --replica --model {} --rendezvous {}`)",
            handle.backplane_addr(),
            session.model(),
            handle.backplane_addr()
        );
    } else {
        let cfg = session.config();
        let spawn = bdia::fleet::ReplicaSpawnOpts {
            model: cfg.model.clone(),
            backend: cfg.backend.name().to_string(),
            artifacts: cfg.artifacts_dir.clone(),
            threads: cfg.threads,
            fleet_timeout_s: opts.deadline.as_secs_f64(),
            tune_profile: p.flags.get("tune-profile").map(PathBuf::from),
        };
        children.0 =
            bdia::fleet::spawn_local_replicas(handle.backplane_addr(), n, &spawn)?;
        println!(
            "fleet: spawned {n} local replicas against backplane {}",
            handle.backplane_addr()
        );
    }
    handle.wait_ready(n, Duration::from_secs(120))?;
    println!(
        "fleet ready: {} on http://{} ({n} replicas live, window {:?}, \
         queue cap {})",
        session.model(),
        handle.addr(),
        opts.batch_window,
        opts.queue_cap
    );
    println!(
        "endpoints: POST /infer  GET /healthz  GET /stats  GET /metrics  \
         POST /shutdown"
    );
    drop(session);
    handle.join()?;
    reap_replicas(&mut children);
    Ok(())
}

/// Reap replica children tolerantly: after a graceful fleet shutdown every
/// replica exits on `FLEET_GOODBYE`, but a replica killed mid-run is the
/// failure mode the router absorbs by design — routine, not worth a
/// non-zero exit from the router process.
fn reap_replicas(children: &mut WorkerRanks) {
    for (i, mut child) in std::mem::take(&mut children.0).into_iter().enumerate()
    {
        match child.wait() {
            Ok(status) if !status.success() => {
                eprintln!("warning: replica {i} exited with {status}");
            }
            Err(e) => eprintln!("warning: reaping replica {i}: {e}"),
            Ok(_) => {}
        }
    }
}

/// Resolve `host:port` (hostnames included, e.g. `localhost:7878`) to a
/// socket address.
fn resolve_addr(s: &str) -> Result<std::net::SocketAddr> {
    use std::net::ToSocketAddrs;
    s.to_socket_addrs()
        .with_context(|| format!("--addr '{s}' must be host:port"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("--addr '{s}' resolved to no address"))
}

fn cmd_bench_serve(p: &Parsed) -> Result<()> {
    let session = builder_from(p)?.build()?;
    let defaults = ServeBenchOpts::default();
    let mut opts = ServeBenchOpts {
        requests: flag_val::<usize>(&p.flags, "requests")?
            .unwrap_or(defaults.requests),
        concurrency: flag_val::<usize>(&p.flags, "concurrency")?
            .unwrap_or(defaults.concurrency),
        workers: flag_val::<usize>(&p.flags, "workers")?.unwrap_or(defaults.workers),
        gamma: flag_val::<f32>(&p.flags, "gamma")?.unwrap_or(defaults.gamma),
        batch_window: flag_val::<u64>(&p.flags, "batch-window-us")?
            .map(Duration::from_micros)
            .unwrap_or(defaults.batch_window),
        addr: p.flags.get("addr").map(|a| resolve_addr(a)).transpose()?,
        verify: !p.flags.contains_key("no-verify"),
    };

    // --replicas N: self-host a fleet (router + N local replica processes)
    // and aim the load at its front door; responses must still be
    // bit-identical to direct local inference on the session's params
    let fleet = match flag_val::<usize>(&p.flags, "replicas")? {
        Some(n) => {
            ensure!(
                opts.addr.is_none(),
                "--replicas self-hosts a fleet; drop --addr"
            );
            let fopts = FleetOpts {
                port: 0,
                backplane: None,
                batch_window: opts.batch_window,
                queue_cap: flag_val::<usize>(&p.flags, "queue-cap")?
                    .unwrap_or(1024),
                deadline: fleet_deadline(p)?,
            };
            let handle = session.serve_fleet(&fopts)?;
            let cfg = session.config();
            let spawn = bdia::fleet::ReplicaSpawnOpts {
                model: cfg.model.clone(),
                backend: cfg.backend.name().to_string(),
                artifacts: cfg.artifacts_dir.clone(),
                threads: cfg.threads,
                fleet_timeout_s: fopts.deadline.as_secs_f64(),
                tune_profile: p.flags.get("tune-profile").map(PathBuf::from),
            };
            let mut children = WorkerRanks::default();
            children.0 = bdia::fleet::spawn_local_replicas(
                handle.backplane_addr(),
                n,
                &spawn,
            )?;
            handle.wait_ready(n, Duration::from_secs(120))?;
            println!(
                "bench-serve: fleet of {n} replicas behind http://{}",
                handle.addr()
            );
            opts.addr = Some(handle.addr());
            Some((handle, children))
        }
        None => None,
    };

    let summary = session.bench_serve(&opts);
    if let Some((handle, mut children)) = fleet {
        handle.stop();
        if let Err(e) = handle.join() {
            eprintln!("warning: fleet shutdown: {e}");
        }
        reap_replicas(&mut children);
    }
    let summary = summary?;
    ensure!(summary.errors == 0, "{} requests failed", summary.errors);
    ensure!(
        summary.mismatches == 0,
        "{} responses were NOT bit-identical to direct inference",
        summary.mismatches
    );
    Ok(())
}

fn cmd_bench(p: &Parsed) -> Result<()> {
    let quick = p.flags.contains_key("quick");
    let mut opts = bdia::bench::suite::SuiteOpts::new(quick);
    if let Some(f) = p.flags.get("families") {
        opts.families = f.split(',').map(str::to_string).collect();
    }
    if let Some(t) = flag_val::<usize>(&p.flags, "threads")? {
        opts.threads = t;
    }
    if let Some(o) = p.flags.get("out") {
        opts.out = PathBuf::from(o);
    }
    opts.tune_profile = p.flags.get("tune-profile").map(PathBuf::from);
    let report = bdia::api::bench_suite(&opts)?;
    ensure!(
        report.all_finite(),
        "bench produced non-finite timings — kernel regression?"
    );
    Ok(())
}

/// `bdia tune`: benchmark candidate kernel profiles on the live pool for
/// one bundle's hot-path shapes and persist the winner as JSON.  Any
/// profile is bit-exact by construction — tuning changes wall time only.
fn cmd_tune(p: &Parsed) -> Result<()> {
    let mut session = builder_from(p)?.build()?;
    let out = p.flags.get("out").map_or_else(
        || PathBuf::from(format!("{}_profile.json", session.model())),
        PathBuf::from,
    );
    let opts = bdia::api::TuneOpts { quick: p.flags.contains_key("quick"), out: Some(out) };
    let report = session.tune(&opts)?;
    println!(
        "tuned {} at {} threads: {} shapes ({} beyond the cap kept default \
         params)",
        report.model, report.threads, report.shapes_tuned, report.shapes_dropped
    );
    println!(
        "candidate sweep total: default {:.2} ms -> tuned {:.2} ms",
        report.default_ms, report.tuned_ms
    );
    if let Some(path) = &report.path {
        println!("profile '{}' written to {}", report.profile.id, path.display());
        println!("use it: bdia serve --model {} --tune-profile {}", report.model, path.display());
    }
    Ok(())
}

fn cmd_repro(p: &Parsed) -> Result<()> {
    let Some(id) = p.rest.first() else {
        bail!("usage: bdia repro <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all>")
    };
    let mut opts = if p.flags.contains_key("quick") {
        bdia::experiments::ExpOpts::quick()
    } else {
        bdia::experiments::ExpOpts::default()
    };
    if let Some(s) = flag_val::<usize>(&p.flags, "steps")? {
        opts.steps = s;
    }
    if let Some(s) = p.flags.get("seeds") {
        opts.seeds = s
            .split(',')
            .map(|x| x.parse().context("--seeds"))
            .collect::<Result<_>>()?;
    }
    if let Some(d) = p.flags.get("out") {
        opts.out_dir = PathBuf::from(d);
    }
    if let Some(d) = p.flags.get("artifacts") {
        opts.artifacts_dir = PathBuf::from(d);
    }
    println!(
        "repro {id}: steps={} seeds={:?} out={}",
        opts.steps,
        opts.seeds,
        opts.out_dir.display()
    );
    bdia::api::repro(id, &opts)?;
    Ok(())
}

fn cmd_info(p: &Parsed) -> Result<()> {
    let session = builder_from(p)?.build()?;
    let info = session.describe();
    println!(
        "bundle {} (family {}, backend {})",
        info.name, info.family, info.backend
    );
    // weight provenance incl. the γ-RNG base a resumed trainer would
    // continue from (pass --ckpt to inspect a checkpoint)
    println!(
        "  weights: {}; {}",
        session.provenance(),
        provenance_line(&session)
    );
    println!(
        "  kernels: threads={} (auto={}, workers spawned={}), workspace \
         hits={} misses={} keyed_hits={} keyed_builds={}",
        info.kernel_threads,
        info.kernel_auto_threads,
        info.kernel_spawned_workers,
        info.workspace_hits,
        info.workspace_misses,
        info.workspace_keyed_hits,
        info.workspace_keyed_builds
    );
    match &info.tune_profile_source {
        Some(s) => println!("  kernel profile: {} (from {})", info.tune_profile, s.display()),
        None => println!("  kernel profile: {}", info.tune_profile),
    }
    println!(
        "  dims: d_model={} heads={} K={} K_enc={} batch={} l={}",
        info.dims.d_model,
        info.dims.n_heads,
        info.dims.n_blocks,
        info.dims.n_enc_blocks,
        info.dims.batch,
        info.dims.lbits
    );
    println!("  params: {}", info.n_params);
    println!("  executables (calls this process):");
    for (name, calls) in &info.call_counts {
        println!("    {name}  calls={calls}");
    }
    for (mode, bytes) in &info.peak_memory {
        println!("  peak training memory [{mode}]: {}", fmt_bytes(*bytes));
    }
    Ok(())
}

/// `bdia trace`: merge per-rank `--trace-out` files onto rank 0's clock
/// (using each file's recorded clock offset) and optionally gate on
/// required span names — the CI check for "every rank traced every
/// phase".
fn cmd_trace(p: &Parsed) -> Result<()> {
    ensure!(
        !p.rest.is_empty(),
        "usage: bdia trace [--out merged.json] [--require fwd,bwd] \
         <trace.rank0.json> <trace.rank1.json> ..."
    );
    let mut texts = Vec::with_capacity(p.rest.len());
    for path in &p.rest {
        texts.push(
            std::fs::read_to_string(path)
                .with_context(|| format!("reading trace file {path}"))?,
        );
    }
    let merged = bdia::obs::trace::merge(&texts)?;
    if let Some(req) = p.flags.get("require") {
        let required: Vec<String> =
            req.split(',').map(|s| s.trim().to_string()).collect();
        bdia::obs::trace::require_spans(&merged, &required)?;
        println!(
            "required spans present on every rank: {}",
            required.join(", ")
        );
    }
    let out = p.flags.get("out").map_or("trace.merged.json", String::as_str);
    std::fs::write(out, &merged).with_context(|| format!("writing {out}"))?;
    println!("merged {} trace file(s) into {out}", p.rest.len());
    Ok(())
}

/// `bdia metrics-check`: validate a Prometheus text exposition (a file,
/// or stdin when no path is given) with the in-repo checker — no scraper
/// is available offline, so this is what CI points `curl /metrics` at.
fn cmd_metrics_check(p: &Parsed) -> Result<()> {
    ensure!(
        p.rest.len() <= 1,
        "metrics-check takes at most one exposition file"
    );
    let text = match p.rest.first() {
        Some(path) => std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?,
        None => {
            use std::io::Read as _;
            let mut s = String::new();
            std::io::stdin()
                .read_to_string(&mut s)
                .context("reading exposition from stdin")?;
            s
        }
    };
    let e = bdia::obs::prom::check(&text)?;
    println!("exposition OK: {} families, {} samples", e.families, e.samples);
    Ok(())
}

fn print_help() {
    let models = ModelId::known_names().join(", ");
    println!(
        "bdia — exact bit-level reversible transformer training (BDIA)\n\n\
         USAGE:\n  bdia train --config configs/<f>.json \
         [--backend native|pjrt] [--threads N] [--save-every K] \
         [--ckpt-dir D] [--resume <ckpt>] [--init-from <ckpt> \
         [--freeze-embed]] [--ranks N [--rank k \
         --rendezvous host:port] [--dist-timeout-s S] \
         [--on-rank-failure abort|restart]] [key=value ...]\n  \
         bdia eval  --model <bundle> --gamma <g> [--ckpt <file>]\n  \
         bdia generate --model <bundle> [--ckpt <file>] [--prompt 1,2,3] \
         [--max-tokens N] [--temperature T] [--top-k K] [--seed S] \
         [--eos E]\n  \
         bdia serve --model <bundle> --ckpt <file> [--port P] [--workers N] \
         [--threads N] [--batch-window-us U] [--queue-cap Q] \
         [--replicas N [--rendezvous host:port] [--fleet-timeout-s S]]\n  \
         bdia serve --replica --model <bundle> --rendezvous host:port \
         [--backend native|pjrt] [--threads N]\n  \
         bdia bench-serve --model <bundle> [--requests N] [--concurrency C] \
         [--workers N] [--gamma g] [--addr host:port] [--ckpt <file>] \
         [--replicas N] [--no-verify]\n  \
         bdia bench [--families a,b,c] [--threads N] [--quick] \
         [--out BENCH_10.json] [--tune-profile p.json]\n  \
         bdia tune  --model <bundle> [--threads N] [--quick] \
         [--out profile.json] [key=value ...]\n  \
         bdia repro <fig1|fig2|fig3|table1|table2|fig4|fig5|exact|all> \
         [--quick] [--steps N] [--seeds 0,1]\n  \
         bdia info  --model <bundle> [--backend native|pjrt]\n  \
         bdia trace [--out merged.json] [--require fwd,bwd,...] \
         <trace.rank0.json> <trace.rank1.json> ...\n  \
         bdia metrics-check [exposition.txt]\n\n\
         Models: {models}\n\
         (any exported AOT bundle directory under artifacts/ also works)\n\n\
         Flags accept --flag value and --flag=value; unknown flags error \
         with a closest-match hint.\n\n\
         Config keys (key=value overrides): model, backend (native|pjrt), \
         mode (bdia|bdia_float|vanilla|revvit), gamma_mag, dataset, steps, \
         lr, optimizer (adam|setadam), seed, eval_every, eval_batches, \
         train_examples, val_examples, artifacts_dir, save_every, ckpt_dir, \
         threads, ranks, grad_accum, dist_timeout_s, on_rank_failure, \
         init_from, freeze_embed\n\n\
         Threads: the native backend runs on a deterministic kernel pool \
         (row-partitioned parallelism only) — losses, gradients and served \
         bytes are bit-identical at any --threads value; 0 = auto.\n\
         Distributed: `train --ranks N` spawns N-1 local worker ranks and \
         rendezvouses on an ephemeral loopback port; with --rank k \
         --rendezvous host:port each rank is launched by hand (rank 0 \
         binds, workers connect).  Gradients all-reduce in a fixed rank \
         order, so losses/params are bit-identical at ANY world size \
         (grad_accum fixed); rank 0 owns eval, logs and checkpoints.  A \
         rank silent past --dist-timeout-s (heartbeats cover slow-but-alive \
         ranks) fails the world with an error naming it — no hang; \
         --on-rank-failure=restart rebuilds the world and resumes \
         bit-exactly from the last completed step.\n\
         Checkpoints: `train save_every=K` writes <run>-step<N>.ckpt + \
         <run>-latest.ckpt under ckpt_dir (versioned, CRC-checked, bit-exact \
         round trip); `eval --ckpt` / `serve --ckpt` load them.\n\
         Fine-tuning: `train --init-from <ckpt>` continues training from a \
         checkpoint (bit-identical to --resume; pair with a new seed= for a \
         fresh corpus split); --freeze-embed pins the embedding — zero \
         grads, skipped by the optimizer, excluded from the all-reduce \
         payload — still bit-exact at any --ranks.\n\
         Generation: `generate` decodes autoregressively on GPT bundles \
         with an incremental KV cache that is bit-identical to \
         re-forwarding the full prefix at any --threads and under any \
         --tune-profile; greedy by default, --temperature/--top-k/--seed \
         for seeded sampling (replays bit-exactly).  `serve` exposes the \
         same path as streaming POST /generate (chunked JSON lines), \
         batching concurrent sessions per decode step.\n\
         Serving: `serve` exposes POST /infer (binary example -> 8-byte \
         loss/correct), GET /healthz, GET /stats, POST /shutdown, with \
         dynamic micro-batching across concurrent requests; `bench-serve` \
         load-tests a server (self-hosted on an ephemeral port unless --addr \
         is given) and verifies responses are bit-identical to direct \
         inference.  Saturated queues answer 503 + Retry-After instead of \
         queueing unboundedly (--queue-cap, 0 = unbounded).\n\
         Fleet serving: `serve --replicas N` runs a router that fans \
         sticky γ-keyed micro-batches over N model replicas (spawned \
         locally, or joining from other hosts via `serve --replica \
         --rendezvous <backplane>`); replicas receive the router's exact \
         weights at join, a silent replica is evicted after \
         --fleet-timeout-s and its un-acked batches re-dispatched, and \
         responses stay bit-identical to single-process serving.  \
         `bench-serve --replicas N` proves that under load.\n\
         Benchmarks: `bench` times fwd/bwd/infer per model family at 1 and \
         N threads — plus a tuned-profile row per family, decode \
         tokens/sec rows for GPT bundles and an observability-overhead \
         block (step time with tracing off / metrics / full spans) — and \
         writes BENCH_10.json.\n\
         Observability: every server answers GET /metrics with Prometheus \
         text (validate offline with `bdia metrics-check`); train/serve/\
         generate take --trace-out <file> to export Chrome trace-event \
         JSON (open in a trace viewer); `bdia trace` merges per-rank files \
         onto rank 0's clock using offsets measured at rendezvous, and \
         --require fwd,bwd,... gates CI on span coverage.  Requests carry \
         an X-Request-Id (client-supplied or minted) echoed in responses, \
         error bodies and fleet replica spans.  Tracing and metrics never \
         feed timestamps into compute — bytes stay bit-identical with \
         observability fully enabled.\n\
         Tuning: `tune` benchmarks candidate kernel parameters (k-panel \
         size, task grain, inner-loop unroll, cached weight transpose) on \
         the live pool for one bundle's hot-path shapes and persists the \
         winner as a versioned JSON profile; train/eval/serve/bench-serve/\
         bench/info load it via --tune-profile.  ANY legal profile is \
         bit-exact by construction — tuning changes wall time, never \
         bytes.\n\n\
         Library use: everything above is a thin client of \
         bdia::api::Session — see rust/README.md \"Library use\".\n\
         The native backend is pure Rust and needs no artifacts; pjrt needs \
         the `pjrt` cargo feature plus `make artifacts`."
    );
}
