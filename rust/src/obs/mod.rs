//! # `bdia::obs` — metrics registry, span tracing, request correlation
//!
//! One observability substrate for every layer, provably non-interfering
//! with the bit-exact numerics:
//!
//! * [`metrics`] — lock-light counters/gauges/fixed-bucket histograms
//!   (atomic u64 cells).  [`serve`](crate::serve) and
//!   [`fleet`](crate::fleet) stats render both their legacy `/stats` JSON
//!   and the new `GET /metrics` Prometheus exposition *from* registries;
//!   the workspace-arena counters live in the process-wide
//!   [`metrics::global`] registry.
//! * [`mod@span`] — `obs::span!("train_step", step = s)` RAII scopes behind a
//!   single atomic level flag: off (default, near-zero cost), metrics-only
//!   (per-name duration histograms), or full spans (bounded ring +
//!   Chrome trace-event export via `--trace-out`).
//! * [`trace`] — merges per-rank trace files onto rank 0's clock using
//!   offsets exchanged over the rendezvous link (`bdia trace`).
//! * [`prom`] — the in-repo Prometheus text checker behind
//!   `bdia metrics-check` and the exposition tests.
//!
//! Correlation: [`fresh_request_id`] mints ids at the serving front door;
//! they are echoed in responses/error bodies and carried over the fleet
//! backplane so router and replica spans join up in a merged trace.
//!
//! Timestamps flow only into histogram cells and the span ring — never
//! into any compute path — so `tests/determinism.rs` and
//! `tests/dist_training.rs` run bit-exact with tracing fully enabled.

pub mod metrics;
pub mod prom;
pub mod span;
pub mod trace;

pub use metrics::{global, Counter, Gauge, Histogram, Registry};
pub use span::{
    chrome_trace_json, clock_offset_us, export_chrome_trace, level, now_us, rank,
    reset_trace, set_clock_offset_us, set_level, set_rank, snapshot, Span, SpanEvent,
    METRICS, OFF, SPANS,
};
// `obs::span!(…)` — the macro is exported at the crate root by
// `#[macro_export]`; re-export it under its natural path too.
pub use crate::span;

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

/// Open a named span over the enclosing scope:
///
/// ```
/// let _span = bdia::obs::span!("train_step", step = 7, phase = "fwd");
/// ```
///
/// Values render through `Display`; numeric values stay JSON numbers,
/// everything else becomes a JSON string.  Key/value arguments are only
/// evaluated at the full-tracing level.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::Span::enter($name, String::new)
    };
    ($name:expr, $($key:ident = $val:expr),+ $(,)?) => {
        $crate::obs::Span::enter($name, || {
            let mut args = String::new();
            $(
                if !args.is_empty() {
                    args.push_str(", ");
                }
                args.push('"');
                args.push_str(stringify!($key));
                args.push_str("\": ");
                args.push_str(&$crate::obs::json_scalar(&format!("{}", $val)));
            )+
            args
        })
    };
}

/// Mint a process-unique request id (used when the client did not supply
/// an `X-Request-Id` header).
pub fn fresh_request_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(1);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    format!("r{:x}-{seq:x}", now_us())
}

/// Render one span-macro argument as a JSON scalar: plain numbers pass
/// through, everything else is quoted with JSON string escaping.
pub fn json_scalar(s: &str) -> String {
    let numeric = s.parse::<f64>().map(f64::is_finite).unwrap_or(false)
        && s.bytes().next().is_some_and(|b| b == b'-' || b.is_ascii_digit());
    if numeric {
        return s.to_string();
    }
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_ids_are_unique_and_url_safe() {
        let a = fresh_request_id();
        let b = fresh_request_id();
        assert_ne!(a, b);
        for id in [&a, &b] {
            assert!(id.chars().all(|c| c.is_ascii_alphanumeric() || c == '-'), "{id}");
        }
    }
}
