//! Lock-light metrics core: counters, gauges and fixed-bucket histograms.
//!
//! Every cell is a relaxed `AtomicU64` behind an `Arc`, so recording on the
//! hot path is one `fetch_add` — no locks, no allocation.  A [`Registry`]
//! owns the name → metric table (a mutex-guarded map touched only at
//! registration and render time) and renders everything in Prometheus text
//! exposition format for `GET /metrics`.
//!
//! Histograms use fixed power-of-two bucket bounds (1 µs, 2 µs, …,
//! 2^27 µs ≈ 134 s, plus `+Inf`), so bucket boundaries are identical
//! across runs and processes by construction — merged dashboards can never
//! see skewed buckets.  Rendering computes the cumulative `le` series and
//! the `_count` line from the same cells, so `+Inf == count` holds even
//! while other threads are recording.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Number of finite histogram buckets; bucket `i` counts observations
/// `<= 2^i` (microseconds for the latency/span histograms in-tree).
pub const HISTOGRAM_BUCKETS: usize = 28;

/// Upper bound of finite bucket `i`: `2^i`.
pub fn bucket_bound(i: usize) -> u64 {
    1u64 << i
}

/// Smallest bucket whose bound covers `v` (the overflow cell for values
/// beyond the last finite bound).
fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        return 0;
    }
    let i = 64 - (v - 1).leading_zeros() as usize;
    i.min(HISTOGRAM_BUCKETS)
}

/// Monotonic counter handle; clones share the same cell.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge handle: a value that can move both ways; clones share the cell.
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Wrapping decrement (mirrors the `fetch_sub` the bespoke counters
    /// used before the registry).
    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistogramCells {
    /// One cell per finite bucket plus a final overflow (`+Inf`) cell.
    buckets: [AtomicU64; HISTOGRAM_BUCKETS + 1],
    sum: AtomicU64,
}

/// Fixed-bucket histogram handle; clones share the cells.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCells>);

impl Default for Histogram {
    fn default() -> Self {
        Histogram(Arc::new(HistogramCells {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }))
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&self, v: u64) {
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total observations (summed over the bucket cells).
    pub fn count(&self) -> u64 {
        self.0.buckets.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket counts (not cumulative): finite buckets then overflow.
    pub fn cells(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Entry {
    help: &'static str,
    metric: Metric,
}

/// A named collection of metrics rendered together.  Registration is
/// get-or-create: asking for an existing name returns a handle to the same
/// cells, so independent call sites can share a metric safely.
pub struct Registry {
    inner: Mutex<BTreeMap<String, Entry>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    /// Get or register a counter.  Panics if `name` is already registered
    /// as a different metric kind (a programming error, not input).
    pub fn counter(&self, name: &str, help: &'static str) -> Counter {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert_with(|| Entry {
            help,
            metric: Metric::Counter(Counter::default()),
        });
        match &e.metric {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Get or register a gauge (same sharing/panic rules as [`counter`]).
    ///
    /// [`counter`]: Registry::counter
    pub fn gauge(&self, name: &str, help: &'static str) -> Gauge {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert_with(|| Entry {
            help,
            metric: Metric::Gauge(Gauge::default()),
        });
        match &e.metric {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Get or register a histogram (same sharing/panic rules as
    /// [`counter`]).
    ///
    /// [`counter`]: Registry::counter
    pub fn histogram(&self, name: &str, help: &'static str) -> Histogram {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert_with(|| Entry {
            help,
            metric: Metric::Histogram(Histogram::default()),
        });
        match &e.metric {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Render every metric in Prometheus text exposition format.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    /// Append the exposition to `out` (lets `/metrics` concatenate the
    /// server registry with the process-wide one).
    pub fn render_into(&self, out: &mut String) {
        let m = self.inner.lock().unwrap();
        for (name, e) in m.iter() {
            let kind = match &e.metric {
                Metric::Counter(_) => "counter",
                Metric::Gauge(_) => "gauge",
                Metric::Histogram(_) => "histogram",
            };
            let _ = writeln!(out, "# HELP {name} {}", e.help);
            let _ = writeln!(out, "# TYPE {name} {kind}");
            match &e.metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{name} {}", g.get());
                }
                Metric::Histogram(h) => {
                    let cells = h.cells();
                    let mut acc = 0u64;
                    for (i, c) in cells.iter().take(HISTOGRAM_BUCKETS).enumerate() {
                        acc += c;
                        let _ = writeln!(
                            out,
                            "{name}_bucket{{le=\"{}\"}} {acc}",
                            bucket_bound(i)
                        );
                    }
                    acc += cells[HISTOGRAM_BUCKETS];
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {acc}");
                    let _ = writeln!(out, "{name}_sum {}", h.sum());
                    let _ = writeln!(out, "{name}_count {acc}");
                }
            }
        }
    }
}

/// The process-wide registry (workspace counters, span histograms, …).
/// Per-server registries exist separately so concurrent servers in one
/// process never share request counters.
pub fn global() -> &'static Registry {
    static G: OnceLock<Registry> = OnceLock::new();
    G.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 27), HISTOGRAM_BUCKETS - 1);
        assert_eq!(bucket_index((1 << 27) + 1), HISTOGRAM_BUCKETS);
        assert_eq!(bucket_index(u64::MAX), HISTOGRAM_BUCKETS);
    }

    #[test]
    fn histogram_render_satisfies_exposition_invariants() {
        let r = Registry::new();
        let h = r.histogram("t_us", "test histogram");
        for v in [0u64, 1, 2, 3, 100, 1 << 30] {
            h.observe(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 106 + (1 << 30));
        let text = r.render();
        crate::obs::prom::check(&text).expect("valid exposition");
        // cumulative +Inf bucket equals the _count line by construction
        assert!(text.contains("t_us_bucket{le=\"+Inf\"} 6"), "{text}");
        assert!(text.contains("t_us_count 6"), "{text}");
    }

    #[test]
    fn get_or_create_shares_the_cell() {
        let r = Registry::new();
        let a = r.counter("c_total", "test counter");
        let b = r.counter("c_total", "test counter");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g = r.gauge("g", "test gauge");
        g.set(5);
        g.inc();
        g.dec();
        assert_eq!(r.gauge("g", "test gauge").get(), 5);
    }

    #[test]
    fn bucket_bounds_stable_across_instances() {
        // fixed power-of-two bounds: two independently built histograms
        // render identical `le` label sequences regardless of the data
        let r1 = Registry::new();
        let r2 = Registry::new();
        r1.histogram("h_us", "test").observe(7);
        r2.histogram("h_us", "test").observe(9_000_000);
        let les = |t: &str| -> Vec<String> {
            t.lines()
                .filter(|l| l.starts_with("h_us_bucket"))
                .map(|l| l.split('"').nth(1).unwrap().to_string())
                .collect()
        };
        assert_eq!(les(&r1.render()), les(&r2.render()));
    }

    #[test]
    fn counters_and_gauges_render_as_single_samples() {
        let r = Registry::new();
        r.counter("reqs_total", "requests").add(7);
        r.gauge("active", "active sessions").set(2);
        let text = r.render();
        let e = crate::obs::prom::check(&text).expect("valid exposition");
        assert_eq!(e.families, 2);
        assert!(text.contains("# TYPE reqs_total counter"), "{text}");
        assert!(text.contains("reqs_total 7"), "{text}");
        assert!(text.contains("# TYPE active gauge"), "{text}");
        assert!(text.contains("active 2"), "{text}");
    }
}
