//! Span tracing: RAII scopes with monotonic timestamps, a bounded ring of
//! completed spans, and Chrome trace-event JSON export.
//!
//! The whole machinery sits behind one atomic level flag:
//!
//! * [`OFF`] (default) — `span!` is a single relaxed load; the clock is
//!   never read.
//! * [`METRICS`] — span durations feed per-name histograms
//!   (`bdia_span_us_<name>`) in the process-wide registry; nothing is
//!   retained per event.
//! * [`SPANS`] — durations plus full span events (name, timestamps,
//!   thread, args) land in a bounded ring for `--trace-out` export.
//!
//! Non-interference is by construction: timestamps flow only into
//! histogram cells and the ring — never into any compute path — so the
//! determinism suites pass bit-exact with tracing fully enabled.
//!
//! Span guards nest lexically per thread (the thread-local span stack is
//! the call stack itself); each thread gets a stable small `tid` so the
//! exported trace groups rows per thread, and the process's dist rank
//! becomes the Chrome `pid`, letting `bdia trace` merge per-rank files
//! onto one timeline.

use super::metrics::{global, Histogram};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Tracing disabled: `span!` costs one relaxed load, no clock reads.
pub const OFF: u8 = 0;
/// Span durations feed per-name histograms in the global registry.
pub const METRICS: u8 = 1;
/// Durations plus full span events in the bounded ring (trace export).
pub const SPANS: u8 = 2;

/// Ring capacity; the oldest events are dropped (and counted) beyond it.
const RING_CAP: usize = 1 << 16;

static LEVEL: AtomicU8 = AtomicU8::new(OFF);
static RANK: AtomicU64 = AtomicU64::new(0);
static CLOCK_OFFSET_US: AtomicI64 = AtomicI64::new(0);

/// Set the process-wide tracing level ([`OFF`]/[`METRICS`]/[`SPANS`]).
pub fn set_level(level: u8) {
    LEVEL.store(level.min(SPANS), Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// Microseconds since the process-wide monotonic epoch (first use).
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Tag exported traces with this process's dist rank (the Chrome `pid`).
pub fn set_rank(rank: u64) {
    RANK.store(rank, Ordering::Relaxed);
}

pub fn rank() -> u64 {
    RANK.load(Ordering::Relaxed)
}

/// Offset (µs) to add to local timestamps to land on rank 0's clock,
/// measured over the rendezvous link (`Collective::clock_sync`).
pub fn set_clock_offset_us(off: i64) {
    CLOCK_OFFSET_US.store(off, Ordering::Relaxed);
}

pub fn clock_offset_us() -> i64 {
    CLOCK_OFFSET_US.load(Ordering::Relaxed)
}

/// Stable small id for the current thread (trace row grouping).
fn tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// One completed span, as stored in the ring.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Start, µs on the local monotonic clock.
    pub ts_us: u64,
    pub dur_us: u64,
    /// Thread id (stable small integer per OS thread).
    pub tid: u64,
    /// Extra `"key": value` pairs, pre-rendered as a JSON fragment.
    pub args: Option<String>,
}

struct TraceState {
    ring: VecDeque<SpanEvent>,
    dropped: u64,
    /// Cached histogram handles so span end is one map lookup, not a
    /// registry registration.
    hists: BTreeMap<&'static str, Histogram>,
}

fn state() -> &'static Mutex<TraceState> {
    static S: OnceLock<Mutex<TraceState>> = OnceLock::new();
    S.get_or_init(|| {
        Mutex::new(TraceState {
            ring: VecDeque::new(),
            dropped: 0,
            hists: BTreeMap::new(),
        })
    })
}

/// RAII span guard: records its duration (and, at [`SPANS`], a ring
/// event) when dropped.  Construct through the [`crate::span!`] macro.
#[must_use = "a span measures the scope it lives in"]
pub struct Span {
    name: &'static str,
    t0: u64,
    args: Option<String>,
    level: u8,
}

impl Span {
    /// `args` renders lazily — and only at [`SPANS`] level — to a
    /// `"key": value, …` JSON-object fragment (possibly empty).
    pub fn enter(name: &'static str, args: impl FnOnce() -> String) -> Span {
        let level = level();
        if level == OFF {
            return Span { name, t0: 0, args: None, level };
        }
        let args = if level >= SPANS {
            let a = args();
            if a.is_empty() { None } else { Some(a) }
        } else {
            None
        };
        Span { name, t0: now_us(), args, level }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.level == OFF {
            return;
        }
        let dur = now_us().saturating_sub(self.t0);
        let mut st = state().lock().unwrap();
        let h = st.hists.entry(self.name).or_insert_with(|| {
            global().histogram(
                &format!("bdia_span_us_{}", self.name),
                "span duration in microseconds",
            )
        });
        h.observe(dur);
        if self.level >= SPANS {
            if st.ring.len() >= RING_CAP {
                st.ring.pop_front();
                st.dropped += 1;
            }
            st.ring.push_back(SpanEvent {
                name: self.name,
                ts_us: self.t0,
                dur_us: dur,
                tid: tid(),
                args: self.args.take(),
            });
        }
    }
}

/// Completed spans currently in the ring (oldest first) plus how many
/// events the bounded ring has dropped.
pub fn snapshot() -> (Vec<SpanEvent>, u64) {
    let st = state().lock().unwrap();
    (st.ring.iter().cloned().collect(), st.dropped)
}

/// Clear the ring (span histograms persist — they are registry metrics).
pub fn reset_trace() {
    let mut st = state().lock().unwrap();
    st.ring.clear();
    st.dropped = 0;
}

/// Render the ring as Chrome trace-event JSON (open in `chrome://tracing`
/// or Perfetto).  `metadata` carries the rank and the measured clock
/// offset so `bdia trace` can merge per-rank files onto one timeline.
pub fn chrome_trace_json() -> String {
    let (events, dropped) = snapshot();
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\"metadata\": {{\"rank\": {}, \"clock_offset_us\": {}, \
         \"dropped\": {dropped}}}, \"traceEvents\": [",
        rank(),
        clock_offset_us()
    );
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(
            out,
            "{{\"name\": \"{}\", \"cat\": \"bdia\", \"ph\": \"X\", \
             \"ts\": {}, \"dur\": {}, \"pid\": {}, \"tid\": {}, \
             \"args\": {{{}}}}}",
            e.name,
            e.ts_us,
            e.dur_us,
            rank(),
            e.tid,
            e.args.as_deref().unwrap_or("")
        );
    }
    out.push_str("]}");
    out
}

/// Write the Chrome trace to `path` (the CLI's `--trace-out`).
pub fn export_chrome_trace(path: &Path) -> Result<()> {
    std::fs::write(path, chrome_trace_json())
        .with_context(|| format!("writing trace to {}", path.display()))
}

/// Serialize tests that mutate the process-global tracing level.
#[cfg(test)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::Json;

    #[test]
    fn off_level_records_nothing() {
        let _l = test_lock();
        let prev = level();
        set_level(OFF);
        reset_trace();
        {
            let _s = crate::span!("obs_test_off");
        }
        let (events, _) = snapshot();
        assert!(events.iter().all(|e| e.name != "obs_test_off"));
        set_level(prev);
    }

    #[test]
    fn full_level_records_args_and_exports_valid_chrome_json() {
        let _l = test_lock();
        let prev = level();
        set_level(SPANS);
        {
            let _s = crate::span!("obs_test_span", step = 7, tag = "x y");
        }
        let (events, _) = snapshot();
        let ev = events.iter().rev().find(|e| e.name == "obs_test_span").expect("recorded");
        assert!(ev.tid >= 1);
        let args = ev.args.as_deref().unwrap();
        assert!(args.contains("\"step\": 7"), "{args}");
        assert!(args.contains("\"tag\": \"x y\""), "{args}");
        let doc = Json::parse(&chrome_trace_json()).expect("valid trace json");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<&str> =
            evs.iter().map(|e| e.get("name").unwrap().as_str().unwrap()).collect();
        assert!(names.contains(&"obs_test_span"), "{names:?}");
        let meta = doc.get("metadata").unwrap();
        assert!(meta.get("clock_offset_us").is_ok());
        set_level(prev);
    }

    #[test]
    fn metrics_level_feeds_histogram_without_ring_events() {
        let _l = test_lock();
        let prev = level();
        set_level(METRICS);
        reset_trace();
        {
            let _s = crate::span!("obs_test_metrics_only", n = 1);
        }
        let (events, _) = snapshot();
        assert!(events.iter().all(|e| e.name != "obs_test_metrics_only"));
        let name = "bdia_span_us_obs_test_metrics_only";
        let h = global().histogram(name, "span duration in microseconds");
        assert!(h.count() >= 1);
        set_level(prev);
    }

    #[test]
    fn clock_is_monotonic() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }

    #[test]
    fn json_scalar_quotes_non_numbers() {
        assert_eq!(crate::obs::json_scalar("42"), "42");
        assert_eq!(crate::obs::json_scalar("-1.5e3"), "-1.5e3");
        assert_eq!(crate::obs::json_scalar("+5"), "\"+5\"");
        assert_eq!(crate::obs::json_scalar("nan"), "\"nan\"");
        assert_eq!(crate::obs::json_scalar("a\"b\\c"), "\"a\\\"b\\\\c\"");
    }
}
