//! Merge per-rank Chrome trace files onto one timeline (`bdia trace`).
//!
//! Each `--trace-out` file carries `metadata.clock_offset_us`, the offset
//! measured over the rendezvous link that maps this rank's monotonic
//! clock onto rank 0's ([`crate::dist::Collective::clock_sync`]).  Merging
//! shifts every event by its file's offset, so spans that truly overlapped
//! in wall time (both ranks inside the same all-reduce) overlap in the
//! merged view.

use crate::config::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Merge per-rank Chrome trace JSON documents (as produced by
/// `--trace-out`) into one document whose timestamps are aligned to rank
/// 0's clock via each file's `metadata.clock_offset_us`.
pub fn merge(texts: &[String]) -> Result<String> {
    ensure!(!texts.is_empty(), "no trace files to merge");
    let mut events: Vec<(f64, Json)> = Vec::new();
    let mut ranks = BTreeSet::new();
    for (i, text) in texts.iter().enumerate() {
        let doc = Json::parse(text).with_context(|| format!("parsing trace file #{i}"))?;
        let meta = doc.get("metadata")?;
        let rank = meta.get("rank")?.as_usize()?;
        ensure!(ranks.insert(rank), "duplicate trace for rank {rank}");
        let offset = meta.get("clock_offset_us")?.as_i64()? as f64;
        for ev in doc.get("traceEvents")?.as_arr()? {
            let mut m = ev.as_obj()?.clone();
            let ts = ev.get("ts")?.as_f64()? + offset;
            m.insert("ts".to_string(), Json::Num(ts));
            events.push((ts, Json::Obj(m)));
        }
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let meta = BTreeMap::from([("ranks".to_string(), Json::Num(ranks.len() as f64))]);
    let doc = Json::Obj(BTreeMap::from([
        ("metadata".to_string(), Json::Obj(meta)),
        (
            "traceEvents".to_string(),
            Json::Arr(events.into_iter().map(|(_, e)| e).collect()),
        ),
    ]));
    Ok(doc.to_string())
}

/// Assert the merged trace has at least one span with each required name
/// for every `pid` (rank) present — the CI gate behind
/// `bdia trace --require fwd,bwd,…`.
pub fn require_spans(merged: &str, required: &[String]) -> Result<()> {
    let doc = Json::parse(merged).context("parsing merged trace")?;
    let events = doc.get("traceEvents")?.as_arr()?;
    ensure!(!events.is_empty(), "merged trace has no events");
    let mut seen: BTreeMap<usize, BTreeSet<&str>> = BTreeMap::new();
    for ev in events {
        let pid = ev.get("pid")?.as_usize()?;
        let name = ev.get("name")?.as_str()?;
        seen.entry(pid).or_default().insert(name);
    }
    for (pid, names) in &seen {
        for want in required {
            if !names.contains(want.as_str()) {
                bail!("rank {pid}: no '{want}' span in the merged trace");
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rank_file(rank: usize, offset_us: i64, name: &str, ts: u64, dur: u64) -> String {
        format!(
            "{{\"metadata\": {{\"rank\": {rank}, \"clock_offset_us\": {offset_us}, \
             \"dropped\": 0}}, \"traceEvents\": [{{\"name\": \"{name}\", \
             \"cat\": \"bdia\", \"ph\": \"X\", \"ts\": {ts}, \"dur\": {dur}, \
             \"pid\": {rank}, \"tid\": 1, \"args\": {{\"step\": 0}}}}]}}"
        )
    }

    #[test]
    fn merge_aligns_timestamps_so_true_overlaps_survive() {
        // rank 1's clock started 1000 µs *after* rank 0's: a span at local
        // ts 200 on rank 1 really began at 1200 on rank 0's clock.  Both
        // ranks sat in the same all-reduce over [1200, 1500] wall time.
        let r0 = rank_file(0, 0, "all_reduce", 1150, 400);
        let r1 = rank_file(1, 1000, "all_reduce", 200, 300);
        let merged = merge(&[r0, r1]).unwrap();
        let doc = Json::parse(&merged).unwrap();
        assert_eq!(doc.get("metadata").unwrap().get("ranks").unwrap().as_usize().unwrap(), 2);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        // events come out sorted by aligned start time
        let (s0, d0) = span_of(&evs[0]);
        let (s1, d1) = span_of(&evs[1]);
        assert!(s0 <= s1);
        // aligned intervals [1150, 1550] and [1200, 1500] overlap
        assert!(s1 < s0 + d0 && s0 < s1 + d1, "spans must overlap after alignment");
    }

    fn span_of(ev: &Json) -> (f64, f64) {
        (ev.get("ts").unwrap().as_f64().unwrap(), ev.get("dur").unwrap().as_f64().unwrap())
    }

    #[test]
    fn require_spans_checks_every_rank() {
        let r0 = rank_file(0, 0, "fwd", 10, 5);
        let r1 = rank_file(1, 0, "bwd", 10, 5);
        let merged = merge(&[r0, r1]).unwrap();
        assert!(require_spans(&merged, &["fwd".to_string()]).is_err());
        assert!(require_spans(&merged, &[]).is_ok());
        let a = rank_file(0, 0, "fwd", 10, 5);
        let b = rank_file(1, -3, "fwd", 20, 5);
        let both = merge(&[a, b]).unwrap();
        assert!(require_spans(&both, &["fwd".to_string()]).is_ok());
        assert!(require_spans(&both, &["nope".to_string()]).is_err());
    }

    #[test]
    fn merge_rejects_duplicate_ranks_and_garbage() {
        let r0 = rank_file(0, 0, "fwd", 10, 5);
        assert!(merge(&[r0.clone(), r0]).is_err());
        assert!(merge(&["not json".to_string()]).is_err());
        assert!(merge(&[]).is_err());
    }
}
