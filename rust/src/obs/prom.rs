//! Tiny Prometheus text-exposition checker.
//!
//! No scraper is available offline, so tests and CI validate `/metrics`
//! output with this in-repo checker (`bdia metrics-check`): every sample
//! needs a preceding `# TYPE`, every typed family a `# HELP`, and
//! histograms must render a non-decreasing cumulative bucket series whose
//! final `+Inf` bucket equals the `_count` line.

use anyhow::{bail, ensure, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Summary returned by [`check`].
#[derive(Clone, Copy, Debug)]
pub struct Exposition {
    /// `# TYPE`-declared metric families.
    pub families: usize,
    /// Sample lines.
    pub samples: usize,
}

fn valid_name(s: &str) -> bool {
    let head = s.bytes().next().is_some_and(|b| b.is_ascii_alphabetic() || b == b'_');
    head && s.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b':')
}

#[derive(Default)]
struct HistAcc {
    /// `(le, cumulative count)` in order of appearance.
    buckets: Vec<(String, f64)>,
    sum: bool,
    count: Option<f64>,
}

/// Validate a Prometheus text exposition document.
pub fn check(text: &str) -> Result<Exposition> {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut helps: BTreeSet<String> = BTreeSet::new();
    let mut hists: BTreeMap<String, HistAcc> = BTreeMap::new();
    let mut samples = 0usize;
    for (i, raw) in text.lines().enumerate() {
        let n = i + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = match rest.split_once(' ') {
                Some(p) => p,
                None => bail!("line {n}: HELP without text"),
            };
            ensure!(valid_name(name), "line {n}: bad metric name '{name}'");
            ensure!(!help.is_empty(), "line {n}: empty HELP text");
            helps.insert(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = match rest.split_once(' ') {
                Some(p) => p,
                None => bail!("line {n}: TYPE without kind"),
            };
            ensure!(valid_name(name), "line {n}: bad metric name '{name}'");
            ensure!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "line {n}: unknown metric type '{kind}'"
            );
            let prev = types.insert(name.to_string(), kind.to_string());
            ensure!(prev.is_none(), "line {n}: duplicate # TYPE for '{name}'");
            continue;
        }
        if line.starts_with('#') {
            continue; // other comments are legal
        }
        // sample line: `name value` or `name{labels} value`
        let (series, rest) = match line.find('{') {
            Some(b) => {
                let close = match line[b..].find('}') {
                    Some(c) => b + c,
                    None => bail!("line {n}: unclosed label set"),
                };
                (&line[..close + 1], &line[close + 1..])
            }
            None => match line.find(' ') {
                Some(sp) => (&line[..sp], &line[sp..]),
                None => bail!("line {n}: sample without value"),
            },
        };
        let value_str = match rest.split_whitespace().next() {
            Some(v) => v,
            None => bail!("line {n}: sample without value"),
        };
        let value: f64 = match value_str.parse() {
            Ok(v) => v,
            Err(_) => bail!("line {n}: bad sample value '{value_str}'"),
        };
        let (name, labels) = match series.split_once('{') {
            Some((nm, rest)) => (nm, Some(rest.trim_end_matches('}'))),
            None => (series, None),
        };
        ensure!(valid_name(name), "line {n}: bad metric name '{name}'");
        samples += 1;
        let family = if types.contains_key(name) {
            name.to_string()
        } else {
            let mut found = None;
            for suffix in ["_bucket", "_sum", "_count"] {
                if let Some(base) = name.strip_suffix(suffix) {
                    if types.get(base).map(String::as_str) == Some("histogram") {
                        found = Some(base.to_string());
                        break;
                    }
                }
            }
            match found {
                Some(f) => f,
                None => bail!("line {n}: sample '{name}' has no preceding # TYPE"),
            }
        };
        if types.get(&family).map(String::as_str) == Some("histogram") {
            let acc = hists.entry(family.clone()).or_default();
            if name.ends_with("_bucket") {
                let le = labels
                    .and_then(|l| l.split("le=\"").nth(1))
                    .and_then(|r| r.split('"').next());
                match le {
                    Some(le) => acc.buckets.push((le.to_string(), value)),
                    None => bail!("line {n}: histogram bucket without le label"),
                }
            } else if name.ends_with("_sum") {
                acc.sum = true;
            } else if name.ends_with("_count") {
                acc.count = Some(value);
            } else {
                bail!("line {n}: bare sample for histogram family '{family}'");
            }
        }
    }
    for (name, kind) in &types {
        ensure!(helps.contains(name), "metric '{name}' has # TYPE but no # HELP");
        if kind != "histogram" {
            continue;
        }
        let acc = match hists.get(name) {
            Some(a) => a,
            None => bail!("histogram '{name}' has no samples"),
        };
        ensure!(!acc.buckets.is_empty(), "histogram '{name}' has no buckets");
        let mut prev = -1.0f64;
        for (le, v) in &acc.buckets {
            ensure!(
                *v >= prev,
                "histogram '{name}': cumulative bucket le=\"{le}\" decreases"
            );
            prev = *v;
        }
        let (last_le, last_v) = acc.buckets.last().unwrap();
        ensure!(
            last_le == "+Inf",
            "histogram '{name}': last bucket is le=\"{last_le}\", not +Inf"
        );
        let count = match acc.count {
            Some(c) => c,
            None => bail!("histogram '{name}' missing _count"),
        };
        ensure!(
            (*last_v - count).abs() < 0.5,
            "histogram '{name}': +Inf bucket {last_v} != count {count}"
        );
        ensure!(acc.sum, "histogram '{name}' missing _sum");
    }
    ensure!(samples > 0, "exposition has no samples");
    Ok(Exposition { families: types.len(), samples })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_well_formed_exposition() {
        let text = "# HELP reqs_total requests\n# TYPE reqs_total counter\n\
                    reqs_total 5\n\
                    # HELP lat_us latency\n# TYPE lat_us histogram\n\
                    lat_us_bucket{le=\"1\"} 1\nlat_us_bucket{le=\"2\"} 3\n\
                    lat_us_bucket{le=\"+Inf\"} 4\nlat_us_sum 9\nlat_us_count 4\n\
                    # HELP calls_total calls\n# TYPE calls_total counter\n\
                    calls_total{exec=\"block_fwd\"} 2\n";
        let e = check(text).unwrap();
        assert_eq!(e.families, 3);
        assert_eq!(e.samples, 7);
    }

    #[test]
    fn rejects_sample_without_type() {
        assert!(check("orphan 1\n").is_err());
    }

    #[test]
    fn rejects_type_without_help() {
        assert!(check("# TYPE x counter\nx 1\n").is_err());
    }

    #[test]
    fn rejects_decreasing_buckets() {
        let text = "# HELP h x\n# TYPE h histogram\n\
                    h_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\n\
                    h_bucket{le=\"+Inf\"} 5\nh_sum 1\nh_count 5\n";
        assert!(check(text).is_err());
    }

    #[test]
    fn rejects_missing_inf_and_count_mismatch() {
        let no_inf = "# HELP h x\n# TYPE h histogram\n\
                      h_bucket{le=\"1\"} 1\nh_sum 1\nh_count 1\n";
        assert!(check(no_inf).is_err());
        let mismatch = "# HELP h x\n# TYPE h histogram\n\
                        h_bucket{le=\"1\"} 1\nh_bucket{le=\"+Inf\"} 1\n\
                        h_sum 1\nh_count 2\n";
        assert!(check(mismatch).is_err());
    }

    #[test]
    fn rejects_bad_names_and_values() {
        assert!(check("# HELP 9bad x\n# TYPE 9bad counter\n9bad 1\n").is_err());
        assert!(check("# HELP x y\n# TYPE x counter\nx one\n").is_err());
        assert!(check("# HELP x y\n# TYPE x pie\nx 1\n").is_err());
        assert!(check("").is_err());
    }
}
