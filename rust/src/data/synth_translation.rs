//! Synthetic transduction grammar (en→fr stand-in, Fig. 4).
//!
//! Source: random content tokens.  Target: the source mapped through a fixed
//! token permutation, locally reordered in blocks of three (swap the first
//! two of every triple — a caricature of adjective-noun inversion), with an
//! "agreement" suffix token appended that depends on the *first* source
//! token (a long-range dependency that forces use of cross-attention).
//! Decoder input is the BOS-shifted target (teacher forcing).

use super::{Batch, Dataset};
use crate::model::{Dims, Family};
use crate::tensor::{IntTensor, Rng};

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
const RESERVED: usize = 4;

pub struct SynthTranslation {
    dims: Dims,
    seed: u64,
    /// fixed "vocabulary mapping" permutation over content tokens
    perm: Vec<i32>,
    train_examples: usize,
    val_examples: usize,
}

impl SynthTranslation {
    pub fn new(dims: Dims, seed: u64, train_examples: usize, val_examples: usize) -> Self {
        let content = dims.vocab - RESERVED;
        let mut rng = Rng::new(seed ^ 0x7ae_57a7e);
        let perm: Vec<i32> = rng
            .permutation(content)
            .into_iter()
            .map(|p| (p + RESERVED) as i32)
            .collect();
        SynthTranslation { dims, seed, perm, train_examples, val_examples }
    }

    /// The deterministic "translation" of a source sentence.
    pub fn translate(&self, src: &[i32]) -> Vec<i32> {
        let mut tgt: Vec<i32> = src
            .iter()
            .map(|&t| self.perm[(t as usize) - RESERVED])
            .collect();
        // local reorder: swap positions (3i, 3i+1)
        let mut i = 0;
        while i + 1 < tgt.len() {
            tgt.swap(i, i + 1);
            i += 3;
        }
        // agreement suffix: depends on the first source token (long-range)
        let agree = RESERVED as i32
            + ((src[0] as usize - RESERVED) % (self.dims.vocab - RESERVED)) as i32;
        let n = tgt.len();
        tgt[n - 1] = agree;
        tgt
    }

    fn example(&self, split: u64, index: usize) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
        let ts = self.dims.seq_src;
        let tt = self.dims.seq;
        let content = self.dims.vocab - RESERVED;
        let mut rng = Rng::new(
            self.seed
                ^ split.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (index as u64).wrapping_mul(0xD134_2543_DE82_EF95),
        );
        let src: Vec<i32> = (0..ts)
            .map(|_| (rng.below(content) + RESERVED) as i32)
            .collect();
        let mut tgt = self.translate(&src);
        tgt.truncate(tt);
        while tgt.len() < tt {
            tgt.push(EOS);
        }
        let mut tgt_in = Vec::with_capacity(tt);
        tgt_in.push(BOS);
        tgt_in.extend_from_slice(&tgt[..tt - 1]);
        (src, tgt_in, tgt)
    }

    fn batch(&self, split: u64, base: usize, n_examples: usize) -> Batch {
        let b = self.dims.batch;
        let (ts, tt) = (self.dims.seq_src, self.dims.seq);
        let mut src = Vec::with_capacity(b * ts);
        let mut tgt_in = Vec::with_capacity(b * tt);
        let mut labels = Vec::with_capacity(b * tt);
        for i in 0..b {
            let (s, ti, l) = self.example(split, (base + i) % n_examples.max(1));
            src.extend_from_slice(&s);
            tgt_in.extend_from_slice(&ti);
            labels.extend_from_slice(&l);
        }
        Batch::Seq2Seq {
            src: IntTensor::from_vec(&[b, ts], src).expect("src"),
            tgt_in: IntTensor::from_vec(&[b, tt], tgt_in).expect("tgt_in"),
            labels: IntTensor::from_vec(&[b, tt], labels).expect("labels"),
        }
    }
}

impl Dataset for SynthTranslation {
    fn family(&self) -> Family {
        Family::EncDec
    }

    fn train_batch(&self, step: usize) -> Batch {
        self.batch(0, step * self.dims.batch, self.train_examples)
    }

    fn val_batch(&self, idx: usize) -> Batch {
        self.batch(1, idx * self.dims.batch, self.val_examples)
    }

    fn n_val_batches(&self) -> usize {
        (self.val_examples / self.dims.batch).max(1)
    }

    fn name(&self) -> &str {
        "synth_translation"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims {
            d_model: 16,
            n_heads: 2,
            n_blocks: 2,
            n_enc_blocks: 2,
            mlp_ratio: 2,
            batch: 4,
            lbits: 9,
            image_size: 0,
            patch: 1,
            channels: 0,
            n_classes: 0,
            seq: 12,
            seq_src: 12,
            vocab: 32,
        }
    }

    #[test]
    fn translation_is_deterministic_function() {
        let d = SynthTranslation::new(dims(), 5, 64, 16);
        let src = vec![4, 5, 6, 7, 8, 9];
        assert_eq!(d.translate(&src), d.translate(&src));
        // permutation actually remaps
        let t = d.translate(&src);
        assert_ne!(t[..3], src[..3]);
    }

    #[test]
    fn teacher_forcing_layout() {
        let d = SynthTranslation::new(dims(), 5, 64, 16);
        let Batch::Seq2Seq { tgt_in, labels, .. } = d.train_batch(0) else {
            panic!()
        };
        for b in 0..4 {
            assert_eq!(tgt_in.data()[b * 12], BOS);
            for j in 0..11 {
                assert_eq!(tgt_in.data()[b * 12 + j + 1], labels.data()[b * 12 + j]);
            }
        }
    }

    #[test]
    fn tokens_in_range() {
        let d = SynthTranslation::new(dims(), 5, 64, 16);
        let Batch::Seq2Seq { src, tgt_in, labels } = d.val_batch(1) else {
            panic!()
        };
        for t in src.data().iter().chain(tgt_in.data()).chain(labels.data()) {
            assert!((0..32).contains(t));
        }
    }

    #[test]
    fn agreement_token_depends_on_first_source() {
        let d = SynthTranslation::new(dims(), 5, 64, 16);
        let a = d.translate(&[4, 5, 6, 7, 8, 9]);
        let b = d.translate(&[5, 5, 6, 7, 8, 9]);
        assert_ne!(a.last(), b.last(), "suffix must track src[0]");
    }

    #[test]
    fn deterministic_batches() {
        let d1 = SynthTranslation::new(dims(), 5, 64, 16);
        let d2 = SynthTranslation::new(dims(), 5, 64, 16);
        let (Batch::Seq2Seq { src: a, .. }, Batch::Seq2Seq { src: b, .. }) =
            (d1.train_batch(2), d2.train_batch(2))
        else {
            panic!()
        };
        assert_eq!(a, b);
    }
}
