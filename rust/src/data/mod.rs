//! Synthetic dataset substrates (DESIGN.md §5 substitutions).
//!
//! The paper's experiments use CIFAR10/100, an en→fr corpus, and a 0.05%
//! openwebtext subset — none downloadable in this offline environment.  Each
//! is replaced by a deterministic synthetic generator that exercises the
//! identical code path and failure mode:
//!
//! * [`synth_image`]  — class-conditional low-frequency texture images
//!   (the CIFAR10/100 stand-in for Fig. 1/3, Tables 1/2),
//! * [`synth_translation`] — a token-transduction grammar (en→fr stand-in,
//!   Fig. 4, exercises the encoder-decoder + cross-attention path),
//! * [`tiny_corpus`] — a small Markov English-like character corpus
//!   (openwebtext-subset stand-in, Fig. 5's overfitting study).
//!
//! Everything is reproducible from `(seed, index)` — no files, no state.

pub mod prefetch;
pub mod synth_image;
pub mod synth_translation;
pub mod tiny_corpus;

use crate::config::TrainConfig;
use crate::model::{Dims, Family};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{bail, Result};

/// One training/eval batch, shaped for the model family.
#[derive(Clone, Debug)]
pub enum Batch {
    Image { images: Tensor, labels: IntTensor },
    Lm { tokens: IntTensor, labels: IntTensor },
    Seq2Seq { src: IntTensor, tgt_in: IntTensor, labels: IntTensor },
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        match self {
            Batch::Image { labels, .. } => labels.shape()[0],
            Batch::Lm { tokens, .. } => tokens.shape()[0],
            Batch::Seq2Seq { src, .. } => src.shape()[0],
        }
    }

    /// Number of classification decisions (accuracy denominator).
    pub fn n_predictions(&self) -> usize {
        match self {
            Batch::Image { labels, .. } => labels.len(),
            Batch::Lm { labels, .. } => labels.len(),
            Batch::Seq2Seq { labels, .. } => labels.len(),
        }
    }
}

/// A deterministic dataset: batches are pure functions of (split, index).
pub trait Dataset: Send + Sync {
    fn family(&self) -> Family;
    /// Training batch for a global step (fresh randomness per step).
    fn train_batch(&self, step: usize) -> Batch;
    /// Fixed held-out batch `idx in [0, n_val_batches)`.
    fn val_batch(&self, idx: usize) -> Batch;
    fn n_val_batches(&self) -> usize;
    fn name(&self) -> &str;
}

/// Instantiate a dataset by config name, shaped by the model dims.
pub fn make_dataset(
    cfg: &TrainConfig,
    dims: &Dims,
    family: Family,
) -> Result<Box<dyn Dataset>> {
    let d: Box<dyn Dataset> = match cfg.dataset.as_str() {
        "synth_cifar10" | "synth_cifar100" | "synth_image" => {
            if family != Family::Vit {
                bail!("dataset '{}' needs a vit model", cfg.dataset);
            }
            Box::new(synth_image::SynthImage::new(
                dims.clone(),
                cfg.seed,
                cfg.train_examples,
                cfg.val_examples,
            ))
        }
        "tiny_corpus" => {
            if family != Family::Gpt {
                bail!("dataset '{}' needs a gpt model", cfg.dataset);
            }
            Box::new(tiny_corpus::TinyCorpus::new(
                dims.clone(),
                cfg.seed,
                cfg.train_examples,
                cfg.val_examples,
            ))
        }
        "synth_translation" => {
            if family != Family::EncDec {
                bail!("dataset '{}' needs an encdec model", cfg.dataset);
            }
            Box::new(synth_translation::SynthTranslation::new(
                dims.clone(),
                cfg.seed,
                cfg.train_examples,
                cfg.val_examples,
            ))
        }
        other => bail!(
            "unknown dataset '{other}' \
             (synth_cifar10|synth_cifar100|tiny_corpus|synth_translation)"
        ),
    };
    Ok(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims {
            d_model: 16,
            n_heads: 2,
            n_blocks: 2,
            n_enc_blocks: 2,
            mlp_ratio: 2,
            batch: 4,
            lbits: 9,
            image_size: 8,
            patch: 4,
            channels: 3,
            n_classes: 4,
            seq: 8,
            seq_src: 8,
            vocab: 16,
        }
    }

    #[test]
    fn dispatch_checks_family() {
        let cfg = TrainConfig { dataset: "synth_cifar10".into(), ..Default::default() };
        assert!(make_dataset(&cfg, &dims(), Family::Vit).is_ok());
        assert!(make_dataset(&cfg, &dims(), Family::Gpt).is_err());
        let cfg = TrainConfig { dataset: "tiny_corpus".into(), ..Default::default() };
        assert!(make_dataset(&cfg, &dims(), Family::Gpt).is_ok());
        let cfg = TrainConfig { dataset: "bogus".into(), ..Default::default() };
        assert!(make_dataset(&cfg, &dims(), Family::Gpt).is_err());
    }
}
