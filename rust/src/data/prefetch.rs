//! Background batch prefetcher: overlaps synthetic-data generation with the
//! training step on a worker thread (bounded channel = backpressure).
//!
//! Datasets are pure functions of the step index, so the prefetcher is
//! trivially correct: it just computes `train_batch(step)` for steps
//! `0..total` ahead of the consumer.

use super::{Batch, Dataset};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct Prefetcher {
    rx: mpsc::Receiver<(usize, Batch)>,
    handle: Option<JoinHandle<()>>,
    next: usize,
}

impl Prefetcher {
    /// Spawn a worker producing batches for steps `0..total` with a bounded
    /// queue of `depth`.
    pub fn new(dataset: Arc<dyn Dataset>, total: usize, depth: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let handle = std::thread::Builder::new()
            .name("bdia-prefetch".into())
            .spawn(move || {
                for step in 0..total {
                    let b = dataset.train_batch(step);
                    if tx.send((step, b)).is_err() {
                        return; // consumer dropped early
                    }
                }
            })
            .expect("spawn prefetcher");
        Prefetcher { rx, handle: Some(handle), next: 0 }
    }

    /// Blocking fetch of the next step's batch (in order).
    pub fn next_batch(&mut self) -> Option<Batch> {
        match self.rx.recv() {
            Ok((step, b)) => {
                debug_assert_eq!(step, self.next);
                self.next += 1;
                Some(b)
            }
            Err(_) => None,
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // close the channel, then join the worker
        let Prefetcher { rx, handle, .. } = self;
        // draining receiver by replacing is unnecessary: dropping self.rx
        // happens after this body; detach by joining once sender errors out.
        let _ = rx;
        if let Some(h) = handle.take() {
            // unblock the worker if it is waiting on a full channel: the
            // receiver half drops right after this scope, erroring its send.
            // We only join if it already finished to avoid a deadlock.
            if h.is_finished() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_image::SynthImage;
    use crate::model::Dims;

    fn dataset() -> Arc<dyn Dataset> {
        Arc::new(SynthImage::new(
            Dims {
                d_model: 16,
                n_heads: 2,
                n_blocks: 2,
                n_enc_blocks: 0,
                mlp_ratio: 2,
                batch: 2,
                lbits: 9,
                image_size: 8,
                patch: 4,
                channels: 3,
                n_classes: 4,
                seq: 0,
                seq_src: 0,
                vocab: 0,
            },
            9,
            32,
            16,
        ))
    }

    #[test]
    fn yields_all_batches_in_order() {
        let ds = dataset();
        let mut pf = Prefetcher::new(ds.clone(), 5, 2);
        for step in 0..5 {
            let got = pf.next_batch().expect("batch");
            let want = ds.train_batch(step);
            let (Batch::Image { images: a, .. }, Batch::Image { images: b, .. }) =
                (got, want)
            else {
                panic!()
            };
            assert_eq!(a, b, "step {step}");
        }
        assert!(pf.next_batch().is_none(), "exhausted");
    }

    #[test]
    fn early_drop_does_not_hang() {
        let ds = dataset();
        let mut pf = Prefetcher::new(ds, 1000, 1);
        let _ = pf.next_batch();
        drop(pf); // worker blocked on full channel must exit cleanly
    }
}
