//! Deterministic tiny character corpus (openwebtext-0.05% stand-in, Fig. 5).
//!
//! A second-order Markov chain over a synthetic English-like lexicon emits a
//! ~200 KB text; character-level tokens index into a 96-symbol vocabulary
//! (printable ASCII).  The paper's §5.3 point — GPT2 badly overfits a very
//! small corpus while BDIA-GPT2 overfits less — only needs a corpus that is
//! (a) small and (b) has learnable nontrivial statistics; a Markov text has
//! both, with the bonus that the achievable cross-entropy floor is roughly
//! the chain's entropy rate.

use super::{Batch, Dataset};
use crate::model::{Dims, Family};
use crate::tensor::{IntTensor, Rng};

const CORPUS_CHARS: usize = 200_000;
const LEXICON: usize = 120;

pub struct TinyCorpus {
    dims: Dims,
    corpus: Vec<i32>,
    /// [0, train_end) is the training region; [train_end, len) validation.
    train_end: usize,
    seed: u64,
    train_examples: usize,
    val_examples: usize,
}

fn synth_lexicon(rng: &mut Rng) -> Vec<String> {
    const ONSETS: &[&str] = &[
        "b", "c", "d", "f", "g", "h", "l", "m", "n", "p", "r", "s", "t", "v",
        "st", "tr", "ch", "th", "qu", "",
    ];
    const NUCLEI: &[&str] = &["a", "e", "i", "o", "u", "ai", "ou", "ea"];
    const CODAS: &[&str] = &["", "n", "r", "s", "t", "l", "nd", "st", "m"];
    let mut words = Vec::with_capacity(LEXICON);
    while words.len() < LEXICON {
        let syllables = 1 + rng.below(3);
        let mut w = String::new();
        for _ in 0..syllables {
            w.push_str(ONSETS[rng.below(ONSETS.len())]);
            w.push_str(NUCLEI[rng.below(NUCLEI.len())]);
            w.push_str(CODAS[rng.below(CODAS.len())]);
        }
        if !words.contains(&w) {
            words.push(w);
        }
    }
    words
}

/// Map a char into the 96-symbol vocab (printable ASCII 32..=126 + newline).
fn char_token(c: char, vocab: usize) -> i32 {
    let idx = match c {
        '\n' => 95,
        c if (' '..='~').contains(&c) => c as usize - 32,
        _ => 0,
    };
    (idx % vocab) as i32
}

impl TinyCorpus {
    pub fn new(dims: Dims, seed: u64, train_examples: usize, val_examples: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x7c0_5e_ed);
        let words = synth_lexicon(&mut rng);
        // sparse bigram transition table: each word allows ~8 successors
        let succ: Vec<Vec<usize>> = (0..LEXICON)
            .map(|_| (0..8).map(|_| rng.below(LEXICON)).collect())
            .collect();
        let mut text = String::with_capacity(CORPUS_CHARS + 64);
        let mut w = 0usize;
        let mut sentence_len = 0usize;
        while text.len() < CORPUS_CHARS {
            text.push_str(&words[w]);
            sentence_len += 1;
            if sentence_len >= 6 + rng.below(9) {
                text.push('.');
                text.push(if rng.below(5) == 0 { '\n' } else { ' ' });
                sentence_len = 0;
            } else {
                text.push(' ');
            }
            w = succ[w][rng.below(8)];
        }
        let vocab = dims.vocab;
        let corpus: Vec<i32> = text.chars().map(|c| char_token(c, vocab)).collect();
        let train_end = corpus.len() * 9 / 10;
        TinyCorpus { dims, corpus, train_end, seed, train_examples, val_examples }
    }

    pub fn corpus_len(&self) -> usize {
        self.corpus.len()
    }

    fn window_batch(&self, region: (usize, usize), base_seed: u64, n_windows: usize,
                    index: usize) -> Batch {
        let (start, end) = region;
        let t = self.dims.seq;
        let b = self.dims.batch;
        let span = end - start - t - 1;
        let mut tokens = Vec::with_capacity(b * t);
        let mut labels = Vec::with_capacity(b * t);
        for i in 0..b {
            // window offset is a pure function of (seed, window id)
            let wid = (index * b + i) % n_windows.max(1);
            let mut r = Rng::new(base_seed ^ (wid as u64).wrapping_mul(0xA24B_AED4_963E_E407));
            let off = start + r.below(span);
            for j in 0..t {
                tokens.push(self.corpus[off + j]);
                labels.push(self.corpus[off + j + 1]);
            }
        }
        Batch::Lm {
            tokens: IntTensor::from_vec(&[b, t], tokens).expect("tokens"),
            labels: IntTensor::from_vec(&[b, t], labels).expect("labels"),
        }
    }
}

impl Dataset for TinyCorpus {
    fn family(&self) -> Family {
        Family::Gpt
    }

    fn train_batch(&self, step: usize) -> Batch {
        // fixed pool of train_examples windows — *small* on purpose so the
        // model can overfit it (the Fig.-5 phenomenon under study)
        self.window_batch((0, self.train_end), self.seed ^ 0x11, self.train_examples, step)
    }

    fn val_batch(&self, idx: usize) -> Batch {
        self.window_batch(
            (self.train_end, self.corpus.len()),
            self.seed ^ 0x22,
            self.val_examples,
            idx,
        )
    }

    fn n_val_batches(&self) -> usize {
        (self.val_examples / self.dims.batch).max(1)
    }

    fn name(&self) -> &str {
        "tiny_corpus"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims {
            d_model: 16,
            n_heads: 2,
            n_blocks: 2,
            n_enc_blocks: 0,
            mlp_ratio: 2,
            batch: 4,
            lbits: 9,
            image_size: 0,
            patch: 1,
            channels: 0,
            n_classes: 0,
            seq: 16,
            seq_src: 0,
            vocab: 96,
        }
    }

    #[test]
    fn corpus_is_deterministic_and_sized() {
        let a = TinyCorpus::new(dims(), 3, 64, 16);
        let b = TinyCorpus::new(dims(), 3, 64, 16);
        assert_eq!(a.corpus, b.corpus);
        assert!(a.corpus_len() >= CORPUS_CHARS);
        assert!(a.corpus.iter().all(|&t| (0..96).contains(&t)));
    }

    #[test]
    fn labels_are_shifted_tokens() {
        let d = TinyCorpus::new(dims(), 3, 64, 16);
        let Batch::Lm { tokens, labels } = d.train_batch(0) else { panic!() };
        // label[i] is token[i+1] within each row
        for b in 0..4 {
            for j in 0..15 {
                assert_eq!(labels.data()[b * 16 + j], tokens.data()[b * 16 + j + 1]);
            }
        }
    }

    #[test]
    fn train_pool_is_finite_and_repeats() {
        // train_examples=4 with batch=4 -> step 0 and step 1 reuse windows
        let mut dd = dims();
        dd.batch = 4;
        let d = TinyCorpus::new(dd, 3, 4, 16);
        let Batch::Lm { tokens: t0, .. } = d.train_batch(0) else { panic!() };
        let Batch::Lm { tokens: t1, .. } = d.train_batch(1) else { panic!() };
        assert_eq!(t0, t1, "pool of 4 windows must cycle");
    }

    #[test]
    fn val_and_train_regions_disjoint() {
        let d = TinyCorpus::new(dims(), 3, 64, 16);
        assert!(d.train_end < d.corpus_len());
        let Batch::Lm { tokens: tv, .. } = d.val_batch(0) else { panic!() };
        // all val windows start past train_end (checked indirectly: the
        // generator draws offsets in [train_end, len-T-1))
        assert_eq!(tv.shape(), &[4, 16]);
    }

    #[test]
    fn corpus_has_nontrivial_statistics() {
        let d = TinyCorpus::new(dims(), 3, 64, 16);
        let mut counts = [0usize; 96];
        for &t in &d.corpus {
            counts[t as usize] += 1;
        }
        let used = counts.iter().filter(|&&c| c > 0).count();
        assert!(used > 15, "alphabet too small: {used}");
        // entropy strictly between 0 and log2(96)
        let n = d.corpus.len() as f64;
        let h: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum();
        assert!(h > 2.0 && h < 6.6, "unigram entropy {h}");
    }
}
