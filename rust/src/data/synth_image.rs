//! Class-conditional synthetic image dataset (CIFAR10/100 stand-in).
//!
//! Each class owns a deterministic low-frequency "texture prototype" — a sum
//! of random 2-D sinusoids per channel — and a sample is `prototype +
//! sigma * N(0,1)` pixel noise plus a random circular shift (so the task
//! needs more than a single template match but remains learnable by a small
//! ViT).  Labels are balanced; every example is a pure function of
//! `(seed, split, index)`.

use super::{Batch, Dataset};
use crate::model::{Dims, Family};
use crate::tensor::{IntTensor, Rng, Tensor};

const NOISE_SIGMA: f32 = 2.5;
const N_WAVES: usize = 5;

pub struct SynthImage {
    dims: Dims,
    seed: u64,
    train_examples: usize,
    val_examples: usize,
    /// per-class sinusoid banks: (freq_x, freq_y, phase, amp) per channel
    protos: Vec<Vec<[f32; 4]>>,
    name: String,
}

impl SynthImage {
    pub fn new(dims: Dims, seed: u64, train_examples: usize, val_examples: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x5159_1a9e);
        let mut protos = Vec::with_capacity(dims.n_classes);
        for _ in 0..dims.n_classes {
            let mut waves = Vec::with_capacity(dims.channels * N_WAVES);
            for _ in 0..dims.channels * N_WAVES {
                waves.push([
                    rng.uniform() * 0.9 + 0.1, // freq x (cycles / image)
                    rng.uniform() * 0.9 + 0.1, // freq y
                    rng.uniform() * std::f32::consts::TAU,
                    rng.normal() * 0.5,
                ]);
            }
            protos.push(waves);
        }
        let name = format!("synth_image(c{})", dims.n_classes);
        SynthImage { dims, seed, train_examples, val_examples, protos, name }
    }

    fn proto_pixel(&self, class: usize, ch: usize, x: f32, y: f32) -> f32 {
        let mut v = 0.0;
        for w in &self.protos[class][ch * N_WAVES..(ch + 1) * N_WAVES] {
            let [fx, fy, ph, amp] = *w;
            v += amp * (std::f32::consts::TAU * (fx * x + fy * y) + ph).sin();
        }
        v
    }

    fn example(&self, split: u64, index: usize) -> (Vec<f32>, i32) {
        let s = self.dims.image_size;
        let c = self.dims.channels;
        let class = index % self.dims.n_classes;
        let mut rng = Rng::new(
            self.seed
                ^ split.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (index as u64).wrapping_mul(0xD134_2543_DE82_EF95),
        );
        // random circular shift
        let (dx, dy) = (rng.below(s), rng.below(s));
        let mut img = vec![0f32; c * s * s];
        for ch in 0..c {
            for yy in 0..s {
                for xx in 0..s {
                    let fx = ((xx + dx) % s) as f32 / s as f32;
                    let fy = ((yy + dy) % s) as f32 / s as f32;
                    let v = self.proto_pixel(class, ch, fx, fy)
                        + 0.7 * self.proto_pixel(0, ch, fy, fx) // shared clutter
                        + NOISE_SIGMA * rng.normal();
                    img[ch * s * s + yy * s + xx] = v;
                }
            }
        }
        (img, class as i32)
    }

    fn batch(&self, split: u64, base: usize, n_examples: usize) -> Batch {
        let b = self.dims.batch;
        let s = self.dims.image_size;
        let c = self.dims.channels;
        let mut images = Vec::with_capacity(b * c * s * s);
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let (img, lab) = self.example(split, (base + i) % n_examples.max(1));
            images.extend_from_slice(&img);
            labels.push(lab);
        }
        Batch::Image {
            images: Tensor::from_vec(&[b, c, s, s], images).expect("image batch"),
            labels: IntTensor::from_vec(&[b], labels).expect("labels"),
        }
    }
}

impl Dataset for SynthImage {
    fn family(&self) -> Family {
        Family::Vit
    }

    fn train_batch(&self, step: usize) -> Batch {
        // epoch-free streaming: a step consumes batch-size fresh indices
        self.batch(0, step * self.dims.batch, self.train_examples)
    }

    fn val_batch(&self, idx: usize) -> Batch {
        self.batch(1, idx * self.dims.batch, self.val_examples)
    }

    fn n_val_batches(&self) -> usize {
        (self.val_examples / self.dims.batch).max(1)
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims(classes: usize) -> Dims {
        Dims {
            d_model: 16,
            n_heads: 2,
            n_blocks: 2,
            n_enc_blocks: 0,
            mlp_ratio: 2,
            batch: 8,
            lbits: 9,
            image_size: 8,
            patch: 4,
            channels: 3,
            n_classes: classes,
            seq: 0,
            seq_src: 0,
            vocab: 0,
        }
    }

    #[test]
    fn deterministic_and_split_disjoint() {
        let d1 = SynthImage::new(dims(4), 7, 64, 32);
        let d2 = SynthImage::new(dims(4), 7, 64, 32);
        let (Batch::Image { images: a, .. }, Batch::Image { images: b, .. }) =
            (d1.train_batch(3), d2.train_batch(3))
        else {
            panic!()
        };
        assert_eq!(a, b);
        // val and train examples differ (different split stream)
        let (Batch::Image { images: tr, .. }, Batch::Image { images: va, .. }) =
            (d1.train_batch(0), d1.val_batch(0))
        else {
            panic!()
        };
        assert!(tr.max_abs_diff(&va).unwrap() > 0.1);
    }

    #[test]
    fn labels_balanced_and_in_range() {
        let d = SynthImage::new(dims(4), 1, 64, 32);
        let Batch::Image { labels, .. } = d.train_batch(0) else { panic!() };
        for (i, &l) in labels.data().iter().enumerate() {
            assert_eq!(l, (i % 4) as i32);
        }
    }

    #[test]
    fn classes_statistically_distinct() {
        // prototype pixels of different classes should differ
        let d = SynthImage::new(dims(4), 1, 64, 32);
        let p0 = d.proto_pixel(0, 0, 0.3, 0.6);
        let p1 = d.proto_pixel(1, 0, 0.3, 0.6);
        assert!((p0 - p1).abs() > 1e-4);
    }

    #[test]
    fn hundred_class_variant() {
        let d = SynthImage::new(dims(100), 1, 256, 128);
        let Batch::Image { labels, .. } = d.train_batch(5) else { panic!() };
        assert!(labels.data().iter().all(|&l| (0..100).contains(&l)));
    }
}
