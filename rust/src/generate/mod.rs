//! # `bdia::generate` — autoregressive decoding with a KV-cache workspace
//!
//! The paper trains a standard GPT with random γ ∈ {±0.5} and serves it at
//! E\[γ\] = 0 with *no architecture change* — which means the standard
//! incremental-decoding trick applies verbatim: cache every block's K/V
//! projections and run each new position as a one-row forward.  This module
//! packages that as a per-session state machine ([`GenSession`]) plus a
//! deterministic sampler ([`Sampler`]), both driven through the
//! `model_decode_step` executable (GPT family only).
//!
//! ## The bit-identity contract
//!
//! Incremental decode is **bit-identical to a full re-forward of the whole
//! prefix** at every thread count and under any kernel tuning profile —
//! not approximately, exactly (`tests/generate.rs` asserts `to_bits`
//! equality against `model_logits`).  The chain of reasons lives in the
//! kernel layer (`kernels::attention::attn_decode`): row-local reductions
//! in ascending index order, causal masking that contributes exact `+0.0`
//! to every unmasked row, and task partitions that never split a
//! reduction.
//!
//! ## Lane packing
//!
//! `model_decode_step` advances up to `batch` sessions per call — one lane
//! each — and every lane's output depends only on that lane's tokens and
//! cache rows.  [`decode_tick`] is the single driver for both shapes of
//! use: `Session::generate` passes one session (lanes = 1); the serving
//! scheduler passes every session that sits at the same position
//! (lanes = n).  Batched and solo calls are bit-identical per lane, so a
//! token streamed from a busy server equals the token generated alone.
//!
//! Per-session caches are compact `(n_blocks, seq, d)` buffers leased from
//! the kernel workspace arena and returned on drop; each tick assembles
//! them into the executable's full-shape `(n_blocks, batch, seq, d)`
//! scratch (copying only the `pos` live rows per block — the same order of
//! work as one projection row).
//!
//! ## Determinism of sampling
//!
//! Greedy picks the first maximum (ties break to the lowest token id, the
//! same rule as the training-accuracy argmax).  Temperature/top-k sampling
//! draws from a dedicated SplitMix64 stream forked off the caller's seed,
//! so a replay with the same seed, prompt and weights reproduces the same
//! token sequence bit-for-bit — there is no global RNG involved.

use crate::kernels::workspace;
use crate::model::{Family, ParamStore};
use crate::runtime::{ArgValue, Runtime};
use crate::tensor::{IntTensor, Rng, Tensor};
use anyhow::{bail, ensure, Result};
use std::cmp::Ordering;

/// Stream tag for the sampler's forked RNG (distinct from the trainer's
/// gamma stream by construction — different root seed *and* tag).
const SAMPLER_STREAM: u64 = 0x6765_6e5f_7361_6d70; // "gen_samp"

/// Options for one generation request.
#[derive(Clone, Debug)]
pub struct GenOpts {
    /// Maximum *new* tokens to generate (the prompt is not counted).
    pub max_tokens: usize,
    /// 0.0 = greedy (deterministic argmax); > 0.0 = sample from the
    /// temperature-scaled softmax.
    pub temperature: f32,
    /// Restrict sampling to the k highest-logit tokens (0 = full vocab).
    /// Ignored under greedy decoding.
    pub top_k: usize,
    /// Seed for the sampler's private RNG stream; same seed + same prompt
    /// + same weights → same tokens, bit-for-bit.
    pub seed: u64,
    /// Stop as soon as this token is generated (it is still emitted).
    pub eos: Option<i32>,
    /// Inference gamma (0.0 = the paper's standard E[γ] inference).
    pub gamma: f32,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts {
            max_tokens: 32,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            eos: None,
            gamma: 0.0,
        }
    }
}

/// Why a generation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenStop {
    /// `max_tokens` new tokens were generated.
    MaxTokens,
    /// The `eos` token was generated.
    Eos,
    /// The KV cache reached the model's context length (`dims.seq`).
    ContextFull,
}

impl GenStop {
    pub fn name(&self) -> &'static str {
        match self {
            GenStop::MaxTokens => "max_tokens",
            GenStop::Eos => "eos",
            GenStop::ContextFull => "context_full",
        }
    }
}

/// What a completed generation reports.
#[derive(Clone, Debug)]
pub struct GenReport {
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// The generated tokens (prompt excluded), in order.
    pub tokens: Vec<i32>,
    /// Wall time per decode step that produced a token, milliseconds —
    /// `token_ms[i]` timed the step that emitted `tokens[i]`.
    pub token_ms: Vec<f64>,
    /// Wall time of the prompt prefill (all steps before the first
    /// sampled token), milliseconds.
    pub prefill_ms: f64,
    pub stop: GenStop,
}

impl GenReport {
    /// Generated tokens per second over the decode (post-prefill) phase.
    pub fn tokens_per_s(&self) -> f64 {
        let ms: f64 = self.token_ms.iter().sum();
        if ms <= 0.0 {
            0.0
        } else {
            self.tokens.len() as f64 / (ms / 1e3)
        }
    }
}

/// Deterministic next-token sampler.
///
/// Greedy (`temperature == 0.0`) returns the first maximum — ties break to
/// the lowest token id, matching the accuracy argmax used everywhere else
/// in the repo.  Otherwise: keep the `top_k` highest logits (value
/// descending, index ascending — a total order, so the candidate set is
/// unambiguous even with tied logits), softmax at `temperature`, and walk
/// the cumulative weights against one `uniform()` draw from the private
/// stream.  Every operation is serial f32, so a replay is exact.
pub struct Sampler {
    temperature: f32,
    top_k: usize,
    rng: Rng,
}

impl Sampler {
    pub fn new(temperature: f32, top_k: usize, seed: u64) -> Self {
        Sampler {
            temperature,
            top_k,
            rng: Rng::new(seed).fork(SAMPLER_STREAM),
        }
    }

    /// Pick the next token id from one row of logits.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        debug_assert!(!logits.is_empty());
        if self.temperature <= 0.0 {
            // first-max-wins argmax (strict `>`): lowest index on ties
            let mut best = 0;
            for (i, &v) in logits.iter().enumerate().skip(1) {
                if v > logits[best] {
                    best = i;
                }
            }
            return best;
        }
        let k = match self.top_k {
            0 => logits.len(),
            k => k.min(logits.len()),
        };
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| {
            logits[b]
                .partial_cmp(&logits[a])
                .unwrap_or(Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k);
        // softmax at temperature over the candidates; idx[0] holds the max
        // so every exponent is <= 0 and the sum is finite
        let m = logits[idx[0]];
        let mut w = Vec::with_capacity(k);
        let mut sum = 0.0f32;
        for &i in &idx {
            let e = ((logits[i] - m) / self.temperature).exp();
            w.push(e);
            sum += e;
        }
        let target = self.rng.uniform() * sum;
        let mut acc = 0.0f32;
        for (j, &wi) in w.iter().enumerate() {
            acc += wi;
            if target < acc {
                return idx[j];
            }
        }
        // uniform() < 1.0 and acc ends at sum, so this is unreachable save
        // for rounding on the last partial sum — the last candidate wins
        idx[k - 1]
    }
}

/// One in-flight generation: prompt + generated tokens, the per-session
/// compact KV cache, the sampler stream, and the stop state.
///
/// A session holds **no** runtime or parameter borrows — [`decode_tick`]
/// takes them per call — so the serving scheduler can own sessions across
/// ticks while the runtime is shared.
pub struct GenSession {
    /// Prompt followed by every generated token.
    toks: Vec<i32>,
    prompt_len: usize,
    /// Cache rows filled so far == next position to feed.
    pos: usize,
    /// Compact per-session caches, `(n_blocks, seq, d)` row-major, leased
    /// from the workspace arena (returned on drop).
    kcache: Vec<f32>,
    vcache: Vec<f32>,
    sampler: Sampler,
    opts: GenOpts,
    stop: Option<GenStop>,
    // model dims, copied so lane helpers need no runtime access
    n_blocks: usize,
    t_max: usize,
    d: usize,
    vocab: usize,
}

impl GenSession {
    /// Validate the prompt against the runtime's manifest and lease the
    /// session cache.  GPT family only.
    pub fn new(rt: &Runtime, prompt: &[i32], opts: GenOpts) -> Result<GenSession> {
        let m = &rt.manifest;
        if m.family != Family::Gpt {
            bail!(
                "generation drives the GPT decode path; model '{}' is {:?}",
                m.name,
                m.family
            );
        }
        let dims = &m.dims;
        ensure!(!prompt.is_empty(), "prompt must contain at least one token");
        ensure!(
            prompt.len() <= dims.seq,
            "prompt has {} tokens but the model context is {}",
            prompt.len(),
            dims.seq
        );
        for (i, &t) in prompt.iter().enumerate() {
            ensure!(
                t >= 0 && (t as usize) < dims.vocab,
                "prompt token {i} = {t} outside vocab 0..{}",
                dims.vocab
            );
        }
        ensure!(opts.max_tokens > 0, "max_tokens must be positive");
        if let Some(eos) = opts.eos {
            ensure!(
                eos >= 0 && (eos as usize) < dims.vocab,
                "eos token {eos} outside vocab 0..{}",
                dims.vocab
            );
        }
        let cache_len = dims.n_blocks * dims.seq * dims.d_model;
        Ok(GenSession {
            toks: prompt.to_vec(),
            prompt_len: prompt.len(),
            pos: 0,
            kcache: workspace::take(cache_len),
            vcache: workspace::take(cache_len),
            sampler: Sampler::new(opts.temperature, opts.top_k, opts.seed),
            opts,
            stop: None,
            n_blocks: dims.n_blocks,
            t_max: dims.seq,
            d: dims.d_model,
            vocab: dims.vocab,
        })
    }

    /// Cache rows filled so far (positions fed to the model).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Prompt plus everything generated so far.
    pub fn tokens(&self) -> &[i32] {
        &self.toks
    }

    /// Only the generated tokens.
    pub fn generated(&self) -> &[i32] {
        &self.toks[self.prompt_len..]
    }

    /// True while the step that produces the *next* sampled token is still
    /// inside the prompt (its logits are discarded).
    pub fn in_prefill(&self) -> bool {
        self.pos + 1 < self.toks.len()
    }

    pub fn is_done(&self) -> bool {
        self.stop.is_some()
    }

    pub fn stop(&self) -> Option<GenStop> {
        self.stop
    }

    /// The token to feed at the current position, `None` once stopped.
    pub fn next_input(&self) -> Option<i32> {
        if self.stop.is_some() {
            None
        } else {
            Some(self.toks[self.pos])
        }
    }

    /// Copy this session's live cache rows (`0..pos`) into lane `lane` of
    /// a full-shape `(n_blocks, batch, seq, d)` scratch pair.
    fn load_lane(&self, kc: &mut [f32], vc: &mut [f32], lane: usize, batch: usize) {
        let (t_max, d) = (self.t_max, self.d);
        let live = self.pos * d;
        for k in 0..self.n_blocks {
            let src = k * t_max * d;
            let dst = (k * batch + lane) * t_max * d;
            kc[dst..dst + live].copy_from_slice(&self.kcache[src..src + live]);
            vc[dst..dst + live].copy_from_slice(&self.vcache[src..src + live]);
        }
    }

    /// Append one new K/V row per block (lane `lane` of the executable's
    /// `(n_blocks, batch, d)` outputs) at the current position.
    fn store_new_row(&mut self, knew: &[f32], vnew: &[f32], lane: usize, batch: usize) {
        let d = self.d;
        for k in 0..self.n_blocks {
            let src = (k * batch + lane) * d;
            let dst = k * self.t_max * d + self.pos * d;
            self.kcache[dst..dst + d].copy_from_slice(&knew[src..src + d]);
            self.vcache[dst..dst + d].copy_from_slice(&vnew[src..src + d]);
        }
    }

    /// Consume one row of logits for the position just fed: advance the
    /// cursor, sample when past the prompt, and update the stop state.
    /// Returns the newly generated token, if any.
    fn advance_with(&mut self, logits: &[f32]) -> Option<i32> {
        debug_assert!(self.stop.is_none());
        self.pos += 1;
        if self.pos < self.toks.len() {
            // still prefilling: the model's prediction is discarded in
            // favour of the known next token
            return None;
        }
        let tok = self.sampler.sample(logits) as i32;
        self.toks.push(tok);
        let n_generated = self.toks.len() - self.prompt_len;
        if self.opts.eos == Some(tok) {
            self.stop = Some(GenStop::Eos);
        } else if n_generated >= self.opts.max_tokens {
            self.stop = Some(GenStop::MaxTokens);
        } else if self.pos >= self.t_max {
            // the sampled token cannot be fed back: the cache is full
            self.stop = Some(GenStop::ContextFull);
        }
        Some(tok)
    }
}

impl Drop for GenSession {
    fn drop(&mut self) {
        workspace::give(std::mem::take(&mut self.kcache));
        workspace::give(std::mem::take(&mut self.vcache));
    }
}

/// Advance every session by one position with a single
/// `model_decode_step` call — session `i` rides lane `i`.
///
/// All sessions must sit at the same position (the executable takes one
/// `pos` scalar) and none may be stopped; the serving scheduler groups by
/// position per tick, and `Session::generate` passes exactly one session.
/// Per-lane outputs are packing-invariant, so the result for each session
/// is bit-identical however the tick is composed.
///
/// Returns, per session in order, the token generated this step (`None`
/// while that session is still prefilling).
pub fn decode_tick(
    rt: &Runtime,
    params: &ParamStore,
    sessions: &mut [&mut GenSession],
) -> Result<Vec<Option<i32>>> {
    ensure!(!sessions.is_empty(), "decode_tick needs at least one session");
    let _span = crate::span!("decode_tick", n = sessions.len(), pos = sessions[0].pos);
    let e = rt.exec("model_decode_step")?;
    let dims = &rt.manifest.dims;
    let (nb, batch, t_max, d) = (dims.n_blocks, dims.batch, dims.seq, dims.d_model);
    ensure!(
        sessions.len() <= batch,
        "{} sessions exceed the manifest batch dimension {batch}",
        sessions.len()
    );
    let pos = sessions[0].pos;
    let gamma = sessions[0].opts.gamma;
    let mut toks = vec![0i32; batch];
    for (i, s) in sessions.iter().enumerate() {
        ensure!(
            s.pos == pos,
            "session {i} is at position {} but the tick runs position {pos}",
            s.pos
        );
        ensure!(
            s.opts.gamma == gamma,
            "session {i} wants gamma {} but the tick runs gamma {gamma} — \
             never mix gammas in one batch",
            s.opts.gamma
        );
        match s.next_input() {
            Some(t) => toks[i] = t,
            None => bail!("session {i} is already stopped"),
        }
    }

    // assemble the full-shape scratch caches: only the pos live rows of
    // each (block, lane) are copied; idle lanes stay zero and are never
    // read (the executable computes `lanes` lanes only)
    let full = nb * batch * t_max * d;
    let (mut kc, mut vc) = (workspace::take(full), workspace::take(full));
    for (i, s) in sessions.iter().enumerate() {
        s.load_lane(&mut kc, &mut vc, i, batch);
    }
    let kt = Tensor::from_vec(&[nb, batch, t_max, d], kc)?;
    let vt = Tensor::from_vec(&[nb, batch, t_max, d], vc)?;
    let tt = IntTensor::from_vec(&[batch], toks)?;
    let refs = params.refs_for(&e.spec, 0)?;
    let mut outs = e.call(
        &refs,
        &[
            ArgValue::I32(&tt),
            ArgValue::F32(&kt),
            ArgValue::F32(&vt),
            ArgValue::Scalar(pos as f32),
            ArgValue::Scalar(sessions.len() as f32),
            ArgValue::Scalar(gamma),
        ],
    )?;
    workspace::give(vt.into_vec());
    workspace::give(kt.into_vec());

    let vnew = outs.pop().expect("decode_step returns 3 outputs");
    let knew = outs.pop().expect("decode_step returns 3 outputs");
    let logits = outs.pop().expect("decode_step returns 3 outputs");
    let mut emitted = Vec::with_capacity(sessions.len());
    for (i, s) in sessions.iter_mut().enumerate() {
        s.store_new_row(knew.data(), vnew.data(), i, batch);
        emitted.push(s.advance_with(&logits.data()[i * dims.vocab..(i + 1) * dims.vocab]));
    }
    Ok(emitted)
}

/// Drive one session to completion, timing each step.  `on_token` fires
/// for every generated token (prefill steps emit nothing).
pub fn run_session(
    rt: &Runtime,
    params: &ParamStore,
    session: &mut GenSession,
    mut on_token: impl FnMut(usize, i32, f64),
) -> Result<GenReport> {
    let mut token_ms = Vec::new();
    let mut prefill_ms = 0.0f64;
    while !session.is_done() {
        let t0 = std::time::Instant::now();
        let emitted = decode_tick(rt, params, &mut [session])?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        match emitted[0] {
            Some(tok) => {
                on_token(token_ms.len(), tok, ms);
                token_ms.push(ms);
            }
            None => prefill_ms += ms,
        }
    }
    Ok(GenReport {
        prompt_len: session.prompt_len,
        tokens: session.generated().to_vec(),
        token_ms,
        prefill_ms,
        stop: session.stop().expect("loop exits only once stopped"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::registry;

    fn smoke() -> (Runtime, ParamStore) {
        let rt =
            Runtime::from_native_manifest(registry::manifest_for("smoke_gpt").unwrap()).unwrap();
        let ps = ParamStore::init(&rt.manifest, 11);
        (rt, ps)
    }

    #[test]
    fn greedy_breaks_ties_to_lowest_index() {
        let mut s = Sampler::new(0.0, 0, 1);
        assert_eq!(s.sample(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(s.sample(&[5.0, 5.0]), 0);
    }

    #[test]
    fn sampler_replays_bit_exactly_and_respects_top_k() {
        let logits: Vec<f32> = (0..16).map(|i| ((i * 37) % 11) as f32 * 0.3).collect();
        let draw = |seed: u64| {
            let mut s = Sampler::new(0.8, 3, seed);
            (0..64).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(draw(7), draw(7), "same seed must replay the same stream");
        assert_ne!(draw(7), draw(8), "different seeds should diverge");
        // top-3 of these logits by (value desc, index asc)
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| {
            logits[b].partial_cmp(&logits[a]).unwrap().then(a.cmp(&b))
        });
        let allowed = &idx[..3];
        for t in draw(7) {
            assert!(allowed.contains(&t), "token {t} escaped the top-k set");
        }
    }

    #[test]
    fn session_validates_inputs() {
        let (rt, _ps) = smoke();
        let vocab = rt.manifest.dims.vocab as i32;
        assert!(GenSession::new(&rt, &[], GenOpts::default()).is_err());
        assert!(GenSession::new(&rt, &[vocab], GenOpts::default()).is_err());
        assert!(GenSession::new(&rt, &[-1], GenOpts::default()).is_err());
        let long = vec![0i32; rt.manifest.dims.seq + 1];
        assert!(GenSession::new(&rt, &long, GenOpts::default()).is_err());
        assert!(GenSession::new(
            &rt,
            &[0],
            GenOpts { max_tokens: 0, ..GenOpts::default() }
        )
        .is_err());
    }

    #[test]
    fn greedy_generation_is_deterministic_and_stops() {
        let (rt, ps) = smoke();
        let seq = rt.manifest.dims.seq;
        let run = || {
            let mut s = GenSession::new(
                &rt,
                &[1, 2, 3],
                GenOpts { max_tokens: 4, ..GenOpts::default() },
            )
            .unwrap();
            run_session(&rt, &ps, &mut s, |_, _, _| {}).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 4);
        assert_eq!(a.stop, GenStop::MaxTokens);
        assert_eq!(a.token_ms.len(), 4);
        assert_eq!(a.prompt_len, 3);

        // context-full: a prompt filling all but one position yields
        // exactly one token
        let mut s = GenSession::new(
            &rt,
            &vec![1i32; seq - 1],
            GenOpts { max_tokens: 100, ..GenOpts::default() },
        )
        .unwrap();
        let r = run_session(&rt, &ps, &mut s, |_, _, _| {}).unwrap();
        assert_eq!(r.tokens.len(), 2);
        assert_eq!(r.stop, GenStop::ContextFull);
    }

    #[test]
    fn eos_stops_generation() {
        let (rt, ps) = smoke();
        // find what greedy emits first, then rerun with that token as eos
        let gen_with = |eos: Option<i32>| {
            let mut s = GenSession::new(
                &rt,
                &[4, 5],
                GenOpts { max_tokens: 6, eos, ..GenOpts::default() },
            )
            .unwrap();
            run_session(&rt, &ps, &mut s, |_, _, _| {}).unwrap()
        };
        let first = gen_with(None).tokens[0];
        let r = gen_with(Some(first));
        assert_eq!(r.tokens, vec![first]);
        assert_eq!(r.stop, GenStop::Eos);
    }

    #[test]
    fn batched_tick_matches_solo_generation_bitwise() {
        let (rt, ps) = smoke();
        let batch = rt.manifest.dims.batch;
        assert!(batch >= 2, "smoke_gpt batch must host two lanes");
        let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![7, 8, 9]];
        let opts = GenOpts { max_tokens: 5, ..GenOpts::default() };

        // solo reference
        let solo: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| {
                let mut s = GenSession::new(&rt, p, opts.clone()).unwrap();
                run_session(&rt, &ps, &mut s, |_, _, _| {}).unwrap().tokens
            })
            .collect();

        // batched: same-length prompts share every tick
        let mut a = GenSession::new(&rt, &prompts[0], opts.clone()).unwrap();
        let mut b = GenSession::new(&rt, &prompts[1], opts).unwrap();
        while !a.is_done() || !b.is_done() {
            match (a.is_done(), b.is_done()) {
                (false, false) => {
                    decode_tick(&rt, &ps, &mut [&mut a, &mut b]).unwrap();
                }
                (false, true) => {
                    decode_tick(&rt, &ps, &mut [&mut a]).unwrap();
                }
                (true, false) => {
                    decode_tick(&rt, &ps, &mut [&mut b]).unwrap();
                }
                (true, true) => unreachable!(),
            }
        }
        assert_eq!(a.generated(), &solo[0][..], "lane 0 diverged from solo");
        assert_eq!(b.generated(), &solo[1][..], "lane 1 diverged from solo");
    }

    #[test]
    fn tick_rejects_mixed_positions_and_vit_models() {
        let (rt, ps) = smoke();
        let opts = GenOpts::default();
        let mut a = GenSession::new(&rt, &[1, 2], opts.clone()).unwrap();
        let mut b = GenSession::new(&rt, &[3], opts).unwrap();
        decode_tick(&rt, &ps, &mut [&mut a]).unwrap(); // a at pos 1, b at 0
        assert!(decode_tick(&rt, &ps, &mut [&mut a, &mut b]).is_err());

        let vit =
            Runtime::from_native_manifest(registry::manifest_for("smoke_vit").unwrap()).unwrap();
        assert!(GenSession::new(&vit, &[1], GenOpts::default()).is_err());
    }
}
