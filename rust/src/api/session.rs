//! The [`Session`] facade and its fluent [`SessionBuilder`].
//!
//! One object owns the whole lifecycle — runtime, parameters, optimizer,
//! config — and exposes it as typed methods: `train`, `evaluate`,
//! `infer`/`infer_batch`, `save`/`resume`, `serve`, `bench`.  The CLI,
//! the experiment drivers and the bench suite are all thin clients of
//! this type; embedders get exactly the same surface.

use super::error::{ApiError, ApiResult};
use super::events::{CheckpointEvent, EvalEvent, EventSink, NullSink, TokenEvent};
use super::model_id::ModelId;
use crate::baseline::RevVitTrainer;
use crate::config::{RankFailurePolicy, TrainConfig, TrainMode};
use crate::coordinator::{StepStats, Trainer};
use crate::data::{make_dataset, Batch, Dataset};
use crate::dist::{self, DistRole, Rendezvous};
use crate::fleet::{FleetConfig, Router};
use crate::metrics::memory::MemoryModel;
use crate::metrics::TrainLog;
use crate::model::{Dims, Family, ParamStore};
use crate::runtime::{BackendKind, Runtime};
use crate::serve::bench as serve_bench;
use crate::serve::wire::{self, Example};
use crate::serve::{ServeConfig, Server};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// Options for [`Session::train`].
#[derive(Clone, Debug, Default)]
pub struct TrainOpts {
    /// Run label for logs and checkpoint file names; defaults to
    /// `<model>_<mode>`.
    pub run_name: Option<String>,
    /// Write the training log as CSV here after the run.
    pub csv_out: Option<PathBuf>,
}

/// What a completed [`Session::train`] call reports.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub run_name: String,
    /// Total optimization steps completed (includes pre-resume steps).
    pub steps_completed: usize,
    pub mean_ms_per_step: f64,
    /// The full per-step/per-eval log (CSV-exportable).
    pub log: TrainLog,
}

/// Options for [`Session::evaluate`].
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOpts {
    /// Constant inference gamma (0.0 = the paper's standard inference).
    pub gamma: f32,
    /// Held-out batches to average over; defaults to the config's
    /// `eval_batches`.
    pub batches: Option<usize>,
}

/// What one evaluation pass reports.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub loss: f32,
    pub acc: f32,
    pub gamma: f32,
    /// Steps completed by the evaluated parameters.
    pub step: usize,
    /// Human-readable weight provenance ("checkpoint …" or "untrained …").
    pub provenance: String,
}

/// Options for [`Session::serve`].
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// 0 picks an ephemeral port (tests / self-hosting).
    pub port: u16,
    pub workers: usize,
    /// How long an under-filled batch waits for stragglers.
    pub batch_window: Duration,
    /// Admission cap on queued requests (0 = unbounded); overflow gets a
    /// prompt `503 Retry-After`.
    pub queue_cap: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            port: 7878,
            workers: 4,
            batch_window: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

/// Options for [`Session::serve_fleet`].
#[derive(Clone, Debug)]
pub struct FleetOpts {
    /// Front-door HTTP port; 0 picks an ephemeral port.
    pub port: u16,
    /// Backplane bind address for replicas; `None` binds an ephemeral
    /// loopback port (read it back via [`FleetHandle::backplane_addr`]).
    pub backplane: Option<String>,
    /// How long an under-filled batch waits for stragglers.
    pub batch_window: Duration,
    /// Admission cap on queued requests (0 = unbounded).
    pub queue_cap: usize,
    /// Backplane silence deadline before a replica is evicted.
    pub deadline: Duration,
}

impl Default for FleetOpts {
    fn default() -> Self {
        FleetOpts {
            port: 7878,
            backplane: None,
            batch_window: Duration::from_millis(2),
            queue_cap: 1024,
            deadline: Duration::from_secs(10),
        }
    }
}

/// Options for [`Session::bench_serve`] (the serving load test).
#[derive(Clone, Debug)]
pub struct ServeBenchOpts {
    pub requests: usize,
    pub concurrency: usize,
    /// Worker pool size for the self-hosted server.
    pub workers: usize,
    pub gamma: f32,
    pub batch_window: Duration,
    /// Target an already-running server; `None` self-hosts one.
    pub addr: Option<SocketAddr>,
    /// Verify every response is bit-identical to direct local inference.
    pub verify: bool,
}

impl Default for ServeBenchOpts {
    fn default() -> Self {
        let d = serve_bench::BenchOpts::default();
        ServeBenchOpts {
            requests: d.requests,
            concurrency: d.concurrency,
            workers: d.workers,
            gamma: d.gamma,
            batch_window: d.batch_window,
            addr: None,
            verify: true,
        }
    }
}

/// Options for [`Session::tune`] (`bdia tune`).
#[derive(Clone, Debug, Default)]
pub struct TuneOpts {
    /// Smaller candidate grid and shape cap (CI smoke).
    pub quick: bool,
    /// Persist the winning profile here (atomic write), typically next to
    /// the checkpoint.
    pub out: Option<PathBuf>,
}

/// What a completed [`Session::tune`] call reports.
#[derive(Clone, Debug)]
pub struct TuneReport {
    pub model: String,
    /// Kernel-pool threads the search ran at (profiles are per-thread-count).
    pub threads: usize,
    /// The composed winning profile (also saved to `path` when set).
    pub profile: crate::kernels::KernelProfile,
    pub path: Option<PathBuf>,
    pub shapes_tuned: usize,
    /// Recorded shapes skipped (wrong thread count or past the cap).
    pub shapes_dropped: usize,
    /// Sum of per-shape min times under the default profile, ms.
    pub default_ms: f64,
    /// Sum of per-shape min times under the winning parameters, ms.
    pub tuned_ms: f64,
}

/// Hot-path wall times measured by [`Session::bench`].
#[derive(Clone, Debug)]
pub struct SessionTimings {
    pub bundle: String,
    pub family: String,
    /// Kernel-pool threads in effect during the measurement.
    pub threads: usize,
    /// Id of the kernel tuning profile active during the measurement
    /// (`"default"` unless one was installed).
    pub profile: String,
    /// Training forward pass, milliseconds (mean).
    pub fwd_ms: f64,
    /// Full train step (forward + online backward + optimizer), ms.
    pub step_ms: f64,
    /// Fused quantized inference over one batch, ms.
    pub infer_ms: f64,
}

/// Bundle/runtime inventory reported by [`Session::describe`]
/// (`bdia info`).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub family: String,
    pub backend: &'static str,
    pub dims: Dims,
    pub n_params: usize,
    /// Per-executable invocation counts (this process).
    pub call_counts: Vec<(String, u64)>,
    pub kernel_threads: usize,
    pub kernel_auto_threads: usize,
    pub kernel_spawned_workers: usize,
    /// Active kernel tuning profile id (`"default"` when none installed).
    pub tune_profile: String,
    /// File the active profile was loaded from, if any.
    pub tune_profile_source: Option<PathBuf>,
    pub workspace_hits: u64,
    pub workspace_misses: u64,
    /// Cached static-weight transposes served / built (`matmul_nt_w`).
    pub workspace_keyed_hits: u64,
    pub workspace_keyed_builds: u64,
    /// (mode name, analytic peak training bytes) per training mode.
    pub peak_memory: Vec<(&'static str, usize)>,
}

/// A running server owned by the caller; see [`Session::serve`].
pub struct ServerHandle {
    inner: Server,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    /// Begin graceful shutdown (idempotent); [`ServerHandle::join`] waits
    /// it out.
    pub fn stop(&self) {
        self.inner.stop();
    }

    /// Wait for the listener and all workers to exit.
    pub fn join(self) -> ApiResult<()> {
        self.inner.join().map_err(ApiError::serve)
    }

    /// `stop` + `join`.
    pub fn shutdown(self) -> ApiResult<()> {
        self.inner.shutdown().map_err(ApiError::serve)
    }
}

/// A running fleet router owned by the caller; see
/// [`Session::serve_fleet`].  Replicas join the backplane address on
/// their own schedule — use [`FleetHandle::wait_ready`] before sending
/// traffic that expects a given capacity.
pub struct FleetHandle {
    inner: Router,
}

impl FleetHandle {
    /// Front-door HTTP address.
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    /// Backplane address replicas join (`bdia serve --replica
    /// --rendezvous <this>`).
    pub fn backplane_addr(&self) -> SocketAddr {
        self.inner.backplane_addr()
    }

    /// Currently live replicas.
    pub fn live_replicas(&self) -> usize {
        self.inner.live_replicas()
    }

    /// Block until at least `n` replicas are live.
    pub fn wait_ready(&self, n: usize, timeout: Duration) -> ApiResult<()> {
        self.inner.wait_ready(n, timeout).map_err(ApiError::serve)
    }

    /// Begin graceful shutdown (idempotent); [`FleetHandle::join`] waits
    /// it out.
    pub fn stop(&self) {
        self.inner.stop();
    }

    /// Wait for the router's threads to exit.
    pub fn join(self) -> ApiResult<()> {
        self.inner.join().map_err(ApiError::serve)
    }

    /// `stop` + `join`.
    pub fn shutdown(self) -> ApiResult<()> {
        self.inner.shutdown().map_err(ApiError::serve)
    }
}

/// The two training engines behind the facade.  BDIA/vanilla runs on the
/// coordinator; the RevViT baseline has its own two-stream trainer and no
/// persistence or fused-inference form (the paper's core criticism).
enum Engine {
    Bdia(Box<Trainer>),
    RevVit(Box<RevVitTrainer>),
}

/// Fluent constructor for [`Session`].
///
/// Setters never fail; errors (bad config file, bad override, unknown
/// model) are deferred and reported once by [`SessionBuilder::build`].
pub struct SessionBuilder {
    cfg: TrainConfig,
    ckpt: Option<PathBuf>,
    tune_profile: Option<PathBuf>,
    sink: Arc<dyn EventSink>,
    dataset_auto: bool,
    dist_rank: Option<usize>,
    rendezvous: Option<String>,
    pending_err: Option<ApiError>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            cfg: TrainConfig::default(),
            ckpt: None,
            tune_profile: None,
            sink: Arc::new(NullSink),
            dataset_auto: false,
            dist_rank: None,
            rendezvous: None,
            pending_err: None,
        }
    }
}

impl SessionBuilder {
    /// Replace the whole config (call before field setters; they apply on
    /// top).
    pub fn config(mut self, cfg: TrainConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Load a JSON config file as the base config.
    pub fn config_file(mut self, path: impl AsRef<Path>) -> Self {
        match TrainConfig::load(path.as_ref()) {
            Ok(cfg) => self.cfg = cfg,
            Err(e) => self.set_err(ApiError::config(e)),
        }
        self
    }

    /// Select a registered model.
    pub fn model(mut self, id: ModelId) -> Self {
        self.cfg.model = id.name().to_string();
        self
    }

    /// Select a model by name: a registry name, or the directory name of
    /// an exported AOT bundle under `artifacts_dir`.  Validated at build
    /// time ([`ApiError::UnknownModel`] lists the valid names).
    pub fn model_name(mut self, name: impl Into<String>) -> Self {
        self.cfg.model = name.into();
        self
    }

    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.cfg.backend = kind;
        self
    }

    pub fn mode(mut self, mode: TrainMode) -> Self {
        self.cfg.mode = mode;
        self
    }

    pub fn dataset(mut self, name: impl Into<String>) -> Self {
        self.cfg.dataset = name.into();
        self
    }

    /// Pick the family-default synthetic dataset for the chosen model at
    /// build time (ViT → synth_cifar10, GPT → tiny_corpus, EncDec →
    /// synth_translation).
    pub fn dataset_auto(mut self) -> Self {
        self.dataset_auto = true;
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.cfg.steps = steps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Kernel-pool parallelism (0 = auto).  Purely a speed knob: results
    /// are bit-identical at any value.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    pub fn gamma_mag(mut self, mag: f32) -> Self {
        self.cfg.gamma_mag = mag;
        self
    }

    /// Data-parallel world size (`ranks` config key).  Training is
    /// bit-identical at any value; see [`crate::dist`].
    pub fn ranks(mut self, n: usize) -> Self {
        self.cfg.ranks = n;
        self
    }

    /// Micro-batches per global optimization step (`grad_accum` config
    /// key; 0 = one per rank).  Must be a multiple of `ranks`.
    pub fn grad_accum(mut self, n: usize) -> Self {
        self.cfg.grad_accum = n;
        self
    }

    /// This process's rank in a multi-process world (0 hosts the
    /// rendezvous).  Unset + `ranks > 1` means rank 0.
    pub fn rank(mut self, rank: usize) -> Self {
        self.dist_rank = Some(rank);
        self
    }

    /// Rendezvous address (`host:port`) for a multi-process world; rank 0
    /// binds it, workers connect to it.  Defaults to
    /// [`dist::DEFAULT_RENDEZVOUS`].
    pub fn rendezvous(mut self, addr: impl Into<String>) -> Self {
        self.rendezvous = Some(addr.into());
        self
    }

    /// Deadline (seconds) on every steady-state collective read/write
    /// (`dist_timeout_s` config key).  A rank silent this long is declared
    /// dead and surfaces as [`ApiError::Dist`] instead of a hang.
    pub fn dist_timeout_s(mut self, secs: f64) -> Self {
        self.cfg.dist_timeout_s = secs;
        self
    }

    /// What rank 0 does when the world loses a rank (`on_rank_failure`
    /// config key): abort with the structured error, or rebuild the world
    /// and resume bit-exactly from the last completed step.
    pub fn on_rank_failure(mut self, policy: RankFailurePolicy) -> Self {
        self.cfg.on_rank_failure = policy;
        self
    }

    pub fn save_every(mut self, every: usize) -> Self {
        self.cfg.save_every = every;
        self
    }

    pub fn ckpt_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.ckpt_dir = dir.into();
        self
    }

    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    pub fn eval_every(mut self, every: usize) -> Self {
        self.cfg.eval_every = every;
        self
    }

    pub fn eval_batches(mut self, n: usize) -> Self {
        self.cfg.eval_batches = n;
        self
    }

    /// Apply a `key=value` config override (same grammar as the CLI).
    pub fn override_kv(mut self, kv: &str) -> Self {
        if let Err(e) = self.cfg.override_kv(kv) {
            self.set_err(ApiError::config(e));
        }
        self
    }

    /// Load this checkpoint into the session at build time (trained
    /// weights + optimizer + step + gamma RNG).
    pub fn checkpoint(mut self, path: impl Into<PathBuf>) -> Self {
        self.ckpt = Some(path.into());
        self
    }

    /// Fine-tune from this checkpoint (`init_from` config key):
    /// mechanically identical to [`SessionBuilder::checkpoint`], but
    /// carried in the config so every rank of a spawned world applies it.
    pub fn init_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.cfg.init_from = Some(path.into());
        self
    }

    /// Freeze the embedding group(s) during training (`freeze_embed`
    /// config key): their gradients are zeroed, they are excluded from the
    /// all-reduce payload, and the optimizer skips them — embeddings stay
    /// bit-identical to the loaded checkpoint.
    pub fn freeze_embed(mut self, freeze: bool) -> Self {
        self.cfg.freeze_embed = freeze;
        self
    }

    /// Install a kernel tuning profile (written by `bdia tune` /
    /// [`Session::tune`]) at build time.  Purely a speed knob: any legal
    /// profile yields bit-identical results.  A corrupt or wrong-version
    /// file is reported with a warning and the default profile is used.
    pub fn tune_profile(mut self, path: impl Into<PathBuf>) -> Self {
        self.tune_profile = Some(path.into());
        self
    }

    /// Observe training / evaluation / serving progress.
    pub fn event_sink(mut self, sink: Arc<dyn EventSink>) -> Self {
        self.sink = sink;
        self
    }

    fn set_err(&mut self, e: ApiError) {
        // keep the first error: it is the root cause
        if self.pending_err.is_none() {
            self.pending_err = Some(e);
        }
    }

    /// Validate, load the runtime, construct the engine, and (optionally)
    /// load the checkpoint.
    pub fn build(mut self) -> ApiResult<Session> {
        if let Some(e) = self.pending_err.take() {
            return Err(e);
        }
        let mut cfg = self.cfg;

        // unknown model names fail here with the full list of valid names;
        // on-disk AOT bundles with arbitrary names stay reachable
        let on_disk =
            cfg.artifacts_dir.join(&cfg.model).join("manifest.json").exists();
        if !on_disk {
            ModelId::parse(&cfg.model)?;
        }

        #[cfg(not(feature = "pjrt"))]
        if cfg.backend == BackendKind::Pjrt {
            return Err(ApiError::Backend(
                "this binary was built without the 'pjrt' cargo feature; \
                 rebuild with `--features pjrt` (and the xla dependency \
                 enabled in rust/Cargo.toml) or use the native backend"
                    .into(),
            ));
        }

        // size the deterministic kernel pool (0 = auto); bit-identical
        // results at any value, so this is purely a speed knob
        crate::kernels::pool::set_threads(cfg.threads);

        // install the kernel tuning profile before any kernel runs.  Also
        // purely a speed knob: any legal profile is bit-exact by
        // construction, so a bad file can safely fall back to the default.
        if let Some(path) = &self.tune_profile {
            match crate::kernels::KernelProfile::load(path) {
                Ok(p) => crate::kernels::profile::set_active(p, Some(path.clone())),
                Err(e) => {
                    eprintln!(
                        "warning: ignoring tune profile: {e:#}; \
                         continuing with the default profile"
                    );
                    crate::kernels::profile::reset_active();
                }
            }
        }

        let rt = Runtime::load_with(&cfg.artifacts_dir, &cfg.model, cfg.backend)
            .map_err(|e| {
                ApiError::Backend(format!(
                    "loading bundle '{}' ({}): {e:#}",
                    cfg.model,
                    cfg.backend.name()
                ))
            })?;
        if self.dataset_auto {
            cfg.dataset = serve_bench::default_dataset(rt.manifest.family).into();
        }
        // engine construction validates the config/mode combination
        let init_from = cfg.init_from.clone();
        let engine = if cfg.mode == TrainMode::RevVit {
            if init_from.is_some() || cfg.freeze_embed {
                return Err(ApiError::Config(
                    "fine-tuning (init_from / freeze_embed) drives the \
                     BDIA/vanilla trainer; the RevViT baseline has no \
                     persistence"
                        .into(),
                ));
            }
            if cfg.ranks > 1 {
                return Err(ApiError::Config(
                    "distributed training drives the BDIA/vanilla trainer \
                     only; the RevViT baseline has no collective integration \
                     — set ranks=1"
                        .into(),
                ));
            }
            Engine::RevVit(Box::new(
                RevVitTrainer::with_runtime(cfg, rt).map_err(ApiError::config)?,
            ))
        } else {
            Engine::Bdia(Box::new(
                Trainer::with_runtime(cfg, rt).map_err(ApiError::config)?,
            ))
        };

        let mut session = Session {
            engine,
            // the engine applied `init_from` itself (every rank of a
            // spawned world does); reflect it in the session's provenance
            resumed_from: init_from,
            sink: self.sink,
            dist_rank: self.dist_rank,
            rendezvous: self.rendezvous,
        };
        if let Some(path) = self.ckpt {
            session.resume(&path)?;
        }
        Ok(session)
    }
}

/// One embeddable handle over the whole BDIA lifecycle: train, evaluate,
/// infer, checkpoint, serve, bench.
///
/// Construct with [`Session::builder`]; see the module docs of
/// [`crate::api`] for the design and the error taxonomy.
pub struct Session {
    engine: Engine,
    sink: Arc<dyn EventSink>,
    resumed_from: Option<PathBuf>,
    dist_rank: Option<usize>,
    rendezvous: Option<String>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// The effective configuration (after file, overrides and setters).
    pub fn config(&self) -> &TrainConfig {
        match &self.engine {
            Engine::Bdia(t) => &t.cfg,
            Engine::RevVit(t) => &t.cfg,
        }
    }

    pub fn model(&self) -> &str {
        &self.config().model
    }

    pub fn family(&self) -> Family {
        self.runtime().manifest.family
    }

    /// Completed optimization steps (nonzero after training or a resume).
    pub fn step(&self) -> usize {
        match &self.engine {
            Engine::Bdia(t) => t.step(),
            Engine::RevVit(t) => t.step(),
        }
    }

    pub fn n_params(&self) -> usize {
        match &self.engine {
            Engine::Bdia(t) => t.n_params(),
            Engine::RevVit(t) => t.n_params(),
        }
    }

    pub fn runtime(&self) -> &Runtime {
        match &self.engine {
            Engine::Bdia(t) => &t.rt,
            Engine::RevVit(t) => &t.rt,
        }
    }

    /// The live parameters (trained in place by [`Session::train`]).
    pub fn params(&self) -> &ParamStore {
        match &self.engine {
            Engine::Bdia(t) => &t.params,
            Engine::RevVit(t) => &t.params,
        }
    }

    /// Checkpoint the session was built from / last resumed from.
    pub fn resumed_from(&self) -> Option<&Path> {
        self.resumed_from.as_deref()
    }

    /// Human-readable weight provenance for reports and warnings.
    pub fn provenance(&self) -> String {
        match (&self.resumed_from, self.step()) {
            (Some(p), step) => format!("checkpoint {}, step {step}", p.display()),
            (None, 0) => format!("untrained seed {}", self.config().seed),
            (None, step) => format!("trained in-session, step {step}"),
        }
    }

    /// The γ-RNG base state `(state, box-muller spare)` driving this
    /// session's gamma streams — restored from the checkpoint on a resume,
    /// so `bdia info` / `bdia eval --ckpt` can surface what training would
    /// continue from.  `None` for the RevViT baseline (no γ-RNG).
    pub fn gamma_rng_state(&self) -> Option<(u64, Option<f32>)> {
        match &self.engine {
            Engine::Bdia(t) => Some(t.rng_gamma_state()),
            Engine::RevVit(_) => None,
        }
    }

    /// The dataset named by the config, shaped for this bundle.
    pub fn dataset(&self) -> ApiResult<Box<dyn Dataset>> {
        let rt = self.runtime();
        make_dataset(self.config(), &rt.manifest.dims, rt.manifest.family)
            .map_err(ApiError::config)
    }

    // ------------------------------------------------------------------
    // distribution
    // ------------------------------------------------------------------

    /// This session's rank (builder `.rank(..)`, default 0).
    pub fn rank(&self) -> usize {
        self.dist_rank.unwrap_or(0)
    }

    /// True once a data-parallel world is attached to the engine.
    pub fn has_dist(&self) -> bool {
        match &self.engine {
            Engine::Bdia(t) => t.has_dist(),
            Engine::RevVit(_) => false,
        }
    }

    /// Attach an already-assembled world (the in-process harness path —
    /// see [`dist::run_local_world`]).  Broadcasts rank 0's training state
    /// so any resume done on rank 0 reaches every rank; call it *after*
    /// [`Session::resume`].
    pub fn attach_dist(&mut self, role: DistRole) -> ApiResult<()> {
        match &mut self.engine {
            Engine::Bdia(t) => t.attach_dist(role).map_err(ApiError::dist),
            Engine::RevVit(_) => Err(ApiError::Config(
                "distributed training drives the BDIA/vanilla trainer only"
                    .into(),
            )),
        }
    }

    /// Leave the attached world while keeping all local training state —
    /// the first half of the restart policy.  On rank 0 that state is the
    /// last completed step (a failed collective never commits), so a
    /// subsequent [`Session::connect_dist`] / [`Session::train`] on a
    /// rebuilt world re-broadcasts it and training resumes bit-exactly.
    /// No-op when no world is attached.
    pub fn detach_dist(&mut self) {
        if let Engine::Bdia(t) = &mut self.engine {
            t.detach_dist();
        }
    }

    /// Join the world described by the builder's `.ranks`/`.rank`/
    /// `.rendezvous`: rank 0 binds and accepts (pass `prebound` if a
    /// launcher already bound the listener to learn its port), workers
    /// connect with retry.  Blocks until the full world assembles.
    pub fn connect_dist(&mut self, prebound: Option<Rendezvous>) -> ApiResult<()> {
        let role = dist::establish(
            self.config(),
            self.rank(),
            self.rendezvous.as_deref(),
            prebound,
        )
        .map_err(ApiError::dist)?;
        self.attach_dist(role)
    }

    // ------------------------------------------------------------------
    // training
    // ------------------------------------------------------------------

    /// Run the training loop to `config().steps`, emitting step / eval /
    /// checkpoint events to the session's [`EventSink`].
    ///
    /// With `ranks > 1` configured and no world attached yet, this first
    /// joins the rendezvous (blocking until all ranks arrive) — so `N`
    /// processes each calling `train` *are* the distributed run.
    pub fn train(&mut self, opts: &TrainOpts) -> ApiResult<TrainReport> {
        if self.config().ranks > 1 && !self.has_dist() {
            self.connect_dist(None)?;
        }
        let run_name = opts.run_name.clone().unwrap_or_else(|| {
            format!("{}_{}", self.config().model, self.config().mode.name())
        });
        if matches!(self.engine, Engine::RevVit(_)) && self.config().save_every > 0 {
            return Err(ApiError::Config(
                "checkpointing is supported by the BDIA/vanilla trainer only \
                 (RevViT baseline has no persistence); set save_every=0"
                    .into(),
            ));
        }
        let ds = self.dataset()?;
        let sink = Arc::clone(&self.sink);
        let log = match &mut self.engine {
            Engine::Bdia(t) => t.run_observed(ds.as_ref(), &run_name, sink.as_ref()),
            Engine::RevVit(t) => {
                t.run_observed(ds.as_ref(), &run_name, sink.as_ref())
            }
        }
        .map_err(ApiError::engine)?;
        if let Some(out) = &opts.csv_out {
            log.write_csv(out).map_err(|e| ApiError::io(out.clone(), e))?;
        }
        Ok(TrainReport {
            run_name,
            steps_completed: self.step(),
            mean_ms_per_step: log.mean_ms_per_step(),
            log,
        })
    }

    /// One optimization step on a caller-supplied batch (no events; the
    /// loop in [`Session::train`] is the observed path).
    pub fn train_step(&mut self, batch: &Batch) -> ApiResult<StepStats> {
        match &mut self.engine {
            Engine::Bdia(t) => t.train_step(batch),
            Engine::RevVit(t) => t.train_step(batch),
        }
        .map_err(ApiError::engine)
    }

    /// Training forward pass only; returns the batch loss (bench probe —
    /// BDIA/vanilla engines only).
    pub fn forward_loss(&mut self, batch: &Batch) -> ApiResult<f32> {
        match &mut self.engine {
            Engine::Bdia(t) => Ok(t.forward(batch).map_err(ApiError::train)?.loss),
            Engine::RevVit(_) => Err(ApiError::Config(
                "forward_loss probes the BDIA/vanilla coordinator; the RevViT \
                 baseline exposes train_step only"
                    .into(),
            )),
        }
    }

    // ------------------------------------------------------------------
    // evaluation / inference
    // ------------------------------------------------------------------

    /// Mean (loss, acc) over held-out batches at a constant inference
    /// gamma; emits one [`EvalEvent`] carrying the gamma used.
    ///
    /// Builds the config's dataset per call; sweeps evaluating many gammas
    /// should build it once and use [`Session::evaluate_on`].
    pub fn evaluate(&self, opts: &EvalOpts) -> ApiResult<EvalReport> {
        let ds = self.dataset()?;
        self.evaluate_on(ds.as_ref(), opts)
    }

    /// [`Session::evaluate`] on a caller-supplied dataset (built once via
    /// [`Session::dataset`], or any custom [`Dataset`] shaped for this
    /// bundle).
    pub fn evaluate_on(
        &self,
        ds: &dyn Dataset,
        opts: &EvalOpts,
    ) -> ApiResult<EvalReport> {
        let n = opts.batches.unwrap_or(self.config().eval_batches);
        let (loss, acc) = match &self.engine {
            Engine::Bdia(t) => {
                t.evaluate(ds, n, opts.gamma).map_err(ApiError::train)?
            }
            Engine::RevVit(t) => {
                if opts.gamma != 0.0 {
                    return Err(ApiError::Config(
                        "the RevViT baseline has no standard-transformer \
                         inference form; inference gamma must be 0.0"
                            .into(),
                    ));
                }
                t.evaluate(ds, n).map_err(ApiError::train)?
            }
        };
        self.sink.on_eval(&EvalEvent {
            step: self.step(),
            gamma: opts.gamma,
            loss,
            acc,
            elapsed_us: crate::obs::now_us(),
        });
        Ok(EvalReport {
            loss,
            acc,
            gamma: opts.gamma,
            step: self.step(),
            provenance: self.provenance(),
        })
    }

    /// Score one example exactly as the serving path would
    /// (fused `model_infer_ex`); returns (loss, correct).
    pub fn infer(&self, example: &Example, gamma: f32) -> ApiResult<(f32, f32)> {
        Ok(self.infer_batch(std::slice::from_ref(example), gamma)?[0])
    }

    /// Score a batch of examples; per-example (loss, correct) pairs in
    /// request order.  Accepts any length: inputs are chunked to the
    /// manifest batch dimension, and per-example outputs are slot- and
    /// neighbour-invariant, so results are bit-identical to
    /// single-example calls regardless of chunking.
    pub fn infer_batch(
        &self,
        examples: &[Example],
        gamma: f32,
    ) -> ApiResult<Vec<(f32, f32)>> {
        let max = self.runtime().manifest.dims.batch.max(1);
        let mut out = Vec::with_capacity(examples.len());
        for chunk in examples.chunks(max) {
            out.extend(
                wire::infer_batch(self.runtime(), self.params(), chunk, gamma)
                    .map_err(ApiError::train)?,
            );
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // generation
    // ------------------------------------------------------------------

    /// Autoregressively generate tokens after `prompt` with this session's
    /// **current parameters** (GPT-family models only).  Decoding is
    /// incremental against a per-call KV-cache workspace and bit-identical
    /// to re-forwarding the whole prefix at every step — at any thread
    /// count and under any kernel tuning profile.  Each generated token is
    /// reported to the session's [`EventSink`] via
    /// [`EventSink::on_token`].
    pub fn generate(
        &self,
        prompt: &[i32],
        opts: &crate::generate::GenOpts,
    ) -> ApiResult<crate::generate::GenReport> {
        self.generate_stream(prompt, opts, |_| {})
    }

    /// [`Session::generate`] with a per-token callback — fires in decode
    /// order, before the report is assembled, so callers can stream.
    pub fn generate_stream(
        &self,
        prompt: &[i32],
        opts: &crate::generate::GenOpts,
        mut on_token: impl FnMut(&TokenEvent),
    ) -> ApiResult<crate::generate::GenReport> {
        let mut session =
            crate::generate::GenSession::new(self.runtime(), prompt, opts.clone())
                .map_err(ApiError::config)?;
        let sink = Arc::clone(&self.sink);
        crate::generate::run_session(
            self.runtime(),
            self.params(),
            &mut session,
            |index, token, ms| {
                let e = TokenEvent {
                    index,
                    token,
                    latency_us: (ms * 1e3) as u64,
                    elapsed_us: crate::obs::now_us(),
                };
                sink.on_token(&e);
                on_token(&e);
            },
        )
        .map_err(ApiError::train)
    }

    // ------------------------------------------------------------------
    // persistence
    // ------------------------------------------------------------------

    /// Write the full training state (params + optimizer + step + gamma
    /// RNG) so a resumed session is bit-identical to an uninterrupted one.
    pub fn save(&self, path: &Path) -> ApiResult<()> {
        match &self.engine {
            Engine::Bdia(t) => t
                .save_checkpoint(path)
                .map_err(|e| ApiError::ckpt(path, e))?,
            Engine::RevVit(_) => {
                return Err(ApiError::Config(
                    "RevViT baseline has no persistence; use mode=bdia or \
                     mode=vanilla"
                        .into(),
                ))
            }
        }
        self.sink
            .on_checkpoint(&CheckpointEvent { step: self.step(), path: path.into() });
        Ok(())
    }

    /// Restore state written by [`Session::save`] (or `bdia train
    /// save_every=K`).
    pub fn resume(&mut self, path: &Path) -> ApiResult<()> {
        match &mut self.engine {
            Engine::Bdia(t) => t
                .load_checkpoint(path)
                .map_err(|e| ApiError::ckpt(path, e))?,
            Engine::RevVit(_) => {
                return Err(ApiError::Config(
                    "RevViT baseline has no persistence; use mode=bdia or \
                     mode=vanilla"
                        .into(),
                ))
            }
        }
        self.resumed_from = Some(path.to_path_buf());
        Ok(())
    }

    // ------------------------------------------------------------------
    // serving
    // ------------------------------------------------------------------

    /// Start an HTTP inference server on this session's model and
    /// **current parameters** (trained weights serve without touching
    /// disk).  Per-request events flow to the session's [`EventSink`].
    pub fn serve(&self, opts: &ServeOpts) -> ApiResult<ServerHandle> {
        let cfg = self.config();
        let serve_cfg = ServeConfig {
            model: cfg.model.clone(),
            backend: cfg.backend,
            artifacts_dir: cfg.artifacts_dir.clone(),
            ckpt: None, // params come from the session, below
            port: opts.port,
            workers: opts.workers,
            batch_window: opts.batch_window,
            threads: cfg.threads,
            queue_cap: opts.queue_cap,
        };
        // the server owns its runtime (compiled sets are not shareable by
        // value); recompiling is cheap on the native backend
        let rt = Runtime::load_with(&cfg.artifacts_dir, &cfg.model, cfg.backend)
            .map_err(|e| ApiError::Backend(format!("{e:#}")))?;
        let inner = Server::start_with_parts(
            serve_cfg,
            rt,
            self.params().clone(),
            Arc::clone(&self.sink),
        )
        .map_err(ApiError::serve)?;
        Ok(ServerHandle { inner })
    }

    /// Start a fleet router on this session's model and **current
    /// parameters**: the router pushes the session's weights to every
    /// replica that joins the backplane, so the whole fleet serves
    /// bit-identically to [`Session::serve`].  Replicas are separate
    /// processes (`bdia serve --replica --rendezvous <backplane>`) or
    /// threads driving [`crate::fleet::replica::serve_connection`].
    pub fn serve_fleet(&self, opts: &FleetOpts) -> ApiResult<FleetHandle> {
        let cfg = self.config();
        let fleet_cfg = FleetConfig {
            model: cfg.model.clone(),
            backend: cfg.backend,
            artifacts_dir: cfg.artifacts_dir.clone(),
            ckpt: None, // params come from the session, below
            port: opts.port,
            backplane: opts.backplane.clone(),
            batch_window: opts.batch_window,
            queue_cap: opts.queue_cap,
            deadline: opts.deadline,
        };
        let rt = Runtime::load_with(&cfg.artifacts_dir, &cfg.model, cfg.backend)
            .map_err(|e| ApiError::Backend(format!("{e:#}")))?;
        let inner = Router::start_with_parts(
            fleet_cfg,
            rt,
            self.params().clone(),
            Arc::clone(&self.sink),
        )
        .map_err(ApiError::serve)?;
        Ok(FleetHandle { inner })
    }

    /// Load-test the serving path and verify responses are bit-identical
    /// to direct local inference.  Self-hosts through [`Session::serve`]
    /// (so the server runs this session's **current** parameters, exactly
    /// like `serve` would) unless `opts.addr` targets a running server —
    /// in that case the remote server must hold the same weights as this
    /// session for verification to pass.
    pub fn bench_serve(
        &self,
        opts: &ServeBenchOpts,
    ) -> ApiResult<serve_bench::BenchSummary> {
        let cfg = self.config();
        // run_against reads only model / gamma / requests / concurrency /
        // verify — the reference weights are this session's live params,
        // and server configuration is handled by Session::serve below
        let bench_opts = serve_bench::BenchOpts {
            model: cfg.model.clone(),
            requests: opts.requests,
            concurrency: opts.concurrency,
            gamma: opts.gamma,
            verify: opts.verify,
            ..serve_bench::BenchOpts::default()
        };
        let (server, addr) = match opts.addr {
            Some(a) => (None, a),
            None => {
                let handle = self.serve(&ServeOpts {
                    port: 0,
                    workers: opts.workers,
                    batch_window: opts.batch_window,
                    ..ServeOpts::default()
                })?;
                let a = handle.addr();
                println!(
                    "bench-serve: self-hosted {} on {a} ({} workers, window \
                     {:?}, session params)",
                    cfg.model, opts.workers, opts.batch_window
                );
                (Some(handle), a)
            }
        };
        let summary = serve_bench::run_against(
            &bench_opts,
            self.runtime(),
            self.params(),
            addr,
        );
        if let Some(handle) = server {
            handle.shutdown()?;
        }
        summary.map_err(ApiError::serve)
    }

    // ------------------------------------------------------------------
    // benchmarking / inspection
    // ------------------------------------------------------------------

    /// Tune the kernel profile for this model at the current kernel-pool
    /// thread count (`bdia tune`): capture the shapes the three hot paths
    /// actually run, benchmark candidate parameters for each on the live
    /// pool, and compose the winners into a [`crate::kernels::KernelProfile`]
    /// (persisted atomically to `opts.out` when set).
    ///
    /// Tuning never changes results — any legal profile is bit-exact by
    /// construction — so the only outputs are the profile and timings.
    /// Note the shape capture runs one real optimization step.
    pub fn tune(&mut self, opts: &TuneOpts) -> ApiResult<TuneReport> {
        if matches!(self.engine, Engine::RevVit(_)) {
            return Err(ApiError::Config(
                "tune profiles the BDIA/vanilla hot paths; mode=revvit is \
                 not tunable through the session facade"
                    .into(),
            ));
        }
        let ds = self.dataset()?;
        let batch = ds.train_batch(0);
        let threads = crate::kernels::pool::threads();
        let id = format!("{}-t{}", self.model(), threads);

        // capture every (op, dims, threads) key the hot paths look up
        crate::kernels::profile::record_shapes(true);
        {
            let Engine::Bdia(tr) = &mut self.engine else { unreachable!() };
            let probe = (|| -> anyhow::Result<()> {
                tr.forward(&batch)?;
                tr.train_step(&batch)?;
                tr.evaluate(ds.as_ref(), 1, 0.0)?;
                Ok(())
            })();
            crate::kernels::profile::record_shapes(false);
            probe.map_err(ApiError::train)?;
        }
        let shapes = crate::kernels::profile::take_recorded();

        let rep = crate::kernels::tune::search(&id, &shapes, opts.quick);
        let (mut default_ms, mut tuned_ms) = (0.0f64, 0.0f64);
        for s in &rep.shapes {
            default_ms += s.default_ms;
            tuned_ms += s.best_ms;
        }
        if let Some(path) = &opts.out {
            rep.profile
                .save(path)
                .map_err(|e| ApiError::io(path.clone(), e))?;
        }
        Ok(TuneReport {
            model: self.model().to_string(),
            threads,
            profile: rep.profile,
            path: opts.out.clone(),
            shapes_tuned: rep.shapes.len(),
            shapes_dropped: rep.dropped,
            default_ms,
            tuned_ms,
        })
    }

    /// Time the three hot paths (training forward, full train step, fused
    /// quantized inference) at the current kernel-pool thread count.
    /// `bdia bench` aggregates these rows into `BENCH_10.json`.
    pub fn bench(
        &mut self,
        budget: Duration,
        max_iters: usize,
    ) -> ApiResult<SessionTimings> {
        if matches!(self.engine, Engine::RevVit(_)) {
            return Err(ApiError::Config(
                "bench times the BDIA/vanilla hot paths; mode=revvit is not \
                 benchable through the session facade"
                    .into(),
            ));
        }
        let ds = self.dataset()?;
        let batch = ds.train_batch(0);
        let bundle = self.model().to_string();
        let family = format!("{:?}", self.family());
        let threads = crate::kernels::pool::threads();
        let ms = |r: &crate::bench::BenchResult| r.mean.as_secs_f64() * 1e3;

        let Engine::Bdia(tr) = &mut self.engine else { unreachable!() };
        // probe each path once so engine failures surface as ApiError;
        // the .expect()s inside the timed closures then only guard
        // against mid-benchmark state corruption
        tr.forward(&batch).map_err(ApiError::train)?;
        tr.train_step(&batch).map_err(ApiError::train)?;
        tr.evaluate(ds.as_ref(), 1, 0.0).map_err(ApiError::train)?;
        let fwd = crate::bench::bench(
            &format!("{bundle} fwd t={threads}"),
            1,
            max_iters,
            budget,
            || {
                tr.forward(&batch).expect("forward");
            },
        );
        let step = crate::bench::bench(
            &format!("{bundle} step t={threads}"),
            1,
            max_iters,
            budget,
            || {
                tr.train_step(&batch).expect("train_step");
            },
        );
        let infer = crate::bench::bench(
            &format!("{bundle} infer t={threads}"),
            1,
            max_iters,
            budget,
            || {
                tr.evaluate(ds.as_ref(), 1, 0.0).expect("model_infer");
            },
        );
        println!("{}", fwd.row());
        println!("{}", step.row());
        println!("{}", infer.row());
        Ok(SessionTimings {
            bundle,
            family,
            threads,
            profile: crate::kernels::profile::active_id(),
            fwd_ms: ms(&fwd),
            step_ms: ms(&step),
            infer_ms: ms(&infer),
        })
    }

    /// Bundle + runtime inventory (dims, params, per-exec call counts,
    /// kernel-pool and workspace state, analytic peak training memory).
    pub fn describe(&self) -> ModelInfo {
        let rt = self.runtime();
        let m = &rt.manifest;
        let ws = crate::kernels::workspace::stats();
        let peak_memory =
            MemoryModel::peak_by_mode(m.family, &m.dims, m.n_params() * 4);
        ModelInfo {
            name: m.name.clone(),
            family: format!("{:?}", m.family),
            backend: rt.backend.name(),
            dims: m.dims.clone(),
            n_params: m.n_params(),
            call_counts: rt.call_counts(),
            kernel_threads: crate::kernels::pool::threads(),
            kernel_auto_threads: crate::kernels::pool::auto_threads(),
            kernel_spawned_workers: crate::kernels::pool::spawned_workers(),
            tune_profile: crate::kernels::profile::active_id(),
            tune_profile_source: crate::kernels::profile::active_source(),
            workspace_hits: ws.hits,
            workspace_misses: ws.misses,
            workspace_keyed_hits: ws.keyed_hits,
            workspace_keyed_builds: ws.keyed_builds,
            peak_memory,
        }
    }
}
