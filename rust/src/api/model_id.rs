//! Typed model identifiers: kill raw model-name strings at the public
//! boundary.
//!
//! [`ModelId`] enumerates every bundle the native registry can synthesize
//! (`runtime::native::registry::config_names`), so `--help`, the
//! unknown-model error and the builder all render the same list — a unit
//! test keeps the two in lockstep.  On-disk AOT bundles with arbitrary
//! names remain reachable through [`super::SessionBuilder::model_name`],
//! which accepts any name for which `artifacts/<name>/manifest.json`
//! exists.

use super::error::ApiError;
use crate::runtime::native::registry;
use std::fmt;
use std::str::FromStr;

/// A bundle the native registry knows how to materialise.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelId {
    /// Paper §5.1 ViT (CIFAR-10 stand-in), K = 6 blocks.
    VitS10,
    /// Paper §5.1 ViT with 100 classes (CIFAR-100 stand-in).
    VitS100,
    /// Paper §5.3 (nano)GPT2, 12 blocks, tiny-corpus overfitting.
    GptTiny,
    /// Paper §5.2 en→fr translation, 6+6 encoder/decoder blocks.
    EncdecMt,
    /// End-to-end GPT config.
    GptE2e,
    /// Tiny ViT shape for tests / CI smoke.
    SmokeVit,
    /// Tiny GPT shape for tests / CI smoke.
    SmokeGpt,
    /// Tiny encoder-decoder shape for tests / CI smoke.
    SmokeEncdec,
}

impl ModelId {
    /// Every registered model, in registry order (drives `--help` and the
    /// unknown-model error).
    pub const ALL: [ModelId; 8] = [
        ModelId::VitS10,
        ModelId::VitS100,
        ModelId::GptTiny,
        ModelId::EncdecMt,
        ModelId::GptE2e,
        ModelId::SmokeVit,
        ModelId::SmokeGpt,
        ModelId::SmokeEncdec,
    ];

    /// The registry / bundle-directory name.
    pub fn name(self) -> &'static str {
        match self {
            ModelId::VitS10 => "vit_s10",
            ModelId::VitS100 => "vit_s100",
            ModelId::GptTiny => "gpt_tiny",
            ModelId::EncdecMt => "encdec_mt",
            ModelId::GptE2e => "gpt_e2e",
            ModelId::SmokeVit => "smoke_vit",
            ModelId::SmokeGpt => "smoke_gpt",
            ModelId::SmokeEncdec => "smoke_encdec",
        }
    }

    /// All registered names (the `known` payload of
    /// [`ApiError::UnknownModel`]).
    pub fn known_names() -> Vec<&'static str> {
        Self::ALL.iter().map(|m| m.name()).collect()
    }

    /// Parse a registry name; failure carries the valid names and a
    /// closest-match suggestion.
    pub fn parse(s: &str) -> Result<Self, ApiError> {
        Self::ALL
            .iter()
            .copied()
            .find(|m| m.name() == s)
            .ok_or_else(|| ApiError::UnknownModel {
                name: s.to_string(),
                known: Self::known_names(),
            })
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ModelId {
    type Err = ApiError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enum_is_in_lockstep_with_native_registry() {
        // ModelId is the public face of the registry; if a config is added
        // or renamed there, this test forces the enum (and with it --help,
        // the unknown-model error and the docs) to follow.
        let enum_names: Vec<&str> = ModelId::ALL.iter().map(|m| m.name()).collect();
        assert_eq!(enum_names, registry::config_names().to_vec());
        for id in ModelId::ALL {
            registry::manifest_for(id.name())
                .unwrap_or_else(|_| panic!("registry rejects {id}"));
        }
    }

    #[test]
    fn parse_display_roundtrip() {
        for id in ModelId::ALL {
            assert_eq!(ModelId::parse(id.name()).unwrap(), id);
            assert_eq!(id.to_string(), id.name());
            assert_eq!(id.name().parse::<ModelId>().unwrap(), id);
        }
    }

    #[test]
    fn parse_failure_is_structured_and_suggests() {
        let err = ModelId::parse("vit_s100x").unwrap_err();
        let ApiError::UnknownModel { name, known } = &err else {
            panic!("wrong variant: {err:?}")
        };
        assert_eq!(name, "vit_s100x");
        assert_eq!(known, &ModelId::known_names());
        assert!(err.to_string().contains("did you mean 'vit_s100'"));
    }
}
