//! The public error taxonomy: one structured enum instead of stringly
//! `anyhow` chains.
//!
//! Everything that crosses the [`super::Session`] boundary is an
//! [`ApiError`], so embedders can `match` on *what went wrong* (bad config
//! vs unknown model vs damaged checkpoint vs backend trouble) instead of
//! grepping error strings.  Every variant renders an actionable message —
//! the unknown-model variant, for example, always carries the full list of
//! known model names plus a "did you mean" suggestion.
//!
//! Internally the crate keeps using `anyhow` (the layers below the facade
//! are not public API); [`ApiError`] wraps those chains at the boundary
//! with `format!("{e:#}")` so no context is lost.

use std::fmt;
use std::path::PathBuf;

/// Result alias for every [`super::Session`] method.
pub type ApiResult<T> = std::result::Result<T, ApiError>;

/// A checkpoint-layer failure, tagged with the file it concerns.
#[derive(Debug)]
pub struct CkptError {
    /// The checkpoint file involved (save target or load source).
    pub path: PathBuf,
    /// What went wrong (truncation, CRC mismatch, model mismatch, I/O …).
    pub message: String,
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "checkpoint {}: {}", self.path.display(), self.message)
    }
}

impl std::error::Error for CkptError {}

/// Structured error for every [`super::Session`] operation.
#[derive(Debug)]
pub enum ApiError {
    /// Invalid or inconsistent configuration (bad key, bad value, or a
    /// combination the engine rejects, e.g. `mode=bdia` with
    /// `gamma_mag != 0.5`).
    Config(String),
    /// Model name not in the native registry and not an on-disk bundle.
    /// Carries the full list of valid names so callers (and `--help`) can
    /// render it without a second source of truth.
    UnknownModel {
        name: String,
        known: Vec<&'static str>,
    },
    /// Saving or loading a checkpoint failed.
    Checkpoint(CkptError),
    /// Execution-backend construction or dispatch failed (e.g. `pjrt`
    /// requested on a build without the cargo feature).
    Backend(String),
    /// The serving layer failed to start or run.
    Serve(String),
    /// Training / evaluation / inference failed inside the engine.
    Train(String),
    /// Distributed rendezvous, collective or world-config verification
    /// failed (rank mismatch, unreachable rendezvous, config digest
    /// disagreement between ranks, …).
    Dist(String),
    /// Filesystem failure outside the checkpoint format (CSV logs, bench
    /// reports, config files).
    Io { path: PathBuf, message: String },
}

impl ApiError {
    /// Wrap an `anyhow` chain from the engine layers as a `Train` error.
    pub(crate) fn train(e: anyhow::Error) -> Self {
        ApiError::Train(format!("{e:#}"))
    }

    /// Wrap an `anyhow` chain from config plumbing as a `Config` error.
    pub(crate) fn config(e: anyhow::Error) -> Self {
        ApiError::Config(format!("{e:#}"))
    }

    /// Wrap an `anyhow` chain from the serving layer.
    pub(crate) fn serve(e: anyhow::Error) -> Self {
        ApiError::Serve(format!("{e:#}"))
    }

    /// Wrap an `anyhow` chain from the distributed layer.
    pub(crate) fn dist(e: anyhow::Error) -> Self {
        ApiError::Dist(format!("{e:#}"))
    }

    /// Route an engine-layer failure by cause: a chain carrying a
    /// [`crate::dist::DistError`] (a lost rank, a deadline expiry, a
    /// relayed world abort) becomes [`ApiError::Dist`] — so embedders and
    /// the CLI's restart policy can react to rank loss — while everything
    /// else stays [`ApiError::Train`].
    pub(crate) fn engine(e: anyhow::Error) -> Self {
        if e.downcast_ref::<crate::dist::DistError>().is_some() {
            ApiError::dist(e)
        } else {
            ApiError::train(e)
        }
    }

    /// Wrap an `anyhow` chain from checkpoint save/load, keeping the path.
    pub(crate) fn ckpt(path: impl Into<PathBuf>, e: anyhow::Error) -> Self {
        ApiError::Checkpoint(CkptError {
            path: path.into(),
            message: format!("{e:#}"),
        })
    }

    /// Wrap a filesystem failure, keeping the path.
    pub(crate) fn io(path: impl Into<PathBuf>, e: anyhow::Error) -> Self {
        ApiError::Io {
            path: path.into(),
            message: format!("{e:#}"),
        }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::Config(m) => write!(f, "invalid configuration: {m}"),
            ApiError::UnknownModel { name, known } => {
                write!(f, "unknown model '{name}'")?;
                if let Some(s) = suggest(name, known.iter().copied()) {
                    write!(f, " (did you mean '{s}'?)")?;
                }
                write!(
                    f,
                    " — known models: {}; or point artifacts_dir at an \
                     exported bundle",
                    known.join(", ")
                )
            }
            ApiError::Checkpoint(e) => write!(f, "{e}"),
            ApiError::Backend(m) => write!(f, "backend error: {m}"),
            ApiError::Serve(m) => write!(f, "serve error: {m}"),
            ApiError::Train(m) => write!(f, "training error: {m}"),
            ApiError::Dist(m) => write!(f, "distributed training error: {m}"),
            ApiError::Io { path, message } => {
                write!(f, "io error at {}: {message}", path.display())
            }
        }
    }
}

impl std::error::Error for ApiError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ApiError::Checkpoint(e) => Some(e),
            _ => None,
        }
    }
}

/// Levenshtein distance, for "did you mean" hints (inputs are short flag /
/// model names, so the O(nm) table is fine).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest candidate within an edit distance of 2 (typo range), if any.
/// Shared by the unknown-model error and the CLI's unknown-flag hint.
pub fn suggest<'a>(
    input: &str,
    candidates: impl IntoIterator<Item = &'a str>,
) -> Option<&'a str> {
    candidates
        .into_iter()
        .map(|c| (edit_distance(input, c), c))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, c)| c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suggest_finds_close_typos_only() {
        let names = ["threads", "backend", "ckpt-dir"];
        assert_eq!(suggest("thread", names), Some("threads"));
        assert_eq!(suggest("backendd", names), Some("backend"));
        assert_eq!(suggest("zzzzzz", names), None);
    }

    #[test]
    fn unknown_model_message_lists_names_and_suggests() {
        let e = ApiError::UnknownModel {
            name: "vit_s1".into(),
            known: vec!["vit_s10", "gpt_tiny"],
        };
        let msg = e.to_string();
        assert!(msg.contains("vit_s10") && msg.contains("gpt_tiny"), "{msg}");
        assert!(msg.contains("did you mean 'vit_s10'"), "{msg}");
    }

    #[test]
    fn error_trait_and_source_chain() {
        let e = ApiError::Checkpoint(CkptError {
            path: PathBuf::from("x.ckpt"),
            message: "CRC mismatch".into(),
        });
        let dynerr: &dyn std::error::Error = &e;
        assert!(dynerr.source().unwrap().to_string().contains("CRC"));
        assert!(e.to_string().contains("x.ckpt"));
    }
}
