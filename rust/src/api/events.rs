//! Typed lifecycle observation: the [`EventSink`] trait.
//!
//! The training loop, evaluation path and serving layer all report
//! progress through one observer trait instead of ad-hoc `println!`s, so
//! an embedder can collect metrics, drive a progress bar, or stream events
//! to its own telemetry — and the CLI's human-readable progress is just
//! one sink implementation ([`StdoutSink`]).
//!
//! Sinks must be `Send + Sync`: the serving layer calls
//! [`EventSink::on_request`] from concurrent connection handlers.
//! Callbacks fire on the hot loop, so implementations should be cheap
//! (push to a channel / vec, not block on I/O).

use std::path::PathBuf;
use std::sync::Mutex;

/// One completed optimization step.
#[derive(Clone, Copy, Debug)]
pub struct StepEvent {
    /// Step index (strictly increasing within a run; resumes continue from
    /// the checkpointed counter).
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub grad_norm: f32,
    /// Wall time of this step in milliseconds.
    pub ms: f64,
    /// Monotonic time since process start when the event fired,
    /// microseconds ([`crate::obs::now_us`]) — one clock orders events
    /// from every layer and thread, and it is non-decreasing within a
    /// sink by construction.
    pub elapsed_us: u64,
}

/// One evaluation pass over held-out batches.
#[derive(Clone, Copy, Debug)]
pub struct EvalEvent {
    /// Steps completed when the evaluation ran.
    pub step: usize,
    /// The constant inference gamma used (0.0 is the paper's standard
    /// inference; the RevViT baseline has no gamma and reports 0.0).
    pub gamma: f32,
    pub loss: f32,
    pub acc: f32,
    /// Monotonic time since process start, microseconds.
    pub elapsed_us: u64,
}

/// One checkpoint written by the training loop or [`super::Session::save`].
#[derive(Clone, Debug)]
pub struct CheckpointEvent {
    pub step: usize,
    pub path: PathBuf,
}

/// One served inference request (terminal state: answered or failed).
#[derive(Clone, Copy, Debug)]
pub struct RequestEvent {
    /// End-to-end latency observed by the server handler, microseconds.
    pub latency_us: u64,
    /// Monotonic time since process start, microseconds.
    pub elapsed_us: u64,
    /// False when the request errored (bad body, engine failure).
    pub ok: bool,
}

/// One token emitted by an autoregressive generation
/// ([`super::Session::generate`] / the streaming `/generate` endpoint).
#[derive(Clone, Copy, Debug)]
pub struct TokenEvent {
    /// 0-based index among the *generated* tokens (prompt excluded).
    pub index: usize,
    pub token: i32,
    /// Wall time of the decode step that produced it, microseconds.
    pub latency_us: u64,
    /// Monotonic time since process start, microseconds.
    pub elapsed_us: u64,
}

/// Observer for training / evaluation / serving progress.  All methods
/// default to no-ops, so sinks implement only what they care about.
pub trait EventSink: Send + Sync {
    fn on_step(&self, _e: &StepEvent) {}
    fn on_eval(&self, _e: &EvalEvent) {}
    fn on_checkpoint(&self, _e: &CheckpointEvent) {}
    fn on_request(&self, _e: &RequestEvent) {}
    fn on_token(&self, _e: &TokenEvent) {}
}

/// Discards everything (the default sink).
pub struct NullSink;

impl EventSink for NullSink {}

/// Human-readable progress on stdout — the CLI's sink.
pub struct StdoutSink {
    /// Print a step line every `every` steps (0 prints nothing per-step;
    /// eval and checkpoint lines always print).
    pub every: usize,
}

impl EventSink for StdoutSink {
    fn on_step(&self, e: &StepEvent) {
        if self.every > 0 && e.step % self.every == 0 {
            println!(
                "[t+{:.1}s] step {:>6}  loss {:.4}  acc {:.3}  |g| {:.3e}  {:.0} ms",
                e.elapsed_us as f64 / 1e6,
                e.step,
                e.loss,
                e.acc,
                e.grad_norm,
                e.ms
            );
        }
    }

    fn on_eval(&self, e: &EvalEvent) {
        println!(
            "[t+{:.1}s] eval @ step {:>4} (gamma {}): val_loss {:.4}  val_acc {:.3}",
            e.elapsed_us as f64 / 1e6,
            e.step,
            e.gamma,
            e.loss,
            e.acc
        );
    }

    fn on_checkpoint(&self, e: &CheckpointEvent) {
        println!("checkpoint @ step {} -> {}", e.step, e.path.display());
    }
}

/// Everything a sink can observe, as an owned value (what [`Collector`]
/// records).
#[derive(Clone, Debug)]
pub enum Event {
    Step(StepEvent),
    Eval(EvalEvent),
    Checkpoint(CheckpointEvent),
    Request(RequestEvent),
    Token(TokenEvent),
}

/// Records every event in order — for tests and programmatic consumers.
#[derive(Default)]
pub struct Collector {
    events: Mutex<Vec<Event>>,
}

impl Collector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of everything recorded so far, in arrival order.
    pub fn events(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    /// Drain the recorded events.
    pub fn take(&self) -> Vec<Event> {
        std::mem::take(&mut self.events.lock().unwrap())
    }

    fn push(&self, e: Event) {
        self.events.lock().unwrap().push(e);
    }
}

impl EventSink for Collector {
    fn on_step(&self, e: &StepEvent) {
        self.push(Event::Step(*e));
    }

    fn on_eval(&self, e: &EvalEvent) {
        self.push(Event::Eval(*e));
    }

    fn on_checkpoint(&self, e: &CheckpointEvent) {
        self.push(Event::Checkpoint(e.clone()));
    }

    fn on_request(&self, e: &RequestEvent) {
        self.push(Event::Request(*e));
    }

    fn on_token(&self, e: &TokenEvent) {
        self.push(Event::Token(*e));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_preserves_order_and_drains() {
        let c = Collector::new();
        let step = StepEvent {
            step: 0,
            loss: 1.0,
            acc: 0.1,
            grad_norm: 0.5,
            ms: 1.0,
            elapsed_us: 1,
        };
        c.on_step(&step);
        c.on_eval(&EvalEvent { step: 1, gamma: 0.25, loss: 0.9, acc: 0.2, elapsed_us: 2 });
        c.on_request(&RequestEvent { latency_us: 42, elapsed_us: 3, ok: true });
        c.on_token(&TokenEvent { index: 0, token: 5, latency_us: 9, elapsed_us: 4 });
        let evs = c.take();
        assert_eq!(evs.len(), 4);
        assert!(matches!(evs[0], Event::Step(s) if s.step == 0));
        assert!(matches!(evs[1], Event::Eval(e) if e.gamma == 0.25));
        assert!(matches!(evs[2], Event::Request(r) if r.ok));
        assert!(matches!(evs[3], Event::Token(t) if t.token == 5));
        assert!(c.events().is_empty());
    }

    #[test]
    fn sinks_are_object_safe_and_shareable() {
        let sink: std::sync::Arc<dyn EventSink> = std::sync::Arc::new(NullSink);
        let step = StepEvent {
            step: 0,
            loss: 0.0,
            acc: 0.0,
            grad_norm: 0.0,
            ms: 0.0,
            elapsed_us: 0,
        };
        sink.on_step(&step);
        let c: std::sync::Arc<dyn EventSink> = std::sync::Arc::new(Collector::new());
        c.on_request(&RequestEvent { latency_us: 1, elapsed_us: 1, ok: false });
    }
}
