//! # `bdia::api` — the embeddable facade
//!
//! One typed entry point over the whole BDIA lifecycle.  The paper's
//! pitch is that exact bit-level reversibility needs *no architecture
//! change* — the value is the **workflow** around a standard transformer
//! (train with random γ ∈ {±0.5} plus side info, infer at E\[γ\] = 0) —
//! and this module packages that workflow as a library surface instead of
//! five unrelated entry conventions.
//!
//! ## Facade over layers
//!
//! [`Session`] owns the runtime, parameters, optimizer and config, and
//! exposes typed lifecycle methods; everything below it stays independent
//! and directly usable:
//!
//! ```text
//! Session (api)  ── train/evaluate/infer/generate/save/resume/serve/bench
//!   ├─ coordinator::Trainer / baseline::RevVitTrainer   (engines)
//!   ├─ runtime::Runtime                                  (backends)
//!   ├─ generate::GenSession (generate/generate_stream)   (decoding)
//!   ├─ checkpoint                                        (persistence)
//!   ├─ serve::Server                                     (deployment)
//!   ├─ fleet::Router (serve_fleet/FleetHandle)           (sharded serving)
//!   ├─ dist (ranks/rank/rendezvous builders,             (distribution)
//!   │  attach_dist/connect_dist)
//!   └─ obs (metrics registry, span tracing,              (observability)
//!      /metrics + Chrome-trace export)
//! ```
//!
//! The CLI (`main.rs`), the experiment drivers (`experiments/*`) and the
//! bench suite (`bench::suite`) are all thin clients of [`Session`] — no
//! config/override/runtime plumbing is duplicated per entry point.
//!
//! ## Error taxonomy
//!
//! Every fallible method returns [`ApiResult`]: a structured
//! [`ApiError`] (`Config`, `UnknownModel { name, known }`,
//! `Checkpoint(CkptError)`, `Backend`, `Serve`, `Train`, `Dist`, `Io`)
//! that implements `std::error::Error` with actionable messages.  Match
//! on the variant programmatically; `Display` renders the human message,
//! including the full model list and a "did you mean" hint for typos.
//! Engine failures caused by a lost rank (a
//! [`crate::dist::DistError`] in the chain) are routed to
//! `ApiError::Dist` so callers can drive a restart policy.
//! Model names are typed too: [`ModelId`] enumerates the registry and is
//! the single source of truth for `--help` and the unknown-model error.
//!
//! ## Observation
//!
//! Progress is reported through the [`EventSink`] observer (per-step,
//! per-eval, per-checkpoint, per-request) instead of ad-hoc printing —
//! the CLI's console output is just [`StdoutSink`]; tests and embedders
//! use [`Collector`] or their own sink.
//!
//! ## Example
//!
//! ```no_run
//! use bdia::api::{EvalOpts, ModelId, Session, TrainOpts};
//!
//! fn main() -> Result<(), bdia::api::ApiError> {
//!     let mut session = Session::builder()
//!         .model(ModelId::VitS10)
//!         .threads(4)
//!         .steps(200)
//!         .build()?;
//!     let report = session.train(&TrainOpts::default())?;
//!     println!("trained {} steps", report.steps_completed);
//!     let eval = session.evaluate(&EvalOpts { gamma: 0.0, batches: None })?;
//!     println!("val_loss {:.4} val_acc {:.3}", eval.loss, eval.acc);
//!     session.save(std::path::Path::new("vit.ckpt"))?;
//!     Ok(())
//! }
//! ```

pub mod error;
pub mod events;
pub mod model_id;
pub mod session;

pub use error::{suggest, ApiError, ApiResult, CkptError};
pub use events::{
    CheckpointEvent, Collector, EvalEvent, Event, EventSink, NullSink,
    RequestEvent, StdoutSink, StepEvent, TokenEvent,
};
pub use model_id::ModelId;
// the generation types used by `Session::generate`/`generate_stream`
pub use crate::generate::{GenOpts, GenReport, GenStop};
// the inference payload type used by `Session::infer`/`infer_batch`
pub use crate::serve::wire::Example;
pub use session::{
    EvalOpts, EvalReport, FleetHandle, FleetOpts, ModelInfo, ServeBenchOpts,
    ServeOpts, ServerHandle, Session, SessionBuilder, SessionTimings,
    TrainOpts, TrainReport, TuneOpts, TuneReport,
};

use crate::experiments::ExpOpts;

/// Run a paper experiment driver (`fig1`..`fig5`, `table1`, `table2`,
/// `exact`, `all`).  The drivers construct their training arms through
/// [`Session`]; this is the CLI's `repro` entry point.
///
/// Drivers mix training, filesystem and plotting work, so failures are
/// reported uniformly as [`ApiError::Train`] with the full underlying
/// context preserved in the message (not classified per variant the way
/// [`Session`] methods are).
pub fn repro(id: &str, opts: &ExpOpts) -> ApiResult<()> {
    crate::experiments::run_experiment(id, opts).map_err(ApiError::train)
}

/// Run the per-family performance suite (`bdia bench`): Session-reported
/// hot-path timings at 1 and N threads — plus a tuned-profile row per
/// family, decode tokens/sec rows for GPT bundles and an observability
/// overhead block (step time with tracing off / metrics / full spans) —
/// written to `BENCH_10.json`.
///
/// Like [`repro`], failures surface as [`ApiError::Train`] with full
/// context in the message.
pub fn bench_suite(
    opts: &crate::bench::suite::SuiteOpts,
) -> ApiResult<crate::bench::suite::SuiteReport> {
    crate::bench::suite::run(opts).map_err(ApiError::train)
}
