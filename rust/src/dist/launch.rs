//! World assembly: in-process harness, per-process join, local spawn —
//! plus the fault-injection harness the no-hang tests are built on.
//!
//! Three ways to stand up an N-rank world, all ending in the same
//! [`DistRole`]:
//!
//! * [`run_local_world`] — N threads in **this process**, rendezvousing
//!   over an ephemeral loopback port.  This is how tier-1 tests and
//!   `bdia bench` run full multi-rank worlds hermetically.
//! * [`establish`] — one process = one rank, the multi-process /
//!   multi-host path behind `bdia train --ranks N --rank k --rendezvous
//!   host:port` (rank 0 binds and accepts, workers connect with retry).
//! * [`spawn_worker_ranks`] — the single-command local mode: the CLI binds
//!   the rendezvous itself, re-execs `current_exe` once per worker rank
//!   with `--rank k --rendezvous <bound addr>` appended, then proceeds as
//!   rank 0.  The children ride in a [`WorkerRanks`] guard that reaps them
//!   on every exit path.
//!
//! [`run_local_world_injected`] is the fault-tolerant variant: it hands
//! each rank a [`FaultInjector`] (kill / delay / wedge a chosen rank at a
//! chosen step) and returns **per-rank** results instead of failing fast,
//! so tests can assert that every survivor of a staged death terminates
//! with a structured error instead of hanging.

use super::collective::Collective;
use super::transport::{
    Rendezvous, Transport, WorldSpec, ACCEPT_TIMEOUT, CONNECT_TIMEOUT,
};
use super::DistRole;
use crate::config::TrainConfig;
use anyhow::{bail, ensure, Context, Result};
use std::net::{SocketAddr, ToSocketAddrs};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

/// Default rendezvous for the two-terminal walkthrough (any free port
/// works; this one just keeps the README copy-pasteable).
pub const DEFAULT_RENDEZVOUS: &str = "127.0.0.1:29400";

/// How many times `--on-rank-failure=restart` will rebuild the world
/// before giving up and surfacing the failure (a rank that dies on every
/// attempt is a bug, not bad luck).
pub const MAX_RESTARTS: usize = 3;

// ---------------------------------------------------------------------
// fault injection
// ---------------------------------------------------------------------

/// What happens to the chosen rank when its step comes up.
#[derive(Clone, Copy, Debug)]
pub enum FaultKind {
    /// The rank errors out instantly — the in-process analogue of a
    /// killed process: its sockets close and peers see EOF.
    Kill,
    /// The rank stalls for the given duration but keeps heartbeating,
    /// then continues normally.  A healthy world must absorb this with
    /// no abort and an unchanged bit-exact result.
    Delay(Duration),
    /// The rank stops heartbeating, stalls, then dies — a livelocked
    /// process as seen from outside: silence until the deadline trips.
    Wedge(Duration),
}

/// One staged fault: `kind` happens on `rank` at the top of `at_step`.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    pub rank: usize,
    pub at_step: usize,
    pub kind: FaultKind,
}

/// Per-rank handle on the (possibly absent) fault plan.  The training
/// closure calls [`FaultInjector::before_step`] at the top of each global
/// step; on every rank and step except the staged one it is a no-op.
pub struct FaultInjector {
    plan: Option<FaultPlan>,
    rank: usize,
}

impl FaultInjector {
    pub fn new(plan: Option<FaultPlan>, rank: usize) -> Self {
        FaultInjector { plan, rank }
    }

    /// Fire the staged fault if `step` on this rank is the chosen moment.
    /// `Kill`/`Wedge` return an error (the rank's death); `Delay` sleeps
    /// and returns `Ok` so the run continues.
    pub fn before_step(&self, step: usize, coll: &mut Collective) -> Result<()> {
        let Some(p) = self.plan else { return Ok(()) };
        if p.rank != self.rank || p.at_step != step {
            return Ok(());
        }
        match p.kind {
            FaultKind::Kill => {
                bail!("fault injection: rank {} killed at step {step}", self.rank)
            }
            FaultKind::Delay(d) => {
                std::thread::sleep(d);
                Ok(())
            }
            FaultKind::Wedge(d) => {
                coll.halt_heartbeat();
                std::thread::sleep(d);
                bail!("fault injection: rank {} wedged at step {step}", self.rank)
            }
        }
    }
}

// ---------------------------------------------------------------------
// in-process worlds
// ---------------------------------------------------------------------

/// Run `f(rank, role)` on every rank of a `cfg.ranks`-sized world inside
/// this process: worker threads connect to an ephemeral loopback
/// rendezvous, the calling thread plays rank 0.  Returns the per-rank
/// results indexed by rank.  Panics and errors from any rank propagate.
pub fn run_local_world<R, F>(cfg: &TrainConfig, f: F) -> Result<Vec<R>>
where
    R: Send,
    F: Fn(usize, DistRole) -> Result<R> + Send + Sync,
{
    let results = run_local_world_inner(cfg, None, |rank, role, _inject| f(rank, role))?;
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        out.push(r?);
    }
    Ok(out)
}

/// [`run_local_world`] with a staged fault and **per-rank** results: the
/// world assembles normally, the chosen rank suffers its fault, and every
/// rank's individual outcome (including the survivors' structured errors)
/// comes back for inspection instead of failing fast on the first one.
pub fn run_local_world_injected<R, F>(
    cfg: &TrainConfig,
    plan: FaultPlan,
    f: F,
) -> Result<Vec<Result<R>>>
where
    R: Send,
    F: Fn(usize, DistRole, FaultInjector) -> Result<R> + Send + Sync,
{
    run_local_world_inner(cfg, Some(plan), f)
}

fn run_local_world_inner<R, F>(
    cfg: &TrainConfig,
    plan: Option<FaultPlan>,
    f: F,
) -> Result<Vec<Result<R>>>
where
    R: Send,
    F: Fn(usize, DistRole, FaultInjector) -> Result<R> + Send + Sync,
{
    let world = cfg.ranks.max(1);
    let spec = WorldSpec::for_config(cfg);
    let deadline = cfg.dist_deadline();
    if world == 1 {
        return Ok(vec![f(0, DistRole::solo(), FaultInjector::new(plan, 0))]);
    }
    let rdv = Rendezvous::bind("127.0.0.1:0", world)?;
    let addr = rdv.addr();
    std::thread::scope(|scope| -> Result<Vec<Result<R>>> {
        let f = &f;
        let mut handles = Vec::with_capacity(world - 1);
        for rank in 1..world {
            handles.push(scope.spawn(move || -> Result<R> {
                let t =
                    Transport::connect(addr, rank, &spec, CONNECT_TIMEOUT, deadline)
                        .with_context(|| format!("rank {rank} failed to join"))?;
                let coll = Collective::new(t, rank, world)?;
                f(rank, DistRole { rank, world, coll }, FaultInjector::new(plan, rank))
            }));
        }
        // rank 0 runs here; its error lands in slot 0 like everyone else's
        // so the workers still get joined (no leaked threads on hub death)
        let r0 = (|| -> Result<R> {
            let hub = rdv.accept(&spec, ACCEPT_TIMEOUT, deadline)?;
            let coll = Collective::new(hub, 0, world)?;
            f(0, DistRole { rank: 0, world, coll }, FaultInjector::new(plan, 0))
        })();
        let mut out = vec![r0];
        for (i, h) in handles.into_iter().enumerate() {
            let r = h
                .join()
                .map_err(|_| anyhow::anyhow!("rank {} thread panicked", i + 1))?;
            out.push(r.with_context(|| format!("rank {} failed", i + 1)));
        }
        Ok(out)
    })
}

// ---------------------------------------------------------------------
// per-process join + local spawn
// ---------------------------------------------------------------------

/// Join a multi-process world as `rank`: rank 0 binds `rendezvous` (or
/// [`DEFAULT_RENDEZVOUS`]) and accepts the workers; everyone else connects
/// to it.  `prebound` lets a launcher that already bound the listener (to
/// learn an ephemeral port before spawning workers) hand it over.
pub fn establish(
    cfg: &TrainConfig,
    rank: usize,
    rendezvous: Option<&str>,
    prebound: Option<Rendezvous>,
) -> Result<DistRole> {
    let world = cfg.ranks.max(1);
    ensure!(rank < world, "--rank {rank} out of range for --ranks {world}");
    let spec = WorldSpec::for_config(cfg);
    let deadline = cfg.dist_deadline();
    if world == 1 {
        return Ok(DistRole::solo());
    }
    let addr_spec = rendezvous.unwrap_or(DEFAULT_RENDEZVOUS);
    let coll = if rank == 0 {
        let rdv = match prebound {
            Some(r) => r,
            None => Rendezvous::bind(addr_spec, world)?,
        };
        Collective::new(rdv.accept(&spec, ACCEPT_TIMEOUT, deadline)?, 0, world)?
    } else {
        let addr = resolve(addr_spec)?;
        Collective::new(
            Transport::connect(addr, rank, &spec, CONNECT_TIMEOUT, deadline)?,
            rank,
            world,
        )?
    };
    Ok(DistRole { rank, world, coll })
}

fn resolve(s: &str) -> Result<SocketAddr> {
    s.to_socket_addrs()
        .with_context(|| format!("rendezvous '{s}' must be host:port"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("rendezvous '{s}' resolved to no address"))
}

/// Spawn ranks `1..world` of this same invocation as child processes:
/// `current_exe` re-run with the caller's CLI arguments, minus any
/// `--rank`/`--rendezvous` they already carried, plus `--rank k
/// --rendezvous <addr>`.  Wrap the result in a [`WorkerRanks`] guard and
/// [`WorkerRanks::reap`] it when the run finishes.
pub fn spawn_worker_ranks(
    addr: SocketAddr,
    world: usize,
    base_args: &[String],
) -> Result<Vec<Child>> {
    ensure!(world >= 2, "spawning workers needs --ranks >= 2");
    let exe = std::env::current_exe().context("locating current executable")?;
    let mut args: Vec<String> = Vec::with_capacity(base_args.len());
    let mut skip_value = false;
    for a in base_args {
        if skip_value {
            skip_value = false;
            continue;
        }
        if a == "--rank" || a == "--rendezvous" {
            skip_value = true;
            continue;
        }
        if a.starts_with("--rank=") || a.starts_with("--rendezvous=") {
            continue;
        }
        args.push(a.clone());
    }
    let mut children = Vec::with_capacity(world - 1);
    for rank in 1..world {
        let child = Command::new(&exe)
            .args(&args)
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--rendezvous")
            .arg(addr.to_string())
            // workers stay quiet on stdout (rank 0 narrates the run) but
            // keep stderr attached so their failures are visible
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning worker rank {rank}"))?;
        children.push(child);
    }
    Ok(children)
}

/// Drop guard over locally spawned worker processes (index `i` holds
/// rank `i + 1`).  Every exit path reaps: [`WorkerRanks::reap`] waits on
/// **all** children and reports every non-zero exit with its rank;
/// [`WorkerRanks::discard`] (also the `Drop` behaviour) kills and waits,
/// for error/restart paths where exit codes no longer matter.  Either
/// way, repeated `bdia train --ranks N` runs cannot accumulate zombies.
#[derive(Default)]
pub struct WorkerRanks(pub Vec<Child>);

impl WorkerRanks {
    /// Wait for every child; error if any exited non-zero (naming each
    /// failed worker's rank and exit status).  All children are waited
    /// even when an early one failed — reporting must not leak zombies.
    pub fn reap(&mut self) -> Result<()> {
        let children = std::mem::take(&mut self.0);
        let mut failures = Vec::new();
        for (i, mut child) in children.into_iter().enumerate() {
            let rank = i + 1;
            match child.wait() {
                Ok(status) if status.success() => {}
                Ok(status) => {
                    failures.push(format!("worker rank {rank} exited with {status}"))
                }
                Err(e) => {
                    failures.push(format!("worker rank {rank} could not be reaped: {e}"))
                }
            }
        }
        ensure!(failures.is_empty(), "{}", failures.join("; "));
        Ok(())
    }

    /// Kill and wait whatever is still running, ignoring exit codes — the
    /// restart/error path, where the old world is torn down by design.
    pub fn discard(&mut self) {
        for mut child in std::mem::take(&mut self.0) {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

impl Drop for WorkerRanks {
    fn drop(&mut self) {
        self.discard();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_local_world_returns_rank_indexed_results() {
        let cfg = TrainConfig { ranks: 3, ..TrainConfig::default() };
        let out = run_local_world(&cfg, |rank, role| {
            assert_eq!(role.rank, rank);
            assert_eq!(role.world, 3);
            Ok(rank * 10)
        })
        .unwrap();
        assert_eq!(out, vec![0, 10, 20]);
    }

    #[test]
    fn run_local_world_solo_short_circuits() {
        let cfg = TrainConfig::default();
        let out = run_local_world(&cfg, |rank, role| {
            assert_eq!((rank, role.world), (0, 1));
            Ok(42)
        })
        .unwrap();
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn rank_errors_propagate() {
        let cfg = TrainConfig { ranks: 2, ..TrainConfig::default() };
        let err = run_local_world(&cfg, |rank, _role| {
            if rank == 1 {
                anyhow::bail!("worker exploded")
            }
            Ok(())
        });
        assert!(err.is_err());
    }

    #[test]
    fn establish_rejects_out_of_range_rank() {
        let cfg = TrainConfig { ranks: 2, ..TrainConfig::default() };
        assert!(establish(&cfg, 2, None, None).is_err());
    }

    #[test]
    fn fault_injector_only_fires_on_its_rank_and_step() {
        let plan = FaultPlan { rank: 1, at_step: 2, kind: FaultKind::Kill };
        let mut coll = Collective::solo();
        let other_rank = FaultInjector::new(Some(plan), 0);
        assert!(other_rank.before_step(2, &mut coll).is_ok());
        let target = FaultInjector::new(Some(plan), 1);
        assert!(target.before_step(1, &mut coll).is_ok());
        let err = target.before_step(2, &mut coll).unwrap_err();
        assert!(err.to_string().contains("rank 1"), "{err:#}");
        let unplanned = FaultInjector::new(None, 1);
        assert!(unplanned.before_step(2, &mut coll).is_ok());
    }

    #[test]
    fn injected_worlds_report_per_rank_outcomes() {
        let cfg = TrainConfig { ranks: 2, ..TrainConfig::default() };
        let plan = FaultPlan { rank: 1, at_step: 0, kind: FaultKind::Kill };
        let out = run_local_world_injected(&cfg, plan, |_rank, mut role, inject| {
            inject.before_step(0, &mut role.coll)?;
            Ok("survived")
        })
        .unwrap();
        assert_eq!(out.len(), 2);
        assert!(out[0].is_ok(), "rank 0 ran no collectives and must survive");
        assert!(out[1].is_err(), "rank 1 was staged to die at step 0");
    }
}
