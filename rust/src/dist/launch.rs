//! World assembly: in-process harness, per-process join, local spawn.
//!
//! Three ways to stand up an N-rank world, all ending in the same
//! [`DistRole`]:
//!
//! * [`run_local_world`] — N threads in **this process**, rendezvousing
//!   over an ephemeral loopback port.  This is how tier-1 tests and
//!   `bdia bench` run full multi-rank worlds hermetically.
//! * [`establish`] — one process = one rank, the multi-process /
//!   multi-host path behind `bdia train --ranks N --rank k --rendezvous
//!   host:port` (rank 0 binds and accepts, workers connect with retry).
//! * [`spawn_worker_ranks`] — the single-command local mode: the CLI binds
//!   the rendezvous itself, re-execs `current_exe` once per worker rank
//!   with `--rank k --rendezvous <bound addr>` appended, then proceeds as
//!   rank 0.

use super::collective::Collective;
use super::transport::{
    Rendezvous, Transport, WorldSpec, ACCEPT_TIMEOUT, CONNECT_TIMEOUT,
};
use super::DistRole;
use crate::config::TrainConfig;
use anyhow::{ensure, Context, Result};
use std::net::{SocketAddr, ToSocketAddrs};
use std::process::{Child, Command, Stdio};

/// Default rendezvous for the two-terminal walkthrough (any free port
/// works; this one just keeps the README copy-pasteable).
pub const DEFAULT_RENDEZVOUS: &str = "127.0.0.1:29400";

/// Run `f(rank, role)` on every rank of a `cfg.ranks`-sized world inside
/// this process: worker threads connect to an ephemeral loopback
/// rendezvous, the calling thread plays rank 0.  Returns the per-rank
/// results indexed by rank.  Panics and errors from any rank propagate.
pub fn run_local_world<R, F>(cfg: &TrainConfig, f: F) -> Result<Vec<R>>
where
    R: Send,
    F: Fn(usize, DistRole) -> Result<R> + Send + Sync,
{
    let world = cfg.ranks.max(1);
    let spec = WorldSpec::for_config(cfg);
    if world == 1 {
        return Ok(vec![f(0, DistRole::solo())?]);
    }
    let rdv = Rendezvous::bind("127.0.0.1:0", world)?;
    let addr = rdv.addr();
    std::thread::scope(|scope| -> Result<Vec<R>> {
        let f = &f;
        let mut handles = Vec::with_capacity(world - 1);
        for rank in 1..world {
            handles.push(scope.spawn(move || -> Result<R> {
                let t = Transport::connect(addr, rank, &spec, CONNECT_TIMEOUT)
                    .with_context(|| format!("rank {rank} failed to join"))?;
                let coll = Collective::new(t, rank, world)?;
                f(rank, DistRole { rank, world, coll })
            }));
        }
        let hub = rdv.accept(&spec, ACCEPT_TIMEOUT)?;
        let coll = Collective::new(hub, 0, world)?;
        let r0 = f(0, DistRole { rank: 0, world, coll })?;
        let mut out = vec![r0];
        for (i, h) in handles.into_iter().enumerate() {
            let r = h
                .join()
                .map_err(|_| anyhow::anyhow!("rank {} thread panicked", i + 1))?;
            out.push(r.with_context(|| format!("rank {} failed", i + 1))?);
        }
        Ok(out)
    })
}

/// Join a multi-process world as `rank`: rank 0 binds `rendezvous` (or
/// [`DEFAULT_RENDEZVOUS`]) and accepts the workers; everyone else connects
/// to it.  `prebound` lets a launcher that already bound the listener (to
/// learn an ephemeral port before spawning workers) hand it over.
pub fn establish(
    cfg: &TrainConfig,
    rank: usize,
    rendezvous: Option<&str>,
    prebound: Option<Rendezvous>,
) -> Result<DistRole> {
    let world = cfg.ranks.max(1);
    ensure!(rank < world, "--rank {rank} out of range for --ranks {world}");
    let spec = WorldSpec::for_config(cfg);
    if world == 1 {
        return Ok(DistRole::solo());
    }
    let addr_spec = rendezvous.unwrap_or(DEFAULT_RENDEZVOUS);
    let coll = if rank == 0 {
        let rdv = match prebound {
            Some(r) => r,
            None => Rendezvous::bind(addr_spec, world)?,
        };
        Collective::new(rdv.accept(&spec, ACCEPT_TIMEOUT)?, 0, world)?
    } else {
        let addr = resolve(addr_spec)?;
        Collective::new(
            Transport::connect(addr, rank, &spec, CONNECT_TIMEOUT)?,
            rank,
            world,
        )?
    };
    Ok(DistRole { rank, world, coll })
}

fn resolve(s: &str) -> Result<SocketAddr> {
    s.to_socket_addrs()
        .with_context(|| format!("rendezvous '{s}' must be host:port"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("rendezvous '{s}' resolved to no address"))
}

/// Spawn ranks `1..world` of this same invocation as child processes:
/// `current_exe` re-run with the caller's CLI arguments, minus any
/// `--rank`/`--rendezvous` they already carried, plus `--rank k
/// --rendezvous <addr>`.  The caller then joins the world as rank 0 and
/// must [`wait`](std::process::Child::wait) on the children afterwards.
pub fn spawn_worker_ranks(
    addr: SocketAddr,
    world: usize,
    base_args: &[String],
) -> Result<Vec<Child>> {
    ensure!(world >= 2, "spawning workers needs --ranks >= 2");
    let exe = std::env::current_exe().context("locating current executable")?;
    let mut args: Vec<String> = Vec::with_capacity(base_args.len());
    let mut skip_value = false;
    for a in base_args {
        if skip_value {
            skip_value = false;
            continue;
        }
        if a == "--rank" || a == "--rendezvous" {
            skip_value = true;
            continue;
        }
        if a.starts_with("--rank=") || a.starts_with("--rendezvous=") {
            continue;
        }
        args.push(a.clone());
    }
    let mut children = Vec::with_capacity(world - 1);
    for rank in 1..world {
        let child = Command::new(&exe)
            .args(&args)
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--rendezvous")
            .arg(addr.to_string())
            // workers stay quiet on stdout (rank 0 narrates the run) but
            // keep stderr attached so their failures are visible
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning worker rank {rank}"))?;
        children.push(child);
    }
    Ok(children)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_local_world_returns_rank_indexed_results() {
        let cfg = TrainConfig { ranks: 3, ..TrainConfig::default() };
        let out = run_local_world(&cfg, |rank, role| {
            assert_eq!(role.rank, rank);
            assert_eq!(role.world, 3);
            Ok(rank * 10)
        })
        .unwrap();
        assert_eq!(out, vec![0, 10, 20]);
    }

    #[test]
    fn run_local_world_solo_short_circuits() {
        let cfg = TrainConfig::default();
        let out = run_local_world(&cfg, |rank, role| {
            assert_eq!((rank, role.world), (0, 1));
            Ok(42)
        })
        .unwrap();
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn rank_errors_propagate() {
        let cfg = TrainConfig { ranks: 2, ..TrainConfig::default() };
        let err = run_local_world(&cfg, |rank, _role| {
            if rank == 1 {
                anyhow::bail!("worker exploded")
            }
            Ok(())
        });
        assert!(err.is_err());
    }

    #[test]
    fn establish_rejects_out_of_range_rank() {
        let cfg = TrainConfig { ranks: 2, ..TrainConfig::default() };
        assert!(establish(&cfg, 2, None, None).is_err());
    }
}
