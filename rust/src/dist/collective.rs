//! Deterministic collectives with a fixed, rank-ordered reduction order.
//!
//! The whole point of this layer is that float addition is not
//! associative, so "sum the gradients across ranks" is only well defined
//! once the association order is pinned.  Every reduction here folds
//! contributions **serially in rank order** (rank 0's buffer, then rank 1,
//! then rank 2, …) into rank 0's accumulator.  Combined with the trainer's
//! round-robin micro-batch ownership (`micro = round·world + rank`), one
//! [`Collective::reduce_sum_rank_ordered`] call per round reproduces the
//! exact left-to-right serial sum over global micro-batch indices that a
//! single process computes — which is what makes training bit-identical at
//! every world size (`tests/dist_training.rs`).
//!
//! Topology is hub-and-spoke (rank 0 is the hub): `reduce` sends worker
//! buffers to the hub, `broadcast` fans the hub's buffer out, `barrier`
//! is a request/ack round trip.  TCP gives per-stream ordering; the hub
//! reads streams in rank order, so arrival races cannot perturb the fold.
//!
//! ## Liveness and abort fan-out
//!
//! Every connection is deadline-armed (see
//! [`super::transport::Link`]), and each non-solo collective owns a
//! background [`Heartbeat`] thread that keeps its connections warm while
//! this rank computes between collectives — so a *slow* rank never trips
//! a peer's read deadline, while a *dead* rank's silence is indistinguish-
//! able from a hang and fails the read within one deadline.  When the hub
//! loses a peer mid-collective it relays an ABORT frame to every surviving
//! worker before returning the error, so the whole world terminates with
//! a [`super::transport::DistError`] naming the same dead rank instead of
//! waiting out staggered timeouts.

use super::transport::{self, op, Link, Transport};
use anyhow::{ensure, Result};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Background liveness thread: periodically writes empty HEARTBEAT frames
/// on every connection this rank owns (hub → all peers, worker → hub).
/// Beats are best-effort and skipped while the main thread holds a write
/// lock — its own in-flight frame is better proof of life.  Dropping the
/// handle stops and joins the thread.
struct Heartbeat {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeat {
    fn spawn(transport: &Transport) -> Option<Heartbeat> {
        let (writers, deadline): (Vec<Arc<Mutex<TcpStream>>>, Duration) =
            match transport {
                Transport::Solo => return None,
                Transport::Hub { peers } => {
                    let deadline = peers.first()?.deadline();
                    (peers.iter().map(Link::writer).collect(), deadline)
                }
                Transport::Worker { hub } => (vec![hub.writer()], hub.deadline()),
            };
        // several beats per deadline, so one lost-to-lock-contention beat
        // cannot look like death
        let interval = (deadline / 4).max(Duration::from_millis(10));
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("bdia-heartbeat".into())
            .spawn(move || {
                let slice = Duration::from_millis(5).min(interval);
                let mut next = Instant::now() + interval;
                while !thread_stop.load(Ordering::Relaxed) {
                    if Instant::now() >= next {
                        let mut any_alive = false;
                        for w in &writers {
                            any_alive |= transport::try_heartbeat(w);
                        }
                        if !any_alive {
                            // every peer is unreachable; the main thread is
                            // about to find out via its own reads
                            return;
                        }
                        next = Instant::now() + interval;
                    }
                    std::thread::sleep(slice);
                }
            })
            .ok()?; // no thread → no beats; deadlines still bound every read
        Some(Heartbeat { stop, handle: Some(handle) })
    }
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Tell every surviving peer that `dead` failed during `during` (best
/// effort — the world is coming down either way).
fn abort_world(peers: &[Link], dead: usize, during: &'static str) {
    for p in peers {
        if p.peer() != dead {
            p.send_abort(dead, during);
        }
    }
}

/// Hub side of one peer's reduce contribution: receive, decode, fold.
fn fold_peer(
    link: &mut Link,
    frame: &mut Vec<u8>,
    scratch: &mut [f32],
    acc: &mut [f32],
) -> Result<()> {
    let got = link.recv_into(frame, "reduce")?;
    ensure!(got == op::REDUCE, "expected reduce frame, got op {got}");
    let mut pos = 0;
    transport::get_f32s(frame, &mut pos, scratch.len(), scratch)?;
    ensure!(pos == frame.len(), "reduce frame length mismatch");
    for (a, c) in acc.iter_mut().zip(scratch.iter()) {
        *a += *c;
    }
    Ok(())
}

/// Hub side of one peer's barrier arrival.
fn barrier_req(link: &mut Link, frame: &mut Vec<u8>) -> Result<()> {
    let got = link.recv_into(frame, "barrier")?;
    ensure!(got == op::BARRIER_REQ, "expected barrier request, got op {got}");
    ensure!(frame.is_empty(), "barrier request carries no payload");
    Ok(())
}

/// One rank's handle on the assembled world.
pub struct Collective {
    transport: Transport,
    rank: usize,
    world: usize,
    /// Reusable wire buffer — gradient frames are ~4·n_params bytes and
    /// move once per accumulation round, so they must not be reallocated.
    frame: Vec<u8>,
    /// Reusable decoded-f32 buffer (hub-side fold input).
    scratch: Vec<f32>,
    /// Liveness thread; `None` for solo worlds (and after
    /// [`Collective::halt_heartbeat`], which the fault harness uses to
    /// simulate a wedged-but-running rank).
    heartbeat: Option<Heartbeat>,
}

impl Collective {
    pub fn new(transport: Transport, rank: usize, world: usize) -> Result<Self> {
        match &transport {
            Transport::Solo => ensure!(
                world == 1 && rank == 0,
                "solo transport is world 1 / rank 0 only"
            ),
            Transport::Hub { peers } => ensure!(
                rank == 0 && peers.len() == world - 1,
                "hub must be rank 0 with world-1 peers"
            ),
            Transport::Worker { .. } => {
                ensure!(rank >= 1 && rank < world, "worker rank out of range")
            }
        }
        let heartbeat = Heartbeat::spawn(&transport);
        Ok(Collective {
            transport,
            rank,
            world,
            frame: Vec::new(),
            scratch: Vec::new(),
            heartbeat,
        })
    }

    /// A world of one: collectives degenerate to local arithmetic.  This is
    /// what a non-distributed trainer uses, so the single-process and
    /// multi-rank code paths are literally the same code.
    pub fn solo() -> Self {
        Collective {
            transport: Transport::Solo,
            rank: 0,
            world: 1,
            frame: Vec::new(),
            scratch: Vec::new(),
            heartbeat: None,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Stop sending liveness beats while staying connected.  Peers will
    /// see this rank as dead one deadline after its last frame — exactly
    /// how a livelocked or GC-stalled process looks from outside.  Exists
    /// for the fault-injection harness; production code never calls it.
    pub fn halt_heartbeat(&mut self) {
        self.heartbeat = None;
    }

    /// Fold this round's per-rank contributions into `acc` **serially in
    /// rank order**: `acc += contrib_0; acc += contrib_1; …`.  Only rank
    /// 0's `acc` is meaningful afterwards (workers' accumulators are left
    /// untouched); fan the final result out with [`Collective::broadcast`].
    pub fn reduce_sum_rank_ordered(
        &mut self,
        acc: &mut [f32],
        contrib: &[f32],
    ) -> Result<()> {
        ensure!(
            acc.len() == contrib.len(),
            "reduce: accumulator has {} elements, contribution {}",
            acc.len(),
            contrib.len()
        );
        let _span = crate::span!("dist_reduce", rank = self.rank, n = contrib.len());
        match &mut self.transport {
            Transport::Solo => {
                for (a, c) in acc.iter_mut().zip(contrib) {
                    *a += *c;
                }
                Ok(())
            }
            Transport::Hub { peers } => {
                // rank 0 first, then each worker in rank order
                for (a, c) in acc.iter_mut().zip(contrib) {
                    *a += *c;
                }
                self.scratch.resize(contrib.len(), 0.0);
                for i in 0..peers.len() {
                    if let Err(e) =
                        fold_peer(&mut peers[i], &mut self.frame, &mut self.scratch, acc)
                    {
                        abort_world(peers, peers[i].peer(), "reduce");
                        return Err(e);
                    }
                }
                Ok(())
            }
            Transport::Worker { hub } => {
                self.frame.clear();
                transport::put_f32s(&mut self.frame, contrib);
                hub.send(op::REDUCE, &self.frame, "reduce")
            }
        }
    }

    /// Rank 0's buffer overwrites everyone's, bit-for-bit (`f32` payloads
    /// travel as raw LE bytes, so `-0.0` / NaN payloads survive).
    pub fn broadcast(&mut self, buf: &mut [f32]) -> Result<()> {
        let _span = crate::span!("dist_broadcast", rank = self.rank, n = buf.len());
        match &mut self.transport {
            Transport::Solo => Ok(()),
            Transport::Hub { peers } => {
                self.frame.clear();
                transport::put_f32s(&mut self.frame, buf);
                for i in 0..peers.len() {
                    if let Err(e) = peers[i].send(op::BCAST, &self.frame, "broadcast") {
                        abort_world(peers, peers[i].peer(), "broadcast");
                        return Err(e);
                    }
                }
                Ok(())
            }
            Transport::Worker { hub } => {
                let got = hub.recv_into(&mut self.frame, "broadcast")?;
                ensure!(got == op::BCAST, "expected broadcast frame, got op {got}");
                let mut pos = 0;
                transport::get_f32s(&self.frame, &mut pos, buf.len(), buf)?;
                ensure!(pos == self.frame.len(), "broadcast frame length mismatch");
                Ok(())
            }
        }
    }

    /// Opaque-byte broadcast (checkpoint-resume state sync): rank 0's blob
    /// reaches every rank verbatim; rank 0 gets its own blob back.
    pub fn broadcast_blob(&mut self, blob: Vec<u8>) -> Result<Vec<u8>> {
        match &mut self.transport {
            Transport::Solo => Ok(blob),
            Transport::Hub { peers } => {
                for i in 0..peers.len() {
                    if let Err(e) = peers[i].send(op::BCAST, &blob, "state-sync") {
                        abort_world(peers, peers[i].peer(), "state-sync");
                        return Err(e);
                    }
                }
                Ok(blob)
            }
            Transport::Worker { hub } => {
                let got = hub.recv_into(&mut self.frame, "state-sync")?;
                ensure!(got == op::BCAST, "expected state frame, got op {got}");
                Ok(std::mem::take(&mut self.frame))
            }
        }
    }

    /// Everyone waits until everyone has arrived.
    pub fn barrier(&mut self) -> Result<()> {
        let _span = crate::span!("dist_barrier", rank = self.rank);
        match &mut self.transport {
            Transport::Solo => Ok(()),
            Transport::Hub { peers } => {
                for i in 0..peers.len() {
                    if let Err(e) = barrier_req(&mut peers[i], &mut self.frame) {
                        abort_world(peers, peers[i].peer(), "barrier");
                        return Err(e);
                    }
                }
                for i in 0..peers.len() {
                    if let Err(e) = peers[i].send(op::BARRIER_ACK, &[], "barrier") {
                        abort_world(peers, peers[i].peer(), "barrier");
                        return Err(e);
                    }
                }
                Ok(())
            }
            Transport::Worker { hub } => {
                hub.send(op::BARRIER_REQ, &[], "barrier")?;
                let got = hub.recv_into(&mut self.frame, "barrier")?;
                ensure!(got == op::BARRIER_ACK, "expected barrier ack, got op {got}");
                ensure!(self.frame.is_empty(), "barrier ack carries no payload");
                Ok(())
            }
        }
    }

    /// Estimate each worker's monotonic-clock offset relative to rank 0,
    /// so per-rank Chrome traces merge onto one timeline (`bdia trace`).
    /// NTP-style: the worker timestamps the send (`t0`) and receive
    /// (`t1`) of a round trip that returns the hub's clock, assumes
    /// symmetric latency, and stores `hub_now + rtt/2 - t1` in
    /// [`crate::obs::set_clock_offset_us`].  Rank 0's offset is zero by
    /// definition.  Timestamps never touch training state, so
    /// bit-determinism is unaffected.
    pub fn clock_sync(&mut self) -> Result<()> {
        match &mut self.transport {
            Transport::Solo => {
                crate::obs::set_clock_offset_us(0);
                Ok(())
            }
            Transport::Hub { peers } => {
                crate::obs::set_clock_offset_us(0);
                for i in 0..peers.len() {
                    let got = peers[i].recv_into(&mut self.frame, "clock-sync")?;
                    ensure!(got == op::CLOCK, "expected clock frame, got op {got}");
                    let mut reply = Vec::with_capacity(8);
                    transport::put_u64(&mut reply, crate::obs::now_us());
                    peers[i].send(op::CLOCK, &reply, "clock-sync")?;
                }
                Ok(())
            }
            Transport::Worker { hub } => {
                let t0 = crate::obs::now_us();
                let mut ping = Vec::with_capacity(8);
                transport::put_u64(&mut ping, t0);
                hub.send(op::CLOCK, &ping, "clock-sync")?;
                let got = hub.recv_into(&mut self.frame, "clock-sync")?;
                ensure!(got == op::CLOCK, "expected clock frame, got op {got}");
                let t1 = crate::obs::now_us();
                let mut pos = 0;
                let hub_now = transport::get_u64(&self.frame, &mut pos)?;
                let offset = hub_now as i64 + ((t1 - t0) / 2) as i64 - t1 as i64;
                crate::obs::set_clock_offset_us(offset);
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::launch::run_local_world;
    use crate::config::TrainConfig;

    fn cfg(world: usize) -> TrainConfig {
        TrainConfig { ranks: world, ..TrainConfig::default() }
    }

    /// The reduction-order contract, on floats chosen so association is
    /// visible: serial rank order gives ((1e8 + 1) - 1e8) + 1 = 1.0 (the
    /// +1 is absorbed while the partial sits at 1e8), while a pairwise
    /// tree would give 2.0.  Every world size must reproduce the serial
    /// answer bit-for-bit.
    #[test]
    fn reduce_is_serial_in_global_micro_order_at_any_world_size() {
        let micros = [1.0e8f32, 1.0, -1.0e8, 1.0];
        let mut answers = Vec::new();
        for world in [1usize, 2, 4] {
            let rounds = micros.len() / world;
            let out = run_local_world(&cfg(world), |rank, mut role| {
                let mut acc = vec![0f32];
                for j in 0..rounds {
                    let m = j * world + rank;
                    role.coll.reduce_sum_rank_ordered(&mut acc, &[micros[m]])?;
                }
                role.coll.broadcast(&mut acc)?;
                Ok(acc[0])
            })
            .unwrap();
            // every rank observes the same folded value
            for v in &out {
                assert_eq!(v.to_bits(), out[0].to_bits(), "world {world}");
            }
            answers.push(out[0]);
        }
        for v in &answers {
            assert_eq!(v.to_bits(), 1.0f32.to_bits(), "serial order violated");
        }
    }

    #[test]
    fn broadcast_is_bit_exact_for_special_values() {
        let payload = [f32::NAN, -0.0, 1.5e-42, f32::INFINITY];
        let out = run_local_world(&cfg(3), |rank, mut role| {
            let mut buf = if rank == 0 { payload.to_vec() } else { vec![0.0; 4] };
            role.coll.broadcast(&mut buf)?;
            Ok(buf.iter().map(|x| x.to_bits()).collect::<Vec<_>>())
        })
        .unwrap();
        let want: Vec<u32> = payload.iter().map(|x| x.to_bits()).collect();
        for bits in out {
            assert_eq!(bits, want);
        }
    }

    #[test]
    fn blob_broadcast_and_barrier() {
        let out = run_local_world(&cfg(2), |rank, mut role| {
            role.coll.barrier()?;
            let blob = if rank == 0 { vec![7u8, 8, 9] } else { Vec::new() };
            let got = role.coll.broadcast_blob(blob)?;
            role.coll.barrier()?;
            Ok(got)
        })
        .unwrap();
        assert_eq!(out, vec![vec![7, 8, 9], vec![7, 8, 9]]);
    }

    /// The CLOCK round trip completes at every rank and never perturbs
    /// the collectives that follow it (it is pure observability).
    #[test]
    fn clock_sync_is_transparent_to_later_collectives() {
        let out = run_local_world(&cfg(2), |_rank, mut role| {
            role.coll.clock_sync()?;
            let mut acc = vec![0f32];
            role.coll.reduce_sum_rank_ordered(&mut acc, &[1.0])?;
            role.coll.broadcast(&mut acc)?;
            Ok(acc[0].to_bits())
        })
        .unwrap();
        assert_eq!(out, vec![2.0f32.to_bits(); 2]);
    }

    #[test]
    fn reduce_rejects_length_mismatch() {
        let mut c = super::Collective::solo();
        let mut acc = vec![0f32; 2];
        assert!(c.reduce_sum_rank_ordered(&mut acc, &[1.0]).is_err());
    }

    /// Slow is not dead: a rank that computes for several deadlines keeps
    /// beating in the background, so the world waits for it instead of
    /// aborting — deadlines bound *silence*, not work.
    #[test]
    fn heartbeats_keep_a_slow_rank_from_tripping_the_deadline() {
        let config = TrainConfig { dist_timeout_s: 0.2, ..cfg(2) };
        let out = run_local_world(&config, |rank, mut role| {
            if rank == 1 {
                // 3× the deadline of pure compute before contributing
                std::thread::sleep(std::time::Duration::from_millis(600));
            }
            let mut acc = vec![0f32];
            role.coll.reduce_sum_rank_ordered(&mut acc, &[1.0])?;
            role.coll.broadcast(&mut acc)?;
            Ok(acc[0].to_bits())
        })
        .unwrap();
        assert_eq!(out, vec![2.0f32.to_bits(); 2]);
    }
}
