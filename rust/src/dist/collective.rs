//! Deterministic collectives with a fixed, rank-ordered reduction order.
//!
//! The whole point of this layer is that float addition is not
//! associative, so "sum the gradients across ranks" is only well defined
//! once the association order is pinned.  Every reduction here folds
//! contributions **serially in rank order** (rank 0's buffer, then rank 1,
//! then rank 2, …) into rank 0's accumulator.  Combined with the trainer's
//! round-robin micro-batch ownership (`micro = round·world + rank`), one
//! [`Collective::reduce_sum_rank_ordered`] call per round reproduces the
//! exact left-to-right serial sum over global micro-batch indices that a
//! single process computes — which is what makes training bit-identical at
//! every world size (`tests/dist_training.rs`).
//!
//! Topology is hub-and-spoke (rank 0 is the hub): `reduce` sends worker
//! buffers to the hub, `broadcast` fans the hub's buffer out, `barrier`
//! is a request/ack round trip.  TCP gives per-stream ordering; the hub
//! reads streams in rank order, so arrival races cannot perturb the fold.

use super::transport::{self, expect_frame, op, write_frame, Transport};
use anyhow::{ensure, Context, Result};

/// One rank's handle on the assembled world.
pub struct Collective {
    transport: Transport,
    rank: usize,
    world: usize,
    /// Reusable wire buffer — gradient frames are ~4·n_params bytes and
    /// move once per accumulation round, so they must not be reallocated.
    frame: Vec<u8>,
    /// Reusable decoded-f32 buffer (hub-side fold input).
    scratch: Vec<f32>,
}

impl Collective {
    pub fn new(transport: Transport, rank: usize, world: usize) -> Result<Self> {
        match &transport {
            Transport::Solo => ensure!(
                world == 1 && rank == 0,
                "solo transport is world 1 / rank 0 only"
            ),
            Transport::Hub { peers } => ensure!(
                rank == 0 && peers.len() == world - 1,
                "hub must be rank 0 with world-1 peers"
            ),
            Transport::Worker { .. } => {
                ensure!(rank >= 1 && rank < world, "worker rank out of range")
            }
        }
        Ok(Collective {
            transport,
            rank,
            world,
            frame: Vec::new(),
            scratch: Vec::new(),
        })
    }

    /// A world of one: collectives degenerate to local arithmetic.  This is
    /// what a non-distributed trainer uses, so the single-process and
    /// multi-rank code paths are literally the same code.
    pub fn solo() -> Self {
        Collective {
            transport: Transport::Solo,
            rank: 0,
            world: 1,
            frame: Vec::new(),
            scratch: Vec::new(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Fold this round's per-rank contributions into `acc` **serially in
    /// rank order**: `acc += contrib_0; acc += contrib_1; …`.  Only rank
    /// 0's `acc` is meaningful afterwards (workers' accumulators are left
    /// untouched); fan the final result out with [`Collective::broadcast`].
    pub fn reduce_sum_rank_ordered(
        &mut self,
        acc: &mut [f32],
        contrib: &[f32],
    ) -> Result<()> {
        ensure!(
            acc.len() == contrib.len(),
            "reduce: accumulator has {} elements, contribution {}",
            acc.len(),
            contrib.len()
        );
        match &mut self.transport {
            Transport::Solo => {
                for (a, c) in acc.iter_mut().zip(contrib) {
                    *a += *c;
                }
                Ok(())
            }
            Transport::Hub { peers } => {
                // rank 0 first, then each worker in rank order
                for (a, c) in acc.iter_mut().zip(contrib) {
                    *a += *c;
                }
                self.scratch.resize(contrib.len(), 0.0);
                for (i, peer) in peers.iter_mut().enumerate() {
                    let got = transport::read_frame_into(peer, &mut self.frame)
                        .with_context(|| format!("reduce from rank {}", i + 1))?;
                    ensure!(got == op::REDUCE, "expected reduce frame, got op {got}");
                    let mut pos = 0;
                    transport::get_f32s(
                        &self.frame,
                        &mut pos,
                        contrib.len(),
                        &mut self.scratch,
                    )?;
                    ensure!(pos == self.frame.len(), "reduce frame length mismatch");
                    for (a, c) in acc.iter_mut().zip(&self.scratch) {
                        *a += *c;
                    }
                }
                Ok(())
            }
            Transport::Worker { hub } => {
                self.frame.clear();
                transport::put_f32s(&mut self.frame, contrib);
                write_frame(hub, op::REDUCE, &self.frame).context("reduce send")
            }
        }
    }

    /// Rank 0's buffer overwrites everyone's, bit-for-bit (`f32` payloads
    /// travel as raw LE bytes, so `-0.0` / NaN payloads survive).
    pub fn broadcast(&mut self, buf: &mut [f32]) -> Result<()> {
        match &mut self.transport {
            Transport::Solo => Ok(()),
            Transport::Hub { peers } => {
                self.frame.clear();
                transport::put_f32s(&mut self.frame, buf);
                for peer in peers.iter_mut() {
                    write_frame(peer, op::BCAST, &self.frame)
                        .context("broadcast send")?;
                }
                Ok(())
            }
            Transport::Worker { hub } => {
                let got = transport::read_frame_into(hub, &mut self.frame)
                    .context("broadcast recv")?;
                ensure!(got == op::BCAST, "expected broadcast frame, got op {got}");
                let mut pos = 0;
                transport::get_f32s(&self.frame, &mut pos, buf.len(), buf)?;
                ensure!(pos == self.frame.len(), "broadcast frame length mismatch");
                Ok(())
            }
        }
    }

    /// Opaque-byte broadcast (checkpoint-resume state sync): rank 0's blob
    /// reaches every rank verbatim; rank 0 gets its own blob back.
    pub fn broadcast_blob(&mut self, blob: Vec<u8>) -> Result<Vec<u8>> {
        match &mut self.transport {
            Transport::Solo => Ok(blob),
            Transport::Hub { peers } => {
                for peer in peers.iter_mut() {
                    write_frame(peer, op::BCAST, &blob).context("blob send")?;
                }
                Ok(blob)
            }
            Transport::Worker { hub } => {
                expect_frame(hub, op::BCAST).context("blob recv")
            }
        }
    }

    /// Everyone waits until everyone has arrived.
    pub fn barrier(&mut self) -> Result<()> {
        match &mut self.transport {
            Transport::Solo => Ok(()),
            Transport::Hub { peers } => {
                for (i, peer) in peers.iter_mut().enumerate() {
                    let p = expect_frame(peer, op::BARRIER_REQ)
                        .with_context(|| format!("barrier from rank {}", i + 1))?;
                    ensure!(p.is_empty(), "barrier request carries no payload");
                }
                for peer in peers.iter_mut() {
                    write_frame(peer, op::BARRIER_ACK, &[])?;
                }
                Ok(())
            }
            Transport::Worker { hub } => {
                write_frame(hub, op::BARRIER_REQ, &[])?;
                let p = expect_frame(hub, op::BARRIER_ACK)?;
                ensure!(p.is_empty(), "barrier ack carries no payload");
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::launch::run_local_world;
    use crate::config::TrainConfig;

    fn cfg(world: usize) -> TrainConfig {
        TrainConfig { ranks: world, ..TrainConfig::default() }
    }

    /// The reduction-order contract, on floats chosen so association is
    /// visible: serial rank order gives ((1e8 + 1) - 1e8) + 1 = 1.0 (the
    /// +1 is absorbed while the partial sits at 1e8), while a pairwise
    /// tree would give 2.0.  Every world size must reproduce the serial
    /// answer bit-for-bit.
    #[test]
    fn reduce_is_serial_in_global_micro_order_at_any_world_size() {
        let micros = [1.0e8f32, 1.0, -1.0e8, 1.0];
        let mut answers = Vec::new();
        for world in [1usize, 2, 4] {
            let rounds = micros.len() / world;
            let out = run_local_world(&cfg(world), |rank, mut role| {
                let mut acc = vec![0f32];
                for j in 0..rounds {
                    let m = j * world + rank;
                    role.coll.reduce_sum_rank_ordered(&mut acc, &[micros[m]])?;
                }
                role.coll.broadcast(&mut acc)?;
                Ok(acc[0])
            })
            .unwrap();
            // every rank observes the same folded value
            for v in &out {
                assert_eq!(v.to_bits(), out[0].to_bits(), "world {world}");
            }
            answers.push(out[0]);
        }
        for v in &answers {
            assert_eq!(v.to_bits(), 1.0f32.to_bits(), "serial order violated");
        }
    }

    #[test]
    fn broadcast_is_bit_exact_for_special_values() {
        let payload = [f32::NAN, -0.0, 1.5e-42, f32::INFINITY];
        let out = run_local_world(&cfg(3), |rank, mut role| {
            let mut buf = if rank == 0 { payload.to_vec() } else { vec![0.0; 4] };
            role.coll.broadcast(&mut buf)?;
            Ok(buf.iter().map(|x| x.to_bits()).collect::<Vec<_>>())
        })
        .unwrap();
        let want: Vec<u32> = payload.iter().map(|x| x.to_bits()).collect();
        for bits in out {
            assert_eq!(bits, want);
        }
    }

    #[test]
    fn blob_broadcast_and_barrier() {
        let out = run_local_world(&cfg(2), |rank, mut role| {
            role.coll.barrier()?;
            let blob = if rank == 0 { vec![7u8, 8, 9] } else { Vec::new() };
            let got = role.coll.broadcast_blob(blob)?;
            role.coll.barrier()?;
            Ok(got)
        })
        .unwrap();
        assert_eq!(out, vec![vec![7, 8, 9], vec![7, 8, 9]]);
    }

    #[test]
    fn reduce_rejects_length_mismatch() {
        let mut c = super::Collective::solo();
        let mut acc = vec![0f32; 2];
        assert!(c.reduce_sum_rank_ordered(&mut acc, &[1.0]).is_err());
    }
}
