//! # `bdia::dist` — deterministic data-parallel training over pure-std TCP
//!
//! The paper's memory saving (§4: two boundary activations + 1-bit side
//! info instead of K+1 stored activations) pays off at scale — when the
//! global batch is spread across workers.  This subsystem adds that scale
//! axis while preserving the repo's signature guarantee: **losses,
//! gradients and parameters are bit-identical at every world size** (and,
//! composed with the kernel layer, at every thread count).
//!
//! ## How bit-identity across world sizes works
//!
//! A global optimization step consumes `grad_accum` micro-batches (each
//! one manifest batch, so executable shapes never change).  Micro-batch
//! `m = step·A + j·world + rank` is owned round-robin, so rank order
//! within a round *is* global micro order:
//!
//! * γ randomness: micro `m`'s gamma plan is drawn from a stream forked
//!   **by value of `m`** off the checkpointed gamma RNG
//!   ([`crate::tensor::Rng::fork`] is a pure function of the parent state,
//!   so any rank derives any micro's stream without replaying draws).
//! * gradients: each rank computes its micro-gradient into a zeroed
//!   buffer; [`collective::Collective::reduce_sum_rank_ordered`] folds the
//!   round's contributions serially in rank order into rank 0's
//!   accumulator.  Across rounds this reproduces the exact left-to-right
//!   serial sum over `m = 0..A` that a single process computes (`+0.0`
//!   normalization of `-0.0` contributions is absorbed by IEEE-754
//!   addition — asserted in `tests/dist_training.rs`).
//! * the folded mean gradient (and summed loss/ncorrect, riding the same
//!   buffer) is broadcast byte-exactly; every rank then runs the identical
//!   serial optimizer step, keeping parameters in lockstep with no further
//!   traffic.
//!
//! Checkpoints are written by rank 0 only; on attach/resume rank 0
//! broadcasts its full training state (params, optimizer moments, step,
//! gamma RNG) so `--resume` on rank 0 alone restores the whole world.
//!
//! ## Failure semantics
//!
//! A dead rank must not hang the world.  Every steady-state read and
//! write is bounded by a configurable deadline (`dist_timeout_s` /
//! `--dist-timeout-s`); a rank that is silent for a full deadline, or
//! whose connection closes, surfaces as a structured
//! [`DistError`] naming the rank, the collective op in flight and the
//! elapsed wait.  Each rank's [`Collective`] runs a background heartbeat
//! thread so *slow* is never mistaken for *dead*: beats keep idle
//! connections warm while a rank computes, and stop flowing the instant
//! its process dies.  When the hub (rank 0) loses a peer mid-collective
//! it relays an ABORT frame to every surviving worker, so the whole world
//! terminates within ~2 deadlines blaming the same rank.  Because a
//! failed step never commits (gradients fold into scratch buffers;
//! params/optimizer/step/γ-RNG advance only in `finish_step`), rank 0's
//! surviving state is exactly the last completed step — rebuilding the
//! world and re-broadcasting that state (`--on-rank-failure=restart`)
//! resumes bit-identically to a run that never failed
//! (`tests/dist_fault.rs`).
//!
//! Layer map: [`transport`] (rendezvous handshake, framed TCP,
//! deadline-armed [`Link`]s, structured [`DistError`]),
//! [`collective`] (rank-ordered reduce / broadcast / barrier, heartbeats,
//! abort fan-out),
//! [`launch`] (in-process N-rank harness, fault injection, per-process
//! join, local spawn + [`WorkerRanks`] child reaping).

pub mod collective;
pub mod launch;
pub mod transport;

pub use collective::Collective;
pub use launch::{
    establish, run_local_world, run_local_world_injected, spawn_worker_ranks,
    FaultInjector, FaultKind, FaultPlan, WorkerRanks, DEFAULT_RENDEZVOUS, MAX_RESTARTS,
};
pub use transport::{DistError, Link, Rendezvous, Transport, WorldSpec};

use crate::model::ParamStore;
use anyhow::{ensure, Result};

/// One rank's identity + wiring, attached to a
/// [`Trainer`](crate::coordinator::Trainer) for the duration of a run.
pub struct DistRole {
    pub rank: usize,
    pub world: usize,
    pub coll: Collective,
}

impl DistRole {
    /// The single-process world: rank 0 of 1, no sockets.
    pub fn solo() -> Self {
        DistRole { rank: 0, world: 1, coll: Collective::solo() }
    }
}

/// Append every leaf of `store` to `out` in the store's canonical order
/// (group name order, then instance, then leaf — identical on every rank
/// because it mirrors the shared manifest).
pub fn flatten_into(store: &ParamStore, out: &mut Vec<f32>) {
    flatten_into_except(store, &[], out)
}

/// [`flatten_into`] minus the groups named in `skip` — the all-reduce
/// payload for a run with frozen parameter groups (`freeze_embed`), whose
/// gradients are pinned to zero locally and need not travel.
pub fn flatten_into_except(store: &ParamStore, skip: &[&str], out: &mut Vec<f32>) {
    for (name, insts) in &store.groups {
        if skip.contains(&name.as_str()) {
            continue;
        }
        for inst in insts {
            for t in inst {
                out.extend_from_slice(t.data());
            }
        }
    }
}

/// Overwrite `store`'s leaves from a flat buffer produced by
/// [`flatten_into`] on a structurally identical store.
pub fn unflatten_from(store: &mut ParamStore, data: &[f32]) -> Result<()> {
    unflatten_from_except(store, &[], data)
}

/// [`unflatten_from`] for a buffer produced by [`flatten_into_except`]
/// with the same `skip` list: skipped groups are left untouched.
pub fn unflatten_from_except(
    store: &mut ParamStore,
    skip: &[&str],
    data: &[f32],
) -> Result<()> {
    let mut pos = 0usize;
    for (name, insts) in store.groups.iter_mut() {
        if skip.contains(&name.as_str()) {
            continue;
        }
        for inst in insts {
            for t in inst {
                let n = t.len();
                ensure!(
                    data.len() >= pos + n,
                    "flat buffer too short: store wants > {} floats, got {}",
                    pos + n,
                    data.len()
                );
                t.data_mut().copy_from_slice(&data[pos..pos + n]);
                pos += n;
            }
        }
    }
    ensure!(
        pos == data.len(),
        "flat buffer has {} floats, store holds {pos}",
        data.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    #[test]
    fn flatten_roundtrip_is_bit_exact() {
        let rt = Runtime::load_with(
            std::path::Path::new("artifacts"),
            "smoke_gpt",
            crate::runtime::BackendKind::Native,
        )
        .unwrap();
        let ps = ParamStore::init(&rt.manifest, 3);
        let mut flat = Vec::new();
        flatten_into(&ps, &mut flat);
        assert_eq!(flat.len(), ps.n_params());
        let mut other = ps.zeros_like();
        unflatten_from(&mut other, &flat).unwrap();
        let mut a = Vec::new();
        let mut b = Vec::new();
        flatten_into(&ps, &mut a);
        flatten_into(&other, &mut b);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // wrong-length buffers are rejected
        assert!(unflatten_from(&mut other, &flat[..flat.len() - 1]).is_err());
    }

    #[test]
    fn flatten_except_skips_group_and_roundtrips() {
        let rt = Runtime::load_with(
            std::path::Path::new("artifacts"),
            "smoke_gpt",
            crate::runtime::BackendKind::Native,
        )
        .unwrap();
        let ps = ParamStore::init(&rt.manifest, 3);
        let embed_n: usize = ps.groups["embed"]
            .iter()
            .flatten()
            .map(|t| t.len())
            .sum();
        assert!(embed_n > 0);
        let mut flat = Vec::new();
        flatten_into_except(&ps, &["embed"], &mut flat);
        assert_eq!(flat.len(), ps.n_params() - embed_n);

        // skipped group is untouched by the unflatten; the rest lands
        let mut other = ps.zeros_like();
        other.groups.get_mut("embed").unwrap()[0]
            .iter_mut()
            .for_each(|t| t.data_mut().fill(7.0));
        unflatten_from_except(&mut other, &["embed"], &flat).unwrap();
        assert!(other.groups["embed"][0]
            .iter()
            .all(|t| t.data().iter().all(|x| *x == 7.0)));
        let mut a = Vec::new();
        let mut b = Vec::new();
        flatten_into_except(&ps, &["embed"], &mut a);
        flatten_into_except(&other, &["embed"], &mut b);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // a full-store buffer no longer matches the except layout
        let mut full = Vec::new();
        flatten_into(&ps, &mut full);
        assert!(unflatten_from_except(&mut other, &["embed"], &full).is_err());
    }
}
