//! Rendezvous + framed point-to-point transport over `std::net` TCP.
//!
//! Topology is hub-and-spoke: rank 0 binds the rendezvous address and
//! accepts one connection per worker rank; workers connect (with retry, so
//! start order between terminals does not matter) and the two sides verify
//! each other with a fixed-size `Hello` — magic, protocol version, rank,
//! world size, a digest of the semantically load-bearing training config,
//! the seed and the derived run id.  Any mismatch aborts the rendezvous
//! with a message naming the field, because a world that disagrees on its
//! config cannot be bit-deterministic and must not get to the point of
//! exchanging gradients.
//!
//! After the handshake every message is a length-prefixed frame
//! (`op: u8, len: u32 LE, payload`); the collectives in
//! [`super::collective`] are built from nothing but these frames.
//!
//! ## Failure semantics
//!
//! Steady-state traffic flows through [`Link`], which arms both socket
//! timeouts with the configured deadline (`dist_timeout_s`).  A read that
//! sees no frame for a full deadline, a closed connection, or a relayed
//! ABORT all surface as a structured [`DistError`] naming the rank at
//! fault, the collective op in flight and the elapsed wait — never an
//! eternal hang.  [`op::HEARTBEAT`] frames (sent by the collective layer's
//! beat thread, skipped transparently by [`Link::recv_into`]) keep a
//! slow-but-alive peer from tripping the deadline; [`op::ABORT`] lets the
//! hub fan a death notice out to every surviving worker within one
//! deadline of detecting it.

use crate::config::TrainConfig;
use anyhow::{bail, ensure, Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Frame opcodes (one byte on the wire).
pub mod op {
    pub const HELLO: u8 = 1;
    pub const WELCOME: u8 = 2;
    pub const REDUCE: u8 = 3;
    pub const BCAST: u8 = 4;
    pub const BARRIER_REQ: u8 = 5;
    pub const BARRIER_ACK: u8 = 6;
    /// Empty liveness frame; invisible to collectives (skipped on read).
    pub const HEARTBEAT: u8 = 7;
    /// World-abort relay: payload names the dead rank and the op it
    /// failed during; decoded into a [`super::DistError`] by the reader.
    pub const ABORT: u8 = 8;
    /// Fleet backplane (`crate::fleet`): replica joins the router —
    /// payload is magic, proto version and the model name it loaded.
    pub const FLEET_HELLO: u8 = 9;
    /// Router admits a replica: payload is the fleet's parameter blob
    /// (count + canonical-order f32s) so every replica serves identical
    /// weights.  During the handshake a [`FLEET_GOODBYE`] instead carries
    /// a UTF-8 rejection reason.
    pub const FLEET_WELCOME: u8 = 10;
    /// One γ-pure micro-batch, router → replica: batch id, example count,
    /// then `wire::encode` chunks (all carrying the same γ bits).
    pub const FLEET_INFER: u8 = 11;
    /// Per-slot results, replica → router: batch id, count, (loss,
    /// correct) pairs, cumulative `model_infer_ex` call count.
    pub const FLEET_RESULT: u8 = 12;
    /// Clean shutdown notice, router → replica (the replica exits 0).
    pub const FLEET_GOODBYE: u8 = 13;
    /// Clock-offset exchange for trace merging (`bdia trace`): a worker
    /// sends its monotonic `now_us` and the hub echoes its own, letting
    /// the worker estimate the hub-relative offset NTP-style.  Purely
    /// observability — no training state ever flows through this frame.
    pub const CLOCK: u8 = 14;
}

/// Handshake magic, shared by the rank protocol and the fleet backplane.
pub(crate) const MAGIC: u32 = 0x4244_4941; // "BDIA"
pub(crate) const PROTO_VERSION: u32 = 2;
/// Upper bound on a single frame payload (grad buffers are ~4·n_params
/// bytes; anything past this is a corrupt length prefix, not a model).
const MAX_FRAME: usize = 1 << 30;
/// How long a worker keeps retrying its rendezvous connect.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);
/// How long the hub waits for the full world to join.
pub const ACCEPT_TIMEOUT: Duration = Duration::from_secs(60);
/// Handshake read bound: pointing `--rendezvous` at some other TCP
/// service fails with a diagnostic instead of hanging forever.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

// ---------------------------------------------------------------------
// structured failure
// ---------------------------------------------------------------------

/// A distributed-runtime fault: the world lost a rank (or a rank went
/// silent past the deadline) during a collective.  This is the typed
/// error every steady-state transport failure resolves to, so callers —
/// the trainer, the session facade, the CLI's restart policy — can
/// `downcast_ref::<DistError>()` through the `anyhow` context chain and
/// react to *which rank* died rather than grepping strings.
#[derive(Debug, Clone)]
pub struct DistError {
    /// The rank this failure is attributed to (the dead or silent peer;
    /// for a relayed abort, the rank the hub reported dead).
    pub rank: usize,
    /// The collective op in flight ("reduce", "broadcast", "barrier",
    /// "state-sync") when the failure surfaced.
    pub op: &'static str,
    /// How long this side waited before giving up.
    pub elapsed: Duration,
    /// Human-readable cause (deadline expiry, closed connection, relayed
    /// world abort, …).
    pub detail: String,
}

impl std::fmt::Display for DistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "distributed world lost rank {} during '{}' after {:.2?}: {}",
            self.rank, self.op, self.elapsed, self.detail
        )
    }
}

impl std::error::Error for DistError {}

/// The root `io::ErrorKind` of an `anyhow` chain, if the cause is I/O.
fn io_kind(e: &anyhow::Error) -> Option<ErrorKind> {
    e.root_cause().downcast_ref::<std::io::Error>().map(std::io::Error::kind)
}

fn is_timeout(kind: ErrorKind) -> bool {
    // SO_RCVTIMEO expiry is WouldBlock on unix, TimedOut on windows
    matches!(kind, ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

// ---------------------------------------------------------------------
// byte helpers (shared with the collective layer and the state sync)
// ---------------------------------------------------------------------

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    ensure!(buf.len() >= *pos + 4, "truncated frame (u32 at {pos})");
    let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

pub fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    ensure!(buf.len() >= *pos + 8, "truncated frame (u64 at {pos})");
    let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

/// Encode an f32 slice as LE bytes (gradient / parameter payloads).
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Decode LE bytes into an f32 buffer of the expected element count.
pub fn get_f32s(buf: &[u8], pos: &mut usize, n: usize, out: &mut [f32]) -> Result<()> {
    ensure!(out.len() == n, "f32 payload target has wrong length");
    ensure!(
        buf.len() >= *pos + 4 * n,
        "truncated frame (wanted {n} f32s at {pos}, have {} bytes)",
        buf.len() - *pos
    );
    for slot in out.iter_mut() {
        *slot = f32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
    }
    Ok(())
}

/// FNV-1a, the digest behind config verification and run ids (no crypto
/// needed — this guards against operator error, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------
// world spec + handshake
// ---------------------------------------------------------------------

/// Everything a joining rank must agree on before any data moves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorldSpec {
    pub world: u32,
    /// Digest of the semantically load-bearing [`TrainConfig`] fields.
    pub digest: u64,
    pub seed: u64,
    /// Deterministic run identity derived from (digest, seed, world).
    pub run_id: u64,
}

impl WorldSpec {
    pub fn for_config(cfg: &TrainConfig) -> Self {
        // per-host knobs (paths, threads, logging cadence, and the
        // operational fault knobs dist_timeout_s / on_rank_failure) are
        // excluded: they may legitimately differ across machines without
        // breaking bit-determinism.  Everything that shapes the numbers
        // is in.
        let key = format!(
            "{}|{}|{:?}|{}|{}|{:?}|{}|{}|{}|{}|{:?}|{}|{}|{}|{}|{}",
            cfg.model,
            cfg.backend.name(),
            cfg.mode,
            cfg.gamma_mag,
            cfg.dataset,
            cfg.optimizer,
            cfg.lr,
            cfg.beta1,
            cfg.beta2,
            cfg.eps,
            cfg.grad_clip,
            cfg.seed,
            cfg.steps,
            cfg.train_examples,
            cfg.val_examples,
            cfg.accum(),
        );
        let digest = fnv1a64(key.as_bytes());
        let world = cfg.ranks.max(1) as u32;
        let mut id = Vec::new();
        put_u64(&mut id, digest);
        put_u64(&mut id, cfg.seed);
        put_u32(&mut id, world);
        WorldSpec { world, digest, seed: cfg.seed, run_id: fnv1a64(&id) }
    }
}

struct Hello {
    rank: u32,
    spec: WorldSpec,
}

impl Hello {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(40);
        put_u32(&mut out, MAGIC);
        put_u32(&mut out, PROTO_VERSION);
        put_u32(&mut out, self.rank);
        put_u32(&mut out, self.spec.world);
        put_u64(&mut out, self.spec.digest);
        put_u64(&mut out, self.spec.seed);
        put_u64(&mut out, self.spec.run_id);
        out
    }

    fn decode(buf: &[u8]) -> Result<Hello> {
        let mut p = 0;
        let magic = get_u32(buf, &mut p)?;
        ensure!(magic == MAGIC, "peer is not a bdia rank (bad magic {magic:#x})");
        let version = get_u32(buf, &mut p)?;
        ensure!(
            version == PROTO_VERSION,
            "protocol version mismatch: peer {version}, ours {PROTO_VERSION}"
        );
        let rank = get_u32(buf, &mut p)?;
        let world = get_u32(buf, &mut p)?;
        let digest = get_u64(buf, &mut p)?;
        let seed = get_u64(buf, &mut p)?;
        let run_id = get_u64(buf, &mut p)?;
        Ok(Hello { rank, spec: WorldSpec { world, digest, seed, run_id } })
    }
}

fn check_spec(theirs: &WorldSpec, ours: &WorldSpec) -> Result<()> {
    ensure!(
        theirs.world == ours.world,
        "world size mismatch: peer says {}, we say {} (--ranks must agree)",
        theirs.world,
        ours.world
    );
    ensure!(
        theirs.seed == ours.seed,
        "seed mismatch: peer {} vs ours {} (seed= must agree)",
        theirs.seed,
        ours.seed
    );
    ensure!(
        theirs.digest == ours.digest,
        "training config mismatch (digest {:#x} vs {:#x}): every rank must \
         run the same model/mode/dataset/optimizer/steps/grad_accum",
        theirs.digest,
        ours.digest
    );
    ensure!(
        theirs.run_id == ours.run_id,
        "run id mismatch ({:#x} vs {:#x})",
        theirs.run_id,
        ours.run_id
    );
    Ok(())
}

// ---------------------------------------------------------------------
// frame I/O
// ---------------------------------------------------------------------

pub fn write_frame(stream: &mut TcpStream, opcode: u8, payload: &[u8]) -> Result<()> {
    ensure!(payload.len() <= MAX_FRAME, "frame too large ({})", payload.len());
    let mut header = [0u8; 5];
    header[0] = opcode;
    header[1..].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    stream.write_all(&header)?;
    stream.write_all(payload)?;
    Ok(())
}

/// Read one frame into a reusable buffer — the hot collective path, so
/// multi-megabyte gradient payloads are not reallocated every round.
pub fn read_frame_into(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<u8> {
    let mut header = [0u8; 5];
    stream.read_exact(&mut header).context("reading frame header")?;
    let len = u32::from_le_bytes(header[1..].try_into().unwrap()) as usize;
    ensure!(len <= MAX_FRAME, "oversized frame ({len} bytes) — corrupt stream?");
    buf.clear();
    buf.resize(len, 0);
    stream.read_exact(buf).context("reading frame payload")?;
    Ok(header[0])
}

pub fn read_frame(stream: &mut TcpStream) -> Result<(u8, Vec<u8>)> {
    let mut payload = Vec::new();
    let opcode = read_frame_into(stream, &mut payload)?;
    Ok((opcode, payload))
}

/// [`read_frame`] that also asserts the expected opcode.
pub(crate) fn expect_frame(stream: &mut TcpStream, opcode: u8) -> Result<Vec<u8>> {
    let (got, payload) = read_frame(stream)?;
    ensure!(got == opcode, "protocol error: expected op {opcode}, got {got}");
    Ok(payload)
}

fn encode_abort(dead_rank: usize, during: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + during.len());
    put_u32(&mut out, dead_rank as u32);
    out.extend_from_slice(during.as_bytes());
    out
}

fn decode_abort(payload: &[u8]) -> (usize, String) {
    let mut pos = 0;
    let rank = get_u32(payload, &mut pos).unwrap_or(0) as usize;
    let during = String::from_utf8_lossy(&payload[pos.min(payload.len())..]);
    (rank, during.into_owned())
}

// ---------------------------------------------------------------------
// deadline-bounded steady-state link
// ---------------------------------------------------------------------

/// One post-handshake connection to a peer rank, with both socket
/// timeouts armed to the configured deadline.  The write half is behind a
/// mutex and shared with the collective layer's heartbeat thread (frames
/// stay whole because every frame is written under the lock); the read
/// half skips heartbeats, translates relayed ABORTs, and turns deadline
/// expiry / closed connections into structured [`DistError`]s.
pub struct Link {
    reader: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
    peer: usize,
    deadline: Duration,
}

impl Link {
    /// Arm `stream` with the steady-state deadline and split it into a
    /// read half and a lockable write half.  A socket that cannot arm its
    /// timeouts is refused outright — an unarmed read is the original
    /// hang-forever bug.
    pub fn new(stream: TcpStream, peer: usize, deadline: Duration) -> Result<Link> {
        ensure!(
            deadline > Duration::ZERO,
            "collective deadline must be positive (dist_timeout_s)"
        );
        stream
            .set_read_timeout(Some(deadline))
            .with_context(|| format!("arming read deadline for rank {peer}"))?;
        stream
            .set_write_timeout(Some(deadline))
            .with_context(|| format!("arming write deadline for rank {peer}"))?;
        let writer = stream
            .try_clone()
            .with_context(|| format!("cloning stream to rank {peer} for writes"))?;
        Ok(Link {
            reader: stream,
            writer: Arc::new(Mutex::new(writer)),
            peer,
            deadline,
        })
    }

    /// The rank on the other end of this connection.
    pub fn peer(&self) -> usize {
        self.peer
    }

    /// The steady-state deadline both socket timeouts are armed with.
    pub fn deadline(&self) -> Duration {
        self.deadline
    }

    /// Shared handle on the write half, for the heartbeat thread.
    pub(crate) fn writer(&self) -> Arc<Mutex<TcpStream>> {
        Arc::clone(&self.writer)
    }

    /// Write one frame, translating a stall past the deadline or a closed
    /// connection into a [`DistError`] attributed to this peer.
    pub fn send(&self, opcode: u8, payload: &[u8], during: &'static str) -> Result<()> {
        let start = Instant::now();
        let mut w = self
            .writer
            .lock()
            .map_err(|_| anyhow::anyhow!("writer lock poisoned (rank {})", self.peer))?;
        write_frame(&mut w, opcode, payload).map_err(|e| {
            let detail = match io_kind(&e) {
                Some(k) if is_timeout(k) => format!(
                    "send stalled past the {:?} deadline (peer stopped \
                     draining its socket)",
                    self.deadline
                ),
                Some(
                    ErrorKind::BrokenPipe
                    | ErrorKind::ConnectionReset
                    | ErrorKind::ConnectionAborted,
                ) => "connection closed (the rank's process is gone)".to_string(),
                _ => format!("transport failure: {e:#}"),
            };
            anyhow::Error::new(DistError {
                rank: self.peer,
                op: during,
                elapsed: start.elapsed(),
                detail,
            })
        })
    }

    /// Read the next collective frame into `buf`, skipping heartbeats.
    /// Deadline expiry, a closed connection, and a relayed ABORT all
    /// resolve to a structured [`DistError`] — the caller can always name
    /// the rank at fault and how long it waited.
    pub fn recv_into(&mut self, buf: &mut Vec<u8>, during: &'static str) -> Result<u8> {
        let start = Instant::now();
        loop {
            match read_frame_into(&mut self.reader, buf) {
                // liveness only — each one restarts the kernel timeout, so
                // a slow-but-alive peer never trips the deadline
                Ok(op::HEARTBEAT) => continue,
                Ok(op::ABORT) => {
                    let (dead, what) = decode_abort(buf);
                    return Err(anyhow::Error::new(DistError {
                        rank: dead,
                        op: during,
                        elapsed: start.elapsed(),
                        detail: format!(
                            "world aborted: rank {dead} failed during '{what}'"
                        ),
                    }));
                }
                Ok(opcode) => return Ok(opcode),
                Err(e) => {
                    let detail = match io_kind(&e) {
                        Some(k) if is_timeout(k) => format!(
                            "no frame within the {:?} deadline (rank wedged or \
                             network stalled; raise --dist-timeout-s if the \
                             deadline is too tight)",
                            self.deadline
                        ),
                        Some(ErrorKind::UnexpectedEof) => {
                            "connection closed (the rank's process is gone)".to_string()
                        }
                        _ => format!("transport failure: {e:#}"),
                    };
                    return Err(anyhow::Error::new(DistError {
                        rank: self.peer,
                        op: during,
                        elapsed: start.elapsed(),
                        detail,
                    }));
                }
            }
        }
    }

    /// Best-effort abort relay: tell this peer that `dead_rank` failed
    /// during `during`.  Errors are swallowed by design — the world is
    /// already coming down and this peer may be gone too.
    pub fn send_abort(&self, dead_rank: usize, during: &str) {
        if let Ok(mut w) = self.writer.lock() {
            let _ = write_frame(&mut w, op::ABORT, &encode_abort(dead_rank, during));
        }
    }
}

/// Best-effort heartbeat on a shared write half.  Skipped (reported as
/// alive) when the main thread holds the lock — its own in-flight frame
/// proves liveness better than a heartbeat would.  Returns `false` once
/// the peer is unreachable so the beat loop can stop early.
pub(crate) fn try_heartbeat(writer: &Mutex<TcpStream>) -> bool {
    match writer.try_lock() {
        Ok(mut w) => write_frame(&mut w, op::HEARTBEAT, &[]).is_ok(),
        Err(std::sync::TryLockError::WouldBlock) => true,
        Err(std::sync::TryLockError::Poisoned(_)) => false,
    }
}

// ---------------------------------------------------------------------
// rendezvous (hub side) + connect (worker side)
// ---------------------------------------------------------------------

/// A bound-but-not-yet-assembled world: the hub binds first (so a local
/// launcher can learn the ephemeral port and spawn workers at it), then
/// [`Rendezvous::accept`] collects and verifies the workers.
pub struct Rendezvous {
    listener: TcpListener,
    world: usize,
}

impl Rendezvous {
    pub fn bind(addr: &str, world: usize) -> Result<Rendezvous> {
        ensure!(world >= 1, "world size must be >= 1");
        let addr: SocketAddr = addr
            .to_socket_addrs()
            .with_context(|| format!("rendezvous address '{addr}' must be host:port"))?
            .next()
            .ok_or_else(|| anyhow::anyhow!("rendezvous '{addr}' resolved to nothing"))?;
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding rendezvous {addr}"))?;
        Ok(Rendezvous { listener, world })
    }

    pub fn addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Accept and verify `world - 1` workers; returns the hub transport
    /// with one deadline-armed [`Link`] per rank.  Fails (rather than
    /// hangs) if the world does not assemble within `timeout`, naming how
    /// many ranks made it; a duplicate or out-of-range rank claim is a
    /// structured error naming the offender, never a panic.
    pub fn accept(
        self,
        spec: &WorldSpec,
        timeout: Duration,
        deadline: Duration,
    ) -> Result<Transport> {
        ensure!(
            spec.world as usize == self.world,
            "rendezvous bound for world {}, spec says {}",
            self.world,
            spec.world
        );
        if self.world == 1 {
            return Ok(Transport::Solo);
        }
        let give_up = Instant::now() + timeout;
        self.listener.set_nonblocking(true)?;
        let mut peers: Vec<Option<Link>> = (1..self.world).map(|_| None).collect();
        let mut joined = 0usize;
        while joined < self.world - 1 {
            ensure!(
                Instant::now() < give_up,
                "rendezvous timed out: {}/{} workers joined within {timeout:?}",
                joined,
                self.world - 1
            );
            let mut stream = match self.listener.accept() {
                Ok((s, _)) => s,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                    continue;
                }
                Err(e) => return Err(e).context("rendezvous accept"),
            };
            stream.set_nonblocking(false)?;
            stream.set_nodelay(true).ok();
            stream
                .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
                .context("arming the handshake read timeout")?;
            let hello = Hello::decode(&expect_frame(&mut stream, op::HELLO)?)?;
            check_spec(&hello.spec, spec)?;
            let r = hello.rank as usize;
            ensure!(
                (1..self.world).contains(&r),
                "worker claims rank {r}, valid ranks are 1..{}",
                self.world
            );
            ensure!(peers[r - 1].is_none(), "two workers both claim rank {r}");
            write_frame(
                &mut stream,
                op::WELCOME,
                &Hello { rank: 0, spec: *spec }.encode(),
            )?;
            peers[r - 1] = Some(Link::new(stream, r, deadline)?);
            joined += 1;
        }
        let mut links = Vec::with_capacity(self.world - 1);
        for (i, p) in peers.into_iter().enumerate() {
            match p {
                Some(link) => links.push(link),
                None => bail!("rendezvous bookkeeping lost rank {}", i + 1),
            }
        }
        Ok(Transport::Hub { peers: links })
    }
}

/// The post-handshake wiring of one rank.
pub enum Transport {
    /// world == 1: no sockets, collectives degenerate to local arithmetic.
    Solo,
    /// rank 0: one deadline-armed link per worker, indexed `rank - 1`.
    Hub { peers: Vec<Link> },
    /// rank > 0: the single link to rank 0.
    Worker { hub: Link },
}

impl Transport {
    /// Worker-side join: connect (retrying until `timeout`, so workers may
    /// start before the hub binds), introduce ourselves, verify the hub's
    /// welcome against our own spec, then arm the steady-state `deadline`.
    pub fn connect(
        addr: SocketAddr,
        rank: usize,
        spec: &WorldSpec,
        timeout: Duration,
        deadline: Duration,
    ) -> Result<Transport> {
        ensure!(
            rank >= 1 && (rank as u32) < spec.world,
            "worker rank must be in 1..{}, got {rank}",
            spec.world
        );
        let give_up = Instant::now() + timeout;
        let mut stream = loop {
            match TcpStream::connect_timeout(&addr, Duration::from_secs(2)) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= give_up {
                        return Err(e).with_context(|| {
                            format!("rank {rank}: rendezvous {addr} unreachable for {timeout:?}")
                        });
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        stream.set_nodelay(true).ok();
        write_frame(
            &mut stream,
            op::HELLO,
            &Hello { rank: rank as u32, spec: *spec }.encode(),
        )?;
        stream
            .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
            .context("arming the handshake read timeout")?;
        let welcome = expect_frame(&mut stream, op::WELCOME).with_context(|| {
            format!("no welcome from {addr} — is that really a bdia rendezvous?")
        })?;
        let welcome = Hello::decode(&welcome)?;
        ensure!(welcome.rank == 0, "welcome did not come from rank 0");
        check_spec(&welcome.spec, spec)?;
        Ok(Transport::Worker { hub: Link::new(stream, 0, deadline)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DL: Duration = Duration::from_secs(30);

    fn spec(world: u32) -> WorldSpec {
        let cfg = TrainConfig { ranks: world as usize, ..TrainConfig::default() };
        WorldSpec::for_config(&cfg)
    }

    #[test]
    fn world_spec_tracks_semantic_fields_only() {
        let a = WorldSpec::for_config(&TrainConfig::default());
        let b = WorldSpec::for_config(&TrainConfig {
            threads: 7,
            ckpt_dir: "elsewhere".into(),
            log_every: 999,
            dist_timeout_s: 2.5,
            on_rank_failure: crate::config::RankFailurePolicy::Restart,
            ..TrainConfig::default()
        });
        assert_eq!(a, b, "per-host knobs must not change the world digest");
        let c = WorldSpec::for_config(&TrainConfig {
            seed: 1,
            ..TrainConfig::default()
        });
        assert_ne!(a.run_id, c.run_id);
        let d = WorldSpec::for_config(&TrainConfig {
            grad_accum: 8,
            ..TrainConfig::default()
        });
        assert_ne!(a.digest, d.digest, "grad_accum shapes the numbers");
    }

    #[test]
    fn handshake_accepts_matching_world() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        let worker = std::thread::spawn(move || {
            Transport::connect(addr, 1, &spec(2), CONNECT_TIMEOUT, DL).unwrap()
        });
        let hub = rdv.accept(&s, ACCEPT_TIMEOUT, DL).unwrap();
        let Transport::Hub { peers } = &hub else {
            panic!("rank 0 must end up with the hub transport")
        };
        assert_eq!(peers.len(), 1);
        assert_eq!(peers[0].peer(), 1);
        assert_eq!(peers[0].deadline(), DL);
        assert!(matches!(worker.join().unwrap(), Transport::Worker { .. }));
    }

    #[test]
    fn handshake_rejects_config_mismatch() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        let worker = std::thread::spawn(move || {
            let bad = WorldSpec::for_config(&TrainConfig {
                ranks: 2,
                lr: 3e-4, // semantically load-bearing difference
                ..TrainConfig::default()
            });
            Transport::connect(addr, 1, &bad, CONNECT_TIMEOUT, DL)
        });
        let hub = rdv.accept(&s, Duration::from_secs(10), DL);
        assert!(hub.is_err(), "hub must reject a mismatched config digest");
        assert!(worker.join().unwrap().is_err());
    }

    #[test]
    fn handshake_rejects_bad_rank() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        // rank outside 1..world is rejected on the worker side already
        let err = Transport::connect(addr, 5, &s, Duration::from_secs(2), DL);
        assert!(err.is_err());
        drop(rdv);
    }

    #[test]
    fn out_of_range_rank_claim_is_rejected_by_the_hub() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        let rogue = std::thread::spawn(move || {
            // a raw client lying about its rank in an otherwise valid hello
            let mut stream = TcpStream::connect(addr).unwrap();
            let hello = Hello { rank: 9, spec: spec(2) }.encode();
            write_frame(&mut stream, op::HELLO, &hello).unwrap();
            read_frame(&mut stream)
        });
        let err = rdv.accept(&s, Duration::from_secs(10), DL).unwrap_err();
        assert!(format!("{err:#}").contains("rank 9"), "{err:#}");
        let _ = rogue.join().unwrap();
    }

    #[test]
    fn duplicate_rank_is_a_structured_error_not_a_panic() {
        let s = spec(3);
        let rdv = Rendezvous::bind("127.0.0.1:0", 3).unwrap();
        let addr = rdv.addr();
        let first = std::thread::spawn(move || {
            Transport::connect(addr, 1, &spec(3), CONNECT_TIMEOUT, DL)
        });
        let second = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            Transport::connect(addr, 1, &spec(3), CONNECT_TIMEOUT, DL)
        });
        let err = rdv.accept(&s, Duration::from_secs(10), DL).unwrap_err();
        assert!(format!("{err:#}").contains("rank 1"), "{err:#}");
        // whichever worker handshook first holds a link to a dead hub; the
        // other got an error — neither may hang
        let _ = first.join().unwrap();
        let _ = second.join().unwrap();
    }

    #[test]
    fn silent_peer_times_out_with_a_structured_dist_error() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        let deadline = Duration::from_millis(200);
        let worker = std::thread::spawn(move || {
            let t = Transport::connect(addr, 1, &spec(2), CONNECT_TIMEOUT, deadline)
                .unwrap();
            // joined, then silent (no heartbeat thread on a raw transport)
            std::thread::sleep(Duration::from_millis(800));
            drop(t);
        });
        let hub = rdv.accept(&s, ACCEPT_TIMEOUT, deadline).unwrap();
        let Transport::Hub { mut peers } = hub else { panic!("expected hub") };
        let mut buf = Vec::new();
        let err = peers[0].recv_into(&mut buf, "reduce").unwrap_err();
        let de = err.downcast_ref::<DistError>().expect("DistError in the chain");
        assert_eq!((de.rank, de.op), (1, "reduce"));
        assert!(de.elapsed >= deadline, "gave up early: {:?}", de.elapsed);
        assert!(err.to_string().contains("rank 1"), "{err:#}");
        worker.join().unwrap();
    }

    #[test]
    fn dead_peer_is_detected_via_eof_before_the_deadline() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        let worker = std::thread::spawn(move || {
            // connect, then die immediately
            drop(Transport::connect(addr, 1, &spec(2), CONNECT_TIMEOUT, DL).unwrap());
        });
        let hub = rdv.accept(&s, ACCEPT_TIMEOUT, DL).unwrap();
        worker.join().unwrap();
        let Transport::Hub { mut peers } = hub else { panic!("expected hub") };
        let mut buf = Vec::new();
        let t0 = Instant::now();
        let err = peers[0].recv_into(&mut buf, "broadcast").unwrap_err();
        let de = err.downcast_ref::<DistError>().expect("DistError in the chain");
        assert_eq!(de.rank, 1);
        assert!(de.detail.contains("closed"), "{}", de.detail);
        assert!(t0.elapsed() < DL, "EOF detection must not wait out the deadline");
    }

    #[test]
    fn heartbeats_are_invisible_to_collective_reads() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        let worker = std::thread::spawn(move || {
            let t = Transport::connect(addr, 1, &spec(2), CONNECT_TIMEOUT, DL).unwrap();
            let Transport::Worker { hub } = t else { panic!("expected worker") };
            for _ in 0..3 {
                hub.send(op::HEARTBEAT, &[], "beat").unwrap();
            }
            hub.send(op::REDUCE, &[1, 2, 3], "reduce").unwrap();
        });
        let hub = rdv.accept(&s, ACCEPT_TIMEOUT, DL).unwrap();
        let Transport::Hub { mut peers } = hub else { panic!("expected hub") };
        let mut buf = Vec::new();
        let got = peers[0].recv_into(&mut buf, "reduce").unwrap();
        assert_eq!((got, buf.as_slice()), (op::REDUCE, &[1u8, 2, 3][..]));
        worker.join().unwrap();
    }

    #[test]
    fn abort_relay_names_the_dead_rank_and_op() {
        let s = spec(3);
        let rdv = Rendezvous::bind("127.0.0.1:0", 3).unwrap();
        let addr = rdv.addr();
        let bystander = std::thread::spawn(move || {
            let t = Transport::connect(addr, 2, &spec(3), CONNECT_TIMEOUT, DL).unwrap();
            let Transport::Worker { mut hub } = t else { panic!("expected worker") };
            let mut buf = Vec::new();
            hub.recv_into(&mut buf, "broadcast").unwrap_err()
        });
        let victim = std::thread::spawn(move || {
            Transport::connect(addr, 1, &spec(3), CONNECT_TIMEOUT, DL).unwrap()
        });
        let hub = rdv.accept(&s, ACCEPT_TIMEOUT, DL).unwrap();
        let Transport::Hub { peers } = &hub else { panic!("expected hub") };
        // the hub decided rank 1 is dead mid-reduce; rank 2 must learn it
        peers[1].send_abort(1, "reduce");
        let err = bystander.join().unwrap();
        let de = err.downcast_ref::<DistError>().expect("DistError in the chain");
        assert_eq!((de.rank, de.op), (1, "broadcast"));
        assert!(de.detail.contains("'reduce'"), "{}", de.detail);
        drop(victim.join().unwrap());
    }

    #[test]
    fn frame_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            write_frame(&mut s, op::REDUCE, &[1, 2, 3]).unwrap();
            let (o, p) = read_frame(&mut s).unwrap();
            (o, p)
        });
        let (mut s, _) = listener.accept().unwrap();
        let (o, p) = read_frame(&mut s).unwrap();
        assert_eq!((o, p), (op::REDUCE, vec![1, 2, 3]));
        write_frame(&mut s, op::BCAST, &[9]).unwrap();
        assert_eq!(t.join().unwrap(), (op::BCAST, vec![9]));
    }

    #[test]
    fn f32_payload_roundtrip_is_bit_exact() {
        let xs = [1.5f32, -0.0, f32::NAN, f32::MIN_POSITIVE, 1e38];
        let mut buf = Vec::new();
        put_f32s(&mut buf, &xs);
        let mut out = [0f32; 5];
        let mut pos = 0;
        get_f32s(&buf, &mut pos, 5, &mut out).unwrap();
        for (a, b) in xs.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn abort_payload_roundtrip() {
        let (rank, during) = decode_abort(&encode_abort(3, "state-sync"));
        assert_eq!((rank, during.as_str()), (3, "state-sync"));
    }
}
