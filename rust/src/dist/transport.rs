//! Rendezvous + framed point-to-point transport over `std::net` TCP.
//!
//! Topology is hub-and-spoke: rank 0 binds the rendezvous address and
//! accepts one connection per worker rank; workers connect (with retry, so
//! start order between terminals does not matter) and the two sides verify
//! each other with a fixed-size `Hello` — magic, protocol version, rank,
//! world size, a digest of the semantically load-bearing training config,
//! the seed and the derived run id.  Any mismatch aborts the rendezvous
//! with a message naming the field, because a world that disagrees on its
//! config cannot be bit-deterministic and must not get to the point of
//! exchanging gradients.
//!
//! After the handshake every message is a length-prefixed frame
//! (`op: u8, len: u32 LE, payload`); the collectives in
//! [`super::collective`] are built from nothing but these frames.

use crate::config::TrainConfig;
use anyhow::{ensure, Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Frame opcodes (one byte on the wire).
pub mod op {
    pub const HELLO: u8 = 1;
    pub const WELCOME: u8 = 2;
    pub const REDUCE: u8 = 3;
    pub const BCAST: u8 = 4;
    pub const BARRIER_REQ: u8 = 5;
    pub const BARRIER_ACK: u8 = 6;
}

const MAGIC: u32 = 0x4244_4941; // "BDIA"
const PROTO_VERSION: u32 = 1;
/// Upper bound on a single frame payload (grad buffers are ~4·n_params
/// bytes; anything past this is a corrupt length prefix, not a model).
const MAX_FRAME: usize = 1 << 30;
/// How long a worker keeps retrying its rendezvous connect.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);
/// How long the hub waits for the full world to join.
pub const ACCEPT_TIMEOUT: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------
// byte helpers (shared with the collective layer and the state sync)
// ---------------------------------------------------------------------

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    ensure!(buf.len() >= *pos + 4, "truncated frame (u32 at {pos})");
    let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

pub fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    ensure!(buf.len() >= *pos + 8, "truncated frame (u64 at {pos})");
    let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

/// Encode an f32 slice as LE bytes (gradient / parameter payloads).
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Decode LE bytes into an f32 buffer of the expected element count.
pub fn get_f32s(buf: &[u8], pos: &mut usize, n: usize, out: &mut [f32]) -> Result<()> {
    ensure!(out.len() == n, "f32 payload target has wrong length");
    ensure!(
        buf.len() >= *pos + 4 * n,
        "truncated frame (wanted {n} f32s at {pos}, have {} bytes)",
        buf.len() - *pos
    );
    for slot in out.iter_mut() {
        *slot = f32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
    }
    Ok(())
}

/// FNV-1a, the digest behind config verification and run ids (no crypto
/// needed — this guards against operator error, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------
// world spec + handshake
// ---------------------------------------------------------------------

/// Everything a joining rank must agree on before any data moves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorldSpec {
    pub world: u32,
    /// Digest of the semantically load-bearing [`TrainConfig`] fields.
    pub digest: u64,
    pub seed: u64,
    /// Deterministic run identity derived from (digest, seed, world).
    pub run_id: u64,
}

impl WorldSpec {
    pub fn for_config(cfg: &TrainConfig) -> Self {
        // per-host knobs (paths, threads, logging cadence) are excluded:
        // they may legitimately differ across machines without breaking
        // bit-determinism.  Everything that shapes the numbers is in.
        let key = format!(
            "{}|{}|{:?}|{}|{}|{:?}|{}|{}|{}|{}|{:?}|{}|{}|{}|{}|{}",
            cfg.model,
            cfg.backend.name(),
            cfg.mode,
            cfg.gamma_mag,
            cfg.dataset,
            cfg.optimizer,
            cfg.lr,
            cfg.beta1,
            cfg.beta2,
            cfg.eps,
            cfg.grad_clip,
            cfg.seed,
            cfg.steps,
            cfg.train_examples,
            cfg.val_examples,
            cfg.accum(),
        );
        let digest = fnv1a64(key.as_bytes());
        let world = cfg.ranks.max(1) as u32;
        let mut id = Vec::new();
        put_u64(&mut id, digest);
        put_u64(&mut id, cfg.seed);
        put_u32(&mut id, world);
        WorldSpec { world, digest, seed: cfg.seed, run_id: fnv1a64(&id) }
    }
}

struct Hello {
    rank: u32,
    spec: WorldSpec,
}

impl Hello {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(40);
        put_u32(&mut out, MAGIC);
        put_u32(&mut out, PROTO_VERSION);
        put_u32(&mut out, self.rank);
        put_u32(&mut out, self.spec.world);
        put_u64(&mut out, self.spec.digest);
        put_u64(&mut out, self.spec.seed);
        put_u64(&mut out, self.spec.run_id);
        out
    }

    fn decode(buf: &[u8]) -> Result<Hello> {
        let mut p = 0;
        let magic = get_u32(buf, &mut p)?;
        ensure!(magic == MAGIC, "peer is not a bdia rank (bad magic {magic:#x})");
        let version = get_u32(buf, &mut p)?;
        ensure!(
            version == PROTO_VERSION,
            "protocol version mismatch: peer {version}, ours {PROTO_VERSION}"
        );
        let rank = get_u32(buf, &mut p)?;
        let world = get_u32(buf, &mut p)?;
        let digest = get_u64(buf, &mut p)?;
        let seed = get_u64(buf, &mut p)?;
        let run_id = get_u64(buf, &mut p)?;
        Ok(Hello { rank, spec: WorldSpec { world, digest, seed, run_id } })
    }
}

fn check_spec(theirs: &WorldSpec, ours: &WorldSpec) -> Result<()> {
    ensure!(
        theirs.world == ours.world,
        "world size mismatch: peer says {}, we say {} (--ranks must agree)",
        theirs.world,
        ours.world
    );
    ensure!(
        theirs.seed == ours.seed,
        "seed mismatch: peer {} vs ours {} (seed= must agree)",
        theirs.seed,
        ours.seed
    );
    ensure!(
        theirs.digest == ours.digest,
        "training config mismatch (digest {:#x} vs {:#x}): every rank must \
         run the same model/mode/dataset/optimizer/steps/grad_accum",
        theirs.digest,
        ours.digest
    );
    ensure!(
        theirs.run_id == ours.run_id,
        "run id mismatch ({:#x} vs {:#x})",
        theirs.run_id,
        ours.run_id
    );
    Ok(())
}

// ---------------------------------------------------------------------
// frame I/O
// ---------------------------------------------------------------------

pub fn write_frame(stream: &mut TcpStream, opcode: u8, payload: &[u8]) -> Result<()> {
    ensure!(payload.len() <= MAX_FRAME, "frame too large ({})", payload.len());
    let mut header = [0u8; 5];
    header[0] = opcode;
    header[1..].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    stream.write_all(&header)?;
    stream.write_all(payload)?;
    Ok(())
}

/// Read one frame into a reusable buffer — the hot collective path, so
/// multi-megabyte gradient payloads are not reallocated every round.
pub fn read_frame_into(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<u8> {
    let mut header = [0u8; 5];
    stream.read_exact(&mut header).context("reading frame header")?;
    let len = u32::from_le_bytes(header[1..].try_into().unwrap()) as usize;
    ensure!(len <= MAX_FRAME, "oversized frame ({len} bytes) — corrupt stream?");
    buf.clear();
    buf.resize(len, 0);
    stream.read_exact(buf).context("reading frame payload")?;
    Ok(header[0])
}

pub fn read_frame(stream: &mut TcpStream) -> Result<(u8, Vec<u8>)> {
    let mut payload = Vec::new();
    let opcode = read_frame_into(stream, &mut payload)?;
    Ok((opcode, payload))
}

/// [`read_frame`] that also asserts the expected opcode.
pub(crate) fn expect_frame(stream: &mut TcpStream, opcode: u8) -> Result<Vec<u8>> {
    let (got, payload) = read_frame(stream)?;
    ensure!(got == opcode, "protocol error: expected op {opcode}, got {got}");
    Ok(payload)
}

// ---------------------------------------------------------------------
// rendezvous (hub side) + connect (worker side)
// ---------------------------------------------------------------------

/// A bound-but-not-yet-assembled world: the hub binds first (so a local
/// launcher can learn the ephemeral port and spawn workers at it), then
/// [`Rendezvous::accept`] collects and verifies the workers.
pub struct Rendezvous {
    listener: TcpListener,
    world: usize,
}

impl Rendezvous {
    pub fn bind(addr: &str, world: usize) -> Result<Rendezvous> {
        ensure!(world >= 1, "world size must be >= 1");
        let addr: SocketAddr = addr
            .to_socket_addrs()
            .with_context(|| format!("rendezvous address '{addr}' must be host:port"))?
            .next()
            .ok_or_else(|| anyhow::anyhow!("rendezvous '{addr}' resolved to nothing"))?;
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding rendezvous {addr}"))?;
        Ok(Rendezvous { listener, world })
    }

    pub fn addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Accept and verify `world - 1` workers; returns the hub transport
    /// with per-rank streams.  Fails (rather than hangs) if the world does
    /// not assemble within `timeout`.
    pub fn accept(self, spec: &WorldSpec, timeout: Duration) -> Result<Transport> {
        ensure!(
            spec.world as usize == self.world,
            "rendezvous bound for world {}, spec says {}",
            self.world,
            spec.world
        );
        if self.world == 1 {
            return Ok(Transport::Solo);
        }
        let deadline = Instant::now() + timeout;
        self.listener.set_nonblocking(true)?;
        let mut peers: Vec<Option<TcpStream>> = (1..self.world).map(|_| None).collect();
        let mut joined = 0usize;
        while joined < self.world - 1 {
            ensure!(
                Instant::now() < deadline,
                "rendezvous timed out: {}/{} workers joined within {timeout:?}",
                joined,
                self.world - 1
            );
            let mut stream = match self.listener.accept() {
                Ok((s, _)) => s,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                    continue;
                }
                Err(e) => return Err(e).context("rendezvous accept"),
            };
            stream.set_nonblocking(false)?;
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
            let hello = Hello::decode(&expect_frame(&mut stream, op::HELLO)?)?;
            check_spec(&hello.spec, spec)?;
            let r = hello.rank as usize;
            ensure!(
                (1..self.world).contains(&r),
                "worker claims rank {r}, valid ranks are 1..{}",
                self.world
            );
            ensure!(peers[r - 1].is_none(), "two workers both claim rank {r}");
            write_frame(
                &mut stream,
                op::WELCOME,
                &Hello { rank: 0, spec: *spec }.encode(),
            )?;
            stream.set_read_timeout(None).ok();
            peers[r - 1] = Some(stream);
            joined += 1;
        }
        let peers = peers.into_iter().map(|p| p.expect("all joined")).collect();
        Ok(Transport::Hub { peers })
    }
}

/// The post-handshake wiring of one rank.
pub enum Transport {
    /// world == 1: no sockets, collectives degenerate to local arithmetic.
    Solo,
    /// rank 0: one stream per worker, indexed `rank - 1`.
    Hub { peers: Vec<TcpStream> },
    /// rank > 0: the single stream to rank 0.
    Worker { hub: TcpStream },
}

impl Transport {
    /// Worker-side join: connect (retrying until `timeout`, so workers may
    /// start before the hub binds), introduce ourselves, verify the hub's
    /// welcome against our own spec.
    pub fn connect(
        addr: SocketAddr,
        rank: usize,
        spec: &WorldSpec,
        timeout: Duration,
    ) -> Result<Transport> {
        ensure!(
            rank >= 1 && (rank as u32) < spec.world,
            "worker rank must be in 1..{}, got {rank}",
            spec.world
        );
        let deadline = Instant::now() + timeout;
        let mut stream = loop {
            match TcpStream::connect_timeout(&addr, Duration::from_secs(2)) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e).with_context(|| {
                            format!("rank {rank}: rendezvous {addr} unreachable for {timeout:?}")
                        });
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        stream.set_nodelay(true).ok();
        write_frame(
            &mut stream,
            op::HELLO,
            &Hello { rank: rank as u32, spec: *spec }.encode(),
        )?;
        // bound the handshake read so pointing --rendezvous at some other
        // TCP service fails with a diagnostic instead of hanging forever
        stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
        let welcome = expect_frame(&mut stream, op::WELCOME).with_context(|| {
            format!("no welcome from {addr} — is that really a bdia rendezvous?")
        })?;
        let welcome = Hello::decode(&welcome)?;
        ensure!(welcome.rank == 0, "welcome did not come from rank 0");
        check_spec(&welcome.spec, spec)?;
        stream.set_read_timeout(None).ok();
        Ok(Transport::Worker { hub: stream })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(world: u32) -> WorldSpec {
        let cfg = TrainConfig { ranks: world as usize, ..TrainConfig::default() };
        WorldSpec::for_config(&cfg)
    }

    #[test]
    fn world_spec_tracks_semantic_fields_only() {
        let a = WorldSpec::for_config(&TrainConfig::default());
        let b = WorldSpec::for_config(&TrainConfig {
            threads: 7,
            ckpt_dir: "elsewhere".into(),
            log_every: 999,
            ..TrainConfig::default()
        });
        assert_eq!(a, b, "per-host knobs must not change the world digest");
        let c = WorldSpec::for_config(&TrainConfig {
            seed: 1,
            ..TrainConfig::default()
        });
        assert_ne!(a.run_id, c.run_id);
        let d = WorldSpec::for_config(&TrainConfig {
            grad_accum: 8,
            ..TrainConfig::default()
        });
        assert_ne!(a.digest, d.digest, "grad_accum shapes the numbers");
    }

    #[test]
    fn handshake_accepts_matching_world() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        let worker = std::thread::spawn(move || {
            Transport::connect(addr, 1, &spec(2), CONNECT_TIMEOUT).unwrap()
        });
        let hub = rdv.accept(&s, ACCEPT_TIMEOUT).unwrap();
        let Transport::Hub { peers } = &hub else {
            panic!("rank 0 must end up with the hub transport")
        };
        assert_eq!(peers.len(), 1);
        assert!(matches!(worker.join().unwrap(), Transport::Worker { .. }));
    }

    #[test]
    fn handshake_rejects_config_mismatch() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        let worker = std::thread::spawn(move || {
            let bad = WorldSpec::for_config(&TrainConfig {
                ranks: 2,
                lr: 3e-4, // semantically load-bearing difference
                ..TrainConfig::default()
            });
            Transport::connect(addr, 1, &bad, CONNECT_TIMEOUT)
        });
        let hub = rdv.accept(&s, Duration::from_secs(10));
        assert!(hub.is_err(), "hub must reject a mismatched config digest");
        assert!(worker.join().unwrap().is_err());
    }

    #[test]
    fn handshake_rejects_bad_rank() {
        let s = spec(2);
        let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
        let addr = rdv.addr();
        // rank outside 1..world is rejected on the worker side already
        let err = Transport::connect(addr, 5, &s, Duration::from_secs(2));
        assert!(err.is_err());
        drop(rdv);
    }

    #[test]
    fn frame_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            write_frame(&mut s, op::REDUCE, &[1, 2, 3]).unwrap();
            let (o, p) = read_frame(&mut s).unwrap();
            (o, p)
        });
        let (mut s, _) = listener.accept().unwrap();
        let (o, p) = read_frame(&mut s).unwrap();
        assert_eq!((o, p), (op::REDUCE, vec![1, 2, 3]));
        write_frame(&mut s, op::BCAST, &[9]).unwrap();
        assert_eq!(t.join().unwrap(), (op::BCAST, vec![9]));
    }

    #[test]
    fn f32_payload_roundtrip_is_bit_exact() {
        let xs = [1.5f32, -0.0, f32::NAN, f32::MIN_POSITIVE, 1e38];
        let mut buf = Vec::new();
        put_f32s(&mut buf, &xs);
        let mut out = [0f32; 5];
        let mut pos = 0;
        get_f32s(&buf, &mut pos, 5, &mut out).unwrap();
        for (a, b) in xs.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
