//! `bdia bench`: the per-family performance suite behind BENCH_4.json.
//!
//! Times the three hot paths — training forward (`fwd`), a full training
//! step (`step` = forward + online backward + optimizer), and fused
//! quantized inference (`infer`) — for each model family, at 1 thread and
//! at the configured thread count, on the native backend.  The contrast
//! is the headline number for the deterministic parallel compute core:
//! same bits, less wall time.
//!
//! Every measurement goes through the [`Session`] facade
//! ([`Session::bench`]), so the suite times exactly the path embedders and
//! the CLI use.  The report prints as rows and lands in a JSON file
//! (default `BENCH_4.json`) so successive PRs can track the perf
//! trajectory.

use crate::api::{Session, SessionTimings};
use crate::kernels::pool;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct SuiteOpts {
    /// Bundle names to time (one per family by default).
    pub families: Vec<String>,
    /// Parallel thread count to compare against 1 (0 = auto-detect).
    pub threads: usize,
    /// Where the JSON report lands.
    pub out: PathBuf,
    /// Quick mode: smoke bundles + short budgets (the CI smoke step).
    pub quick: bool,
    /// Wall budget per measurement.
    pub budget: Duration,
    /// Iteration cap per measurement.
    pub max_iters: usize,
}

impl SuiteOpts {
    pub fn new(quick: bool) -> Self {
        if quick {
            SuiteOpts {
                families: vec![
                    "smoke_vit".into(),
                    "smoke_gpt".into(),
                    "smoke_encdec".into(),
                ],
                threads: 0,
                out: PathBuf::from("BENCH_4.json"),
                quick,
                budget: Duration::from_millis(250),
                max_iters: 4,
            }
        } else {
            SuiteOpts {
                families: vec![
                    "vit_s10".into(),
                    "gpt_tiny".into(),
                    "encdec_mt".into(),
                ],
                threads: 0,
                out: PathBuf::from("BENCH_4.json"),
                quick,
                budget: Duration::from_millis(1500),
                max_iters: 10,
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct SuiteReport {
    pub threads_baseline: usize,
    pub threads_parallel: usize,
    /// One [`SessionTimings`] row per (bundle, thread count).
    pub rows: Vec<SessionTimings>,
}

impl SuiteReport {
    pub fn all_finite(&self) -> bool {
        self.rows.iter().all(|r| {
            r.fwd_ms.is_finite() && r.step_ms.is_finite() && r.infer_ms.is_finite()
        })
    }

    /// step-time speedup of the parallel run over the 1-thread run.
    pub fn step_speedup(&self, bundle: &str) -> Option<f64> {
        let at = |t: usize| {
            self.rows
                .iter()
                .find(|r| r.bundle == bundle && r.threads == t)
                .map(|r| r.step_ms)
        };
        match (at(self.threads_baseline), at(self.threads_parallel)) {
            (Some(base), Some(par)) if par > 0.0 => Some(base / par),
            _ => None,
        }
    }

    fn to_json(&self, quick: bool) -> String {
        let rows: Vec<String> = self
            .rows
            .iter()
            .map(|r| {
                format!(
                    "    {{\"bundle\": \"{}\", \"family\": \"{}\", \
                     \"threads\": {}, \"fwd_ms\": {:.3}, \"step_ms\": {:.3}, \
                     \"infer_ms\": {:.3}}}",
                    r.bundle, r.family, r.threads, r.fwd_ms, r.step_ms,
                    r.infer_ms
                )
            })
            .collect();
        format!(
            "{{\n  \"bench\": \"BENCH_4\",\n  \"quick\": {},\n  \
             \"threads_baseline\": {},\n  \"threads_parallel\": {},\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            quick,
            self.threads_baseline,
            self.threads_parallel,
            rows.join(",\n")
        )
    }
}

/// Run the suite and write the JSON report.
pub fn run(opts: &SuiteOpts) -> Result<SuiteReport> {
    let par = if opts.threads == 0 { pool::auto_threads() } else { opts.threads };
    let mut counts = vec![1usize];
    if par > 1 {
        counts.push(par);
    }
    println!(
        "bdia bench: families {:?}, threads {counts:?}, budget {:?}/measurement",
        opts.families, opts.budget
    );

    let mut rows = Vec::new();
    for bundle in &opts.families {
        // one Session per bundle: the suite times the same facade path the
        // CLI and embedders use
        let mut session = Session::builder()
            .model_name(bundle.clone())
            .dataset_auto()
            .build()
            .with_context(|| format!("loading bundle '{bundle}'"))?;
        for &t in &counts {
            pool::set_threads(t);
            let timings = session.bench(opts.budget, opts.max_iters)?;
            rows.push(timings);
        }
    }
    pool::set_threads(par);

    let report = SuiteReport {
        threads_baseline: 1,
        threads_parallel: *counts.last().unwrap(),
        rows,
    };
    for bundle in &opts.families {
        if let Some(s) = report.step_speedup(bundle) {
            println!(
                "{bundle}: step speedup x{s:.2} ({} -> {} threads)",
                report.threads_baseline, report.threads_parallel
            );
        }
    }
    std::fs::write(&opts.out, report.to_json(opts.quick))
        .with_context(|| format!("writing {}", opts.out.display()))?;
    println!("report written to {}", opts.out.display());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_suite_runs_and_writes_report() {
        let dir = std::env::temp_dir().join(format!(
            "bdia_bench_suite_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_4.json");
        let opts = SuiteOpts {
            families: vec!["smoke_gpt".into()],
            threads: 2,
            out: out.clone(),
            budget: Duration::from_millis(40),
            max_iters: 3,
            ..SuiteOpts::new(true)
        };
        let report = run(&opts).unwrap();
        assert!(report.all_finite());
        assert_eq!(report.threads_parallel, 2);
        // one row per thread count
        assert_eq!(report.rows.len(), 2);
        let text = std::fs::read_to_string(&out).unwrap();
        let parsed = crate::config::json::Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("bench").unwrap().as_str().unwrap(),
            "BENCH_4"
        );
        assert!(report.step_speedup("smoke_gpt").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
