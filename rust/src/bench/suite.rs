//! `bdia bench`: the per-family performance suite behind BENCH_10.json.
//!
//! Times the three hot paths — training forward (`fwd`), a full training
//! step (`step` = forward + online backward + optimizer), and fused
//! quantized inference (`infer`) — for each model family, at 1 thread and
//! at the configured thread count, on the native backend.  The contrast
//! is the headline number for the deterministic parallel compute core:
//! same bits, less wall time.
//!
//! Families with a `model_decode_step` executable (GPT) additionally get
//! **decode** rows: autoregressive tokens/sec through
//! [`Session::generate`] at 1 thread and at the parallel thread count,
//! plus a tuned-profile row — the same 1-vs-N / default-vs-tuned
//! contrasts as the training paths, but for the KV-cache decode loop.
//!
//! Each bundle also gets a **tuned** row: the parallel-thread measurement
//! repeated under a tuned kernel profile (loaded from
//! [`SuiteOpts::tune_profile`], or found by a quick in-process `bdia tune`
//! search when none is given), so every report carries a
//! default-vs-tuned contrast per family.  Any legal profile is bit-exact
//! by construction, so the tuned row differs in wall time only.
//!
//! Three more blocks track the rest of the scaling story:
//!
//! * `dist` — per-family global-step wall time at world sizes 1 and 2
//!   (full in-process ranks over loopback TCP, same `grad_accum`, so the
//!   contrast isolates collective overhead vs compute split);
//! * `memory` — the analytic Table-1 peak-training-memory per
//!   family/mode ([`MemoryModel`]), so the perf trajectory tracks memory
//!   alongside speed;
//! * `obs_overhead` — the same step measurement at the three
//!   [`crate::obs`] tracing levels (off / metrics-only / full spans), the
//!   evidence behind the "observability costs ≤1%" claim.  Levels change
//!   wall time only; the bits are identical by construction.
//!
//! Every hot-path measurement goes through the [`Session`] facade
//! ([`Session::bench`]), so the suite times exactly the path embedders and
//! the CLI use.  The report prints as rows and lands in a JSON file
//! (default `BENCH_10.json`) so successive PRs can track the trajectory.

use crate::api::{Session, SessionTimings, TuneOpts};
use crate::config::{TrainConfig, TrainMode};
use crate::coordinator::Trainer;
use crate::data::make_dataset;
use crate::dist::run_local_world;
use crate::kernels::{pool, profile, KernelProfile};
use crate::metrics::memory::MemoryModel;
use crate::serve::bench as serve_bench;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct SuiteOpts {
    /// Bundle names to time (one per family by default).
    pub families: Vec<String>,
    /// Parallel thread count to compare against 1 (0 = auto-detect).
    pub threads: usize,
    /// Where the JSON report lands.
    pub out: PathBuf,
    /// Quick mode: smoke bundles + short budgets (the CI smoke step).
    pub quick: bool,
    /// Wall budget per measurement.
    pub budget: Duration,
    /// Iteration cap per measurement.
    pub max_iters: usize,
    /// Persisted kernel profile for the tuned row (`--tune-profile`).
    /// `None` runs a quick in-process tune search per bundle instead.
    pub tune_profile: Option<PathBuf>,
}

impl SuiteOpts {
    pub fn new(quick: bool) -> Self {
        if quick {
            SuiteOpts {
                families: vec![
                    "smoke_vit".into(),
                    "smoke_gpt".into(),
                    "smoke_encdec".into(),
                ],
                threads: 0,
                out: PathBuf::from("BENCH_10.json"),
                quick,
                budget: Duration::from_millis(250),
                max_iters: 4,
                tune_profile: None,
            }
        } else {
            SuiteOpts {
                families: vec![
                    "vit_s10".into(),
                    "gpt_tiny".into(),
                    "encdec_mt".into(),
                ],
                threads: 0,
                out: PathBuf::from("BENCH_10.json"),
                quick,
                budget: Duration::from_millis(1500),
                max_iters: 10,
                tune_profile: None,
            }
        }
    }
}

/// One global-step timing at a given world size (dist scaling block).
#[derive(Clone, Debug)]
pub struct DistTimings {
    pub bundle: String,
    pub ranks: usize,
    /// Mean wall time of one *global* optimization step, ms.
    pub step_ms: f64,
}

/// One autoregressive-decode timing (decode block; GPT bundles only).
#[derive(Clone, Debug)]
pub struct DecodeTimings {
    pub bundle: String,
    pub threads: usize,
    /// Kernel profile the row ran under (`"default"` or the tuned id).
    pub profile: String,
    /// Greedy decode throughput until the context window fills.
    pub tokens_per_s: f64,
}

/// One analytic Table-1 peak-memory number (memory block).
#[derive(Clone, Debug)]
pub struct MemoryRow {
    pub bundle: String,
    pub mode: &'static str,
    pub peak_bytes: usize,
}

/// Step time under each [`crate::obs`] tracing level (obs_overhead block).
#[derive(Clone, Debug)]
pub struct ObsOverheadRow {
    pub bundle: String,
    /// Tracing fully disabled (the baseline).
    pub step_ms_off: f64,
    /// Span durations feed histograms; no ring events.
    pub step_ms_metrics: f64,
    /// Full span events recorded for trace export.
    pub step_ms_spans: f64,
}

#[derive(Clone, Debug)]
pub struct SuiteReport {
    pub threads_baseline: usize,
    pub threads_parallel: usize,
    /// One [`SessionTimings`] row per (bundle, thread count), plus one
    /// tuned-profile row per bundle at the parallel thread count
    /// (`row.profile` names the kernel profile each row ran under).
    pub rows: Vec<SessionTimings>,
    /// Global-step time per (bundle, world size) — ranks 1 and 2.
    pub dist: Vec<DistTimings>,
    /// Decode tokens/sec per (bundle, threads, profile) — GPT bundles only.
    pub decode: Vec<DecodeTimings>,
    /// Analytic peak training memory per (bundle, mode).
    pub memory: Vec<MemoryRow>,
    /// Step time at the three tracing levels, one row per bundle.
    pub obs: Vec<ObsOverheadRow>,
}

impl SuiteReport {
    pub fn all_finite(&self) -> bool {
        self.rows.iter().all(|r| {
            r.fwd_ms.is_finite() && r.step_ms.is_finite() && r.infer_ms.is_finite()
        }) && self.dist.iter().all(|d| d.step_ms.is_finite())
            && self.decode.iter().all(|d| d.tokens_per_s.is_finite())
            && self.obs.iter().all(|o| {
                o.step_ms_off.is_finite()
                    && o.step_ms_metrics.is_finite()
                    && o.step_ms_spans.is_finite()
            })
    }

    /// step-time speedup of the parallel run over the 1-thread run
    /// (default-profile rows only — the tuned row shares the parallel
    /// thread count and must not shadow it).
    pub fn step_speedup(&self, bundle: &str) -> Option<f64> {
        let at = |t: usize| {
            self.rows
                .iter()
                .find(|r| {
                    r.bundle == bundle && r.threads == t && r.profile == "default"
                })
                .map(|r| r.step_ms)
        };
        match (at(self.threads_baseline), at(self.threads_parallel)) {
            (Some(base), Some(par)) if par > 0.0 => Some(base / par),
            _ => None,
        }
    }

    fn to_json(&self, quick: bool) -> String {
        let rows: Vec<String> = self
            .rows
            .iter()
            .map(|r| {
                format!(
                    "    {{\"bundle\": \"{}\", \"family\": \"{}\", \
                     \"threads\": {}, \"profile\": \"{}\", \
                     \"fwd_ms\": {:.3}, \"step_ms\": {:.3}, \
                     \"infer_ms\": {:.3}}}",
                    r.bundle, r.family, r.threads, r.profile, r.fwd_ms,
                    r.step_ms, r.infer_ms
                )
            })
            .collect();
        let dist: Vec<String> = self
            .dist
            .iter()
            .map(|d| {
                format!(
                    "    {{\"bundle\": \"{}\", \"ranks\": {}, \
                     \"step_ms\": {:.3}}}",
                    d.bundle, d.ranks, d.step_ms
                )
            })
            .collect();
        let decode: Vec<String> = self
            .decode
            .iter()
            .map(|d| {
                format!(
                    "    {{\"bundle\": \"{}\", \"threads\": {}, \
                     \"profile\": \"{}\", \"tokens_per_s\": {:.3}}}",
                    d.bundle, d.threads, d.profile, d.tokens_per_s
                )
            })
            .collect();
        let memory: Vec<String> = self
            .memory
            .iter()
            .map(|m| {
                format!(
                    "    {{\"bundle\": \"{}\", \"mode\": \"{}\", \
                     \"peak_bytes\": {}}}",
                    m.bundle, m.mode, m.peak_bytes
                )
            })
            .collect();
        let obs: Vec<String> = self
            .obs
            .iter()
            .map(|o| {
                format!(
                    "    {{\"bundle\": \"{}\", \"step_ms_off\": {:.3}, \
                     \"step_ms_metrics\": {:.3}, \"step_ms_spans\": {:.3}}}",
                    o.bundle, o.step_ms_off, o.step_ms_metrics, o.step_ms_spans
                )
            })
            .collect();
        format!(
            "{{\n  \"bench\": \"BENCH_10\",\n  \"quick\": {},\n  \
             \"threads_baseline\": {},\n  \"threads_parallel\": {},\n  \
             \"results\": [\n{}\n  ],\n  \"dist\": [\n{}\n  ],\n  \
             \"decode\": [\n{}\n  ],\n  \"memory\": [\n{}\n  ],\n  \
             \"obs_overhead\": [\n{}\n  ]\n}}\n",
            quick,
            self.threads_baseline,
            self.threads_parallel,
            rows.join(",\n"),
            dist.join(",\n"),
            decode.join(",\n"),
            memory.join(",\n"),
            obs.join(",\n")
        )
    }
}

/// Mean global-step wall time of a full in-process `ranks`-sized world
/// (loopback TCP, `grad_accum = 2` at every world size so the 1→2
/// contrast isolates collective overhead vs compute split).
fn dist_step_ms(
    bundle: &str,
    dataset: &str,
    ranks: usize,
    steps: usize,
) -> Result<f64> {
    let cfg = TrainConfig {
        model: bundle.into(),
        dataset: dataset.into(),
        mode: TrainMode::BdiaReversible,
        steps,
        eval_every: 0,
        log_every: 1,
        train_examples: 64,
        val_examples: 8,
        ranks,
        grad_accum: 2,
        ..TrainConfig::default()
    };
    let per_rank = run_local_world(&cfg, |_rank, role| {
        let mut tr = Trainer::new(cfg.clone())?;
        tr.attach_dist(role)?;
        let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), tr.family)?;
        let t0 = Instant::now();
        for _ in 0..steps {
            tr.train_step_global(ds.as_ref())?;
        }
        Ok(t0.elapsed().as_secs_f64() * 1e3 / steps as f64)
    })
    .with_context(|| format!("dist bench {bundle} ranks={ranks}"))?;
    Ok(per_rank[0])
}

/// Greedy decode throughput of one [`Session::generate`] run until the
/// bundle's context window fills — the decode-loop analogue of the
/// hot-path rows.  Only called for bundles with `model_decode_step`.
fn decode_tokens_per_s(session: &Session) -> Result<f64> {
    let seq = session.runtime().manifest.dims.seq;
    let gen_opts = crate::generate::GenOpts {
        max_tokens: seq,
        ..Default::default()
    };
    let report = session.generate(&[0], &gen_opts)?;
    Ok(report.tokens_per_s())
}

/// Run the suite and write the JSON report.
pub fn run(opts: &SuiteOpts) -> Result<SuiteReport> {
    let par = if opts.threads == 0 { pool::auto_threads() } else { opts.threads };
    let mut counts = vec![1usize];
    if par > 1 {
        counts.push(par);
    }
    println!(
        "bdia bench: families {:?}, threads {counts:?}, budget {:?}/measurement",
        opts.families, opts.budget
    );

    let mut rows = Vec::new();
    let mut dist = Vec::new();
    let mut decode = Vec::new();
    let mut memory = Vec::new();
    let mut obs = Vec::new();
    let dist_steps = if opts.quick { 2 } else { 3 };
    for bundle in &opts.families {
        // one Session per bundle: the suite times the same facade path the
        // CLI and embedders use
        let mut session = Session::builder()
            .model_name(bundle.clone())
            .dataset_auto()
            .build()
            .with_context(|| format!("loading bundle '{bundle}'"))?;
        let has_decode = session.runtime().has_exec("model_decode_step");
        for &t in &counts {
            pool::set_threads(t);
            let timings = session.bench(opts.budget, opts.max_iters)?;
            rows.push(timings);
            if has_decode {
                decode.push(DecodeTimings {
                    bundle: bundle.clone(),
                    threads: t,
                    profile: "default".into(),
                    tokens_per_s: decode_tokens_per_s(&session)?,
                });
            }
        }
        // tuned row: the parallel measurement again under a tuned kernel
        // profile — persisted one if given, else a quick in-process search
        pool::set_threads(par);
        let (tuned, src) = match &opts.tune_profile {
            Some(path) => {
                let p = KernelProfile::load(path).with_context(|| {
                    format!("loading tune profile {}", path.display())
                })?;
                (p, Some(path.clone()))
            }
            None => {
                let rep =
                    session.tune(&TuneOpts { quick: true, out: None })?;
                (rep.profile, None)
            }
        };
        let prev = profile::active();
        let prev_src = profile::active_source();
        profile::set_active(tuned, src);
        let tuned_id = profile::active_id();
        let timings = session.bench(opts.budget, opts.max_iters);
        // tuned decode row rides the same active-profile window; errors
        // are deferred until after the ambient profile is restored
        let tuned_decode =
            if has_decode { Some(decode_tokens_per_s(&session)) } else { None };
        match prev {
            Some(p) => profile::set_active((*p).clone(), prev_src),
            None => profile::reset_active(),
        }
        rows.push(timings?);
        if let Some(tps) = tuned_decode {
            decode.push(DecodeTimings {
                bundle: bundle.clone(),
                threads: par,
                profile: tuned_id,
                tokens_per_s: tps?,
            });
        }
        // analytic Table-1 peak memory rides along with every report
        let m = &session.runtime().manifest;
        for (mode, peak_bytes) in
            MemoryModel::peak_by_mode(m.family, &m.dims, m.n_params() * 4)
        {
            memory.push(MemoryRow { bundle: bundle.clone(), mode, peak_bytes });
        }
        // observability overhead: the same step timing at the three
        // tracing levels.  Levels gate clock reads and ring pushes only —
        // timestamps never enter compute — so only wall time may move.
        let prev_level = crate::obs::level();
        crate::obs::set_level(crate::obs::OFF);
        let r_off = session.bench(opts.budget, opts.max_iters);
        crate::obs::set_level(crate::obs::METRICS);
        let r_metrics = session.bench(opts.budget, opts.max_iters);
        crate::obs::set_level(crate::obs::SPANS);
        let r_spans = session.bench(opts.budget, opts.max_iters);
        crate::obs::set_level(prev_level);
        obs.push(ObsOverheadRow {
            bundle: bundle.clone(),
            step_ms_off: r_off?.step_ms,
            step_ms_metrics: r_metrics?.step_ms,
            step_ms_spans: r_spans?.step_ms,
        });
        // dist scaling: the same global step at world sizes 1 and 2
        let dataset = serve_bench::default_dataset(session.family());
        drop(session);
        for ranks in [1usize, 2] {
            let step_ms = dist_step_ms(bundle, dataset, ranks, dist_steps)?;
            dist.push(DistTimings { bundle: bundle.clone(), ranks, step_ms });
        }
    }
    pool::set_threads(par);

    let report = SuiteReport {
        threads_baseline: 1,
        threads_parallel: *counts.last().unwrap(),
        rows,
        dist,
        decode,
        memory,
        obs,
    };
    for bundle in &opts.families {
        if let Some(s) = report.step_speedup(bundle) {
            println!(
                "{bundle}: step speedup x{s:.2} ({} -> {} threads)",
                report.threads_baseline, report.threads_parallel
            );
        }
        let tuned = report
            .rows
            .iter()
            .find(|r| r.bundle == *bundle && r.profile != "default");
        let def_par = report.rows.iter().find(|r| {
            r.bundle == *bundle
                && r.threads == report.threads_parallel
                && r.profile == "default"
        });
        if let (Some(t), Some(d)) = (tuned, def_par) {
            println!(
                "{bundle}: tuned profile '{}' step {:.2} ms vs default \
                 {:.2} ms @{} threads (identical bits)",
                t.profile, t.step_ms, d.step_ms, report.threads_parallel
            );
        }
        let at = |r: usize| {
            report
                .dist
                .iter()
                .find(|d| d.bundle == *bundle && d.ranks == r)
                .map(|d| d.step_ms)
        };
        if let (Some(r1), Some(r2)) = (at(1), at(2)) {
            println!(
                "{bundle}: dist global step {r1:.2} ms @1 rank, {r2:.2} ms \
                 @2 ranks (identical bits)"
            );
        }
        let dec_at = |t: usize| {
            report
                .decode
                .iter()
                .find(|d| {
                    d.bundle == *bundle && d.threads == t && d.profile == "default"
                })
                .map(|d| d.tokens_per_s)
        };
        if let (Some(d1), Some(dp)) =
            (dec_at(report.threads_baseline), dec_at(report.threads_parallel))
        {
            println!(
                "{bundle}: decode {d1:.1} tok/s @1 thread, {dp:.1} tok/s \
                 @{} threads (identical bits)",
                report.threads_parallel
            );
        }
        if let Some(t) = report
            .decode
            .iter()
            .find(|d| d.bundle == *bundle && d.profile != "default")
        {
            println!(
                "{bundle}: decode tuned profile '{}' {:.1} tok/s (identical \
                 bits)",
                t.profile, t.tokens_per_s
            );
        }
        if let Some(o) = report.obs.iter().find(|o| o.bundle == *bundle) {
            println!(
                "{bundle}: obs overhead step {:.2} ms off, {:.2} ms \
                 metrics, {:.2} ms full spans (identical bits)",
                o.step_ms_off, o.step_ms_metrics, o.step_ms_spans
            );
        }
    }
    std::fs::write(&opts.out, report.to_json(opts.quick))
        .with_context(|| format!("writing {}", opts.out.display()))?;
    println!("report written to {}", opts.out.display());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_suite_runs_and_writes_report() {
        // run() installs/resets the process-wide kernel profile for the
        // tuned row: serialize with the other profile-state tests
        let _guard = crate::kernels::profile::test_lock();
        // run() also toggles the global tracing level for the obs block
        let _obs_guard = crate::obs::span::test_lock();
        let dir = std::env::temp_dir().join(format!(
            "bdia_bench_suite_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_10.json");
        let opts = SuiteOpts {
            families: vec!["smoke_gpt".into()],
            threads: 2,
            out: out.clone(),
            budget: Duration::from_millis(40),
            max_iters: 3,
            ..SuiteOpts::new(true)
        };
        let report = run(&opts).unwrap();
        assert!(report.all_finite());
        assert_eq!(report.threads_parallel, 2);
        // one row per thread count, plus the tuned row
        assert_eq!(report.rows.len(), 3);
        assert_eq!(
            report.rows.iter().filter(|r| r.profile == "default").count(),
            2
        );
        let tuned = report
            .rows
            .iter()
            .find(|r| r.profile != "default")
            .expect("tuned row");
        assert_eq!(tuned.threads, 2);
        // the suite restores the ambient (default) profile afterwards
        assert_eq!(crate::kernels::profile::active_id(), "default");
        // dist scaling block: world sizes 1 and 2 for the one bundle
        assert_eq!(report.dist.len(), 2);
        assert_eq!(
            report.dist.iter().map(|d| d.ranks).collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert!(report.dist.iter().all(|d| d.step_ms > 0.0));
        // decode block (smoke_gpt has model_decode_step): one row per
        // thread count plus the tuned row, all with positive throughput
        assert_eq!(report.decode.len(), 3);
        assert_eq!(
            report
                .decode
                .iter()
                .filter(|d| d.profile == "default")
                .map(|d| d.threads)
                .collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert!(report
            .decode
            .iter()
            .any(|d| d.profile != "default" && d.threads == 2));
        assert!(report.decode.iter().all(|d| d.tokens_per_s > 0.0));
        // memory block: one row per training mode
        assert_eq!(report.memory.len(), 4);
        assert!(report.memory.iter().all(|m| m.peak_bytes > 0));
        // obs overhead block: one row per bundle, all three levels timed
        assert_eq!(report.obs.len(), 1);
        assert!(report.obs.iter().all(|o| {
            o.step_ms_off > 0.0
                && o.step_ms_metrics > 0.0
                && o.step_ms_spans > 0.0
        }));
        let text = std::fs::read_to_string(&out).unwrap();
        let parsed = crate::config::json::Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("bench").unwrap().as_str().unwrap(),
            "BENCH_10"
        );
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 3);
        assert!(results
            .iter()
            .any(|r| r.get("profile").unwrap().as_str().unwrap() != "default"));
        let dist = parsed.get("dist").unwrap().as_arr().unwrap();
        assert_eq!(dist.len(), 2);
        assert_eq!(dist[1].get("ranks").unwrap().as_usize().unwrap(), 2);
        let decode = parsed.get("decode").unwrap().as_arr().unwrap();
        assert_eq!(decode.len(), 3);
        assert!(decode
            .iter()
            .any(|d| d.get("profile").unwrap().as_str().unwrap() != "default"));
        let mem = parsed.get("memory").unwrap().as_arr().unwrap();
        assert_eq!(mem.len(), 4);
        assert!(mem[0].get("peak_bytes").unwrap().as_usize().unwrap() > 0);
        let obs = parsed.get("obs_overhead").unwrap().as_arr().unwrap();
        assert_eq!(obs.len(), 1);
        assert_eq!(
            obs[0].get("bundle").unwrap().as_str().unwrap(),
            "smoke_gpt"
        );
        assert!(report.step_speedup("smoke_gpt").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
