//! Minimal micro-benchmark harness (criterion is unavailable offline).
//!
//! Warms up, runs timed iterations until a wall budget or count is hit, and
//! reports mean / p50 / p95 like a criterion one-liner.  Bench binaries in
//! `rust/benches/` use this and print one row per paper table they back.
//! [`suite`] builds the `bdia bench` per-family report (BENCH_10.json) on
//! top of it, timing the hot paths through the `api::Session` facade.

pub mod suite;

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>6} iters  mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        )
    }

    /// Throughput helper: units per second given units-per-iteration.
    pub fn per_sec(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly: `warmup` unmeasured, then up to `max_iters` or until
/// `budget` wall time elapses (at least 3 measured iterations).
pub fn bench(name: &str, warmup: usize, max_iters: usize, budget: Duration,
             mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters);
    let start = Instant::now();
    while samples.len() < max_iters
        && (samples.len() < 3 || start.elapsed() < budget)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    BenchResult { name: name.into(), iters: samples.len(), mean, p50, p95 }
}

/// Standard budget for exec-heavy benches.
pub fn default_budget() -> Duration {
    Duration::from_secs(5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 50, Duration::from_millis(200), || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.mean > Duration::ZERO);
        assert!(r.p50 <= r.p95);
        assert!(r.row().contains("spin"));
    }
}
