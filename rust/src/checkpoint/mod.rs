//! Checkpoint persistence: a versioned, checksummed, dependency-free binary
//! format for trained state.
//!
//! A checkpoint carries everything needed to either *serve* a model (the
//! [`ParamStore`]) or *resume* training bit-exactly (optimizer moments +
//! step counter + the per-sample gamma RNG state).  The paper's point is
//! that BDIA inference is a standard transformer (eqs. 18–22); this module
//! is what lets `bdia eval`/`bdia serve` run the weights `bdia train`
//! produced instead of a fresh seed.
//!
//! Layout (all little-endian):
//!
//! ```text
//! magic  "BDIACKPT"              8 bytes
//! version u32                    format revision (currently 1)
//! crc32   u32                    IEEE CRC-32 over the body
//! body_len u64                   byte length of the body
//! body    ...                    model name, step, rng, stores
//! ```
//!
//! f32 payloads are written as raw IEEE-754 bit patterns, so a save→load
//! round trip is bit-exact (including negative zero and NaN payloads).
//! Truncation is caught by `body_len`, corruption by the CRC; both produce
//! a clear error instead of silently-wrong weights.

use crate::model::ParamStore;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"BDIACKPT";
pub const VERSION: u32 = 1;
/// magic + version + crc32 + body_len
const HEADER_LEN: usize = 8 + 4 + 4 + 8;

/// Snapshot of a [`crate::tensor::Rng`] (state word + cached Box–Muller
/// spare), so resumed training draws the exact gamma sequence.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngSnapshot {
    pub state: u64,
    pub spare: Option<f32>,
}

/// Optimizer state: step count plus first/second moment stores.
pub struct OptState {
    pub t: u64,
    pub m: ParamStore,
    pub v: ParamStore,
}

/// A loaded checkpoint (owned).
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub rng_gamma: RngSnapshot,
    pub params: ParamStore,
    /// Absent for inference-only exports.
    pub opt: Option<OptState>,
}

/// Borrowed view for saving (avoids cloning multi-MB stores).
pub struct CheckpointRef<'a> {
    pub model: &'a str,
    pub step: u64,
    pub rng_gamma: RngSnapshot,
    pub params: &'a ParamStore,
    pub opt: Option<(u64, &'a ParamStore, &'a ParamStore)>,
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected) — no external crates offline
// ---------------------------------------------------------------------------

fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, e) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
        }
        *e = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// little-endian body writer / reader
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_store(out: &mut Vec<u8>, store: &ParamStore) {
    put_u32(out, store.groups.len() as u32);
    for (name, insts) in &store.groups {
        put_str(out, name);
        put_u32(out, insts.len() as u32);
        let leaves = insts.first().map_or(0, Vec::len);
        put_u32(out, leaves as u32);
        if let Some(first) = insts.first() {
            for t in first {
                put_u32(out, t.shape().len() as u32);
                for &d in t.shape() {
                    put_u64(out, d as u64);
                }
            }
        }
        for inst in insts {
            debug_assert_eq!(inst.len(), leaves);
            for t in inst {
                for &v in t.data() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "truncated checkpoint body (wanted {n} bytes at offset {}, {} left)",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n <= 1 << 20, "unreasonable string length {n} in checkpoint");
        Ok(std::str::from_utf8(self.take(n)?)
            .context("non-utf8 string in checkpoint")?
            .to_string())
    }

    fn store(&mut self) -> Result<ParamStore> {
        let n_groups = self.u32()? as usize;
        ensure!(n_groups <= 1 << 16, "unreasonable group count {n_groups}");
        let mut groups = BTreeMap::new();
        for _ in 0..n_groups {
            let name = self.str()?;
            let n_inst = self.u32()? as usize;
            let n_leaves = self.u32()? as usize;
            ensure!(
                n_inst <= 1 << 20 && n_leaves <= 1 << 20,
                "unreasonable store geometry ({n_inst} instances, {n_leaves} leaves)"
            );
            let mut shapes = Vec::with_capacity(n_leaves);
            for _ in 0..n_leaves {
                let ndim = self.u32()? as usize;
                ensure!(ndim <= 8, "unreasonable tensor rank {ndim}");
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    shape.push(self.u64()? as usize);
                }
                ensure!(
                    shape.iter().product::<usize>() <= 1 << 32,
                    "unreasonable tensor size in checkpoint"
                );
                shapes.push(shape);
            }
            let mut insts = Vec::with_capacity(n_inst);
            for _ in 0..n_inst {
                let mut inst = Vec::with_capacity(n_leaves);
                for shape in &shapes {
                    let n: usize = shape.iter().product();
                    let raw = self.take(n * 4)?;
                    let data: Vec<f32> = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    inst.push(Tensor::from_vec(shape, data)?);
                }
                insts.push(inst);
            }
            groups.insert(name, insts);
        }
        Ok(ParamStore { groups })
    }
}

// ---------------------------------------------------------------------------
// encode / decode
// ---------------------------------------------------------------------------

/// Serialize to the framed byte format (header + checksummed body).
pub fn to_bytes(ckpt: &CheckpointRef) -> Vec<u8> {
    let mut body = Vec::new();
    put_str(&mut body, ckpt.model);
    put_u64(&mut body, ckpt.step);
    put_u64(&mut body, ckpt.rng_gamma.state);
    match ckpt.rng_gamma.spare {
        Some(v) => {
            body.push(1);
            body.extend_from_slice(&v.to_le_bytes());
        }
        None => {
            body.push(0);
            body.extend_from_slice(&0f32.to_le_bytes());
        }
    }
    put_store(&mut body, ckpt.params);
    match ckpt.opt {
        Some((t, m, v)) => {
            body.push(1);
            put_u64(&mut body, t);
            put_store(&mut body, m);
            put_store(&mut body, v);
        }
        None => body.push(0),
    }

    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, crc32(&body));
    put_u64(&mut out, body.len() as u64);
    out.extend_from_slice(&body);
    out
}

/// Parse the framed byte format, verifying magic, version, length and CRC.
pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
    ensure!(
        bytes.len() >= HEADER_LEN,
        "not a bdia checkpoint: {} bytes is shorter than the header",
        bytes.len()
    );
    ensure!(
        &bytes[..8] == MAGIC,
        "not a bdia checkpoint (bad magic; expected {:?})",
        std::str::from_utf8(MAGIC).unwrap()
    );
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    ensure!(
        version == VERSION,
        "unsupported checkpoint version {version} (this build reads {VERSION})"
    );
    let crc_stored = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let body_len = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let body = &bytes[HEADER_LEN..];
    ensure!(
        body.len() == body_len,
        "truncated checkpoint: header promises {body_len} body bytes, file has {}",
        body.len()
    );
    let crc_actual = crc32(body);
    ensure!(
        crc_actual == crc_stored,
        "checkpoint checksum mismatch (stored {crc_stored:#010x}, computed \
         {crc_actual:#010x}) — the file is corrupted"
    );

    let mut r = Reader { buf: body, pos: 0 };
    let model = r.str()?;
    let step = r.u64()?;
    let rng_state = r.u64()?;
    let has_spare = r.take(1)?[0];
    let spare_bits = r.f32()?;
    let rng_gamma = RngSnapshot {
        state: rng_state,
        spare: (has_spare != 0).then_some(spare_bits),
    };
    let params = r.store()?;
    let opt = match r.take(1)?[0] {
        0 => None,
        1 => {
            let t = r.u64()?;
            let m = r.store()?;
            let v = r.store()?;
            Some(OptState { t, m, v })
        }
        other => bail!("bad optimizer-state flag {other} in checkpoint"),
    };
    ensure!(r.pos == body.len(), "trailing garbage after checkpoint body");
    // a decoded store is a brand-new parameter set: any cached weight
    // transposes (matmul_nt_w) keyed on reused allocations must not match
    crate::kernels::workspace::bump_weight_generation();
    Ok(Checkpoint { model, step, rng_gamma, params, opt })
}

/// Write a checkpoint atomically: tmp file, fsync, rename, directory fsync
/// — so a crash mid-write never leaves a torn checkpoint at `path`, and a
/// crash right after the rename cannot roll back to the old inode with the
/// new name (the rolling `-latest.ckpt` overwrite depends on this).
pub fn save(path: &Path, ckpt: &CheckpointRef) -> Result<()> {
    use std::io::Write;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    let bytes = to_bytes(ckpt);
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all()
            .with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            // best-effort: persist the rename itself (POSIX allows fsync on
            // a read-only directory handle; harmless where unsupported)
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// Read and verify a checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::Json;
    use crate::model::Manifest;

    fn toy_store(seed: u64) -> ParamStore {
        let text = r#"{
          "name": "toy", "family": "gpt",
          "dims": {"d_model": 4, "n_heads": 2, "n_blocks": 2,
                   "n_enc_blocks": 0, "mlp_ratio": 2, "batch": 2, "lbits": 9,
                   "image_size": 32, "patch": 4, "channels": 3,
                   "n_classes": 10, "seq": 8, "seq_src": 0, "vocab": 16},
          "param_groups": {
            "embed": [{"name": "wte", "shape": [16, 4], "init": "normal:0.02"}],
            "block": [{"name": "w", "shape": [4, 4], "init": "normal:1.0"},
                      {"name": "b", "shape": [4], "init": "zeros"}]
          },
          "executables": {}, "source_hash": "x"
        }"#;
        let m = Manifest::from_json(&Json::parse(text).unwrap()).unwrap();
        ParamStore::init(&m, seed)
    }

    fn bit_equal(a: &ParamStore, b: &ParamStore) -> bool {
        if !a.same_structure(b) {
            return false;
        }
        a.groups.values().zip(b.groups.values()).all(|(ia, ib)| {
            ia.iter().zip(ib).all(|(la, lb)| {
                la.iter().zip(lb).all(|(ta, tb)| {
                    ta.data()
                        .iter()
                        .zip(tb.data())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
                })
            })
        })
    }

    fn refr<'a>(
        params: &'a ParamStore,
        opt: Option<(u64, &'a ParamStore, &'a ParamStore)>,
    ) -> CheckpointRef<'a> {
        CheckpointRef {
            model: "toy",
            step: 17,
            rng_gamma: RngSnapshot { state: 0xDEAD_BEEF, spare: Some(-0.5) },
            params,
            opt,
        }
    }

    #[test]
    fn crc32_known_vector() {
        // the classic IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_is_bit_exact_with_and_without_opt() {
        let params = toy_store(3);
        let m = toy_store(4);
        let v = toy_store(5);
        for opt in [None, Some((9u64, &m, &v))] {
            let bytes = to_bytes(&refr(&params, opt));
            let ck = from_bytes(&bytes).unwrap();
            assert_eq!(ck.model, "toy");
            assert_eq!(ck.step, 17);
            assert_eq!(
                ck.rng_gamma,
                RngSnapshot { state: 0xDEAD_BEEF, spare: Some(-0.5) }
            );
            assert!(bit_equal(&ck.params, &params));
            match (&ck.opt, opt) {
                (None, None) => {}
                (Some(o), Some((t, em, ev))) => {
                    assert_eq!(o.t, t);
                    assert!(bit_equal(&o.m, em));
                    assert!(bit_equal(&o.v, ev));
                }
                _ => panic!("opt presence mismatch"),
            }
            // re-save of the load is byte-identical (canonical encoding)
            let ck_opt = ck.opt.as_ref().map(|o| (o.t, &o.m, &o.v));
            let again = to_bytes(&CheckpointRef {
                model: &ck.model,
                step: ck.step,
                rng_gamma: ck.rng_gamma,
                params: &ck.params,
                opt: ck_opt,
            });
            assert_eq!(bytes, again);
        }
    }

    #[test]
    fn nan_and_negative_zero_survive() {
        let mut params = toy_store(1);
        params.for_each_mut(|t| {
            let d = t.data_mut();
            d[0] = f32::NAN;
            if d.len() > 1 {
                d[1] = -0.0;
            }
        });
        let bytes = to_bytes(&refr(&params, None));
        let ck = from_bytes(&bytes).unwrap();
        assert!(bit_equal(&ck.params, &params));
    }

    #[test]
    fn truncation_is_rejected_with_clear_error() {
        let params = toy_store(2);
        let bytes = to_bytes(&refr(&params, None));
        for cut in [bytes.len() - 1, bytes.len() / 2, HEADER_LEN, 5] {
            let err = from_bytes(&bytes[..cut]).unwrap_err();
            let msg = format!("{err:#}").to_lowercase();
            assert!(
                msg.contains("truncated") || msg.contains("shorter"),
                "cut {cut}: {msg}"
            );
        }
    }

    #[test]
    fn corruption_is_rejected_with_checksum_error() {
        let params = toy_store(2);
        let bytes = to_bytes(&refr(&params, None));
        // flip one payload bit deep in the body
        let mut bad = bytes.clone();
        let idx = HEADER_LEN + bytes.len() / 2;
        bad[idx] ^= 0x40;
        let err = from_bytes(&bad).unwrap_err();
        assert!(
            format!("{err:#}").contains("checksum"),
            "expected checksum error, got: {err:#}"
        );
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let params = toy_store(2);
        let bytes = to_bytes(&refr(&params, None));
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(format!("{:#}", from_bytes(&bad).unwrap_err()).contains("magic"));
        let mut bad = bytes;
        bad[8] = 99; // version
        assert!(format!("{:#}", from_bytes(&bad).unwrap_err()).contains("version"));
    }

    #[test]
    fn file_roundtrip_and_atomic_write() {
        let dir = std::env::temp_dir().join("bdia_ckpt_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.ckpt");
        let params = toy_store(7);
        save(&path, &refr(&params, None)).unwrap();
        assert!(!path.with_extension("ckpt.tmp").exists(), "tmp not renamed");
        let ck = load(&path).unwrap();
        assert!(bit_equal(&ck.params, &params));
        std::fs::remove_dir_all(dir).ok();
    }
}
