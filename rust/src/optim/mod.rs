//! Optimizers: Adam and SET-Adam [31] over [`ParamStore`]s.
//!
//! The paper trains with SET-Adam (Zhang, ECML'24: "On Suppressing Range of
//! Adaptive Stepsizes of Adam to Improve Generalisation Performance") with
//! the configuration `(eta0, b1, b2, eps) = (1e-4, 0.9, 0.999, 1e-18)`.
//! SET-Adam's idea is to *suppress the range* of the per-coordinate adaptive
//! stepsizes `1/(sqrt(vhat)+eps)`; we implement the layerwise form: within
//! every parameter tensor the adaptive stepsize is clamped from above at
//! `kappa x` the tensor's mean stepsize, which caps the outliers produced by
//! rarely-updated coordinates (tiny second moments) while leaving typical
//! coordinates untouched.  `kappa = 1` reduces the range most aggressively;
//! `kappa -> inf` recovers Adam.  (The cited paper is a companion of the
//! BDIA paper and not reproduced in full here; this captures the
//! range-suppression mechanism its title describes — recorded as a
//! substitution in DESIGN.md §5.)

use crate::config::{OptimKind, TrainConfig};
use crate::model::ParamStore;
use anyhow::{ensure, Result};

pub struct Optimizer {
    kind: OptimKind,
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    /// SET-Adam range-suppression factor.
    pub kappa: f32,
    t: u64,
    m: ParamStore,
    v: ParamStore,
    /// Parameter groups excluded from updates (fine-tuning freezes).  A
    /// frozen group's params *and* moments are left untouched — zeroed
    /// gradients alone would not freeze, because checkpoint-restored first
    /// moments keep decaying into parameter motion.
    frozen: Vec<String>,
}

impl Optimizer {
    pub fn new(cfg: &TrainConfig, params: &ParamStore) -> Self {
        Optimizer {
            kind: cfg.optimizer,
            lr: cfg.lr,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            kappa: 2.0,
            t: 0,
            m: params.zeros_like(),
            v: params.zeros_like(),
            frozen: Vec::new(),
        }
    }

    /// Freeze parameter groups by name: [`Optimizer::step`] skips them
    /// entirely (no param update, no moment update).  Unknown names are
    /// ignored — `enc_embed` only exists on encoder-decoder models.
    pub fn set_frozen(&mut self, groups: Vec<String>) {
        self.frozen = groups;
    }

    /// Groups currently excluded from updates.
    pub fn frozen(&self) -> &[String] {
        &self.frozen
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Borrow the full optimizer state `(t, m, v)` for checkpointing.
    pub fn state(&self) -> (u64, &ParamStore, &ParamStore) {
        (self.t, &self.m, &self.v)
    }

    /// Restore state saved by [`Optimizer::state`].  The moment stores must
    /// structurally match the parameters this optimizer was built for.
    pub fn restore(&mut self, t: u64, m: ParamStore, v: ParamStore) -> Result<()> {
        ensure!(
            self.m.same_structure(&m) && self.v.same_structure(&v),
            "optimizer state structure does not match the model parameters"
        );
        self.t = t;
        self.m = m;
        self.v = v;
        Ok(())
    }

    /// Payload bytes of optimizer state (2x params) — memory accounting.
    pub fn nbytes(&self) -> usize {
        self.m.nbytes() + self.v.nbytes()
    }

    /// One update: `params -= stepsize(mhat, vhat)` with grads in `grads`.
    pub fn step(&mut self, params: &mut ParamStore, grads: &ParamStore) -> Result<()> {
        // params are about to change in place: stale cached weight
        // transposes (matmul_nt_w) must stop matching
        crate::kernels::workspace::bump_weight_generation();
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let (lr, b1, b2, eps) = (self.lr, self.beta1, self.beta2, self.eps);
        let kind = self.kind;
        let kappa = self.kappa;

        // walk (param, grad, m, v) tensors in lockstep (identical structure);
        // keyed so frozen groups can be skipped while the iterators stay
        // aligned
        let frozen = &self.frozen;
        let mut mg = self.m.groups.values_mut();
        let mut vg = self.v.groups.values_mut();
        for ((name, pg), gg) in params.groups.iter_mut().zip(grads.groups.values())
        {
            let minsts = mg.next().expect("m structure");
            let vinsts = vg.next().expect("v structure");
            if frozen.iter().any(|f| f == name) {
                continue;
            }
            for (((pinst, ginst), minst), vinst) in
                pg.iter_mut().zip(gg).zip(minsts.iter_mut()).zip(vinsts.iter_mut())
            {
                for (((p, g), m), v) in pinst
                    .iter_mut()
                    .zip(ginst)
                    .zip(minst.iter_mut())
                    .zip(vinst.iter_mut())
                {
                    let pd = p.data_mut();
                    let gd = g.data();
                    let md = m.data_mut();
                    let vd = v.data_mut();
                    // moments
                    for i in 0..pd.len() {
                        md[i] = b1 * md[i] + (1.0 - b1) * gd[i];
                        vd[i] = b2 * vd[i] + (1.0 - b2) * gd[i] * gd[i];
                    }
                    match kind {
                        OptimKind::Adam => {
                            for i in 0..pd.len() {
                                let mhat = md[i] / bc1;
                                let vhat = vd[i] / bc2;
                                pd[i] -= lr * mhat / (vhat.sqrt() + eps);
                            }
                        }
                        OptimKind::SetAdam => {
                            // layerwise adaptive-stepsize range suppression:
                            // a_i = 1/(sqrt(vhat_i)+eps) clamped at
                            // kappa * mean(a) for this tensor.
                            let mut mean_a = 0.0f64;
                            for i in 0..pd.len() {
                                let vhat = vd[i] / bc2;
                                mean_a += 1.0 / (vhat.sqrt() + eps) as f64;
                            }
                            mean_a /= pd.len().max(1) as f64;
                            let cap = (kappa as f64 * mean_a) as f32;
                            for i in 0..pd.len() {
                                let mhat = md[i] / bc1;
                                let vhat = vd[i] / bc2;
                                let a = (1.0 / (vhat.sqrt() + eps)).min(cap);
                                pd[i] -= lr * mhat * a;
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Global-norm gradient clipping (in place). Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut ParamStore, max_norm: f32) -> f32 {
    let norm = grads.global_norm();
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        grads.for_each_mut(|t| t.scale(scale));
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::Json;
    use crate::model::Manifest;

    fn toy() -> ParamStore {
        let text = r#"{
          "name": "toy", "family": "gpt",
          "dims": {"d_model": 4, "n_heads": 2, "n_blocks": 2,
                   "n_enc_blocks": 0, "mlp_ratio": 2, "batch": 2, "lbits": 9,
                   "image_size": 32, "patch": 4, "channels": 3,
                   "n_classes": 10, "seq": 8, "seq_src": 0, "vocab": 16},
          "param_groups": {
            "w": [{"name": "a", "shape": [8], "init": "normal:1.0"}]
          },
          "executables": {}, "source_hash": "x"
        }"#;
        let m = Manifest::from_json(&Json::parse(text).unwrap()).unwrap();
        ParamStore::init(&m, 3)
    }

    fn cfg(kind: OptimKind) -> TrainConfig {
        TrainConfig { optimizer: kind, lr: 0.1, eps: 1e-8, ..TrainConfig::default() }
    }

    fn clone_store(ps: &ParamStore) -> ParamStore {
        let mut out = ps.zeros_like();
        let mut src = ps.groups.values();
        for insts in out.groups.values_mut() {
            let sinsts = src.next().unwrap();
            for (inst, sinst) in insts.iter_mut().zip(sinsts) {
                for (t, s) in inst.iter_mut().zip(sinst) {
                    t.data_mut().copy_from_slice(s.data());
                }
            }
        }
        out
    }

    #[test]
    fn adam_descends_quadratic() {
        // minimize 0.5*||p||^2: grad = p
        let mut ps = toy();
        let mut opt = Optimizer::new(&cfg(OptimKind::Adam), &ps);
        let n0 = ps.global_norm();
        for _ in 0..200 {
            let g = clone_store(&ps);
            opt.step(&mut ps, &g).unwrap();
        }
        assert!(ps.global_norm() < 0.1 * n0, "did not descend");
    }

    #[test]
    fn setadam_descends_and_differs_from_adam() {
        let ps0 = toy();
        let run = |kind| {
            let mut ps = clone_store(&ps0);
            let mut opt = Optimizer::new(&cfg(kind), &ps);
            opt.kappa = 1.0;
            for _ in 0..20 {
                // anisotropic grads: one coordinate rarely updated
                let mut g = clone_store(&ps);
                g.for_each_mut(|t| {
                    let d = t.data_mut();
                    d[0] *= 1e-4; // tiny grad -> tiny v -> huge adaptive step
                });
                opt.step(&mut ps, &g).unwrap();
            }
            ps
        };
        let a = run(OptimKind::Adam);
        let s = run(OptimKind::SetAdam);
        assert!(a.global_norm() < ps0.global_norm());
        assert!(s.global_norm() < ps0.global_norm());
        let mut diff = 0.0f32;
        for (ia, is_) in a.groups["w"][0].iter().zip(&s.groups["w"][0]) {
            diff = diff.max(ia.max_abs_diff(is_).unwrap());
        }
        assert!(diff > 1e-5, "SET-Adam should suppress the outlier stepsize");
    }

    #[test]
    fn frozen_group_is_bitwise_pinned() {
        // two groups so one can freeze while the other trains
        let text = r#"{
          "name": "toy2", "family": "gpt",
          "dims": {"d_model": 4, "n_heads": 2, "n_blocks": 2,
                   "n_enc_blocks": 0, "mlp_ratio": 2, "batch": 2, "lbits": 9,
                   "image_size": 32, "patch": 4, "channels": 3,
                   "n_classes": 10, "seq": 8, "seq_src": 0, "vocab": 16},
          "param_groups": {
            "embed": [{"name": "e", "shape": [8], "init": "normal:1.0"}],
            "w": [{"name": "a", "shape": [8], "init": "normal:1.0"}]
          },
          "executables": {}, "source_hash": "x"
        }"#;
        let m = Manifest::from_json(&Json::parse(text).unwrap()).unwrap();
        let mut ps = ParamStore::init(&m, 3);
        let before = clone_store(&ps);
        let mut opt = Optimizer::new(&cfg(OptimKind::Adam), &ps);
        // non-zero restored moments would move params even under zero
        // grads — the group skip is what actually freezes
        opt.m.for_each_mut(|t| t.data_mut().fill(0.5));
        opt.set_frozen(vec!["embed".into(), "enc_embed".into()]);
        for _ in 0..5 {
            let mut g = clone_store(&ps);
            g.groups.get_mut("embed").unwrap()[0]
                .iter_mut()
                .for_each(|t| t.data_mut().fill(0.0));
            opt.step(&mut ps, &g).unwrap();
        }
        let bits = |s: &ParamStore, g: &str| {
            s.groups[g][0][0]
                .data()
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>()
        };
        assert_eq!(bits(&ps, "embed"), bits(&before, "embed"));
        assert_ne!(bits(&ps, "w"), bits(&before, "w"));
        // frozen moments are untouched too
        assert!(opt.m.groups["embed"][0][0].data().iter().all(|x| *x == 0.5));
        assert!(opt.m.groups["w"][0][0].data().iter().any(|x| *x != 0.5));
    }

    #[test]
    fn clip_reduces_norm() {
        let ps = toy();
        let mut g = clone_store(&ps);
        let pre = g.global_norm();
        let reported = clip_global_norm(&mut g, pre / 2.0);
        assert!((reported - pre).abs() < 1e-5);
        assert!((g.global_norm() - pre / 2.0).abs() < 1e-4);
        let post = g.global_norm();
        clip_global_norm(&mut g, post * 10.0);
        assert!((g.global_norm() - post).abs() < 1e-6);
    }

    #[test]
    fn state_bytes_accounted() {
        let ps = toy();
        let opt = Optimizer::new(&cfg(OptimKind::Adam), &ps);
        assert_eq!(opt.nbytes(), 2 * ps.nbytes());
    }

    #[test]
    fn bias_correction_first_step() {
        // after one step with grad g, Adam moves by ~lr * sign(g)
        let mut ps = toy();
        let before = clone_store(&ps);
        let g = clone_store(&ps);
        let mut opt = Optimizer::new(&cfg(OptimKind::Adam), &ps);
        opt.step(&mut ps, &g).unwrap();
        for (p, b) in ps.groups["w"][0][0].data().iter().zip(before.groups["w"][0][0].data()) {
            let delta = p - b;
            if *b != 0.0 {
                assert!((delta.abs() - 0.1).abs() < 1e-3, "delta {delta}");
                assert_eq!(delta.signum(), -b.signum());
            }
        }
    }
}
