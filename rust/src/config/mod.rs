//! Configuration system: JSON config files + `key=value` CLI overrides.
//!
//! A training run is fully specified by a [`TrainConfig`]; experiment drivers
//! construct them programmatically, the CLI loads them from `configs/*.json`.

pub mod json;

pub use json::Json;

use crate::runtime::BackendKind;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Which training coordinator to use (the paper's three compared systems).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// BDIA-transformer, exact bit-level reversible (quantized, side info).
    BdiaReversible,
    /// BDIA regularization only: float eq. 10, store-all activations
    /// (Table-2 ablation: "w.o. quantization, w.o. online back-propagation").
    BdiaFloat,
    /// Conventional transformer, store-all activations (the "ViT" baseline).
    Vanilla,
    /// RevViT-style two-stream reversible baseline [19].
    RevVit,
}

impl TrainMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "bdia" | "bdia_reversible" => TrainMode::BdiaReversible,
            "bdia_float" => TrainMode::BdiaFloat,
            "vanilla" => TrainMode::Vanilla,
            "revvit" => TrainMode::RevVit,
            _ => bail!("unknown mode '{s}' (bdia|bdia_float|vanilla|revvit)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TrainMode::BdiaReversible => "bdia",
            TrainMode::BdiaFloat => "bdia_float",
            TrainMode::Vanilla => "vanilla",
            TrainMode::RevVit => "revvit",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimKind {
    Adam,
    /// SET-Adam [31]: Adam with suppressed adaptive-stepsize range (the
    /// paper's training configuration).
    SetAdam,
}

impl OptimKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "adam" => OptimKind::Adam,
            "setadam" | "set_adam" => OptimKind::SetAdam,
            _ => bail!("unknown optimizer '{s}' (adam|setadam)"),
        })
    }
}

/// What the rank-0 driver does when the distributed world loses a rank
/// (see the failure-semantics notes in [`crate::dist`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankFailurePolicy {
    /// Tear the world down and exit with the structured error (default).
    Abort,
    /// Rebuild the world (re-rendezvous, respawn local workers) and
    /// resume from rank 0's last completed step via the state broadcast.
    /// Recovery is bit-identical to an uninterrupted run.
    Restart,
}

impl RankFailurePolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "abort" => RankFailurePolicy::Abort,
            "restart" => RankFailurePolicy::Restart,
            _ => bail!("unknown rank-failure policy '{s}' (abort|restart)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RankFailurePolicy::Abort => "abort",
            RankFailurePolicy::Restart => "restart",
        }
    }
}

/// Complete specification of one training run.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// Bundle name (a native-registry config, or an AOT bundle under
    /// `artifacts_dir`).
    pub model: String,
    /// Execution backend: `native` (default, pure Rust) or `pjrt`.
    pub backend: BackendKind,
    pub mode: TrainMode,
    /// |gamma| drawn with random sign per sample per block (paper: 0.5).
    /// 0.0 disables BDIA (reduces to vanilla update even in bdia_float mode).
    pub gamma_mag: f32,
    pub dataset: String,
    pub steps: usize,
    /// optimizer
    pub optimizer: OptimKind,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub grad_clip: Option<f32>,
    /// bookkeeping
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    /// number of held-out batches per evaluation pass
    pub eval_batches: usize,
    pub artifacts_dir: PathBuf,
    /// dataset size knobs (synthetic generators honor these)
    pub train_examples: usize,
    pub val_examples: usize,
    /// checkpointing: save every K steps (0 disables) into `ckpt_dir`
    pub save_every: usize,
    pub ckpt_dir: PathBuf,
    /// kernel thread-pool parallelism (0 = auto-detect).  Results are
    /// bit-identical at any value; this is purely a speed knob.
    pub threads: usize,
    /// data-parallel world size (number of ranks; 1 = single process).
    /// Results are bit-identical at any value — see [`crate::dist`].
    pub ranks: usize,
    /// micro-batches per global optimization step (gradient accumulation).
    /// 0 = auto: one micro-batch per rank (`max(ranks, 1)`).  Must be a
    /// multiple of `ranks`; the global sample/γ sequence is a pure function
    /// of this value, so runs at different rank counts (same `grad_accum`)
    /// consume identical data and produce bit-identical training.
    pub grad_accum: usize,
    /// Deadline (seconds) on every steady-state distributed read/write: a
    /// rank silent for this long is declared dead and the world aborts
    /// with a structured error instead of hanging.  Heartbeats keep slow
    /// ranks alive, so this bounds *silence*, not compute.  Operational
    /// knob — excluded from the world-config digest.
    pub dist_timeout_s: f64,
    /// What rank 0 does when the world loses a rank: abort (default) or
    /// rebuild + resume bit-exactly.  Operational knob — excluded from
    /// the world-config digest.
    pub on_rank_failure: RankFailurePolicy,
    /// Fine-tuning: load this checkpoint (params + optimizer + step +
    /// gamma RNG) before training starts.  Mechanically identical to
    /// `--resume`, but carried in the config so every rank of a spawned
    /// world applies it; pair with a new `seed` for a fresh corpus split.
    pub init_from: Option<PathBuf>,
    /// Fine-tuning: freeze the embedding group(s) — their gradients are
    /// zeroed before clipping, they are excluded from the all-reduce
    /// payload, and the optimizer skips them (moments untouched), so
    /// embeddings stay bit-identical to the loaded checkpoint.
    pub freeze_embed: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        // Paper §5.1: SET-Adam (1e-4, 0.9, 0.999, 1e-18).
        TrainConfig {
            model: "vit_s10".into(),
            backend: BackendKind::default(),
            mode: TrainMode::BdiaReversible,
            gamma_mag: 0.5,
            dataset: "synth_cifar10".into(),
            steps: 200,
            optimizer: OptimKind::SetAdam,
            lr: 1e-4,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-18,
            grad_clip: Some(1.0),
            seed: 0,
            log_every: 20,
            eval_every: 100,
            eval_batches: 4,
            artifacts_dir: PathBuf::from("artifacts"),
            train_examples: 2048,
            val_examples: 512,
            save_every: 0,
            ckpt_dir: PathBuf::from("checkpoints"),
            threads: 0,
            ranks: 1,
            grad_accum: 0,
            dist_timeout_s: 30.0,
            on_rank_failure: RankFailurePolicy::Abort,
            init_from: None,
            freeze_embed: false,
        }
    }
}

impl TrainConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = TrainConfig::default();
        let obj = j.as_obj()?;
        for (k, v) in obj {
            c.apply(k, v).with_context(|| format!("config key '{k}'"))?;
        }
        Ok(c)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    fn apply(&mut self, key: &str, v: &Json) -> Result<()> {
        match key {
            "model" => self.model = v.as_str()?.into(),
            "backend" => self.backend = BackendKind::parse(v.as_str()?)?,
            "mode" => self.mode = TrainMode::parse(v.as_str()?)?,
            "gamma_mag" => self.gamma_mag = v.as_f64()? as f32,
            "dataset" => self.dataset = v.as_str()?.into(),
            "steps" => self.steps = v.as_usize()?,
            "optimizer" => self.optimizer = OptimKind::parse(v.as_str()?)?,
            "lr" => self.lr = v.as_f64()? as f32,
            "beta1" => self.beta1 = v.as_f64()? as f32,
            "beta2" => self.beta2 = v.as_f64()? as f32,
            "eps" => self.eps = v.as_f64()? as f32,
            "grad_clip" => {
                self.grad_clip = match v {
                    Json::Null => None,
                    _ => Some(v.as_f64()? as f32),
                }
            }
            "seed" => self.seed = v.as_i64()? as u64,
            "log_every" => self.log_every = v.as_usize()?,
            "eval_every" => self.eval_every = v.as_usize()?,
            "eval_batches" => self.eval_batches = v.as_usize()?,
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(v.as_str()?),
            "train_examples" => self.train_examples = v.as_usize()?,
            "val_examples" => self.val_examples = v.as_usize()?,
            "save_every" => self.save_every = v.as_usize()?,
            "ckpt_dir" => self.ckpt_dir = PathBuf::from(v.as_str()?),
            "threads" => self.threads = v.as_usize()?,
            "ranks" => self.ranks = v.as_usize()?,
            "grad_accum" => self.grad_accum = v.as_usize()?,
            "dist_timeout_s" => self.dist_timeout_s = v.as_f64()?,
            "on_rank_failure" => {
                self.on_rank_failure = RankFailurePolicy::parse(v.as_str()?)?
            }
            "init_from" => {
                self.init_from = match v {
                    Json::Null => None,
                    _ => Some(PathBuf::from(v.as_str()?)),
                }
            }
            "freeze_embed" => self.freeze_embed = v.as_bool()?,
            _ => bail!("unknown config key"),
        }
        Ok(())
    }

    /// Effective micro-batches per global optimization step (resolves the
    /// `grad_accum = 0` auto default to one micro-batch per rank).
    pub fn accum(&self) -> usize {
        if self.grad_accum == 0 {
            self.ranks.max(1)
        } else {
            self.grad_accum
        }
    }

    /// The collective deadline as a [`Duration`](std::time::Duration),
    /// floored at 50ms so a typo'd tiny value cannot make every read an
    /// instant failure.
    pub fn dist_deadline(&self) -> std::time::Duration {
        std::time::Duration::from_secs_f64(self.dist_timeout_s.max(0.05))
    }

    /// Apply a `key=value` CLI override (values parsed as JSON when
    /// possible, else treated as strings).
    pub fn override_kv(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("override must be key=value: '{kv}'"))?;
        let j = Json::parse(v).unwrap_or_else(|_| Json::Str(v.to_string()));
        self.apply(k, &j).with_context(|| format!("override '{kv}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.lr, 1e-4);
        assert_eq!(c.beta1, 0.9);
        assert_eq!(c.beta2, 0.999);
        assert_eq!(c.eps, 1e-18);
        assert_eq!(c.gamma_mag, 0.5);
        assert_eq!(c.optimizer, OptimKind::SetAdam);
    }

    #[test]
    fn from_json_and_overrides() {
        let j = Json::parse(
            r#"{"model": "gpt_tiny", "mode": "vanilla", "steps": 50,
                "lr": 0.001, "grad_clip": null}"#,
        )
        .unwrap();
        let mut c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "gpt_tiny");
        assert_eq!(c.mode, TrainMode::Vanilla);
        assert_eq!(c.steps, 50);
        assert_eq!(c.grad_clip, None);
        c.override_kv("mode=bdia_float").unwrap();
        assert_eq!(c.mode, TrainMode::BdiaFloat);
        c.override_kv("gamma_mag=0.25").unwrap();
        assert_eq!(c.gamma_mag, 0.25);
        assert!(c.override_kv("nonsense=1").is_err());
        assert!(c.override_kv("noequals").is_err());
    }

    #[test]
    fn backend_defaults_native_and_overrides() {
        let c = TrainConfig::default();
        assert_eq!(c.backend, BackendKind::Native);
        let mut c = TrainConfig::default();
        c.override_kv("backend=pjrt").unwrap();
        assert_eq!(c.backend, BackendKind::Pjrt);
        c.override_kv("backend=native").unwrap();
        assert_eq!(c.backend, BackendKind::Native);
        assert!(c.override_kv("backend=tpu").is_err());
    }

    #[test]
    fn checkpoint_keys_parse() {
        let mut c = TrainConfig::default();
        assert_eq!(c.save_every, 0);
        c.override_kv("save_every=50").unwrap();
        c.override_kv("ckpt_dir=ckpts/run1").unwrap();
        assert_eq!(c.save_every, 50);
        assert_eq!(c.ckpt_dir, PathBuf::from("ckpts/run1"));
    }

    #[test]
    fn threads_key_parses_and_defaults_to_auto() {
        let mut c = TrainConfig::default();
        assert_eq!(c.threads, 0); // 0 = auto-detect
        c.override_kv("threads=4").unwrap();
        assert_eq!(c.threads, 4);
        let j = Json::parse(r#"{"threads": 2}"#).unwrap();
        assert_eq!(TrainConfig::from_json(&j).unwrap().threads, 2);
    }

    #[test]
    fn dist_keys_parse_and_accum_resolves() {
        let mut c = TrainConfig::default();
        assert_eq!(c.ranks, 1);
        assert_eq!(c.grad_accum, 0);
        assert_eq!(c.accum(), 1); // auto: one micro-batch per rank
        c.override_kv("ranks=4").unwrap();
        assert_eq!(c.accum(), 4);
        c.override_kv("grad_accum=8").unwrap();
        assert_eq!(c.accum(), 8);
        let j = Json::parse(r#"{"ranks": 2, "grad_accum": 6}"#).unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!((c.ranks, c.accum()), (2, 6));
    }

    #[test]
    fn fault_keys_parse_and_deadline_is_floored() {
        let mut c = TrainConfig::default();
        assert_eq!(c.dist_timeout_s, 30.0);
        assert_eq!(c.on_rank_failure, RankFailurePolicy::Abort);
        c.override_kv("dist_timeout_s=0.5").unwrap();
        assert_eq!(c.dist_deadline(), std::time::Duration::from_millis(500));
        c.override_kv("on_rank_failure=restart").unwrap();
        assert_eq!(c.on_rank_failure, RankFailurePolicy::Restart);
        assert!(c.override_kv("on_rank_failure=retry").is_err());
        // a typo'd tiny deadline is floored, not honored
        c.override_kv("dist_timeout_s=0.000001").unwrap();
        assert_eq!(c.dist_deadline(), std::time::Duration::from_millis(50));
        for p in [RankFailurePolicy::Abort, RankFailurePolicy::Restart] {
            assert_eq!(RankFailurePolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn finetune_keys_parse() {
        let mut c = TrainConfig::default();
        assert_eq!(c.init_from, None);
        assert!(!c.freeze_embed);
        c.override_kv("init_from=ckpts/run1-latest.ckpt").unwrap();
        assert_eq!(c.init_from, Some(PathBuf::from("ckpts/run1-latest.ckpt")));
        c.override_kv("freeze_embed=true").unwrap();
        assert!(c.freeze_embed);
        c.override_kv("init_from=null").unwrap();
        assert_eq!(c.init_from, None);
        assert!(c.override_kv("freeze_embed=maybe").is_err());
        let j = Json::parse(
            r#"{"init_from": "a.ckpt", "freeze_embed": true}"#,
        )
        .unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.init_from, Some(PathBuf::from("a.ckpt")));
        assert!(c.freeze_embed);
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"modle": "typo"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
    }

    #[test]
    fn mode_names_roundtrip() {
        for m in [
            TrainMode::BdiaReversible,
            TrainMode::BdiaFloat,
            TrainMode::Vanilla,
            TrainMode::RevVit,
        ] {
            assert_eq!(TrainMode::parse(m.name()).unwrap(), m);
        }
    }
}
