//! Minimal JSON parser substrate (no serde available offline).
//!
//! Covers the full JSON grammar; used for the AOT `manifest.json` ABI files
//! and the experiment/training config files.  Parsing is recursive-descent
//! over bytes with proper string-escape and number handling.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i);
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.unwrap_or('\u{FFFD}'));
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x20 => bail!("raw control char in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self
            .b
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn usize_vec_and_accessors() {
        let j = Json::parse(r#"{"shape": [2, 8, 16], "n": 5, "f": true}"#).unwrap();
        assert_eq!(j.get("shape").unwrap().usize_vec().unwrap(), vec![2, 8, 16]);
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 5);
        assert!(j.get("f").unwrap().as_bool().unwrap());
        assert!(j.get("missing").is_err());
        assert!(j.get("n").unwrap().as_str().is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn parses_real_manifest() {
        // smoke: the actual ABI file if artifacts are built
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/smoke_gpt/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let j = Json::parse(&text).unwrap();
            assert_eq!(j.get("family").unwrap().as_str().unwrap(), "gpt");
        }
    }
}
