//! Candidate search for kernel tuning profiles (`bdia tune`).
//!
//! [`search`] takes the shapes a model actually runs (captured via
//! [`profile::record_shapes`]), benchmarks a grid of candidate
//! [`OpParams`] for each shape **on the live pool at the current thread
//! count**, and composes the per-shape winners into a
//! [`KernelProfile`].  Every candidate is a legal profile, and legal
//! profiles are bit-exact by construction, so the search can only change
//! speed — never results.
//!
//! Probing installs each candidate as the process-wide active profile's
//! fallback parameters (entries would not engage for the attention proxy
//! shapes below), times a warmup plus min-of-iterations run on synthetic
//! data, and restores whatever profile was active before returning.

use super::attention::{attn_fwd, AttnW};
use super::matmul::{matmul, matmul_nt_w, matmul_tn};
use super::pool;
use super::profile::{self, KernelProfile, OpKey, OpKind, OpParams};
use super::workspace;
use std::collections::BTreeMap;
use std::time::Instant;

/// Most shapes tuned per run (largest by flop count first).
pub const MAX_SHAPES: usize = 24;
/// Shape cap under `--quick` (CI smoke).
pub const MAX_SHAPES_QUICK: usize = 12;

/// Timing result for one tuned shape.
#[derive(Clone, Copy, Debug)]
pub struct ShapeTiming {
    pub key: OpKey,
    /// min-of-iterations time under [`OpParams::DEFAULT`].
    pub default_ms: f64,
    /// min-of-iterations time under the winning candidate.
    pub best_ms: f64,
    pub best: OpParams,
}

/// What [`search`] produced: the composed profile plus per-shape timings.
pub struct SearchReport {
    pub profile: KernelProfile,
    pub shapes: Vec<ShapeTiming>,
    /// Recorded shapes not tuned: wrong thread count, zero work, or past
    /// the per-run cap.
    pub dropped: usize,
}

/// Deterministic synthetic operand data (xorshift32 in [-0.5, 0.5)).
fn synth(n: usize, seed: u32) -> Vec<f32> {
    let mut s = seed | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 17;
            s ^= s << 5;
            (s as f32 / u32::MAX as f32) - 0.5
        })
        .collect()
}

fn isqrt(v: usize) -> usize {
    if v == 0 {
        return 0;
    }
    let mut r = (v as f64).sqrt() as usize;
    while r.saturating_mul(r) > v {
        r -= 1;
    }
    while (r + 1).saturating_mul(r + 1) <= v {
        r += 1;
    }
    r
}

/// The candidate grid for one op kind.  Always contains
/// [`OpParams::DEFAULT`], so `default_ms` is measured for free.
fn candidates(op: OpKind, quick: bool) -> Vec<OpParams> {
    let (kcs, grains, unrolls): (&[usize], &[usize], &[usize]) = if quick {
        (&[64, 128], &[1 << 12, 1 << 14], &[1, 8])
    } else {
        (&[32, 64, 128, 256], &[1 << 12, 1 << 14, 1 << 16], &[1, 4, 8, 16])
    };
    let mut out = Vec::new();
    match op {
        // the attention head loops have no k-panel; only grain and the
        // axpy chunk width matter
        OpKind::Attention => {
            for &g in grains {
                for &u in unrolls {
                    out.push(OpParams {
                        kc: OpParams::DEFAULT.kc,
                        grain_flop: g,
                        unroll: u,
                        nt_cache: false,
                    });
                }
            }
        }
        OpKind::MatmulNt => {
            for &kc in kcs {
                for &g in grains {
                    for &u in unrolls {
                        for nt in [false, true] {
                            out.push(OpParams {
                                kc,
                                grain_flop: g,
                                unroll: u,
                                nt_cache: nt,
                            });
                        }
                    }
                }
            }
        }
        OpKind::Matmul | OpKind::MatmulTn => {
            for &kc in kcs {
                for &g in grains {
                    for &u in unrolls {
                        out.push(OpParams {
                            kc,
                            grain_flop: g,
                            unroll: u,
                            nt_cache: false,
                        });
                    }
                }
            }
        }
    }
    debug_assert!(out.contains(&OpParams::DEFAULT));
    out
}

/// Warmup once, then min over `iters` timed runs.
fn time_ms(iters: usize, f: &mut dyn FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        if dt < best {
            best = dt;
        }
    }
    best
}

/// Benchmark every candidate for one shape and return the winner.
fn bench_shape(key: &OpKey, quick: bool) -> ShapeTiming {
    let iters = if quick { 2 } else { 3 };
    // one kernel invocation on synthetic operands matching the recorded
    // dims; buffers go back to the arena so steady-state runs don't
    // allocate
    let mut run: Box<dyn FnMut()> = match key.op {
        OpKind::Matmul => {
            let (m, k, n) = (key.m, key.k, key.n);
            let a = synth(m * k, 1);
            let b = synth(k * n, 2);
            Box::new(move || {
                workspace::give(matmul(&a, &b, m, k, n));
            })
        }
        OpKind::MatmulTn => {
            let (m, k, n) = (key.m, key.k, key.n);
            let a = synth(m * k, 1);
            let b = synth(m * n, 2);
            Box::new(move || {
                workspace::give(matmul_tn(&a, &b, m, k, n));
            })
        }
        OpKind::MatmulNt => {
            // key is (m, reduction, output cols); `b` plays the static
            // weight so nt_cache candidates exercise the keyed cache
            let (m, red, cols) = (key.m, key.k, key.n);
            let a = synth(m * red, 1);
            let b = synth(cols * red, 2);
            Box::new(move || {
                workspace::give(matmul_nt_w(&a, &b, m, red, cols));
            })
        }
        OpKind::Attention => {
            // proxy the (b·heads, tq·tk, dh) key with heads = 1 and a
            // square tq = tk = isqrt(tq·tk); candidates install as the
            // probe's fallback params, so an inexact proxy shape still
            // engages them
            let b = key.m.max(1);
            let t = isqrt(key.k).max(1);
            let d = key.n.max(1);
            let wq = synth(d * d, 3);
            let wk = synth(d * d, 4);
            let wv = synth(d * d, 5);
            let wo = synth(d * d, 6);
            let bias = synth(d, 7);
            let x = synth(b * t * d, 8);
            Box::new(move || {
                let w = AttnW {
                    wq: &wq,
                    bq: &bias,
                    wk: &wk,
                    bk: &bias,
                    wv: &wv,
                    bv: &bias,
                    wo: &wo,
                    bo: &bias,
                };
                let (y, cache) = attn_fwd(&w, &x, &x, b, t, t, d, 1, true);
                workspace::give(y);
                cache.recycle();
            })
        }
    };
    let mut default_ms = f64::INFINITY;
    let mut best_ms = f64::INFINITY;
    let mut best = OpParams::DEFAULT;
    for cand in candidates(key.op, quick) {
        profile::set_active(
            KernelProfile {
                id: "probe".into(),
                default_params: cand,
                ..KernelProfile::default()
            },
            None,
        );
        let ms = time_ms(iters, &mut run);
        if cand == OpParams::DEFAULT {
            default_ms = ms;
        }
        if ms < best_ms {
            best_ms = ms;
            best = cand;
        }
    }
    ShapeTiming { key: *key, default_ms, best_ms, best }
}

/// Benchmark candidate parameters for `shapes` at the current pool width
/// and compose the winners into a profile named `id`.
///
/// Shapes recorded at a different thread count are skipped (a profile
/// tuned at 2 threads says nothing about 8); the rest are ranked by flop
/// count and capped at [`MAX_SHAPES`] ([`MAX_SHAPES_QUICK`] under
/// `quick`).  The previously active profile is restored before returning.
pub fn search(id: &str, shapes: &[OpKey], quick: bool) -> SearchReport {
    let threads = pool::threads();
    profile::record_shapes(false);
    let prev = profile::active();
    let prev_src = profile::active_source();

    let mut keys: Vec<OpKey> = shapes
        .iter()
        .copied()
        .filter(|s| s.threads == threads && s.work() > 0)
        .collect();
    keys.sort_by(|a, b| b.work().cmp(&a.work()).then(a.cmp(b)));
    keys.dedup();
    let cap = if quick { MAX_SHAPES_QUICK } else { MAX_SHAPES };
    keys.truncate(cap);
    let dropped = shapes.len().saturating_sub(keys.len());

    let mut timings = Vec::with_capacity(keys.len());
    let mut entries = BTreeMap::new();
    for key in &keys {
        let t = bench_shape(key, quick);
        entries.insert(*key, t.best);
        timings.push(t);
    }

    // roll back the probe installs and drop probe-era transpose cache
    // entries (pruned on the next keyed insert)
    match prev {
        Some(p) => profile::set_active((*p).clone(), prev_src),
        None => profile::reset_active(),
    }
    workspace::bump_weight_generation();

    let profile = KernelProfile {
        id: id.to_string(),
        default_params: OpParams::DEFAULT,
        entries,
        ..KernelProfile::default()
    };
    SearchReport { profile, shapes: timings, dropped }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_search_produces_a_valid_profile_and_restores_active() {
        let _guard = profile::test_lock();
        profile::reset_active();
        pool::set_threads(2);
        let t = pool::threads();
        let shapes = vec![
            OpKey { op: OpKind::Matmul, m: 48, k: 32, n: 24, threads: t },
            OpKey { op: OpKind::MatmulNt, m: 16, k: 24, n: 32, threads: t },
            OpKey { op: OpKind::Attention, m: 4, k: 36, n: 8, threads: t },
            // wrong thread count: must be skipped, not mis-tuned
            OpKey { op: OpKind::Matmul, m: 8, k: 8, n: 8, threads: t + 13 },
        ];
        let rep = search("test-quick", &shapes, true);
        assert_eq!(rep.profile.id, "test-quick");
        rep.profile.validate().expect("searched profile must be legal");
        assert_eq!(rep.shapes.len(), 3);
        assert_eq!(rep.profile.entries.len(), 3);
        assert_eq!(rep.dropped, 1);
        for s in &rep.shapes {
            assert!(s.default_ms.is_finite(), "default never timed");
            assert!(
                s.best_ms <= s.default_ms,
                "winner slower than the default it competed against"
            );
        }
        // the probe installs were rolled back
        assert_eq!(profile::active_id(), "default");
        pool::set_threads(0);
    }

    #[test]
    fn isqrt_is_exact_floor() {
        for v in [0usize, 1, 2, 3, 4, 8, 9, 35, 36, 37, 1 << 20] {
            let r = isqrt(v);
            assert!(r * r <= v && (r + 1) * (r + 1) > v, "isqrt({v}) = {r}");
        }
    }
}
