//! Multi-head attention, forward and backward, parallel across
//! (batch, head) pairs.
//!
//! Semantics mirror `python/compile/kernels/attention.py`:
//! `softmax(Q K^T / sqrt(d_head)) V`, causal mask at -1e30, max-subtracted
//! softmax.  Every (batch, head) pair is an independent unit of work whose
//! outputs live in disjoint buffer regions, so the pairs are partitioned
//! across pool tasks; within a pair, the instruction stream is identical
//! to the serial code — bit-identical results at any thread count.
//!
//! Unlike the seed interpreter, no `p != 0.0` fast paths: masked softmax
//! zeros are accumulated like any other value (adding `±0.0` to a finite
//! accumulator is a bit-exact no-op, and non-finite values now propagate
//! faithfully instead of being silently dropped).
//!
//! Head gather/scatter scratch comes from the thread-local workspace
//! arena, so steady-state calls allocate only the buffers that escape
//! into the cache.

use super::elementwise::{add_into, axpy, col_sum};
use super::matmul::{linear, matmul_nt_w, matmul_tn};
use super::pool;
use super::profile::{self, OpKind};
use super::workspace;

pub const NEG_INF: f32 = -1e30;

/// Attention projection weights, views into parameter leaves.
pub struct AttnW<'a> {
    pub wq: &'a [f32],
    pub bq: &'a [f32],
    pub wk: &'a [f32],
    pub bk: &'a [f32],
    pub wv: &'a [f32],
    pub bv: &'a [f32],
    pub wo: &'a [f32],
    pub bo: &'a [f32],
}

/// Parameter gradients, same shapes as [`AttnW`].
pub struct AttnGrads {
    pub wq: Vec<f32>,
    pub bq: Vec<f32>,
    pub wk: Vec<f32>,
    pub bk: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    pub wo: Vec<f32>,
    pub bo: Vec<f32>,
}

/// Forward residuals needed by [`attn_bwd`].
pub struct AttnCache {
    /// projected q/k/v, (b*tq, d) / (b*tk, d)
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// pre-output-projection context, (b*tq, d)
    pub o: Vec<f32>,
    /// softmax weights, (b*heads, tq, tk)
    pub att: Vec<f32>,
}

impl AttnCache {
    /// Hand the residual buffers back to the workspace arena.
    pub fn recycle(self) {
        workspace::give(self.q);
        workspace::give(self.k);
        workspace::give(self.v);
        workspace::give(self.o);
        workspace::give(self.att);
    }
}

/// Copy one head's rows into a contiguous (t, dh) buffer.
fn gather_head(
    src: &[f32],
    bi: usize,
    hi: usize,
    t: usize,
    d: usize,
    dh: usize,
    out: &mut [f32],
) {
    for i in 0..t {
        let base = (bi * t + i) * d + hi * dh;
        out[i * dh..(i + 1) * dh].copy_from_slice(&src[base..base + dh]);
    }
}

/// Accumulate a contiguous (t, dh) head buffer back into (b*t, d) rows.
fn scatter_head_add(
    dst: &mut [f32],
    src: &[f32],
    bi: usize,
    hi: usize,
    t: usize,
    d: usize,
    dh: usize,
) {
    for i in 0..t {
        let base = (bi * t + i) * d + hi * dh;
        for j in 0..dh {
            dst[base + j] += src[i * dh + j];
        }
    }
}

/// Profile lookup for the head loops: task count over `b * heads`
/// independent pairs (sized so each task amortizes the fan-out cost) plus
/// the inner-loop chunk width.
fn head_params(
    b: usize,
    heads: usize,
    tq: usize,
    tk: usize,
    dh: usize,
) -> (usize, usize) {
    let prm = profile::params_for(OpKind::Attention, b * heads, tq * tk, dh);
    let grain = profile::grain_of(prm.grain_flop, 2 * tq * tk * dh);
    (pool::n_tasks(b * heads, grain), prm.unroll)
}

/// One (batch, head) pair of the forward: scores, masked softmax, and the
/// per-head context, written into this pair's disjoint `att`/`oh` rows.
#[allow(clippy::too_many_arguments)]
fn attn_fwd_head(
    qh: &[f32],
    kh: &[f32],
    vh: &[f32],
    att: &mut [f32],
    oh: &mut [f32],
    tq: usize,
    tk: usize,
    dh: usize,
    scale: f32,
    causal: bool,
    unroll: usize,
) {
    for i in 0..tq {
        let qr = &qh[i * dh..(i + 1) * dh];
        let arow = &mut att[i * tk..(i + 1) * tk];
        let mut m = NEG_INF;
        for (jj, a) in arow.iter_mut().enumerate() {
            let mut s = 0.0f32;
            let kr = &kh[jj * dh..(jj + 1) * dh];
            for (qv, kvv) in qr.iter().zip(kr) {
                s += *qv * *kvv;
            }
            s *= scale;
            if causal && jj > i {
                s = NEG_INF;
            }
            *a = s;
            if s > m {
                m = s;
            }
        }
        let mut denom = 0.0f32;
        for a in arow.iter_mut() {
            *a = (*a - m).exp();
            denom += *a;
        }
        let or = &mut oh[i * dh..(i + 1) * dh];
        for (jj, a) in arow.iter_mut().enumerate() {
            let p = *a / denom;
            *a = p;
            // context accumulation over independent output elements —
            // chunkable; the score dots above stay a single sequential
            // accumulator (they are reductions, never unrolled)
            let vr = &vh[jj * dh..(jj + 1) * dh];
            axpy(or, p, vr, unroll);
        }
    }
}

/// Multi-head attention forward.
///
/// `x`: (b*tq, d) queries input; `kv`: (b*tk, d) key/value input (== `x`
/// for self-attention).  Returns the (b*tq, d) output and the backward
/// cache.
#[allow(clippy::too_many_arguments)]
pub fn attn_fwd(
    w: &AttnW,
    x: &[f32],
    kv: &[f32],
    b: usize,
    tq: usize,
    tk: usize,
    d: usize,
    heads: usize,
    causal: bool,
) -> (Vec<f32>, AttnCache) {
    debug_assert_eq!(d % heads, 0);
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let nq = b * tq;
    let nk = b * tk;

    let q = linear(x, w.wq, w.bq, nq, d, d);
    let k = linear(kv, w.wk, w.bk, nk, d, d);
    let v = linear(kv, w.wv, w.bv, nk, d, d);

    let bh = b * heads;
    let mut att = workspace::take(bh * tq * tk);
    let mut oh_all = workspace::take(bh * tq * dh);

    let (parts, unroll) = head_params(b, heads, tq, tk, dh);
    {
        let atts = pool::split_rows_mut(&mut att, tq * tk, parts);
        let ohs = pool::split_rows_mut(&mut oh_all, tq * dh, parts);
        let (q, k, v) = (&q, &k, &v);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = atts
            .into_iter()
            .zip(ohs)
            .map(|(mut ca, mut co)| {
                Box::new(move || {
                    let mut qh = workspace::take(tq * dh);
                    let mut kh = workspace::take(tk * dh);
                    let mut vh = workspace::take(tk * dh);
                    let n_pairs = ca.rows.len() / (tq * tk);
                    for li in 0..n_pairs {
                        let bhi = ca.row0 + li;
                        let (bi, hi) = (bhi / heads, bhi % heads);
                        gather_head(q, bi, hi, tq, d, dh, &mut qh);
                        gather_head(k, bi, hi, tk, d, dh, &mut kh);
                        gather_head(v, bi, hi, tk, d, dh, &mut vh);
                        attn_fwd_head(
                            &qh,
                            &kh,
                            &vh,
                            &mut ca.rows[li * tq * tk..(li + 1) * tq * tk],
                            &mut co.rows[li * tq * dh..(li + 1) * tq * dh],
                            tq,
                            tk,
                            dh,
                            scale,
                            causal,
                            unroll,
                        );
                    }
                    workspace::give(qh);
                    workspace::give(kh);
                    workspace::give(vh);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_tasks(tasks);
    }

    // combine heads: disjoint element sets per (bi, hi), any order
    let mut o = workspace::take(nq * d);
    for bhi in 0..bh {
        let (bi, hi) = (bhi / heads, bhi % heads);
        scatter_head_add(
            &mut o,
            &oh_all[bhi * tq * dh..(bhi + 1) * tq * dh],
            bi,
            hi,
            tq,
            d,
            dh,
        );
    }
    workspace::give(oh_all);

    let out = linear(&o, w.wo, w.bo, nq, d, d);
    (out, AttnCache { q, k, v, o, att })
}

/// Copy one head's cached rows (layout `(b, t_max, d)`) plus the freshly
/// projected row `pos` into a contiguous `(pos+1, dh)` buffer.
#[allow(clippy::too_many_arguments)]
fn gather_cache_head(
    cache: &[f32],
    new_row: &[f32],
    bi: usize,
    hi: usize,
    pos: usize,
    t_max: usize,
    d: usize,
    dh: usize,
    out: &mut [f32],
) {
    for t in 0..pos {
        let base = (bi * t_max + t) * d + hi * dh;
        out[t * dh..(t + 1) * dh].copy_from_slice(&cache[base..base + dh]);
    }
    let base = bi * d + hi * dh;
    out[pos * dh..(pos + 1) * dh].copy_from_slice(&new_row[base..base + dh]);
}

/// Single-position decode attention against per-session K/V caches.
///
/// `x` is the `(b, d)` ln1-normalised row at position `pos` (one lane per
/// batch slot); `kcache`/`vcache` are `(b, t_max, d)` buffers whose rows
/// `0..pos` hold the post-projection keys/values of the prefix.  Projects
/// q/k/v for the new row, attends over the `pos+1` keys (no mask needed:
/// every key is at or before the query), and returns
/// `(out (b,d), knew (b,d), vnew (b,d))` — the caller appends knew/vnew to
/// the caches.
///
/// Bit contract: the output rows are bit-identical to row `pos` of
/// [`attn_fwd`] with `causal = true` over the full prefix, at every thread
/// count and kernel profile.  Three facts compose into that guarantee:
/// (1) `linear` reduces each output element over `k` in a fixed ascending
/// order regardless of row count, so a 1-row projection equals the same
/// row of the full projection; (2) the full forward's masked scores sit at
/// `NEG_INF` *after* the unmasked ones (`jj > i`), contribute
/// `exp(NEG_INF - m) = 0.0` exactly, and adding `±0.0` to the softmax
/// denominator / context accumulator (which starts at `+0.0` and can never
/// become `-0.0`: `a + b == -0.0` only when both operands are `-0.0`) is a
/// bit-exact no-op; (3) each (batch, head) pair runs the identical serial
/// instruction stream whatever the task partition.
#[allow(clippy::too_many_arguments)]
pub fn attn_decode(
    w: &AttnW,
    x: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    b: usize,
    pos: usize,
    t_max: usize,
    d: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(d % heads, 0);
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let tk = pos + 1;

    let q = linear(x, w.wq, w.bq, b, d, d);
    let knew = linear(x, w.wk, w.bk, b, d, d);
    let vnew = linear(x, w.wv, w.bv, b, d, d);

    let bh = b * heads;
    let mut att = workspace::take(bh * tk);
    let mut oh_all = workspace::take(bh * dh);

    let (parts, unroll) = head_params(b, heads, 1, tk, dh);
    {
        let atts = pool::split_rows_mut(&mut att, tk, parts);
        let ohs = pool::split_rows_mut(&mut oh_all, dh, parts);
        let (q, knew, vnew) = (&q, &knew, &vnew);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = atts
            .into_iter()
            .zip(ohs)
            .map(|(mut ca, mut co)| {
                Box::new(move || {
                    let mut qh = workspace::take(dh);
                    let mut kh = workspace::take(tk * dh);
                    let mut vh = workspace::take(tk * dh);
                    let n_pairs = ca.rows.len() / tk;
                    for li in 0..n_pairs {
                        let bhi = ca.row0 + li;
                        let (bi, hi) = (bhi / heads, bhi % heads);
                        gather_head(q, bi, hi, 1, d, dh, &mut qh);
                        gather_cache_head(
                            kcache, knew, bi, hi, pos, t_max, d, dh, &mut kh,
                        );
                        gather_cache_head(
                            vcache, vnew, bi, hi, pos, t_max, d, dh, &mut vh,
                        );
                        attn_fwd_head(
                            &qh,
                            &kh,
                            &vh,
                            &mut ca.rows[li * tk..(li + 1) * tk],
                            &mut co.rows[li * dh..(li + 1) * dh],
                            1,
                            tk,
                            dh,
                            scale,
                            false,
                            unroll,
                        );
                    }
                    workspace::give(qh);
                    workspace::give(kh);
                    workspace::give(vh);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_tasks(tasks);
    }
    workspace::give(att);

    let mut o = workspace::take(b * d);
    for bhi in 0..bh {
        let (bi, hi) = (bhi / heads, bhi % heads);
        scatter_head_add(&mut o, &oh_all[bhi * dh..(bhi + 1) * dh], bi, hi, 1, d, dh);
    }
    workspace::give(oh_all);

    let out = linear(&o, w.wo, w.bo, b, d, d);
    workspace::give(o);
    workspace::give(q);
    (out, knew, vnew)
}

/// One (batch, head) pair of the backward: softmax jacobian and the
/// dq/dk/dv head gradients, written into this pair's disjoint rows.
#[allow(clippy::too_many_arguments)]
fn attn_bwd_head(
    qh: &[f32],
    kh: &[f32],
    vh: &[f32],
    doh: &[f32],
    att: &[f32],
    dqh: &mut [f32],
    dkh: &mut [f32],
    dvh: &mut [f32],
    datt: &mut [f32],
    tq: usize,
    tk: usize,
    dh: usize,
    scale: f32,
    unroll: usize,
) {
    for i in 0..tq {
        let arow = &att[i * tk..(i + 1) * tk];
        let dor = &doh[i * dh..(i + 1) * dh];
        // datt row + softmax jacobian row
        let mut rowdot = 0.0f32;
        for jj in 0..tk {
            let p = arow[jj];
            let vr = &vh[jj * dh..(jj + 1) * dh];
            // score-gradient dot: a reduction, stays a single sequential
            // accumulator regardless of the profile's unroll width
            let mut s = 0.0f32;
            for (dov, vv) in dor.iter().zip(vr) {
                s += *dov * *vv;
            }
            datt[jj] = s;
            rowdot += s * p;
            // dv accumulation: dv[jj] += p * do[i] — independent output
            // elements, chunkable
            let dvr = &mut dvh[jj * dh..(jj + 1) * dh];
            axpy(dvr, p, dor, unroll);
        }
        let dqr = &mut dqh[i * dh..(i + 1) * dh];
        for jj in 0..tk {
            let p = arow[jj];
            let ds = p * (datt[jj] - rowdot) * scale;
            let kr = &kh[jj * dh..(jj + 1) * dh];
            axpy(dqr, ds, kr, unroll);
            let qr = &qh[i * dh..(i + 1) * dh];
            let dkr = &mut dkh[jj * dh..(jj + 1) * dh];
            axpy(dkr, ds, qr, unroll);
        }
    }
}

/// Backward of [`attn_fwd`].  Returns (dx, dkv, param grads); for
/// self-attention the caller adds dx + dkv.
#[allow(clippy::too_many_arguments)]
pub fn attn_bwd(
    w: &AttnW,
    x: &[f32],
    kv: &[f32],
    cache: &AttnCache,
    dout: &[f32],
    b: usize,
    tq: usize,
    tk: usize,
    d: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>, AttnGrads) {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let nq = b * tq;
    let nk = b * tk;

    // output projection
    let dbo = col_sum(dout, nq, d);
    let dwo = matmul_tn(&cache.o, dout, nq, d, d);
    let do_ = matmul_nt_w(dout, w.wo, nq, d, d);

    let bh = b * heads;
    let mut dqh_all = workspace::take(bh * tq * dh);
    let mut dkh_all = workspace::take(bh * tk * dh);
    let mut dvh_all = workspace::take(bh * tk * dh);

    let (parts, unroll) = head_params(b, heads, tq, tk, dh);
    {
        let dqs = pool::split_rows_mut(&mut dqh_all, tq * dh, parts);
        let dks = pool::split_rows_mut(&mut dkh_all, tk * dh, parts);
        let dvs = pool::split_rows_mut(&mut dvh_all, tk * dh, parts);
        let do_ref = &do_;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = dqs
            .into_iter()
            .zip(dks)
            .zip(dvs)
            .map(|((mut cq, mut ck), mut cv)| {
                Box::new(move || {
                    let mut qh = workspace::take(tq * dh);
                    let mut kh = workspace::take(tk * dh);
                    let mut vh = workspace::take(tk * dh);
                    let mut doh = workspace::take(tq * dh);
                    let mut datt = workspace::take(tk);
                    let n_pairs = cq.rows.len() / (tq * dh);
                    for li in 0..n_pairs {
                        let bhi = cq.row0 + li;
                        let (bi, hi) = (bhi / heads, bhi % heads);
                        gather_head(&cache.q, bi, hi, tq, d, dh, &mut qh);
                        gather_head(&cache.k, bi, hi, tk, d, dh, &mut kh);
                        gather_head(&cache.v, bi, hi, tk, d, dh, &mut vh);
                        gather_head(do_ref, bi, hi, tq, d, dh, &mut doh);
                        let att =
                            &cache.att[bhi * tq * tk..(bhi + 1) * tq * tk];
                        attn_bwd_head(
                            &qh,
                            &kh,
                            &vh,
                            &doh,
                            att,
                            &mut cq.rows[li * tq * dh..(li + 1) * tq * dh],
                            &mut ck.rows[li * tk * dh..(li + 1) * tk * dh],
                            &mut cv.rows[li * tk * dh..(li + 1) * tk * dh],
                            &mut datt,
                            tq,
                            tk,
                            dh,
                            scale,
                            unroll,
                        );
                    }
                    workspace::give(qh);
                    workspace::give(kh);
                    workspace::give(vh);
                    workspace::give(doh);
                    workspace::give(datt);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_tasks(tasks);
    }

    let mut dq = workspace::take(nq * d);
    let mut dk = workspace::take(nk * d);
    let mut dv = workspace::take(nk * d);
    for bhi in 0..bh {
        let (bi, hi) = (bhi / heads, bhi % heads);
        scatter_head_add(
            &mut dq,
            &dqh_all[bhi * tq * dh..(bhi + 1) * tq * dh],
            bi,
            hi,
            tq,
            d,
            dh,
        );
        scatter_head_add(
            &mut dk,
            &dkh_all[bhi * tk * dh..(bhi + 1) * tk * dh],
            bi,
            hi,
            tk,
            d,
            dh,
        );
        scatter_head_add(
            &mut dv,
            &dvh_all[bhi * tk * dh..(bhi + 1) * tk * dh],
            bi,
            hi,
            tk,
            d,
            dh,
        );
    }
    workspace::give(dqh_all);
    workspace::give(dkh_all);
    workspace::give(dvh_all);

    // input projections
    let dwq = matmul_tn(x, &dq, nq, d, d);
    let dbq = col_sum(&dq, nq, d);
    let dx = matmul_nt_w(&dq, w.wq, nq, d, d);

    let dwk = matmul_tn(kv, &dk, nk, d, d);
    let dbk = col_sum(&dk, nk, d);
    let mut dkv = matmul_nt_w(&dk, w.wk, nk, d, d);

    let dwv = matmul_tn(kv, &dv, nk, d, d);
    let dbv = col_sum(&dv, nk, d);
    let dkv_v = matmul_nt_w(&dv, w.wv, nk, d, d);
    add_into(&mut dkv, &dkv_v);
    workspace::give(dq);
    workspace::give(dk);
    workspace::give(dv);
    workspace::give(dkv_v);
    workspace::give(do_);

    (
        dx,
        dkv,
        AttnGrads {
            wq: dwq,
            bq: dbq,
            wk: dwk,
            bk: dbk,
            wv: dwv,
            bv: dbv,
            wo: dwo,
            bo: dbo,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::super::pool::set_threads;
    use super::*;
    use crate::tensor::Rng;

    fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * s).collect()
    }

    #[test]
    fn attention_rows_sum_to_one_and_causal_masks() {
        let mut rng = Rng::new(2);
        let (b, t, d, heads) = (2usize, 4usize, 8usize, 2usize);
        let w_ = randv(&mut rng, d * d, 0.2);
        let bias0 = vec![0.0f32; d];
        let w = AttnW {
            wq: &w_,
            bq: &bias0,
            wk: &w_,
            bk: &bias0,
            wv: &w_,
            bv: &bias0,
            wo: &w_,
            bo: &bias0,
        };
        let x = randv(&mut rng, b * t * d, 1.0);
        let (_, cache) = attn_fwd(&w, &x, &x, b, t, t, d, heads, true);
        for bh in 0..b * heads {
            for i in 0..t {
                let row = &cache.att[bh * t * t + i * t..bh * t * t + (i + 1) * t];
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "softmax row sum {s}");
                for (jj, &p) in row.iter().enumerate() {
                    if jj > i {
                        assert_eq!(p, 0.0, "causal leak at ({i},{jj})");
                    }
                }
            }
        }
        cache.recycle();
    }

    #[test]
    fn attn_bwd_matches_finite_difference_on_x() {
        let mut rng = Rng::new(3);
        let (b, t, d, heads) = (1usize, 3usize, 4usize, 2usize);
        let mk = |rng: &mut Rng| randv(rng, d * d, 0.3);
        let (wq, wk, wv, wo) =
            (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let (bq, bk, bv, bo) = (
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
        );
        let w = AttnW {
            wq: &wq,
            bq: &bq,
            wk: &wk,
            bk: &bk,
            wv: &wv,
            bv: &bv,
            wo: &wo,
            bo: &bo,
        };
        let x = randv(&mut rng, b * t * d, 1.0);
        let g = randv(&mut rng, b * t * d, 1.0);
        let (_, cache) = attn_fwd(&w, &x, &x, b, t, t, d, heads, false);
        let (dx, dkv, _) = attn_bwd(&w, &x, &x, &cache, &g, b, t, t, d, heads);

        let probe = |xs: &[f32]| -> f64 {
            let (y, c) = attn_fwd(&w, xs, xs, b, t, t, d, heads, false);
            let s = y.iter().zip(&g).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            c.recycle();
            s
        };
        let eps = 1e-2f32;
        for idx in 0..b * t * d {
            let mut xp = x.to_vec();
            xp[idx] += eps;
            let mut xm = x.to_vec();
            xm[idx] -= eps;
            let fd = ((probe(&xp) - probe(&xm)) / (2.0 * eps as f64)) as f32;
            let an = dx[idx] + dkv[idx]; // self-attention: both paths
            assert!(
                (fd - an).abs() < 3e-2 * an.abs().max(1.0),
                "d/dx[{idx}]: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn decode_attention_matches_full_causal_rows_bitwise() {
        let mut rng = Rng::new(11);
        let (b, t, d, heads) = (3usize, 12usize, 16usize, 4usize);
        let mk = |rng: &mut Rng| randv(rng, d * d, 0.2);
        let (wq, wk, wv, wo) =
            (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let (bq, bk, bv, bo) = (
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
            randv(&mut rng, d, 0.1),
        );
        let w = AttnW {
            wq: &wq,
            bq: &bq,
            wk: &wk,
            bk: &bk,
            wv: &wv,
            bv: &bv,
            wo: &wo,
            bo: &bo,
        };
        let x = randv(&mut rng, b * t * d, 1.0);
        let (y_full, cache) = attn_fwd(&w, &x, &x, b, t, t, d, heads, true);
        cache.recycle();
        for threads in [1usize, 2, 4, 7] {
            set_threads(threads);
            let mut kc = vec![0.0f32; b * t * d];
            let mut vc = vec![0.0f32; b * t * d];
            for pos in 0..t {
                let mut row = vec![0.0f32; b * d];
                for bi in 0..b {
                    let src = (bi * t + pos) * d;
                    row[bi * d..(bi + 1) * d].copy_from_slice(&x[src..src + d]);
                }
                let (out, knew, vnew) =
                    attn_decode(&w, &row, &kc, &vc, b, pos, t, d, heads);
                for bi in 0..b {
                    let dst = (bi * t + pos) * d;
                    kc[dst..dst + d].copy_from_slice(&knew[bi * d..(bi + 1) * d]);
                    vc[dst..dst + d].copy_from_slice(&vnew[bi * d..(bi + 1) * d]);
                    let want: Vec<u32> =
                        y_full[dst..dst + d].iter().map(|v| v.to_bits()).collect();
                    let got: Vec<u32> = out[bi * d..(bi + 1) * d]
                        .iter()
                        .map(|v| v.to_bits())
                        .collect();
                    assert_eq!(
                        want, got,
                        "decode row {pos} lane {bi} at {threads} threads"
                    );
                }
            }
        }
        set_threads(0);
    }

    #[test]
    fn attention_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(7);
        // big enough that head_params() yields >1 task at multi-thread counts
        let (b, t, d, heads) = (4usize, 24usize, 32usize, 4usize);
        let mk = |rng: &mut Rng| randv(rng, d * d, 0.2);
        let (wq, wk, wv, wo) =
            (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let bias0 = vec![0.0f32; d];
        let w = AttnW {
            wq: &wq,
            bq: &bias0,
            wk: &wk,
            bk: &bias0,
            wv: &wv,
            bv: &bias0,
            wo: &wo,
            bo: &bias0,
        };
        let x = randv(&mut rng, b * t * d, 1.0);
        let g = randv(&mut rng, b * t * d, 1.0);
        set_threads(1);
        let (y1, c1) = attn_fwd(&w, &x, &x, b, t, t, d, heads, true);
        let (dx1, dkv1, g1) = attn_bwd(&w, &x, &x, &c1, &g, b, t, t, d, heads);
        for threads in [2usize, 4, 7] {
            set_threads(threads);
            let (y, c) = attn_fwd(&w, &x, &x, b, t, t, d, heads, true);
            let (dx, dkv, gr) = attn_bwd(&w, &x, &x, &c, &g, b, t, t, d, heads);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&y1), bits(&y), "fwd at {threads} threads");
            assert_eq!(bits(&c1.att), bits(&c.att), "att at {threads} threads");
            assert_eq!(bits(&dx1), bits(&dx), "dx at {threads} threads");
            assert_eq!(bits(&dkv1), bits(&dkv), "dkv at {threads} threads");
            assert_eq!(bits(&g1.wq), bits(&gr.wq), "dwq at {threads} threads");
            assert_eq!(bits(&g1.bo), bits(&gr.bo), "dbo at {threads} threads");
            c.recycle();
        }
        c1.recycle();
        set_threads(0);
    }
}
